"""Case study (paper SSIV-C): traffic-flow forecasting over the PeMS sensor
network with ASTGCN-lite, served by Fograph.

    PYTHONPATH=src python examples/traffic_forecasting.py
"""
import dataclasses

import jax
import numpy as np

from repro.core import compression, placement, simulation
from repro.gnn import datasets, models
from repro.gnn.layers import EdgeList

# PeMS-style spatial-temporal data: 307 sensors, 12x5-min history window.
tg = datasets.load_pems_window(scale=1.0, seed=0)
g = tg.graph
print(f"PeMS-like sensor graph: {g.num_vertices} sensors, "
      f"{g.num_edges // 2} roads; forecasting {tg.target.shape[0]} steps")

params, (mu, sd), loss = models.train_astgcn(
    jax.random.PRNGKey(0), tg, steps=300)
edges = EdgeList.from_graph(g)
pred = np.asarray(models.astgcn_apply(params, tg.history, edges)) * sd + mu
print("forecast errors:", {k: round(v, 2) for k, v in
                           models.forecast_errors(pred, tg.target).items()})

# Degree-aware quantized collection of the sensor window (paper SSIII-D).
window = tg.history.transpose(1, 0, 2).reshape(g.num_vertices, -1)
packed = compression.daq_pack(window.astype(np.float64), g.degrees)
print(f"DAQ: {packed.raw_bits // 8} B -> {packed.nbytes(True)} B on the wire "
      f"(ratio {packed.nbytes(True) / (packed.raw_bits // 8):.3f})")

# Serving comparison on the case-study cluster (1xA + 2xB + 1xC, 4G).
g_srv = dataclasses.replace(g, features=window.astype(np.float32))
cluster = simulation.make_cluster("1A+2B+1C", "4g", g_srv,
                                  hidden=256, k_layers=4)
fogs = cluster.fog_specs(seed=0)
pl = placement.iep_place(g_srv, fogs, seed=0, sync_cost=cluster.sync_cost)
cloud = simulation.simulate_cloud(cluster)
fograph = simulation.simulate_multi_fog(cluster, pl, compress="daq")
print(f"cloud {cloud.total_latency:.2f}s vs Fograph "
      f"{fograph.total_latency:.2f}s "
      f"({cloud.total_latency / fograph.total_latency:.2f}x speedup; "
      f"paper reports up to 2.79x)")
print("vertices per fog (heterogeneity-aware):",
      np.bincount(pl.assignment, minlength=4))
