"""Quickstart: compile a Fograph serving plan and serve queries.

The whole paper workflow (Fig. 5/6) behind one API:

    Engine(model, cluster, **knobs).compile(graph) -> Plan   (setup phase)
    Plan.session() -> Session                                 (runtime)
    Session.query() / .adapt()
    Plan.server() -> Server                                   (request level)
    Server.replay(traces.poisson(...)) -> [Response, ...]

    PYTHONPATH=src python examples/quickstart.py
    (or, after `pip install -e .`:  fograph-demo)
"""
import jax
import numpy as np

from repro.api import Engine, traces
from repro.gnn import datasets, models

# 1. Data + a trained GNN (SIoT-style social-IoT graph, GCN classifier).
graph = datasets.load("siot", scale=0.1, seed=0)
params, loss = models.train_node_classifier(
    jax.random.PRNGKey(0), "gcn", graph, steps=80)
print(f"trained 2-layer GCN on |V|={graph.num_vertices} "
      f"|E|={graph.num_edges} (loss {loss:.3f})")

# 2. Setup phase: every pipeline stage is a registry key — swap
#    placement="metis+greedy", compressor="uniform8", executor="mesh-bsp",
#    ... with no other code changes.
engine = Engine((params, "gcn"),
                cluster="1A+4B+1C",   # paper Table II node types
                network="wifi", compressor="daq", placement="iep",
                executor="sim")
plan = engine.compile(graph)          # profile + IEP placement, frozen
print("placement (vertices per fog):", plan.vertices_per_fog())
print(f"estimated makespan: {plan.est_makespan:.3f}s")

# 3. Runtime phase: a session serves repeated queries and owns the
#    adaptive-scheduler state; the plan stays immutable.
session = plan.session(accuracy_fn=lambda emb: float(
    models.accuracy(emb, graph.labels)))
result = session.query()
print(f"latency {result.latency:.3f}s  "
      f"throughput {result.throughput:.2f}/s  "
      f"wire {result.wire_bytes / 1e3:.1f} KB  "
      f"accuracy {result.accuracy:.4f}  [{result.backend}]")

# 4. Request-level serving (§III-D): a Server micro-batches compatible
#    arrivals into one batched collect + one executor run, and pipelines
#    query i+1's collection against query i's execution. Same numerics,
#    higher throughput under load than the serial one-at-a-time loop.
trace = traces.poisson(24, rate=8.0, seed=1)       # arrivals on a sim clock
serial = plan.server(max_batch=1, pipelined=False).replay(list(trace))
batched = plan.server(max_batch=8, max_wait=0.05).replay(list(trace))
from repro.api import Server  # noqa: E402
s0, s1 = Server.summarize(serial), Server.summarize(batched)
print(f"serial loop : makespan {s0['makespan_s']:.2f}s  "
      f"throughput {s0['throughput_rps']:.2f}/s")
print(f"server      : makespan {s1['makespan_s']:.2f}s  "
      f"throughput {s1['throughput_rps']:.2f}/s  "
      f"(mean batch {s1['mean_batch']:.2f}, "
      f"{s0['makespan_s'] / s1['makespan_s']:.2f}x)")

# 5. Adaptive scheduling: overload the busiest node, watch the dual-mode
#    scheduler migrate vertices away (paper Fig. 10 diffusion).
from repro.core import simulation  # noqa: E402
t = simulation.measured_exec_times(plan.cluster, session.placement)
plan.cluster.nodes[int(np.argmax(t))].background_load = 2.5
print("scheduler action after overload:", session.adapt(lam=1.2))
print("latency after adaptation:", f"{session.query().latency:.3f}s")
