"""Quickstart: deploy Fograph on a simulated fog cluster and serve a query.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.gnn import datasets, models
from repro.runtime import serving

# 1. Data + a trained GNN (SIoT-style social-IoT graph, GCN classifier).
graph = datasets.load("siot", scale=0.1, seed=0)
params, loss = models.train_node_classifier(
    jax.random.PRNGKey(0), "gcn", graph, steps=80)
print(f"trained 2-layer GCN on |V|={graph.num_vertices} "
      f"|E|={graph.num_edges} (loss {loss:.3f})")

# 2. Setup phase: profile the heterogeneous fog nodes, register metadata,
#    and plan the data placement with the Inference Execution Planner.
svc = serving.deploy(graph, params, "gcn",
                     cluster_spec="1A+4B+1C",   # paper Table II node types
                     network="wifi", compress="daq")
print("placement (vertices per fog):",
      np.bincount(svc.placement.assignment))
print(f"estimated makespan: {svc.placement.est_makespan:.3f}s")

# 3. Runtime phase: compressed collection -> distributed inference.
result = serving.serve_query(svc)
acc = float(models.accuracy(result.embeddings, graph.labels))
print(f"latency {result.latency:.3f}s  throughput {result.throughput:.2f}/s"
      f"  wire {result.wire_bytes / 1e3:.1f} KB  accuracy {acc:.4f}")

# 4. Adaptive scheduling: overload the busiest node, watch the dual-mode
#    scheduler migrate vertices away (paper Fig. 10 diffusion).
from repro.core import simulation  # noqa: E402
t = simulation.measured_exec_times(svc.cluster, svc.state.placement)
svc.cluster.nodes[int(np.argmax(t))].background_load = 2.5
print("scheduler action after overload:", serving.adapt(svc, lam=1.2))
print("latency after adaptation:", f"{serving.serve_query(svc).latency:.3f}s")
