"""Beyond the paper: Fograph's placement machinery scheduling LLM serving.

Requests = data points, pods = fog nodes: the proxy-guided profiler fits
omega(<batch, cache_tokens>) per pod and the LBAP bottleneck solver places
request batches (see src/repro/launch/serve.py for the full driver).

    PYTHONPATH=src python examples/llm_serving_iep.py
"""
from repro.launch.serve import main

raise SystemExit(main(["--arch", "qwen1.5-0.5b", "--requests", "12",
                       "--tokens", "12", "--pods", "1.0,2.0,3.0"]))
