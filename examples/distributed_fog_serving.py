"""Distributed BSP inference over a real multi-device JAX mesh.

The same Engine config switches executor backends by key: "single" runs
the one-program reference, "mesh-bsp" runs the paper's BSP runtime
(§III-E) with one device per fog partition and a halo/allgather collective
per GNN layer. Must set the device-count flag BEFORE jax imports, hence
the first lines.

    PYTHONPATH=src python examples/distributed_fog_serving.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.api import Engine  # noqa: E402
from repro.gnn import datasets, models  # noqa: E402

print("devices:", jax.devices())
g = datasets.load("yelp", scale=0.1, seed=0)
params, _ = models.train_node_classifier(jax.random.PRNGKey(0), "sage", g,
                                         steps=60)

# One shared config; only the executor / exchange registry keys change.
base = dict(cluster="4B", network="wifi", compressor="none")
ref = Engine((params, "sage"), executor="single",
             **base).compile(g).session().query()

for ex in ("allgather", "halo"):
    engine = Engine((params, "sage"), executor="mesh-bsp", exchange=ex,
                    **base)
    plan = engine.compile(g)
    if ex == "allgather":
        pg = plan.partitioned
        print(f"partitions: slots={pg.slots} edges/part={pg.edges_per_part} "
              f"boundary={pg.boundary_slots}")
    r = plan.session().query()
    err = float(np.abs(r.embeddings - ref.embeddings).max())
    print(f"exchange={ex:10s} bytes/sync={r.exchange_bytes:>10,d} "
          f"max|dist - single|={err:.2e}")
print("halo exchange moves only boundary rows — the paper's "
      "'exchange vertices data when needed'.")

# Request-level serving over the real mesh: the Server micro-batches a
# Poisson trace into batched BSP supersteps and pipelines collection
# against execution (§III-D) — same shard_map numerics per request.
from repro.api import traces  # noqa: E402
halo_plan, halo_ref = plan, r       # the loop's last iteration (halo)
server = halo_plan.server(max_batch=4, max_wait=0.05)
responses = server.replay(traces.poisson(12, rate=6.0, seed=1))
ok = all(np.allclose(resp.embeddings, halo_ref.embeddings)
         for resp in responses)
s = server.summarize(responses)
print(f"mesh-bsp trace of {s['requests']}: makespan {s['makespan_s']:.2f}s "
      f"throughput {s['throughput_rps']:.2f}/s mean batch "
      f"{s['mean_batch']:.2f} (numerics match: {ok})")
