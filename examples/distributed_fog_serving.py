"""Distributed BSP inference over a real multi-device JAX mesh.

Each of 4 virtual fog devices owns a vertex partition; every GNN layer
does a halo exchange (jax.lax collectives under shard_map), exactly the
paper's BSP runtime (SSIII-E). Must set the device-count flag BEFORE jax
imports, hence the first lines.

    PYTHONPATH=src python examples/distributed_fog_serving.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import partition  # noqa: E402
from repro.gnn import datasets, models  # noqa: E402
from repro.gnn.layers import EdgeList  # noqa: E402
from repro.runtime import bsp  # noqa: E402

print("devices:", jax.devices())
g = datasets.load("yelp", scale=0.1, seed=0)
params, _ = models.train_node_classifier(jax.random.PRNGKey(0), "sage", g,
                                         steps=60)

assign = partition.bgp(g, 4, seed=0)  # min-cut balanced partitions
pg = bsp.build_partitioned(g, assign)
print(f"partitions: slots={pg.slots} edges/part={pg.edges_per_part} "
      f"boundary={pg.boundary_slots}")
for ex in ("allgather", "halo"):
    out = bsp.bsp_infer(params, "sage", g, assign, exchange=ex)
    ref = np.asarray(models.gnn_apply(params, "sage", g.features,
                                      EdgeList.from_graph(g)))
    print(f"exchange={ex:10s} bytes/sync="
          f"{bsp.exchange_bytes(pg, g.feature_dim, ex):>10,d} "
          f"max|dist - single|={np.abs(out - ref).max():.2e}")
print("halo exchange moves only boundary rows — the paper's "
      "'exchange vertices data when needed'.")
