"""IEP placement: Hungarian/LBAP exactness + placement invariants."""
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep:
# property tests skip cleanly when hypothesis is not installed

from repro.core import placement, simulation
from repro.core.placement import (FogSpec, hungarian, iep_place, lbap,
                                  lbap_threshold_descending)
from repro.core.profiler import LatencyModel
from repro.gnn import datasets


def brute_min_sum(cost):
    n = cost.shape[0]
    best = None
    for perm in itertools.permutations(range(n)):
        s = sum(cost[i, perm[i]] for i in range(n))
        if best is None or s < best:
            best = s
    return best


def brute_min_max(cost):
    n = cost.shape[0]
    best = None
    for perm in itertools.permutations(range(n)):
        s = max(cost[i, perm[i]] for i in range(n))
        if best is None or s < best:
            best = s
    return best


@given(st.integers(2, 6), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_hungarian_optimal_vs_bruteforce(n, seed):
    rng = np.random.default_rng(seed)
    cost = rng.uniform(0, 10, size=(n, n))
    assign = hungarian(cost)
    assert sorted(assign) == list(range(n))  # a permutation
    got = sum(cost[i, assign[i]] for i in range(n))
    assert got <= brute_min_sum(cost) + 1e-9


@given(st.integers(2, 6), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_lbap_bottleneck_optimal(n, seed):
    rng = np.random.default_rng(seed)
    cost = rng.uniform(0, 10, size=(n, n))
    assign = lbap(cost)
    assert sorted(assign) == list(range(n))
    got = max(cost[i, assign[i]] for i in range(n))
    assert got <= brute_min_max(cost) + 1e-9


@given(st.integers(2, 5), st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_lbap_binary_search_equals_descending(n, seed):
    """Paper Alg. 1 (descending thresholds) == binary-search variant."""
    rng = np.random.default_rng(seed)
    cost = rng.uniform(0, 10, size=(n, n))
    a = lbap(cost)
    b = lbap_threshold_descending(cost)
    va = max(cost[i, a[i]] for i in range(n))
    vb = max(cost[i, b[i]] for i in range(n))
    assert abs(va - vb) < 1e-9


@pytest.fixture(scope="module")
def small_cluster():
    g = datasets.load("siot", scale=0.05, seed=0)
    cluster = simulation.make_cluster("1A+2B+1C", "wifi", g)
    return g, cluster, cluster.fog_specs(seed=0)


def test_iep_placement_covers_all_vertices(small_cluster):
    g, cluster, fogs = small_cluster
    pl = iep_place(g, fogs, seed=0)
    assert pl.assignment.shape == (g.num_vertices,)
    assert pl.assignment.min() >= 0
    assert pl.assignment.max() < len(fogs)
    # mapping is a permutation of fogs
    assert sorted(pl.mapping) == list(range(len(fogs)))


def test_iep_beats_or_ties_random_and_greedy(small_cluster):
    """Paper Fig. 8: IEP <= METIS+Greedy <= (usually) METIS+Random."""
    g, cluster, fogs = small_cluster
    mk = {s: iep_place(g, fogs, seed=0, strategy=s).est_makespan
          for s in ("iep", "greedy", "random")}
    assert mk["iep"] <= mk["greedy"] + 1e-9
    assert mk["iep"] <= mk["random"] + 1e-9


def test_heterogeneity_awareness(small_cluster):
    """The most powerful fog must receive >= the weakest fog's workload."""
    g, cluster, fogs = small_cluster
    pl = iep_place(g, fogs, seed=0)
    sizes = np.bincount(pl.assignment, minlength=len(fogs))
    caps = [n.capability for n in cluster.nodes]
    assert sizes[int(np.argmax(caps))] >= sizes[int(np.argmin(caps))]


def test_pair_cost_formula(small_cluster):
    """Eq. (8) = collection + execution + K*delta."""
    g, cluster, fogs = small_cluster
    part = np.arange(g.num_vertices // 4)
    c = placement.pair_cost(g, part, fogs[0], bytes_per_vertex=100.0,
                            k_layers=2, sync_cost=0.5)
    t_colle = len(part) * 100.0 / fogs[0].bandwidth_bytes_per_s
    assert c >= t_colle + 2 * 0.5
