"""Geo-distributed fleet serving: router, failover, staleness, checks.

The load-bearing guarantees:
  * the Router covers every site and routes nearest-first, spilling on
    the capacity knob and failing over off down sites;
  * a site going down mid-trace reroutes its queued work — zero drops;
  * ``staleness_bound=0`` with ``exchange="halo_async"`` is bit-identical
    to the synchronous ``halo`` exchange (sim in-process, mesh-bsp in a
    subprocess), and bounded-stale outputs are exactly reproducible by
    replaying the recorded halo-table versions through
    ``bsp.bsp_infer_stale``;
  * attaching geo origins never perturbs a trace's arrivals / features /
    SLO draws (defaults stay byte-identical);
  * the ``fleet.*`` analysis checks fire on mutation, stay silent on
    healthy fleets.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.analysis import AnalysisContext, run_checks
from repro.api import Engine, traces
from repro.api.fleet import CLOUD, FleetServer, Router, Site, haversine_km
from repro.api.server import Response, Server
from repro.api.slo import SLOPolicy, per_site
from repro.api.updates import GraphDelta
from repro.gnn import datasets, models

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SITES = {"north": (59.33, 18.07), "south": (48.21, 16.37),
         "west": (51.51, -0.13)}


@pytest.fixture(scope="module")
def setup():
    g = datasets.load("siot", scale=0.06, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    eng = Engine((params, "gcn"), cluster="1A+2B",
                 exchange="halo_async", staleness_bound=2)
    return g, params, eng.compile_fleet(g, SITES)


# ----------------------------------------------------------------------------
# Fleet / Router construction
# ----------------------------------------------------------------------------

def test_compile_fleet_shape(setup):
    g, params, fleet = setup
    assert fleet.site_names == ("north", "south", "west")
    assert fleet.cloud_plan.config.executor == "cloud"
    assert fleet.cloud_plan.config.staleness_bound == 0
    # per-site profiling seeds: same knobs otherwise
    seeds = {s.plan.config.seed for s in fleet.sites}
    assert len(seeds) == len(fleet.sites)
    for s in fleet.sites:
        assert s.plan.config.staleness_bound == 2
        assert s.plan.config.exchange == "halo_async"
    assert fleet.centroids() == [SITES[n] for n in fleet.site_names]


def test_fleet_validation(setup):
    g, params, fleet = setup
    with pytest.raises(ValueError, match="at least one site"):
        Engine((params, "gcn"), "1A+1B").compile_fleet(g, {})
    with pytest.raises(ValueError, match="reserved"):
        Site(name="cloud", location=(0.0, 0.0), plan=fleet.sites[0].plan)
    with pytest.raises(KeyError, match="unknown site"):
        fleet.site("nowhere")


def test_router_nearest_spill_failover(setup):
    _, _, fleet = setup
    fs = fleet.server(capacity=2)
    # nearest-first
    d = fs.router.route((59.0, 18.0), fs.queue_depth)
    assert (d.site, d.route) == ("north", "local")
    assert d.routing_delay > 0
    # rank is full-coverage and distance-sorted
    ranked = fs.router.rank((59.0, 18.0))
    assert [n for n, _ in ranked][0] == "north"
    assert {n for n, _ in ranked} == set(fleet.site_names)
    dists = [x for _, x in ranked]
    assert dists == sorted(dists)
    # capacity knob: saturate north -> spill to next-nearest
    depth = {"north": 2, "south": 0, "west": 0}
    d2 = fs.router.route((59.0, 18.0), lambda n: depth[n])
    assert d2.site != "north" and d2.route == "spilled"
    # down -> failover off the nearest site
    fs.router.set_down("north")
    d3 = fs.router.route((59.0, 18.0), fs.queue_depth)
    assert d3.site != "north" and d3.route == "failed_over"
    # everything down or full -> cloud
    d4 = fs.router.route((59.0, 18.0), lambda n: 99)
    assert (d4.site, d4.route) == (CLOUD, "failed_over")
    fs.router.set_down("north", False)
    with pytest.raises(KeyError):
        fs.router.set_down("nowhere")
    # origin-less requests fall back to listed site order
    assert fs.router.rank(None)[0][0] == "north"


def test_haversine_sanity():
    assert haversine_km((0.0, 0.0), (0.0, 0.0)) == 0.0
    # Stockholm -> Vienna is ~1250 km
    d = haversine_km(SITES["north"], SITES["south"])
    assert 1100 < d < 1400, d


# ----------------------------------------------------------------------------
# Serving: spillover, failover, clocks
# ----------------------------------------------------------------------------

def test_spillover_respects_capacity(setup):
    _, _, fleet = setup
    fs = fleet.server(capacity=3)
    for i in range(8):
        fs.submit(arrival_time=0.01 * i, origin=SITES["north"])
    assert fs.queue_depth("north") == 3   # knob is a hard queue cap
    out = fs.drain()
    s = fs.summarize(out)
    assert s["sites"]["north"]["served"] == 3
    assert s["routes"]["spilled"] >= 1
    assert s["dropped"] == 0
    assert sum(v["served"] for v in s["sites"].values()) == 8


def test_site_down_midtrace_zero_drops(setup):
    _, _, fleet = setup
    fs = fleet.server(capacity=100)
    trace = traces.poisson(
        20, rate=50.0, seed=2,
        origin_fn=traces.geo_origins([SITES["north"]], spread=0.1, seed=5))
    submitted = [fs.submit(r) for r in trace[:12]]
    assert fs.queue_depth("north") == 12
    rerouted = fs.set_down("north")
    assert rerouted == 12
    assert fs.queue_depth("north") == 0
    submitted += [fs.submit(r) for r in trace[12:]]
    out = fs.drain()
    resp = [r for r in out if isinstance(r, Response)]
    assert len(resp) == 20              # nothing dropped
    assert all(r.site != "north" for r in resp)
    assert all(r.route == "failed_over" for r in resp)
    # rerouted requests keep their true arrival times
    by_id = {r.request_id: r for r in resp}
    for req in submitted:
        assert by_id[req.request_id].arrival_time == pytest.approx(
            req.arrival_time)
    assert fs.summarize(out)["dropped"] == 0
    # back up: traffic routes locally again
    fs.set_down("north", False)
    fs.submit(origin=SITES["north"])
    [r2] = [r for r in fs.drain() if isinstance(r, Response)]
    assert (r2.site, r2.route) == ("north", "local")


def test_site_down_up_down_round_trip(setup):
    """Revival pulls still-pending failed-over work back to its home site
    (route "recovered"); a second outage re-forwards it — pending work at
    every transition, zero drops throughout."""
    _, _, fleet = setup
    fs = fleet.server(capacity=100)
    north_only = traces.geo_origins([SITES["north"]], spread=0.1, seed=5)
    trace = traces.poisson(18, rate=50.0, seed=2, origin_fn=north_only)
    for r in trace[:6]:
        fs.submit(r)
    assert fs.set_down("north") == 6            # down: forwarded off-site
    for r in trace[6:12]:                       # land elsewhere directly
        fs.submit(r)
    moved = fs.set_down("north", False)         # up: refugees pulled home
    assert moved == 12
    assert fs.queue_depth("north") == 12
    for r in trace[12:]:
        fs.submit(r)
    assert fs.set_down("north") == 18           # down again: all forwarded
    assert fs.set_down("north", False) == 18    # ... and all pulled home
    out = fs.drain()
    resp = [r for r in out if isinstance(r, Response)]
    assert len(resp) == 18                      # zero drops end to end
    assert fs.summarize(out)["dropped"] == 0
    # the final revival pulled every refugee back to its home site
    assert all((r.site, r.route) == ("north", "recovered") for r in resp)
    # revival on a live fleet: a fresh submit routes local again, and the
    # recovered/"failed_over" split is visible in the summary
    fs2 = fleet.server(capacity=100)
    for r in trace[:6]:
        fs2.submit(r)
    fs2.set_down("north")
    assert fs2.set_down("north", False) == 6
    req = fs2.submit(origin=SITES["north"])
    out2 = fs2.drain()
    resp2 = [r for r in out2 if isinstance(r, Response)]
    assert len(resp2) == 7
    by_id = {r.request_id: r for r in resp2}
    assert (by_id[req.request_id].site,
            by_id[req.request_id].route) == ("north", "local")
    recovered = [r for r in resp2 if r.route == "recovered"]
    assert len(recovered) == 6
    assert all(r.site == "north" for r in recovered)
    # pulled-back requests keep their true arrivals and pay the extra hop
    for r in recovered:
        assert r.routing_delay > 0
    s = fs2.summarize(out2)
    assert s["routes"]["recovered"] == 6
    assert s["sites"]["north"]["recovered"] == 6
    assert s["dropped"] == 0


def test_cross_site_clocks_and_latency(setup):
    """Per-site clocks: two sites serve concurrently (neither queues
    behind the other); one site serving both requests serializes them.
    Latency includes the routing delay."""
    _, _, fleet = setup
    fs_two = fleet.server(capacity=8, max_batch=1)
    fs_two.submit(arrival_time=0.0, origin=SITES["north"])
    fs_two.submit(arrival_time=0.0, origin=SITES["south"])
    out_two = [r for r in fs_two.drain() if isinstance(r, Response)]
    assert {r.site for r in out_two} == {"north", "south"}
    # independent clocks: no cross-site queueing
    assert all(r.queue_delay == pytest.approx(0.0) for r in out_two)

    fs_one = fleet.server(capacity=8, max_batch=1)
    fs_one.submit(arrival_time=0.0, origin=SITES["north"])
    fs_one.submit(arrival_time=0.0, origin=SITES["north"])
    out_one = sorted((r for r in fs_one.drain()
                      if isinstance(r, Response)),
                     key=lambda r: r.finish_time)
    # one clock: the second request queues behind the first
    assert out_one[1].queue_delay > 0
    for r in out_two + out_one:
        assert r.routing_delay > 0
        assert r.breakdown["routing"] == pytest.approx(r.routing_delay)
        assert r.breakdown["total"] == pytest.approx(r.latency)
        assert r.latency >= r.routing_delay


def test_update_fanout_and_numerics(setup):
    g, _, fleet = setup
    fs = fleet.server()
    delta = GraphDelta(feature_ids=np.array([3]),
                       feature_values=np.full((1, g.feature_dim), 0.5,
                                              np.float32))
    reports = fs.update(delta)
    assert set(reports) == set(fs.tier_names)
    rep = run_checks(AnalysisContext(fleet=fs), families=["fleet"])
    assert not rep.errors
    # all tiers answer identically on the mutated graph (fresh serves)
    outs = {}
    for name in fs.tier_names:
        sess = fs.servers[name].session
        outs[name] = np.asarray(sess.execute(sess.plan.graph.features))
    ref = outs[CLOUD]
    for name, got in outs.items():
        np.testing.assert_allclose(got, ref, rtol=0, atol=5e-4,
                                   err_msg=name)


def test_per_site_slo_table(setup):
    _, _, fleet = setup
    tight = SLOPolicy(default_deadline=0.05)
    loose = SLOPolicy(default_deadline=5.0)
    fs = fleet.server(slo=per_site(default=loose, north=tight))
    assert fs.servers["north"].slo is tight
    assert fs.servers["south"].slo is loose
    assert fs.servers[CLOUD].slo is loose
    with pytest.raises(ValueError, match="not fleet sites"):
        fleet.server(slo=per_site(nowhere=tight))
    with pytest.raises(TypeError):
        per_site(north="tight")


def test_updates_not_routable(setup):
    g, _, fleet = setup
    fs = fleet.server()
    delta = GraphDelta(feature_ids=np.array([0]),
                       feature_values=np.zeros((1, g.feature_dim),
                                               np.float32))
    with pytest.raises(TypeError, match="update"):
        fs.submit(delta)


def test_fleet_summarize_shape(setup):
    _, _, fleet = setup
    fs = fleet.server(capacity=4)
    trace = traces.poisson(
        12, rate=30.0, seed=3,
        origin_fn=traces.geo_origins(fleet.centroids(), seed=4))
    out = fs.replay(trace)
    s = fs.summarize(out)
    assert set(s["sites"]) == set(fs.tier_names)
    assert sum(s["routes"].values()) == 12
    assert s["capacity"] == 4 and s["staleness_bound"] == 2
    for stats in s["sites"].values():
        assert {"served", "spilled", "failed_over", "latency_p95_s",
                "staleness_histogram"} <= set(stats)
        if stats["served"] == 0:
            assert stats["latency_p95_s"] is None   # empty-site guard
    assert sum(s["staleness_histogram"].values()) == 12
    # empty summarize still reports every tier
    s0 = Server.summarize([], sites=fs.tier_names)
    assert set(s0["sites"]) == set(fs.tier_names)


# ----------------------------------------------------------------------------
# Stale-tolerant halo exchange
# ----------------------------------------------------------------------------

def test_bound0_bit_identity_sim(setup):
    """staleness_bound=0 halo_async == halo, bit for bit (sim backend)."""
    g, params, _ = setup
    sync = Engine((params, "gcn"), "1A+2B",
                  exchange="halo").compile(g).session()
    async0 = Engine((params, "gcn"), "1A+2B", exchange="halo_async",
                    staleness_bound=0).compile(g).session()
    rng = np.random.default_rng(0)
    for _ in range(3):
        f = rng.standard_normal(g.features.shape).astype(np.float32)
        a, b = sync.execute(f), async0.execute(f)
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert async0.last_staleness == 0


def test_staleness_pattern_and_accounting(setup):
    g, params, _ = setup
    sess = Engine((params, "gcn"), "1A+2B", exchange="halo_async",
                  staleness_bound=2).compile(g).session()
    rng = np.random.default_rng(1)
    seen = []
    for _ in range(5):
        sess.execute(rng.standard_normal(g.features.shape
                                         ).astype(np.float32))
        seen.append(sess.last_staleness)
    assert seen == [0, 1, 2, 0, 1]   # bound caps the replay run length
    # a stale serve skips the sync term and ships zero exchange bytes
    assert sess.account(staleness=1).total_latency < \
        sess.account(staleness=0).total_latency
    assert sess.exchange_bytes(staleness=1) == 0
    assert sess.exchange_bytes(staleness=0) > 0
    # responses carry the served staleness (fresh session: 0, 1, 2)
    srv = Server(sess.plan.session(), max_batch=1)
    for i in range(3):
        srv.submit(arrival_time=0.01 * i)
    st = [r.staleness for r in srv.drain()]
    assert st == [0, 1, 2]


def test_update_forces_fresh_serve(setup):
    g, params, _ = setup
    sess = Engine((params, "gcn"), "1A+2B", exchange="halo_async",
                  staleness_bound=3).compile(g).session()
    sess.execute(g.features)
    sess.execute(g.features)
    assert sess.last_staleness == 1
    sess.update(GraphDelta(feature_ids=np.array([0]),
                           feature_values=np.ones((1, g.feature_dim),
                                                  np.float32)))
    sess.execute(sess.plan.graph.features)
    assert sess.last_staleness == 0   # invalidated, not replayed


def test_engine_rejects_bound_on_sync_exchange(setup):
    g, params, _ = setup
    with pytest.raises(ValueError, match="stale-tolerant"):
        Engine((params, "gcn"), "1A+2B", exchange="halo",
               staleness_bound=1)
    with pytest.raises(ValueError, match=">= 0"):
        Engine((params, "gcn"), "1A+2B", exchange="halo_async",
               staleness_bound=-1)


def test_mesh_stale_bit_identity_and_replay_subprocess():
    """mesh-bsp: bound=0 bit-identical to halo; bounded-stale output ==
    a reference replaying the same recorded halo-table versions."""
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.api.engine import Engine
        from repro.gnn import datasets, models
        from repro.runtime import bsp
        g = datasets.load('yelp', scale=0.06, seed=3)
        params = models.gnn_init(jax.random.PRNGKey(0), 'gcn',
                                 [g.feature_dim, 32, 8])
        model = (params, 'gcn')
        kw = dict(executor='mesh-bsp', aggregation='segment_sum')
        s_sync = Engine(model, '1A+3B', exchange='halo', **kw
                        ).compile(g).session()
        s_b2 = Engine(model, '1A+3B', exchange='halo_async',
                      staleness_bound=2, **kw).compile(g).session()
        s_b0 = Engine(model, '1A+3B', exchange='halo_async',
                      staleness_bound=0, **kw).compile(g).session()
        rng = np.random.default_rng(0)
        feats = [rng.standard_normal(g.features.shape).astype(np.float32)
                 for _ in range(3)]
        # bound=0: bit-identical to the synchronous exchange
        assert np.array_equal(s_b0.execute(feats[0]),
                              s_sync.execute(feats[0]))
        # bound=2: serve 0 fresh, serve 1 stale
        out0 = s_b2.execute(feats[0]); assert s_b2.last_staleness == 0
        out1 = s_b2.execute(feats[1]); assert s_b2.last_staleness == 1
        assert np.array_equal(out0, s_sync.execute(feats[0]))
        # reference: rebuild serve-0's halo tables from its recorded
        # layer inputs and replay them against serve-1's features
        plan = s_sync.plan
        layers0 = s_sync.resolve_executor().run_layers(
            plan, feats[0], plan.placement.assignment,
            s_sync.partitioned(), 'halo', aggregation='segment_sum')
        inputs0 = [feats[0]] + [np.asarray(x) for x in layers0[:-1]]
        tables0 = bsp.build_halo_tables(s_sync.partitioned(), inputs0)
        ref1 = bsp.bsp_infer_stale(list(plan.model.params), 'gcn',
                                   feats[1], s_b2.partitioned(), tables0,
                                   aggregation='segment_sum')
        assert np.array_equal(out1, np.asarray(ref1)), 'stale replay'
        # and the stale serve genuinely differs from a fresh one
        assert not np.array_equal(out1, s_sync.execute(feats[1]))
        print('OK')
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


# ----------------------------------------------------------------------------
# Traces: geo origins never perturb existing draws
# ----------------------------------------------------------------------------

def test_geo_origins_byte_identical_trace():
    def feats_fn(i, rng):
        return rng.standard_normal((4,)).astype(np.float32)

    def slo_fn(i, rng):
        return (float(rng.uniform(0.1, 1.0)), int(rng.integers(0, 3)))

    kw = dict(seed=11, features_fn=feats_fn, slo_fn=slo_fn)
    plain = traces.poisson(32, rate=10.0, **kw)
    geo = traces.poisson(32, rate=10.0, origin_fn=traces.geo_origins(
        list(SITES.values()), seed=9), **kw)
    assert all(r.origin is None for r in plain)
    assert all(r.origin is not None for r in geo)
    for a, b in zip(plain, geo):
        assert a.arrival_time == b.arrival_time
        assert np.array_equal(a.features, b.features)
        assert (a.deadline, a.priority) == (b.deadline, b.priority)
    # bursty/constant/mixed accept the knob too
    assert traces.constant(3, 5.0, origin_fn=lambda i: (0.0, 0.0)
                           )[0].origin == (0.0, 0.0)
    assert traces.bursty(3, 5.0, origin_fn=lambda i: (1.0, 2.0)
                         )[2].origin == (1.0, 2.0)


def test_geo_origins_zipf_skew():
    cents = [(0.0, 0.0), (50.0, 50.0)]
    fn = traces.geo_origins(cents, spread=0.01, zipf_s=2.0, seed=0)
    firsts = sum(1 for i in range(200)
                 if abs(fn(i)[0]) < 1.0)   # near centroid 0
    assert firsts > 140   # rank-1 site dominates under skew
    uni = traces.geo_origins(cents, spread=0.01, zipf_s=0.0, seed=0)
    firsts_uni = sum(1 for i in range(200) if abs(uni(i)[0]) < 1.0)
    assert 60 < firsts_uni < 140   # uniform when s=0
    with pytest.raises(ValueError):
        traces.geo_origins([])
    with pytest.raises(ValueError):
        traces.geo_origins(cents, spread=-1.0)


# ----------------------------------------------------------------------------
# Analysis checks: silent on healthy, fire on mutation
# ----------------------------------------------------------------------------

FLEET_CHECKS = {"fleet.router.coverage", "fleet.revision.agreement",
                "fleet.staleness.consistency"}


def test_fleet_checks_silent_on_healthy(setup):
    _, _, fleet = setup
    fs = fleet.server()
    rep = run_checks(AnalysisContext(fleet=fs), families=["fleet"])
    assert set(rep.ran) == FLEET_CHECKS
    assert not rep.errors and not rep.warnings
    # bare Fleet is accepted too
    rep2 = run_checks(AnalysisContext(fleet=fleet), families=["fleet"])
    assert not rep2.errors
    # and skipped (not failed) without a fleet in the context
    rep3 = run_checks(AnalysisContext(plan=fleet.sites[0].plan),
                      families=["fleet"])
    assert FLEET_CHECKS <= set(rep3.skipped)


def test_fleet_check_router_coverage_fires(setup):
    _, _, fleet = setup
    fs = fleet.server()
    removed = fs.router.table.pop("south")
    rep = run_checks(AnalysisContext(fleet=fs), families=["fleet"])
    assert any(d.check_id == "fleet.router.coverage" for d in rep.errors)
    fs.router.table["south"] = (0.0, 0.0)   # wrong centroid also fires
    rep2 = run_checks(AnalysisContext(fleet=fs), families=["fleet"])
    assert any(d.check_id == "fleet.router.coverage" for d in rep2.errors)
    fs.router.table["south"] = removed
    rep3 = run_checks(AnalysisContext(fleet=fs), families=["fleet"])
    assert not rep3.errors


def test_fleet_check_revision_agreement_fires(setup):
    g, _, fleet = setup
    fs = fleet.server()
    delta = GraphDelta(feature_ids=np.array([1]),
                       feature_values=np.zeros((1, g.feature_dim),
                                               np.float32))
    fs.servers["west"].session.update(delta)   # one tier diverges
    rep = run_checks(AnalysisContext(fleet=fs), families=["fleet"])
    errs = [d for d in rep.errors
            if d.check_id == "fleet.revision.agreement"]
    assert errs and "west" in errs[0].message
    fs.update(delta)   # proper fan-out heals the divergence
    rep2 = run_checks(AnalysisContext(fleet=fs), families=["fleet"])
    assert not [d for d in rep2.errors
                if d.check_id == "fleet.revision.agreement"]


def test_fleet_check_staleness_consistency_fires(setup):
    _, _, fleet = setup
    fs = fleet.server()
    fs.staleness_bound = 9   # facade no longer matches the sessions
    rep = run_checks(AnalysisContext(fleet=fs), families=["fleet"])
    assert any(d.check_id == "fleet.staleness.consistency"
               for d in rep.errors)
    fs.staleness_bound = 2
    # a halo store on the cloud tier is a contract violation
    from repro.api.session import _HaloStore
    fs.servers[CLOUD].session._halo = _HaloStore(1)
    rep2 = run_checks(AnalysisContext(fleet=fs), families=["fleet"])
    errs = [d for d in rep2.errors
            if d.check_id == "fleet.staleness.consistency"]
    assert errs and "cloud" in errs[0].message
    fs.servers[CLOUD].session._halo = None
    rep3 = run_checks(AnalysisContext(fleet=fs), families=["fleet"])
    assert not rep3.errors
