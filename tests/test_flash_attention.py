"""Flash attention Pallas kernel vs plain-softmax oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention, gqa_flash
from repro.models.attention import chunked_causal_attention


@pytest.mark.parametrize("bh,s,t,dh", [(4, 256, 256, 64), (2, 128, 128, 128),
                                       (1, 512, 512, 32), (3, 128, 384, 64)])
def test_flash_matches_ref(bh, s, t, dh):
    rng = np.random.default_rng(hash((bh, s, t, dh)) % 2 ** 31)
    q = jnp.asarray(rng.normal(size=(bh, s, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(bh, t, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(bh, t, dh)), jnp.float32)
    qoff = t - s  # suffix queries (chunked prefill layout)
    out = flash_attention(q, k, v, bq=64, bk=64, q_offset=qoff)
    want = ref.flash_attention_ref(q, k, v, q_offset=qoff)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_windowed(window):
    rng = np.random.default_rng(window)
    q = jnp.asarray(rng.normal(size=(2, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 256, 64)), jnp.float32)
    out = flash_attention(q, k, v, bq=64, bk=64, window=window)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("block", [(32, 64), (64, 32), (128, 128)])
def test_flash_block_shape_sweep(block):
    bq, bk = block
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.float32)
    out = flash_attention(q, k, v, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_flash_bf16():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, bq=64, bk=64)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_gqa_flash_matches_model_attention():
    """Kernel == the model's chunked_causal_attention (GQA, kv groups)."""
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(2, 128, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 128, 2, 32)), jnp.float32)
    o1 = gqa_flash(q, k, v, bq=64, bk=64)
    o2 = chunked_causal_attention(q, k, v, 4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)


def test_model_level_flash_option():
    """ArchConfig(attn_impl='flash') routes gqa_forward through the Pallas
    kernel and matches the chunked XLA path end-to-end."""
    import dataclasses

    import jax

    from repro.configs import registry
    from repro.models import transformer as tf

    cfg = registry.reduced(registry.get("granite-3-2b"))
    cfgf = dataclasses.replace(cfg, attn_impl="flash")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 128)), jnp.int32)
    l1, _ = tf.forward(params, cfg, toks)
    l2, _ = tf.forward(params, cfgf, toks)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32),
                               rtol=1e-4, atol=1e-4)
