"""Engine/Plan/Session API: registries, immutability, backend equality,
deprecated serving shims."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.api import (ALL_REGISTRIES, COMPRESSORS, EXCHANGES, EXECUTORS,
                       PARTITIONERS, PLACEMENTS, Engine, ModelSpec,
                       UnknownComponentError)
from repro.gnn import datasets, models
from repro.runtime import serving

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    g = datasets.load("siot", scale=0.08, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 32, 8])
    return g, params


# ----------------------------------------------------------------------------
# Registries
# ----------------------------------------------------------------------------

def test_registries_have_expected_keys():
    assert "bgp" in PARTITIONERS
    assert {"iep", "metis+greedy", "random"} <= set(PLACEMENTS.keys())
    assert {"daq", "uniform8", "none"} <= set(COMPRESSORS.keys())
    assert set(EXCHANGES.keys()) == {"allgather", "halo",
                                 "halo_async"}
    assert {"sim", "single", "mesh-bsp", "cloud"} <= set(EXECUTORS.keys())


@pytest.mark.parametrize("name", sorted(ALL_REGISTRIES))
def test_unknown_key_message_names_registry_and_keys(name):
    """Every registry's resolve error names the registry and lists every
    available key (e.g. unknown executor backend 'mesh'; available:
    cloud, mesh-bsp, sim, single (did you mean 'mesh-bsp'?))."""
    registry = ALL_REGISTRIES[name]
    with pytest.raises(UnknownComponentError) as ei:
        registry.resolve("definitely-not-a-key")
    msg = str(ei.value)
    assert registry.kind in msg
    assert "definitely-not-a-key" in msg
    for key in registry.keys():
        assert key in msg
    assert ei.value.available == tuple(registry.keys())


def test_unknown_key_suggests_close_match():
    with pytest.raises(UnknownComponentError, match="did you mean 'mesh-bsp'"):
        EXECUTORS.resolve("mesh")
    with pytest.raises(UnknownComponentError, match="did you mean 'daq'"):
        COMPRESSORS.resolve("dac")


def test_unknown_key_error_lists_available(setup):
    g, params = setup
    with pytest.raises(UnknownComponentError) as ei:
        Engine((params, "gcn"), compressor="zstd")
    msg = str(ei.value)
    assert "zstd" in msg and "daq" in msg and "none" in msg
    with pytest.raises(UnknownComponentError, match="sim"):
        Engine((params, "gcn"), executor="tpu-pod")
    with pytest.raises(UnknownComponentError, match="iep"):
        Engine((params, "gcn"), placement="round-robin")


def test_registry_aliases_and_passthrough(setup):
    g, params = setup
    assert PLACEMENTS.resolve("greedy") is PLACEMENTS.resolve("metis+greedy")
    assert COMPRESSORS.resolve(None) is None          # non-str passes through
    eng = Engine((params, "gcn"), compressor=None)    # None -> "none"
    assert eng.config.compressor == "none"


def test_model_spec_validation(setup):
    g, params = setup
    with pytest.raises(ValueError, match="gcn"):
        ModelSpec(params=tuple(params), kind="transformer")
    with pytest.raises(TypeError):
        Engine(object())
    # both (params, kind) and (kind, params) coerce
    assert Engine((params, "gcn")).model.kind == "gcn"
    assert Engine(("gcn", params)).model.kind == "gcn"


# ----------------------------------------------------------------------------
# Plan immutability
# ----------------------------------------------------------------------------

def test_plan_frozen_and_stable_across_session(setup):
    g, params = setup
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)
    with pytest.raises(dataclasses.FrozenInstanceError):
        plan.placement = None
    before = plan.placement.assignment.copy()
    session = plan.session()
    session.query()
    # overload a node so adaptation actually migrates vertices
    t = [plan.cluster.ground_truth_exec(n, np.flatnonzero(
        session.placement.assignment == j))
        for j, n in enumerate(plan.cluster.nodes)]
    plan.cluster.nodes[int(np.argmax(t))].background_load = 4.0
    mode = session.adapt(lam=1.1)
    assert mode != "none"
    assert not np.array_equal(before, session.placement.assignment)
    assert np.array_equal(before, plan.placement.assignment)
    # a second session starts from the pristine plan, not the adapted one
    assert np.array_equal(before, plan.session().placement.assignment)
    plan.cluster.nodes[int(np.argmax(t))].background_load = 0.0


def test_sessions_do_not_share_latency_model_state(setup):
    """adapt() updates the online eta on session-owned copies, never on the
    plan's profiled FogSpecs (sibling sessions stay uncontaminated)."""
    g, params = setup
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)
    s1 = plan.session()
    plan.cluster.nodes[0].background_load = 5.0
    s1.adapt(lam=1.05)
    assert any(f.latency_model.load_factor != 1.0 for f in s1.fogs)
    assert all(f.latency_model.load_factor == 1.0 for f in plan.fogs)
    assert all(f.latency_model.load_factor == 1.0
               for f in plan.session().fogs)
    plan.cluster.nodes[0].background_load = 0.0


def test_shim_knobs_stay_writable(setup):
    """The old dataclass allowed reassigning compress/exchange between
    queries; the shim must honor that on the next serve_query."""
    g, params = setup
    with pytest.warns(DeprecationWarning):
        svc = serving.deploy(g, params, "gcn", cluster_spec="1A+2B+1C",
                             compress="daq")
    with pytest.warns(DeprecationWarning):
        wire_daq = serving.serve_query(svc).wire_bytes
    svc.compress = None
    assert svc.compress is None
    with pytest.warns(DeprecationWarning):
        wire_raw = serving.serve_query(svc).wire_bytes
    assert wire_raw > 2 * wire_daq
    svc.exchange = "allgather"
    assert svc.exchange == "allgather"


def test_stream_and_adapt_every(setup):
    g, params = setup
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)
    session = plan.session(adapt_every=2, lam=1.5)
    results = list(session.stream(4))
    assert len(results) == 4
    assert session.num_queries == 4
    assert len(session.state.mode_history) == 2  # ticked at queries 2 and 4
    # stream also accepts an iterable of feature overrides
    noisy = g.features + 0.01
    r = list(plan.session().stream([None, noisy]))
    assert len(r) == 2 and not np.allclose(r[0].embeddings, r[1].embeddings)


# ----------------------------------------------------------------------------
# Executor backends
# ----------------------------------------------------------------------------

def test_sim_and_single_numerically_equal(setup):
    g, params = setup
    base = dict(cluster="1A+2B+1C", compressor="daq")
    r_sim = Engine((params, "gcn"), executor="sim",
                   **base).compile(g).session().query()
    r_single = Engine((params, "gcn"), executor="single",
                      **base).compile(g).session().query()
    np.testing.assert_allclose(r_sim.embeddings, r_single.embeddings,
                               rtol=1e-6, atol=1e-6)
    assert r_sim.backend == "sim" and r_single.backend == "single"
    # unified metrics schema across backends
    for r in (r_sim, r_single):
        assert {"collect", "execute", "unpack", "total"} <= set(r.breakdown)
        assert r.latency > 0 and r.throughput > 0 and r.wire_bytes > 0
    assert r_sim.exchange_bytes > 0        # BSP sync payload
    assert r_single.exchange_bytes == 0    # no cross-fog sync


def test_cloud_executor_end_to_end(setup):
    """Fig. 3 cloud-vs-fog through the same API: identical numerics,
    WAN-dominated collection, no cross-fog sync."""
    g, params = setup
    base = dict(cluster="1A+2B+1C", compressor="daq")
    r_fog = Engine((params, "gcn"), executor="sim",
                   **base).compile(g).session().query()
    r_cloud = Engine((params, "gcn"), executor="cloud",
                     **base).compile(g).session().query()
    np.testing.assert_allclose(r_cloud.embeddings, r_fog.embeddings,
                               rtol=1e-6, atol=1e-6)
    assert r_cloud.backend == "cloud"
    assert r_cloud.exchange_bytes == 0          # no BSP sync to the cloud
    assert {"collect", "execute", "unpack", "total"} <= set(r_cloud.breakdown)
    # paper Fig. 3: fog collection is a fraction of the cloud's WAN upload
    assert r_fog.breakdown["collect"] < 0.5 * r_cloud.breakdown["collect"]
    # a per-query override reaches the same accounting
    r_override = Engine((params, "gcn"), executor="sim", **base).compile(
        g).session().query(executor="cloud")
    assert r_override.backend == "cloud"
    assert r_override.latency == pytest.approx(r_cloud.latency)


def test_compressor_swap_changes_wire_not_agreement(setup):
    g, params = setup
    base = dict(cluster="1A+2B+1C")
    r_raw = Engine((params, "gcn"), compressor="none",
                   **base).compile(g).session().query()
    r_daq = Engine((params, "gcn"), compressor="daq",
                   **base).compile(g).session().query()
    assert r_daq.wire_bytes < 0.5 * r_raw.wire_bytes
    agree = np.mean(r_raw.embeddings.argmax(-1) == r_daq.embeddings.argmax(-1))
    assert agree > 0.97


def test_mesh_bsp_device_check_is_helpful(setup):
    g, params = setup
    plan = Engine((params, "gcn"), cluster="1A+4B+1C",
                  executor="mesh-bsp").compile(g)
    if len(jax.devices()) >= plan.num_fogs:
        pytest.skip("enough devices present; check cannot trip")
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        plan.session()


def test_mesh_bsp_backend_switch_subprocess():
    """Same Engine config, executor sim vs mesh-bsp: identical numerics."""
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.api import Engine
        from repro.gnn import datasets, models
        g = datasets.load('yelp', scale=0.06, seed=3)
        params = models.gnn_init(jax.random.PRNGKey(0), 'sage',
                                 [g.feature_dim, 16, 8])
        base = dict(cluster='4B', compressor='daq')
        ref = Engine((params, 'sage'), executor='sim',
                     **base).compile(g).session().query()
        for ex in ('allgather', 'halo'):
            r = Engine((params, 'sage'), executor='mesh-bsp', exchange=ex,
                       **base).compile(g).session().query()
            err = float(np.abs(r.embeddings - ref.embeddings).max())
            assert err < 5e-4, (ex, err)
            assert r.backend == 'mesh-bsp'
            assert r.exchange_bytes > 0
        print('OK')
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


# ----------------------------------------------------------------------------
# Deprecated serving shims
# ----------------------------------------------------------------------------

def test_deploy_serve_query_adapt_shims(setup):
    g, params = setup
    with pytest.warns(DeprecationWarning):
        svc = serving.deploy(g, params, "gcn", cluster_spec="1A+2B+1C",
                             compress="daq")
    assert isinstance(svc, serving.FographService)
    # legacy attribute surface
    assert svc.kind == "gcn" and svc.compress == "daq"
    assert svc.placement.assignment.shape == (g.num_vertices,)
    assert len(svc.fogs) == len(svc.cluster.nodes) == 4
    with pytest.warns(DeprecationWarning):
        r = serving.serve_query(svc)
    assert r.embeddings.shape == (g.num_vertices, 8)
    assert r.latency > 0 and r.throughput > 0
    # shim result equals a direct session query on the same config
    direct = Engine((params, "gcn"), cluster="1A+2B+1C",
                    compressor="daq").compile(g).session().query()
    np.testing.assert_allclose(r.embeddings, direct.embeddings,
                               rtol=1e-6, atol=1e-6)
    assert r.latency == pytest.approx(direct.latency)
    with pytest.warns(DeprecationWarning):
        mode = serving.adapt(svc)
    assert mode in ("none",) or mode.startswith(("diffusion", "replan"))


def test_pod_matching_uses_placement_registry():
    """launch.serve's batch matcher is a thin adapter over PLACEMENTS."""
    from repro.core.profiler import LatencyModel
    from repro.launch.serve import Pod, place_batches

    class R:  # minimal request stub
        prompt = np.zeros(8, np.int32)
        max_new = 16

    pods = [Pod(f"p{i}", s, model=LatencyModel(
        beta=np.array([1e-3 / s, 1e-5 / s]), eps=1e-4))
        for i, s in enumerate((1.0, 2.0, 4.0))]
    batches = [[R()] * b for b in (4, 2, 1)]
    mapping = place_batches(batches, pods, placement="iep")
    assert sorted(mapping) == [0, 1, 2]
    # bottleneck property: biggest batch lands on the fastest pod
    assert mapping[0] == 2
    with pytest.raises(UnknownComponentError):
        place_batches(batches, pods, placement="nope")
