"""Node-level fault tolerance: chaos injection, retry, failover, restore.

The load-bearing guarantees:
  * ``FaultSchedule`` is deterministic — same seed, nodes and rates give
    the identical event list, and the generator never crashes the last
    surviving node;
  * ``simulate_retry`` prices exponential backoff against the retry
    budget/timeout, surfaced per exchange via
    ``ExchangeSpec.recovery_cost``;
  * ``Engine.fail_nodes`` evicts the crashed node everywhere, carries
    ``cluster_spec=None`` (so later recompiles/pricing never resurrect
    it — the ``simulate_update`` bugfix), and with ``mode="recompile"``
    equals a fresh ``Engine.compile`` on the surviving cluster;
  * the ``Server`` walks retry -> stale -> failover per injected fault,
    answers every admitted request (zero drops, in-flight work replayed
    on the degraded plan), tags responses
    (``retries``/``recovered``/``capacity``), and costs nothing when no
    fault fires;
  * seeded chaos across executors: every response is bit-identical to
    the fault-free run or carries an explicit staleness/degradation tag.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.analysis import AnalysisContext, run_checks
from repro.api import Engine
from repro.api.faults import (FailoverAudit, Fault, FaultInjector,
                              FaultSchedule)
from repro.api.registry import EXCHANGES
from repro.api.server import Request, Server
from repro.api.slo import default_ladder
from repro.api.updates import GraphDelta
from repro.core import simulation
from repro.gnn import datasets, models

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    g = datasets.load("siot", scale=0.06, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    eng = Engine((params, "gcn"), "1A+3B", executor="sim",
                 exchange="halo_async", staleness_bound=2)
    return g, params, eng, eng.compile(g)


# ----------------------------------------------------------------------------
# Fault / FaultSchedule / FaultInjector
# ----------------------------------------------------------------------------

def test_fault_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault(0.0, "meteor", node="fog0(A)")
    with pytest.raises(ValueError, match=">= 0"):
        Fault(-1.0, "halo_loss")
    with pytest.raises(ValueError, match="needs a node"):
        Fault(0.0, "crash")
    with pytest.raises(ValueError, match="slowdown"):
        Fault(0.0, "straggler", node="x", slowdown=0.5, duration=1.0)
    with pytest.raises(ValueError, match="duration"):
        Fault(0.0, "straggler", node="x", slowdown=2.0)
    with pytest.raises(ValueError, match="losses"):
        Fault(0.0, "halo_loss", losses=0)


def test_schedule_sorts_and_injector_fires_once():
    sched = FaultSchedule([Fault(0.5, "halo_loss"), Fault(0.1, "halo_loss"),
                           Fault(0.3, "crash", node="a")])
    assert [f.time for f in sched] == [0.1, 0.3, 0.5]
    inj = FaultInjector(sched)
    assert [f.time for f in inj.due(0.3)] == [0.1, 0.3]
    assert inj.due(0.3) == []           # consumed exactly once
    assert inj.remaining == 1
    assert [f.time for f in inj.flush()] == [0.5]
    assert inj.remaining == 0


def test_random_schedule_deterministic_and_safe():
    nodes = ["n0", "n1"]
    kw = dict(horizon=20.0, crash_rate=0.5, loss_rate=0.5,
              straggler_rate=0.3, seed=7)
    a, b = FaultSchedule.random(nodes, **kw), FaultSchedule.random(nodes,
                                                                   **kw)
    assert list(a) == list(b)           # seeded: bit-identical
    assert len(a) > 0
    c = FaultSchedule.random(nodes, **dict(kw, seed=8))
    assert list(a) != list(c)
    # never all nodes down at once: replay the crash/recover intervals
    down = set()
    for f in a:
        if f.kind == "crash":
            down.add(f.node)
            assert len(down) < len(nodes)
        elif f.kind == "recover":
            down.discard(f.node)
    # every crash pairs with a recover
    counts = a.counts()
    assert counts["crash"] == counts["recover"]


# ----------------------------------------------------------------------------
# Retry / failover pricing + exchange knobs
# ----------------------------------------------------------------------------

def test_simulate_retry_pricing():
    t1, n1, ok1 = simulation.simulate_retry(1, sync_cost=5e-3)
    assert ok1 and n1 == 1
    assert t1 == pytest.approx(5e-3 + simulation.RETRY_BACKOFF_BASE_S)
    t2, n2, ok2 = simulation.simulate_retry(2, sync_cost=5e-3)
    assert ok2 and n2 == 2 and t2 > t1      # backoff grows per attempt
    # more losses than the attempt budget: fails, partial cost reported
    t6, n6, ok6 = simulation.simulate_retry(6, sync_cost=5e-3)
    assert not ok6 and n6 <= simulation.RETRY_MAX_ATTEMPTS and t6 > 0
    # a tiny timeout binds before the attempt budget does
    tt, nt, okt = simulation.simulate_retry(2, sync_cost=5e-3,
                                            timeout=0.01)
    assert not okt and nt < 2 and tt <= 0.01 + 1e-12


def test_exchange_recovery_cost():
    halo = EXCHANGES.resolve("halo")
    asy = EXCHANGES.resolve("halo_async")
    assert halo.retryable and asy.retryable and asy.stale_tolerant
    s, n, ok = asy.recovery_cost(1, 5e-3)
    assert ok and n == 1 and s > 0
    s6, n6, ok6 = asy.recovery_cost(6, 5e-3)
    assert not ok6                          # budget exhausted -> tier 2/3
    # a non-retryable spec reports zero recoverable budget
    from repro.runtime.bsp import ExchangeSpec
    none = ExchangeSpec(name="custom")
    assert none.recovery_cost(1, 5e-3) == (0.0, 0, False)


def test_simulate_failover_pricing(setup):
    g, params, eng, plan = setup
    t0 = simulation.simulate_failover(plan.cluster, 0)
    assert t0 >= simulation.FAILOVER_BASE_S
    t1 = simulation.simulate_failover(plan.cluster, 100, g.feature_dim)
    t2 = simulation.simulate_failover(plan.cluster, 200, g.feature_dim)
    assert t2 > t1 > t0                     # moved rows cost wire + flops


# ----------------------------------------------------------------------------
# Engine.fail_nodes
# ----------------------------------------------------------------------------

def test_fail_nodes_repair_coverage(setup):
    g, params, eng, plan = setup
    crashed = plan.cluster.nodes[-1].name
    plan2 = eng.fail_nodes(plan, [crashed])
    assert plan2.provenance == "failover"
    assert plan2.config.cluster_spec is None     # the pricing bugfix
    names = [n.name for n in plan2.cluster.nodes]
    assert crashed not in names and len(names) == len(
        plan.cluster.nodes) - 1
    assert crashed not in [f.name for f in plan2.fogs]
    a = np.asarray(plan2.placement.assignment)
    assert a.shape[0] == g.num_vertices
    assert a.min() >= 0 and a.max() < len(plan2.fogs)
    assert (np.bincount(a, minlength=len(plan2.fogs)) > 0).all()
    # partition-independent numerics: degraded plan answers identically
    assert np.array_equal(plan2.session().query().embeddings,
                          plan.session().query().embeddings)
    # the fault analysis family signs off and stays silent when healthy
    audit = FailoverAudit(plan=plan2, base_plan=plan, crashed=(crashed,))
    report = run_checks(AnalysisContext(plan=plan2, failover=audit),
                        families=("fault",))
    assert set(report.ran) == {"fault.failover.coverage",
                               "fault.halo.consistency",
                               "fault.retry.budget"}
    assert not report.errors and not report.warnings


def test_fail_nodes_rejects_bad_input(setup):
    g, params, eng, plan = setup
    with pytest.raises(KeyError, match="unknown node"):
        eng.fail_nodes(plan, ["not-a-node"])
    with pytest.raises(ValueError):
        eng.fail_nodes(plan, [])
    with pytest.raises(ValueError):
        eng.fail_nodes(plan, [n.name for n in plan.cluster.nodes])
    with pytest.raises(ValueError):
        eng.fail_nodes(plan, [99])


def test_fail_nodes_recompile_equals_fresh_compile(setup):
    g, params, eng, plan = setup
    crashed = plan.cluster.nodes[-1].name
    plan2 = eng.fail_nodes(plan, [crashed], mode="recompile")
    survivors = dataclasses.replace(
        plan.cluster,
        nodes=[n for n in plan.cluster.nodes if n.name != crashed])
    fresh = Engine((params, "gcn"), survivors, executor="sim",
                   exchange="halo_async", staleness_bound=2).compile(g)
    assert plan2.provenance == "failover"
    assert np.array_equal(plan2.placement.assignment,
                          fresh.placement.assignment)
    assert [n.name for n in plan2.cluster.nodes] == [
        n.name for n in fresh.cluster.nodes]
    assert plan2.config == dataclasses.replace(fresh.config,
                                               cluster_spec=None)
    assert np.array_equal(plan2.session().query().embeddings,
                          fresh.session().query().embeddings)


def test_failover_plan_never_resurrects_node(setup):
    """The simulate_update bugfix: after a failover, recompiles and update
    pricing must see the SURVIVING cluster, not the named spec."""
    g, params, eng, plan = setup
    crashed = plan.cluster.nodes[-1].name
    plan2 = eng.fail_nodes(plan, [crashed])
    eng2 = Engine.from_plan(plan2)
    survivors = [n.name for n in plan2.cluster.nodes]
    assert [n.name for n in eng2.cluster.nodes] == survivors
    # a delta-driven recompile stays on the survivors
    delta = GraphDelta(add_features=np.ones((1, g.feature_dim), np.float32),
                       add_edges=[(g.num_vertices, 0)])
    plan3 = eng2.apply_delta(plan2, delta, force="recompile")
    assert [n.name for n in plan3.cluster.nodes] == survivors
    # and update pricing reads the surviving (degraded) capability pool
    assert simulation.simulate_update(plan2.cluster, delta) > 0


# ----------------------------------------------------------------------------
# Server recovery tiers
# ----------------------------------------------------------------------------

def _trace(n, dt=0.03):
    return [Request(arrival_time=i * dt) for i in range(n)]


def test_server_rejects_unknown_fault_node(setup):
    g, params, eng, plan = setup
    with pytest.raises(ValueError, match="unknown nodes"):
        plan.server(faults=FaultSchedule(
            [Fault(0.1, "crash", node="ghost")]))


def test_fault_free_schedule_costs_nothing(setup):
    g, params, eng, plan = setup
    srv0 = plan.server(max_batch=4)
    base = srv0.serve(_trace(16))
    srv1 = plan.server(max_batch=4, faults=FaultSchedule([]))
    out = srv1.serve(_trace(16))
    assert len(out) == len(base) == 16
    for a, b in zip(out, base):
        assert a.latency == b.latency       # exact, not approx
        assert np.array_equal(a.embeddings, b.embeddings)
        assert a.retries == 0 and a.recovered is None
        assert a.capacity == "full"
        assert a.breakdown["recovery"] == 0.0
    assert "recovery" not in base[0].breakdown   # injector-only key


def test_tier1_retry(setup):
    g, params, eng, plan = setup
    sched = FaultSchedule([Fault(0.10, "halo_loss", losses=2)])
    srv = plan.server(max_batch=4, faults=sched)
    out = srv.serve(_trace(16))
    base = plan.server(max_batch=4).serve(_trace(16))
    retried = [r for r in out if r.recovered == "retry"]
    assert retried and all(r.retries == 2 for r in retried)
    assert all(r.breakdown["recovery"] > 0 for r in retried)
    # numerics untouched: the loss costs time, never accuracy
    for a, b in zip(out, base):
        assert np.array_equal(a.embeddings, b.embeddings)
    assert srv.summarize(out)["retried"] == len(retried)
    # deterministic replay: same schedule + trace -> identical timings
    out2 = plan.server(max_batch=4, faults=sched).serve(_trace(16))
    assert [r.latency for r in out] == [r.latency for r in out2]


def test_tier2_stale_ride_through(setup):
    g, params, eng, plan = setup
    # losses=6 exhausts the 4-attempt retry budget; no node is named, so
    # tier 3 is unreachable -> the warm halo store must carry the serve.
    # (Fire early, while the store's age is still within the bound — at
    # the bound the session forces a fresh sync and tier 2 is unusable.)
    sched = FaultSchedule([Fault(0.08, "halo_loss", losses=6)])
    srv = plan.server(max_batch=4, faults=sched)
    out = srv.serve(_trace(16))
    assert len(out) == 16
    stale = [r for r in out if r.recovered == "stale"]
    assert stale, "warm halo store should have absorbed the loss"
    assert all(r.capacity == "full" for r in out)   # no failover happened


def test_tier3_crash_failover_and_restore(setup):
    g, params, eng, plan = setup
    victim = plan.cluster.nodes[-1].name
    sched = FaultSchedule([Fault(0.10, "crash", node=victim),
                           Fault(0.60, "recover", node=victim)])
    srv = plan.server(max_batch=4, faults=sched)
    n = 40
    out = srv.serve(_trace(n))
    assert len(out) == n                    # zero drops
    assert srv.replayed > 0                 # in-flight work was replayed
    tags = [r.recovered for r in out]
    assert "failover" in tags and "restored" in tags
    i_f, i_r = tags.index("failover"), tags.index("restored")
    # between failover and restore the survivors serve, tagged degraded
    assert all(r.capacity == "degraded" for r in out[i_f:i_r])
    assert all(r.capacity == "full" for r in out[i_r:])
    assert not srv._crashed
    # restored back onto the original full-cluster plan object
    assert srv.session.plan is plan
    # numerics: identical to fault-free wherever not explicitly tagged
    base = plan.server(max_batch=4).serve(_trace(n))
    for a, b in zip(out, base):
        assert (np.array_equal(a.embeddings, b.embeddings)
                or a.capacity == "degraded" or a.staleness > 0)
    s = srv.summarize(out)
    assert s["availability"] == 1.0 and s["recovered"] >= 2


def test_straggler_slows_then_recovers(setup):
    g, params, eng, plan = setup
    victim = plan.cluster.nodes[1]
    load0 = victim.background_load
    sched = FaultSchedule([Fault(0.05, "straggler", node=victim.name,
                                 slowdown=4.0, duration=0.30)])
    srv = plan.server(max_batch=4, faults=sched)
    out = srv.serve(_trace(24))
    base = plan.server(max_batch=4).serve(_trace(24))
    assert len(out) == 24
    # pricing only: slower somewhere, never different answers
    assert max(r.latency for r in out) > max(r.latency for r in base)
    for a, b in zip(out, base):
        assert np.array_equal(a.embeddings, b.embeddings)
    # the extra load was lifted at expiry
    assert not srv._slow
    assert victim.background_load == pytest.approx(load0)


def test_survivor_degraded_ladder(setup):
    g, params, eng, plan = setup
    crashed = plan.cluster.nodes[-1].name
    ladder = default_ladder(eng.fail_nodes(plan, [crashed]).session())
    assert ladder[0].name == "survivor-degraded"
    # the full ladder's knob rungs are replaced, layer rungs remain
    assert all("survivor" not in r.name for r in default_ladder(
        plan.session()))


def test_crash_under_slo_rebuilds_ladder(setup):
    g, params, eng, plan = setup
    victim = plan.cluster.nodes[-1].name
    sched = FaultSchedule([Fault(0.10, "crash", node=victim)])
    srv = plan.server(max_batch=4, slo=True, faults=sched)
    out = srv.serve(_trace(24))
    answered = [r for r in out if hasattr(r, "embeddings")]
    assert answered and srv.ladder[0].name == "survivor-degraded"


# ----------------------------------------------------------------------------
# Seeded chaos property: zero drops, bit-identical or tagged
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("executor,aggregation", [
    ("sim", "segment_sum"), ("sim", "pallas"), ("single", "segment_sum")])
def test_chaos_property(setup, executor, aggregation):
    g, params, _eng, _plan = setup
    eng = Engine((params, "gcn"), "1A+3B", executor=executor,
                 aggregation=aggregation, exchange="halo_async",
                 staleness_bound=2)
    plan = eng.compile(g)
    n = 32
    base = plan.server(max_batch=4).serve(_trace(n))
    by_id = {r.request_id: r for r in base}
    sched = FaultSchedule.random(
        [nd.name for nd in plan.cluster.nodes],
        horizon=n * 0.03, crash_rate=1.5, loss_rate=2.0,
        straggler_rate=1.0, mean_outage=0.3, seed=11)
    assert len(sched) > 0
    srv = plan.server(max_batch=4, faults=sched)
    out = srv.serve(_trace(n))
    assert len(out) == n                    # every admitted request answered
    for r in out:
        ref = by_id[r.request_id]
        assert (np.array_equal(r.embeddings, ref.embeddings)
                or r.staleness > 0 or r.capacity == "degraded"), (
            f"untagged divergence on request {r.request_id}")
    assert srv.summarize(out)["availability"] == 1.0


def test_chaos_property_mesh_bsp_subprocess():
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.api import Engine
        from repro.api.faults import FaultSchedule
        from repro.api.server import Request
        from repro.gnn import datasets, models
        g = datasets.load('siot', scale=0.05, seed=0)
        params = models.gnn_init(jax.random.PRNGKey(0), 'gcn',
                                 [g.feature_dim, 16, 8])
        for aggregation in ('segment_sum', 'pallas'):
            eng = Engine((params, 'gcn'), '1A+3B', executor='mesh-bsp',
                         aggregation=aggregation, exchange='halo_async',
                         staleness_bound=2)
            plan = eng.compile(g)
            trace = lambda: [Request(arrival_time=i * 0.03)
                             for i in range(24)]
            base = plan.server(max_batch=4).serve(trace())
            by_id = {r.request_id: r for r in base}
            sched = FaultSchedule.random(
                [nd.name for nd in plan.cluster.nodes], horizon=0.8,
                crash_rate=1.5, loss_rate=2.0, straggler_rate=1.0,
                mean_outage=0.3, seed=5)
            assert len(sched) > 0
            srv = plan.server(max_batch=4, faults=sched)
            out = srv.serve(trace())
            assert len(out) == 24, (aggregation, len(out))
            for r in out:
                ref = by_id[r.request_id]
                ok = (np.array_equal(r.embeddings, ref.embeddings)
                      or r.staleness > 0 or r.capacity == 'degraded')
                assert ok, (aggregation, r.request_id)
        print('OK')
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


def test_fleet_composes_node_faults(setup):
    """Per-site chaos schedules ride the fleet facade: a node crash
    inside one site fails over within that site, zero drops fleet-wide,
    and other sites never notice."""
    g, params, _eng, _plan = setup
    sites = {"north": (59.33, 18.07), "south": (48.21, 16.37)}
    eng = Engine((params, "gcn"), "1A+2B", exchange="halo_async",
                 staleness_bound=2)
    fleet = eng.compile_fleet(g, sites)
    with pytest.raises(ValueError, match="unknown sites"):
        fleet.server(faults={"atlantis": FaultSchedule([])})
    node = fleet.site("north").plan.cluster.nodes[-1].name
    sched = FaultSchedule([Fault(0.05, "crash", node=node),
                           Fault(0.50, "recover", node=node)])
    fs = fleet.server(capacity=100, max_batch=4,
                      faults={"north": sched})
    n = 24
    for i in range(n):
        fs.submit(arrival_time=i * 0.03,
                  origin=sites["north" if i % 2 == 0 else "south"])
    out = fs.drain()
    from repro.api.server import Response
    resp = [r for r in out if isinstance(r, Response)]
    assert len(resp) == n                       # zero drops
    north = [r for r in resp if r.site == "north"]
    south = [r for r in resp if r.site == "south"]
    assert any(r.recovered == "failover" for r in north)
    assert all(r.recovered is None and r.capacity == "full"
               for r in south)                  # blast radius: one site
    s = fs.summarize(out)
    assert s["dropped"] == 0 and s["availability"] == 1.0
    assert s["recovered"] >= 1


# ----------------------------------------------------------------------------
# Fault checks fire on mutation
# ----------------------------------------------------------------------------

def test_fault_checks_fire_on_mutation(setup):
    g, params, eng, plan = setup
    crashed = plan.cluster.nodes[-1].name
    plan2 = eng.fail_nodes(plan, [crashed])
    # resurrect the spec: the coverage check must flag it
    bad = dataclasses.replace(
        plan2, config=plan2.config.with_overrides(cluster_spec="1A+3B"))
    report = run_checks(
        AnalysisContext(plan=bad, failover=FailoverAudit(
            plan=bad, base_plan=plan, crashed=(crashed,))),
        families=("fault",))
    assert any(d.check_id == "fault.failover.coverage"
               for d in report.errors)
    # malformed schedule: double crash without recover
    sched = FaultSchedule([Fault(0.1, "crash", node="a"),
                           Fault(0.2, "crash", node="a")])
    report = run_checks(
        AnalysisContext(plan=plan2, failover=FailoverAudit(
            plan=plan2, schedule=sched)),
        families=("fault",))
    assert any(d.check_id == "fault.retry.budget" for d in report.errors)
