"""Optional-hypothesis shim for property-based tests.

``hypothesis`` is an optional dev dependency (see pyproject.toml). When it
is installed, this module re-exports the real ``given``/``settings``/``st``.
When it is missing, stand-ins keep the module importable — strategy
construction at decoration time becomes a no-op, and each property test
body is replaced by ``pytest.importorskip("hypothesis")`` so it reports as
a cleanly skipped test instead of a collection error. Plain tests in the
same module still run either way.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis missing
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategy constructor call (st.integers(...) etc.)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda fn: fn

    def given(*args, **kwargs):
        def deco(fn):
            # Zero-arg replacement (no functools.wraps: pytest would read
            # the wrapped signature and hunt for fixtures named after the
            # hypothesis parameters).
            def skipper():
                pytest.importorskip("hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco
