"""Suite-wide fixtures.

Every ``Plan`` that ``Engine.compile`` / ``Engine.apply_delta`` produces
anywhere in the tier-1 suite is run through the ``repro.analysis`` plan
invariant checks in warn mode, so a layout/update regression surfaces as a
``PlanInvariantWarning`` in whichever test built the plan — without that
test knowing about the verifier.  Opt out per-test with
``@pytest.mark.no_plan_invariants`` (e.g. when deliberately building a
corrupt plan).
"""
import pytest


@pytest.fixture(autouse=True)
def _plan_invariants(request, monkeypatch):
    if request.node.get_closest_marker("no_plan_invariants"):
        yield
        return
    from repro.analysis import verify_plan
    from repro.api.engine import Engine

    orig_compile = Engine.compile
    orig_apply = Engine.apply_delta

    def compile_checked(self, graph):
        plan = orig_compile(self, graph)
        verify_plan(plan, mode="warn")
        return plan

    def apply_checked(self, plan, delta, **kw):
        out = orig_apply(self, plan, delta, **kw)
        verify_plan(out, mode="warn")
        return out

    monkeypatch.setattr(Engine, "compile", compile_checked)
    monkeypatch.setattr(Engine, "apply_delta", apply_checked)
    yield
