"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep:
# property tests skip cleanly when hypothesis is not installed

from repro.gnn import datasets
from repro.kernels import ops, ref
from repro.kernels.daq_dequant import (dequant, dequant_spmm,
                                       dequant_spmm_batched)
from repro.kernels.gather_aggregate import (block_spmm, block_spmm_batched,
                                            build_block_csr)


def _random_graph(n, e, seed):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, n, e).astype(np.int32)
    r = rng.integers(0, n, e).astype(np.int32)
    return s, r


@pytest.mark.parametrize("n,e,f", [(64, 256, 128), (200, 1000, 128),
                                   (300, 4000, 256), (128, 128, 384)])
def test_block_spmm_matches_ref_and_edge_sum(n, e, f):
    s, r = _random_graph(n, e, 0)
    blocks, cols, mask, pv = build_block_csr(s, r, n)
    rng = np.random.default_rng(1)
    h = np.zeros((pv, f), np.float32)
    h[:n] = rng.normal(size=(n, f)).astype(np.float32)
    out = np.asarray(block_spmm(jnp.asarray(blocks), jnp.asarray(cols),
                                jnp.asarray(mask), jnp.asarray(h)))
    want = np.asarray(ref.block_spmm_ref(jnp.asarray(blocks),
                                         jnp.asarray(cols),
                                         jnp.asarray(mask), jnp.asarray(h)))
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)
    # ground truth via edge accumulation (duplicate edges accumulate)
    agg = np.zeros_like(h)
    np.add.at(agg, r, h[s])
    np.testing.assert_allclose(out[:n], agg[:n], rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("f_tile", [128, 256])
def test_block_spmm_f_tiles(f_tile):
    s, r = _random_graph(100, 500, 2)
    blocks, cols, mask, pv = build_block_csr(s, r, 100)
    h = np.random.default_rng(3).normal(size=(pv, 256)).astype(np.float32)
    out = np.asarray(block_spmm(jnp.asarray(blocks), jnp.asarray(cols),
                                jnp.asarray(mask), jnp.asarray(h),
                                f_tile=f_tile))
    want = np.asarray(ref.block_spmm_ref(jnp.asarray(blocks),
                                         jnp.asarray(cols),
                                         jnp.asarray(mask), jnp.asarray(h)))
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("b,n,e,f", [(2, 64, 256, 128), (3, 200, 1000, 128),
                                     (4, 130, 700, 256)])
def test_block_spmm_batched_matches_ref_and_serial(b, n, e, f):
    """The batch-grid kernel == the vmapped oracle AND is bit-identical
    per example to the unbatched kernel (the run_many contract)."""
    s, r = _random_graph(n, e, 0)
    blocks, cols, mask, pv = build_block_csr(s, r, n)
    rng = np.random.default_rng(1)
    h = np.zeros((b, pv, f), np.float32)
    h[:, :n] = rng.normal(size=(b, n, f)).astype(np.float32)
    out = np.asarray(block_spmm_batched(
        jnp.asarray(blocks), jnp.asarray(cols), jnp.asarray(mask),
        jnp.asarray(h)))
    want = np.asarray(ref.block_spmm_batched_ref(
        jnp.asarray(blocks), jnp.asarray(cols), jnp.asarray(mask),
        jnp.asarray(h)))
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-4)
    for k in range(b):
        one = np.asarray(block_spmm(jnp.asarray(blocks), jnp.asarray(cols),
                                    jnp.asarray(mask), jnp.asarray(h[k])))
        assert np.array_equal(out[k], one)


def test_dequant_spmm_batched_matches_ref_and_serial():
    s, r = _random_graph(150, 800, 2)
    blocks, cols, mask, pv = build_block_csr(s, r, 150)
    rng = np.random.default_rng(3)
    b, f = 3, 64
    codes = rng.integers(0, 255, (b, pv, f)).astype(np.uint8)
    sc = rng.uniform(1e-3, 0.1, (b, pv)).astype(np.float32)
    mn = rng.normal(size=(b, pv)).astype(np.float32)
    out = np.asarray(dequant_spmm_batched(
        jnp.asarray(blocks), jnp.asarray(cols), jnp.asarray(mask),
        jnp.asarray(codes), jnp.asarray(sc), jnp.asarray(mn)))
    want = np.asarray(ref.dequant_spmm_batched_ref(
        jnp.asarray(blocks), jnp.asarray(cols), jnp.asarray(mask),
        jnp.asarray(codes), jnp.asarray(sc), jnp.asarray(mn)))
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=2e-3)
    for k in range(b):
        one = np.asarray(dequant_spmm(
            jnp.asarray(blocks), jnp.asarray(cols), jnp.asarray(mask),
            jnp.asarray(codes[k]), jnp.asarray(sc[k]), jnp.asarray(mn[k])))
        assert np.array_equal(out[k], one)


@pytest.mark.parametrize("dtype", [np.uint8, np.uint16, np.uint32])
@pytest.mark.parametrize("v,f", [(256, 128), (512, 256)])
def test_dequant_kernel_dtypes(dtype, v, f):
    rng = np.random.default_rng(4)
    info = np.iinfo(dtype)
    codes = rng.integers(0, min(info.max, 1 << 20), (v, f)).astype(dtype)
    sc = rng.uniform(1e-3, 1.0, v).astype(np.float32)
    mn = rng.normal(size=v).astype(np.float32)
    out = np.asarray(dequant(jnp.asarray(codes), jnp.asarray(sc),
                             jnp.asarray(mn)))
    want = np.asarray(ref.dequant_ref(jnp.asarray(codes), jnp.asarray(sc),
                                      jnp.asarray(mn)))
    np.testing.assert_allclose(out, want, rtol=1e-6, atol=1e-5)


def test_fused_dequant_spmm_matches_unfused():
    g = datasets.load("yelp", scale=0.05, seed=5)
    rng = np.random.default_rng(6)
    codes = rng.integers(0, 255, (g.num_vertices, 64)).astype(np.uint8)
    sc = rng.uniform(0.01, 0.1, g.num_vertices).astype(np.float32)
    mn = rng.normal(size=g.num_vertices).astype(np.float32)
    bc = ops.BlockCsr(g)
    fused = bc.aggregate_quantized(codes, sc, mn)
    feats = codes.astype(np.float32) * sc[:, None] + mn[:, None]
    agg = np.zeros_like(feats)
    np.add.at(agg, g.receivers, feats[g.senders])
    np.testing.assert_allclose(fused, agg, rtol=1e-4, atol=2e-3)


def test_ops_mean_aggregate_normalization():
    g = datasets.load("yelp", scale=0.05, seed=7)
    h = np.random.default_rng(8).normal(
        size=(g.num_vertices, 32)).astype(np.float32)
    out = ops.BlockCsr(g, normalize="mean").aggregate(h)
    agg = np.zeros_like(h)
    np.add.at(agg, g.receivers, h[g.senders])
    want = agg / np.maximum(g.degrees, 1)[:, None]
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_block_spmm_property_random_graphs(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(16, 200))
    e = int(rng.integers(n, 6 * n))
    s, r = _random_graph(n, e, seed + 1)
    blocks, cols, mask, pv = build_block_csr(s, r, n)
    h = np.zeros((pv, 128), np.float32)
    h[:n] = rng.normal(size=(n, 128)).astype(np.float32)
    out = np.asarray(block_spmm(jnp.asarray(blocks), jnp.asarray(cols),
                                jnp.asarray(mask), jnp.asarray(h)))
    agg = np.zeros_like(h)
    np.add.at(agg, r, h[s])
    np.testing.assert_allclose(out[:n], agg[:n], rtol=1e-4, atol=1e-3)


def test_kernel_backed_gcn_layer_matches_model():
    """Full GCN layer with the Pallas block-CSR aggregation == the model's
    segment-sum path (kernel as drop-in aggregation backend)."""
    import jax

    from repro.gnn import models
    from repro.gnn.layers import EdgeList

    g = datasets.load("siot", scale=0.04, seed=11)
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16])
    ref = np.asarray(models.gnn_apply(params, "gcn", g.features,
                                      EdgeList.from_graph(g)))
    # kernel path: aggregate via block-CSR SpMM, then the GCN update
    bc = ops.BlockCsr(g)
    a = bc.aggregate(g.features)
    deg = g.degrees.astype(np.float32)
    z = (a + g.features) / (deg + 1.0)[:, None]
    out = z @ np.asarray(params[0]["w"]) + np.asarray(params[0]["b"])
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
