"""BGP partitioner invariants, profiler regression, adaptive scheduler."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep:
# property tests skip cleanly when hypothesis is not installed

from repro.core import partition, profiler, scheduler, simulation
from repro.core.placement import iep_place
from repro.gnn import datasets
from repro.gnn.graph import edge_cut


@given(st.integers(0, 100), st.integers(2, 8))
@settings(max_examples=15, deadline=None)
def test_bgp_invariants(seed, n):
    g = datasets.load("yelp", scale=0.03, seed=seed % 5)
    a = partition.bgp(g, n, seed=seed)
    assert a.shape == (g.num_vertices,)
    assert a.min() >= 0 and a.max() < n
    sizes = np.bincount(a, minlength=n)
    assert sizes.min() >= 1
    # balance within tolerance of the refinement (±~12%+1 of ideal)
    ideal = g.num_vertices / n
    assert sizes.max() <= np.ceil(ideal * 1.15) + 1


def test_bgp_reduces_cut_vs_random():
    g = datasets.load("siot", scale=0.05, seed=0)
    rng = np.random.default_rng(0)
    rand = rng.integers(0, 4, g.num_vertices)
    ours = partition.bgp(g, 4, seed=0)
    assert edge_cut(g, ours) < edge_cut(g, rand)


def test_bgp_capacity_weights():
    g = datasets.load("yelp", scale=0.05, seed=1)
    a = partition.bgp(g, 2, weights=np.array([0.75, 0.25]), seed=0)
    sizes = np.bincount(a, minlength=2)
    assert sizes[0] > sizes[1]


def test_profiler_recovers_planted_linear_model():
    g = datasets.load("yelp", scale=0.05, seed=0)
    beta = np.array([3e-6, 1e-7])
    eps = 2e-3

    def measure_c(c):
        return float(beta @ np.asarray(c, np.float64) + eps)

    model = profiler.profile_node_analytic(g, measure_c, seed=0)
    # predictions within 10% across the calibration range (paper Fig. 14)
    for ids in profiler.sample_calibration_set(g, 4, 3, seed=1):
        c = profiler.cardinality_of(g, ids)
        assert model.predict(c) == pytest.approx(measure_c(c), rel=0.10)


def test_online_load_factor_two_step_estimation():
    m = profiler.LatencyModel(beta=np.array([1e-5, 1e-6]), eps=1e-3)
    c = (1000, 5000)
    base = m.predict(c)
    eta = m.observe(c, 2.0 * base)      # node got 2x slower
    assert eta == pytest.approx(2.0, rel=1e-6)
    c2 = (500, 2000)
    assert m.predict(c2) == pytest.approx(
        2.0 * (m.beta @ np.array(c2) + m.eps), rel=1e-6)


@pytest.fixture()
def loaded_cluster():
    g = datasets.load("siot", scale=0.1, seed=0)
    cluster = simulation.make_cluster("1A+2B+1C", "wifi", g)
    fogs = cluster.fog_specs(seed=0)
    pl = iep_place(g, fogs, seed=0, sync_cost=cluster.sync_cost)
    return g, cluster, fogs, pl


def test_scheduler_noop_when_balanced(loaded_cluster):
    g, cluster, fogs, pl = loaded_cluster
    st_ = scheduler.SchedulerState(placement=pl)
    t = simulation.measured_exec_times(cluster, pl)
    st_ = scheduler.schedule_step(g, st_, fogs, t, lam=1.5)
    assert st_.mode_history[-1] == "none"


def test_scheduler_diffusion_on_single_overload(loaded_cluster):
    g, cluster, fogs, pl = loaded_cluster
    st_ = scheduler.SchedulerState(placement=pl)
    j = int(np.argmax(simulation.measured_exec_times(cluster, pl)))
    cluster.nodes[j].background_load = 3.5
    t = simulation.measured_exec_times(cluster, pl)
    before = t.max()
    st_ = scheduler.schedule_step(g, st_, fogs, t, lam=1.2)
    assert st_.mode_history[-1].startswith("diffusion")
    after = simulation.measured_exec_times(cluster, st_.placement).max()
    assert after <= before + 1e-9


def test_scheduler_global_replan_on_majority_overload(loaded_cluster):
    g, cluster, fogs, pl = loaded_cluster
    st_ = scheduler.SchedulerState(placement=pl)
    # skew 3 of 4 nodes with very different loads -> mu spread, n+/n > theta
    cluster.nodes[0].background_load = 6.0
    cluster.nodes[1].background_load = 5.0
    cluster.nodes[2].background_load = 4.0
    t = simulation.measured_exec_times(cluster, pl)
    st_ = scheduler.schedule_step(g, st_, fogs, t, lam=1.02, theta=0.25)
    assert st_.mode_history[-1] == "replan"
    assert st_.replans == 1


def test_diffusion_migrates_boundary_vertices(loaded_cluster):
    g, cluster, fogs, pl = loaded_cluster
    fogs[0].latency_model.load_factor = 3.0   # pretend fog0 overloaded
    new = scheduler.diffusion_adjust(g, pl.assignment, fogs, lam=1.2,
                                     max_migrations=64)
    moved = np.flatnonzero(new != pl.assignment)
    if moved.size:  # migration happened -> all moved away from overloaded 0
        assert (pl.assignment[moved] == 0).any()
