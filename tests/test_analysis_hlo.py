"""Unit tests for the HLO call-graph/trip-count analyzer on a canned
module — locking parse/multiplier/flops behavior now that the analyzer
lives in ``repro.analysis.hlo`` (with ``repro.launch.hlo_analysis`` as a
re-export shim)."""
import pytest

from repro.analysis import AnalysisContext, hlo, run_checks

# A hand-written post-optimization-style module: a while loop with
# known_trip_count=3 whose body does one 8x16 @ 16x16 dot, then an
# all-reduce of the result in ENTRY.
CANNED = """\
HloModule canned

%add.red (x: f32[], y: f32[]) -> f32[] {
  %x = f32[] parameter(0)
  %y = f32[] parameter(1)
  ROOT %add.1 = f32[] add(%x, %y)
}

%cond (state.c: (f32[8,16], s32[])) -> pred[] {
  %state.c = (f32[8,16], s32[]) parameter(0)
  %iter = s32[] get-tuple-element(%state.c), index=1
  %limit = s32[] constant(3)
  ROOT %lt = pred[] compare(%iter, %limit), direction=LT
}

%body (state.b: (f32[8,16], s32[])) -> (f32[8,16], s32[]) {
  %state.b = (f32[8,16], s32[]) parameter(0)
  %h = f32[8,16] get-tuple-element(%state.b), index=0
  %i = s32[] get-tuple-element(%state.b), index=1
  %w = f32[16,16] constant(0)
  %mm = f32[8,16] dot(%h, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %out = (f32[8,16], s32[]) tuple(%mm, %i2)
}

ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16] parameter(0)
  %zero = s32[] constant(0)
  %init = (f32[8,16], s32[]) tuple(%p0, %zero)
  %loop = (f32[8,16], s32[]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"3"}}
  %res = f32[8,16] get-tuple-element(%loop), index=0
  ROOT %ar = f32[8,16] all-reduce(%res), replica_groups={}, to_apply=%add.red
}
"""


def test_parse_module_structure():
    comps, shapes, entry = hlo.parse_module(CANNED)
    assert entry == "main"
    assert set(comps) == {"add.red", "cond", "body", "main"}
    assert shapes["mm"] == "f32[8,16]"
    assert shapes["w"] == "f32[16,16]"
    ops = {op.name: op for op in comps["main"].ops}
    assert ops["loop"].opcode == "while"
    assert ops["ar"].opcode == "all-reduce"


def test_call_edges_and_trip_count():
    comps, _, _ = hlo.parse_module(CANNED)
    loop = next(op for op in comps["main"].ops if op.opcode == "while")
    edges = dict(hlo._call_edges(loop))
    assert edges == {"cond": True, "body": True}
    assert hlo._trip_count(loop) == 3
    ar = next(op for op in comps["main"].ops if op.opcode == "all-reduce")
    assert dict(hlo._call_edges(ar)) == {"add.red": False}


def test_computation_multipliers_multiply_trip_counts():
    comps, _, entry = hlo.parse_module(CANNED)
    mult = hlo.computation_multipliers(comps, entry)
    assert mult["main"] == 1.0
    assert mult["body"] == 3.0      # while body runs known_trip_count times
    assert mult["cond"] == 3.0
    assert mult["add.red"] == 1.0   # to_apply is not a loop edge


def test_dot_flops_scaled_by_loop():
    cost = hlo.analyze(CANNED)
    # one 8x16 @ 16x16 dot: 2 * 128 results * 16 contracted = 4096 flops,
    # executed 3 times by the while loop.
    assert cost.unscaled_flops == pytest.approx(4096.0)
    assert cost.flops == pytest.approx(3 * 4096.0)
    assert cost.dot_count == 1


def test_collective_bytes_counted_once():
    cost = hlo.analyze(CANNED)
    # all-reduce of f32[8,16] in ENTRY (multiplier 1): 512 bytes.
    assert cost.collective_bytes["all-reduce"] == pytest.approx(512.0)
    assert cost.total_collective == pytest.approx(512.0)
    assert cost.bytes_accessed > 0


def test_shape_elems_bytes():
    elems, nbytes = hlo._shape_elems_bytes("(f32[8,16], s32[])")
    assert elems == 128 + 1
    assert nbytes == 512 + 4


def test_shim_reexports_same_objects():
    from repro.launch import hlo_analysis as shim
    assert shim.analyze is hlo.analyze
    assert shim.parse_module is hlo.parse_module
    assert shim.HloCost is hlo.HloCost
    assert shim.computation_multipliers is hlo.computation_multipliers


def test_hlo_check_clean_on_canned_module():
    report = run_checks(AnalysisContext(hlo=CANNED), families=("hlo",))
    assert report.ran == ("hlo.module.structure",)
    assert report.ok and not report.warnings, report.format()


def test_hlo_check_fires_on_garbage_and_truncation():
    report = run_checks(AnalysisContext(hlo="not an hlo module"),
                        families=("hlo",))
    assert any("no computations" in d.message
               for d in report.by_check("hlo.module.structure"))
    truncated = CANNED.replace("body=%body", "body=%missing.comp")
    report = run_checks(AnalysisContext(hlo=truncated), families=("hlo",))
    hits = report.by_check("hlo.module.structure")
    assert any(d.severity == "warning" and "missing" in d.message
               for d in hits)


def test_hlo_check_skipped_without_hlo_text():
    report = run_checks(AnalysisContext(plan=None), families=("hlo",))
    assert report.ran == ()
    assert "hlo.module.structure" in report.skipped


def test_analyze_real_lowered_module():
    """End-to-end on a real jitted scan: trip count multiplies the dot."""
    import jax
    import jax.numpy as jnp

    def stack(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=4)
        return h

    x = jnp.zeros((8, 16), jnp.float32)
    w = jnp.zeros((16, 16), jnp.float32)
    text = jax.jit(stack).lower(x, w).compile().as_text()
    cost = hlo.analyze(text)
    assert cost.dot_count >= 1
    # 4 iterations x 2*8*16*16 flops per dot.
    assert cost.flops >= 4 * 4096
