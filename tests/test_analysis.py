"""Mutation tests for the repro.analysis static verifier.

Each test corrupts one structure a real serving path depends on and
asserts the matching check fires with the right check-id — and the
verifier's silence on every healthy plan is asserted across the registry
grid.  Corruption happens on ``copy.deepcopy`` innards (Plan is frozen but
its array contents are mutable), so the shared healthy plans stay healthy.
"""
import copy

import jax
import numpy as np
import pytest

import repro.analysis as analysis
from repro.analysis import (AnalysisContext, PlanInvariantWarning,
                            PlanValidationError, cache_audit, run_checks,
                            verify_plan)
from repro.api.engine import Engine
from repro.gnn import datasets, models

pytestmark = pytest.mark.no_plan_invariants

PLAN_FAMILIES = ("plan", "kernel", "cache")


def _make_plan(executor="mesh-bsp", compressor="daq", aggregation="pallas",
               scale=0.03, seed=0):
    g = datasets.load("siot", scale=scale, seed=seed)
    params = models.gnn_init(jax.random.PRNGKey(seed), "gcn",
                             [g.feature_dim, 16, 8])
    eng = Engine((params, "gcn"), "1A+3B", executor=executor,
                 compressor=compressor, aggregation=aggregation)
    return eng, eng.compile(g)


@pytest.fixture(scope="module")
def mesh_plan():
    return _make_plan()[1]


@pytest.fixture()
def corrupt(mesh_plan):
    """A deep copy whose innards tests may freely mutate."""
    return copy.deepcopy(mesh_plan)


def _errors_of(plan_or_ctx, check_id, families=PLAN_FAMILIES):
    report = run_checks(plan_or_ctx, families=families)
    return report, report.by_check(check_id)


# ---------------------------------------------------------------- healthy


@pytest.mark.parametrize("executor,compressor,aggregation", [
    ("sim", "none", "auto"),
    ("single", "daq", "auto"),
    ("mesh-bsp", "daq", "pallas"),
    ("cloud", "uniform8", "auto"),
])
def test_silent_on_healthy_plans(executor, compressor, aggregation):
    _, plan = _make_plan(executor=executor, compressor=compressor,
                         aggregation=aggregation)
    report = run_checks(plan, families=PLAN_FAMILIES)
    assert report.ok, report.format()
    assert not report.warnings, report.format()
    # Every plan/kernel/cache check actually ran (none silently skipped).
    assert len(report.ran) >= 12


def test_healthy_plan_all_plan_checks_ran(mesh_plan):
    report = run_checks(mesh_plan, families=("plan",))
    want = {fn.check_id for fn in analysis.checks_for(("plan",))}
    assert set(report.ran) == want
    assert report.ok and not report.warnings, report.format()


# ----------------------------------------------------------- plan family


def test_corrupt_part_of_fires_coverage_and_update(corrupt):
    pg = corrupt.partitioned
    pg.part_of[0] = (pg.part_of[0] + 1) % pg.n
    report = run_checks(corrupt, families=("plan",))
    assert not report.ok
    fired = report.check_ids()
    assert "plan.update.consistency" in fired
    # Depending on the stolen slot's occupancy the move lands on a dead
    # slot (coverage) or on another vertex's slot (disjoint).
    assert fired & {"plan.partition.coverage", "plan.partition.disjoint"}


def test_duplicate_slot_fires_disjoint(corrupt):
    pg = corrupt.partitioned
    # Vertex 1 steals vertex 0's (partition, slot).
    pg.part_of[1] = pg.part_of[0]
    pg.slot_of[1] = pg.slot_of[0]
    _, hits = _errors_of(corrupt, "plan.partition.disjoint",
                         families=("plan",))
    assert hits and hits[0].severity == "error"


def test_nonbinary_mask_fires_layout_masks(corrupt):
    corrupt.partitioned.vertex_mask[0, 0] = 0.5
    _, hits = _errors_of(corrupt, "plan.layout.masks", families=("plan",))
    assert hits


def test_nonzero_padded_feature_row_fires_layout_masks(corrupt):
    pg = corrupt.partitioned
    dead = np.argwhere(pg.vertex_mask == 0.0)
    if len(dead) == 0:
        pytest.skip("layout has no padded slots at this scale")
    p, s = dead[0]
    pg.feats[p, s, 0] = 7.0
    _, hits = _errors_of(corrupt, "plan.layout.masks", families=("plan",))
    assert any("padded feature rows" in d.message for d in hits)


def test_dropped_halo_row_fires_halo_consistency(corrupt):
    pg = corrupt.partitioned
    p = int(np.argmax(pg.boundary_mask.sum(axis=1)))
    assert pg.boundary_mask[p].sum() > 0, "no boundary rows at this scale"
    # Drop the partition's first exported halo row from the exchange map.
    pg.boundary_mask[p, 0] = 0.0
    _, hits = _errors_of(corrupt, "plan.halo.consistency",
                         families=("plan",))
    assert hits and f"[{p}]" in hits[0].subject


def test_zeroed_halo_tile_fires_halo_consistency(corrupt):
    csr = corrupt.partitioned.halo_csr
    live = np.argwhere(np.asarray(csr.mask) == 1.0)
    assert len(live), "halo shards empty at this scale"
    p, i, k = live[0]
    csr.mask[p, i, k] = 0.0
    csr.blocks[p, i, k] = 0.0
    csr.cols[p, i, k] = 0
    report, hits = _errors_of(corrupt, "plan.halo.consistency",
                              families=("plan",))
    assert any("missing" in d.message for d in hits), report.format()


def test_nonzero_padding_tile_fires_blocks_ell(corrupt):
    csr = corrupt.partitioned.local_csr
    pad = np.argwhere(np.asarray(csr.mask) == 0.0)
    if len(pad) == 0:
        pytest.skip("local shards have no ELL padding at this scale")
    p, i, k = pad[0]
    csr.blocks[p, i, k, 0, 0] = 1.0
    _, hits = _errors_of(corrupt, "plan.blocks.ell", families=("plan",))
    assert any("padding tiles carry" in d.message for d in hits)


def test_skewed_estimates_fire_capacity_warning(corrupt):
    pl = corrupt.placement
    pl.est_exec[0] = 1000.0 * (pl.est_total.mean() + 1e-6)
    report = run_checks(corrupt, families=("plan",))
    hits = report.by_check("plan.capacity.imbalance")
    assert hits and hits[0].severity == "warning"


def test_stale_frozen_features_fire_update_consistency(corrupt):
    pg = corrupt.partitioned
    p, s = int(pg.part_of[0]), int(pg.slot_of[0])
    pg.feats[p, s] += 1.0
    _, hits = _errors_of(corrupt, "plan.update.consistency",
                         families=("plan",))
    assert any("frozen feature rows" in d.message for d in hits)


def test_unknown_registry_key_fires_config_keys(corrupt):
    object.__setattr__(corrupt.config, "compressor", "definitely-not-real")
    _, hits = _errors_of(corrupt, "plan.config.keys", families=("plan",))
    assert hits and "compressor" in hits[0].message


# --------------------------------------------------------- kernel family


def test_perturbed_block_cols_fire_prefetch_bounds(corrupt):
    csr = corrupt.partitioned.halo_csr
    live = np.argwhere(np.asarray(csr.mask) == 1.0)
    assert len(live), "halo shards empty at this scale"
    p, i, k = live[0]
    block = csr.blocks.shape[-1]
    csr.cols[p, i, k] = csr.src_rows // block + 3   # past the source table
    report = run_checks(corrupt, families=("kernel",))
    hits = report.by_check("kernel.prefetch.bounds")
    assert hits and "bounds check" in hits[0].message


def test_widened_wire_dtype_fires_wire_dtype(mesh_plan, monkeypatch):
    import jax.numpy as jnp

    from repro.runtime import bsp

    def float_wire(x):   # regression: ship f32 "codes" on the DAQ wire
        return (x.astype(jnp.float32),
                jnp.zeros((x.shape[0],), jnp.float32),
                jnp.zeros((x.shape[0],), jnp.float32))

    monkeypatch.setattr(bsp, "_wire_quantize", float_wire)
    report = run_checks(mesh_plan, families=("kernel",))
    hits = report.by_check("kernel.wire.dtype")
    assert any("codes" in d.message for d in hits)
    assert any("wire format" in d.message for d in hits)


def test_wire_dtype_silent_on_healthy(mesh_plan):
    report = run_checks(mesh_plan, families=("kernel",))
    assert not report.by_check("kernel.wire.dtype")


def test_inflated_src_rows_fire_vmem_budget(corrupt):
    csr = corrupt.partitioned.halo_csr
    block = csr.blocks.shape[-1]
    object.__setattr__(csr, "src_rows", block * 40000)  # ~20 MiB f32 panel
    report = run_checks(corrupt, families=("kernel",))
    hits = report.by_check("kernel.vmem.budget")
    assert hits and hits[0].severity == "warning"
    assert "VMEM" in hits[0].message


def test_grid_divisibility_fires_on_ragged_src_rows(corrupt):
    csr = corrupt.partitioned.local_csr
    object.__setattr__(csr, "src_rows", csr.src_rows + 1)
    report = run_checks(corrupt, families=("kernel",))
    assert report.by_check("kernel.grid.divisibility")


# ---------------------------------------------------------- cache family


def _ctx(plan=None, program_cache=None, block_csr_cache=None):
    return AnalysisContext(plan=plan,
                           program_cache=program_cache or {},
                           block_csr_cache=block_csr_cache or {})


def test_stripped_program_key_fires_key_fields():
    # A key missing its trailing fields (as if a knob were dropped).
    ctx = _ctx(program_cache={("mesh", "gcn", "fog"): lambda: None})
    report = run_checks(ctx, families=("cache",))
    hits = report.by_check("cache.program.key_fields")
    assert any("collide" in d.message for d in hits)


def test_mistyped_program_key_fires_key_fields():
    key = ("mesh", "gcn", "fog", "halo", 1, False, False, (), ())  # int, not bool
    ctx = _ctx(program_cache={key: lambda: None})
    report = run_checks(ctx, families=("cache",))
    hits = report.by_check("cache.program.key_fields")
    assert any("use_kernels" in d.message for d in hits)


def test_unclassified_knob_fires_key_fields(monkeypatch):
    monkeypatch.delitem(cache_audit.KNOB_COVERAGE, "aggregation")
    report = run_checks(_ctx(), families=("cache",))
    hits = report.by_check("cache.program.key_fields")
    assert any("EngineConfig.aggregation" in d.subject for d in hits)


def test_malformed_blockcsr_key_fires_key_fields():
    ctx = _ctx(block_csr_cache={("deadbeef", None, 128): object(),
                                ("x" * 32, "median", 128): object()})
    report = run_checks(ctx, families=("cache",))
    hits = report.by_check("cache.blockcsr.key_fields")
    assert any("digest" in d.message for d in hits)
    assert any("normalization" in d.message for d in hits)


def test_closure_pin_fires():
    big = np.zeros(4096, np.float32)

    def make_leaky():
        pinned = big

        def program(x):
            return pinned

        return program

    ctx = _ctx(program_cache={("k",): make_leaky()})
    report = run_checks(ctx, families=("cache",))
    hits = report.by_check("cache.program.closure_pins")
    assert any("pinned" in d.message for d in hits)


def test_live_caches_are_clean_after_serving():
    # Exercise the real single-program BlockCsr cache, then audit the
    # live process-wide caches (the mesh program cache needs a 4-device
    # subprocess; its live audit runs inside test_bsp's mesh workers).
    _, plan = _make_plan(executor="single", aggregation="pallas")
    plan.session().query()
    from repro.kernels import ops
    assert len(ops._BLOCK_CSR_CACHE) > 0
    report = run_checks(AnalysisContext(), families=("cache",))
    assert report.ok, report.format()


# ------------------------------------------------- verify_plan + Engine


def test_verify_plan_strict_raises(corrupt):
    corrupt.partitioned.part_of[0] = (corrupt.partitioned.part_of[0] + 1
                                      ) % corrupt.partitioned.n
    with pytest.raises(PlanValidationError) as ei:
        verify_plan(corrupt, mode="strict")
    assert "plan." in str(ei.value)
    assert ei.value.report.errors


def test_verify_plan_warn_warns(corrupt):
    corrupt.partitioned.part_of[0] = (corrupt.partitioned.part_of[0] + 1
                                      ) % corrupt.partitioned.n
    with pytest.warns(PlanInvariantWarning):
        verify_plan(corrupt, mode="warn")


def test_verify_plan_off_is_noop(corrupt):
    corrupt.partitioned.part_of[0] = (corrupt.partitioned.part_of[0] + 1
                                      ) % corrupt.partitioned.n
    report = verify_plan(corrupt, mode="off")
    assert report.diagnostics == []


def test_verify_plan_rejects_unknown_mode(mesh_plan):
    with pytest.raises(ValueError, match="validate mode"):
        verify_plan(mesh_plan, mode="loud")


def test_engine_validate_strict_passes_healthy_plan():
    g = datasets.load("siot", scale=0.03, seed=2)
    params = models.gnn_init(jax.random.PRNGKey(2), "gcn",
                             [g.feature_dim, 16, 8])
    eng = Engine((params, "gcn"), "1A+3B", executor="mesh-bsp",
                 aggregation="pallas", validate="strict")
    plan = eng.compile(g)
    assert plan.config.validate == "strict"
    assert Engine.from_plan(plan).config.validate == "strict"


def test_engine_validate_strict_covers_apply_delta():
    from repro.api.updates import GraphDelta
    g = datasets.load("siot", scale=0.03, seed=3)
    params = models.gnn_init(jax.random.PRNGKey(3), "gcn",
                             [g.feature_dim, 16, 8])
    eng = Engine((params, "gcn"), "1A+3B", executor="mesh-bsp",
                 aggregation="pallas", validate="strict")
    plan = eng.compile(g)
    v = g.num_vertices
    delta = GraphDelta(add_features=np.ones((1, g.feature_dim), np.float32),
                       add_edges=[(v, 0)])
    updated = eng.apply_delta(plan, delta, force="incremental")
    assert updated.provenance == "incremental"


def test_engine_rejects_unknown_validate():
    g = datasets.load("siot", scale=0.03, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    with pytest.raises(ValueError, match="validate"):
        Engine((params, "gcn"), "1A+3B", validate="shout")


def test_run_checks_reports_crashing_check(mesh_plan, monkeypatch):
    from repro.analysis import CHECKS

    def boom(ctx):
        raise RuntimeError("verifier bug")

    boom.check_id = "plan.partition.coverage"
    boom.family, boom.layer, boom.requires = "plan", "plan", ("plan",)
    monkeypatch.setitem(CHECKS._entries, "plan.partition.coverage", boom)
    report = run_checks(mesh_plan, families=("plan",),
                        checks=["plan.partition.coverage"])
    hits = report.by_check("plan.partition.coverage")
    assert any("check crashed" in d.message for d in hits)


def test_cli_list_and_catalogue():
    from repro.analysis.cli import main
    assert main(["--list"]) == 0


# --------------------------------------- shipped-stack regression probes


def test_empty_trailing_shard_update_passes_checks():
    """PR 4's ``n=`` path: a delta that empties the trailing partition
    must still produce a layout the verifier accepts (the empty shard
    keeps its slot geometry, exports no halo rows, and its block-CSR
    tiles are all padding)."""
    from repro.api.updates import GraphDelta
    eng, plan = _make_plan(seed=4)
    last = plan.partitioned.n - 1
    victims = np.flatnonzero(plan.placement.assignment == last)
    updated = eng.apply_delta(plan, GraphDelta(remove_vertices=victims),
                              force="incremental")
    assert updated.partitioned.n == plan.partitioned.n   # n pinned
    assert np.bincount(updated.partitioned.part_of,
                       minlength=updated.partitioned.n)[last] == 0
    report = run_checks(updated, families=("plan", "kernel"))
    assert report.ok and not report.warnings, report.format()


def test_slo_rung_sessions_rebased_after_structural_update():
    """SLO ladder rungs cache Sessions keyed on the base plan's identity;
    after a structural update rebases the base session, every rung must
    serve the new layout — and every rung plan must pass the verifier."""
    from repro.api.updates import GraphDelta
    g = datasets.load("siot", scale=0.03, seed=5)
    params = models.gnn_init(jax.random.PRNGKey(5), "gcn",
                             [g.feature_dim, 16, 8])
    plan = Engine((params, "gcn"), "1A+3B", executor="sim",
                  compressor="daq").compile(g)
    server = plan.server(slo=True)
    for lvl in range(len(server.ladder) + 1):
        server._session_for(lvl)          # build every rung pre-update
    old_partitioned = server.session.plan.partitioned
    v = g.num_vertices
    server.submit(GraphDelta(
        add_features=np.ones((2, g.feature_dim), np.float32),
        add_edges=[(v, 0), (v + 1, 1)],
        remove_edges=[(int(g.senders[0]), int(g.receivers[0]))]))
    (ack,) = server.drain()
    assert ack.applied
    for lvl in range(len(server.ladder) + 1):
        rung_plan = server._session_for(lvl).plan
        assert rung_plan.partitioned is not old_partitioned
        assert rung_plan.graph.num_vertices == v + 2
        report = run_checks(rung_plan, families=("plan",))
        assert report.ok and not report.warnings, report.format()


# ------------------------------------------------------- frontier family


def _frontier_ctx():
    """A session with a pending dirty frontier + its analysis context."""
    from repro.api.updates import GraphDelta
    from repro.gnn.graph import from_edge_list
    rng = np.random.default_rng(11)
    v = 40
    g = from_edge_list(v, np.array([(i, i + 1) for i in range(v - 1)],
                                   np.int64),
                       rng.normal(size=(v, 4)).astype(np.float32))
    params = models.gnn_init(jax.random.PRNGKey(11), "gcn",
                             [g.feature_dim, 8, 4])
    plan = Engine((params, "gcn"), "1A+2B", executor="sim",
                  aggregation="segment_sum").compile(g)
    sess = plan.session(activation_cache=True, frontier_max_fraction=1.0)
    sess.query()
    sess.update(GraphDelta(feature_ids=[3], feature_values=np.ones(
        (1, g.feature_dim), np.float32)))
    fp = sess.frontier_state()
    assert fp is not None
    return sess, AnalysisContext(plan=sess.plan, frontier=fp)


def test_frontier_checks_silent_on_healthy_pending_delta():
    _, ctx = _frontier_ctx()
    report = run_checks(ctx, families=("frontier",))
    assert report.ok and not report.warnings, report.format()
    assert set(report.ran) == {"plan.frontier.closure",
                               "plan.frontier.revision"}


def test_frontier_checks_skip_without_frontier(mesh_plan):
    # frontier-less contexts must skip (requires=) rather than crash
    report = run_checks(AnalysisContext(plan=mesh_plan),
                        families=("frontier",))
    assert report.ok and not report.ran


def test_truncated_rows_fire_frontier_closure():
    import dataclasses
    sess, ctx = _frontier_ctx()
    fp = ctx.frontier
    bad = dataclasses.replace(fp, rows=fp.rows[:-1])
    report, diags = _errors_of(
        AnalysisContext(plan=sess.plan, frontier=bad),
        "plan.frontier.closure", families=("frontier",))
    assert not report.ok
    assert any(d.severity == "error" for d in diags), report.format()


def test_undercovered_rows_fire_frontier_closure():
    import dataclasses
    sess, ctx = _frontier_ctx()
    fp = ctx.frontier
    # drop a dirty vertex from the last layer: closure under-coverage
    assert len(fp.rows[-1]) > 1
    bad = dataclasses.replace(fp, rows=fp.rows[:-1] + [fp.rows[-1][:-1]])
    report, diags = _errors_of(
        AnalysisContext(plan=sess.plan, frontier=bad),
        "plan.frontier.closure", families=("frontier",))
    assert not report.ok
    assert any(d.severity == "error" for d in diags), report.format()


def test_out_of_range_seed_fires_frontier_closure():
    import dataclasses
    sess, ctx = _frontier_ctx()
    fp = ctx.frontier
    bad = dataclasses.replace(
        fp, seeds=np.concatenate([fp.seeds, [fp.num_vertices + 5]]))
    report, diags = _errors_of(
        AnalysisContext(plan=sess.plan, frontier=bad),
        "plan.frontier.closure", families=("frontier",))
    assert not report.ok
    assert any(d.severity == "error" for d in diags), report.format()


def test_stale_revision_fires_frontier_revision():
    import dataclasses
    sess, ctx = _frontier_ctx()
    bad = dataclasses.replace(ctx.frontier, revision="deadbeef")
    report, diags = _errors_of(
        AnalysisContext(plan=sess.plan, frontier=bad),
        "plan.frontier.revision", families=("frontier",))
    assert not report.ok
    assert any(d.severity == "error" for d in diags), report.format()


def test_vertex_count_mismatch_fires_frontier_revision():
    import dataclasses
    sess, ctx = _frontier_ctx()
    bad = dataclasses.replace(ctx.frontier,
                              num_vertices=ctx.frontier.num_vertices + 1)
    report, diags = _errors_of(
        AnalysisContext(plan=sess.plan, frontier=bad),
        "plan.frontier.revision", families=("frontier",))
    assert not report.ok
    assert any(d.severity == "error" for d in diags), report.format()
