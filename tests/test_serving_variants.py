"""§Perf serving variants: int8 KV cache correctness + sharding variants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import sharding as shd
from repro.models import transformer as tf
from repro.models.attention import QuantKVCache, _dequantize_heads, \
    _quantize_heads
from repro.models.config import ArchConfig


@pytest.fixture(scope="module")
def dense_cfg():
    return ArchConfig(name="t", family="dense", source="t", num_layers=2,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=512, head_dim=32,
                      activation_dtype="float32")


def test_quantize_heads_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, 4, 16)) * 3, jnp.float32)
    q, s = _quantize_heads(x)
    assert q.dtype == jnp.int8
    rec = _dequantize_heads(q, s, jnp.float32)
    # per-head max error <= scale = amax/127
    err = jnp.abs(rec - x).max(axis=-1)
    bound = jnp.abs(x).max(axis=-1) / 127.0 * 1.01 + 1e-7
    assert bool((err <= bound).all())


def test_quant_cache_decode_close_to_full(dense_cfg):
    cfg = dense_cfg
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 512, (2, 24)),
                       jnp.int32)
    logits, _ = tf.forward(params, cfg, toks)
    caches = tf.init_cache(cfg, 2, 24, quantized=True)
    assert isinstance(jax.tree_util.tree_leaves(caches)[0], jnp.ndarray)
    outs = []
    for t in range(8):
        lg, caches = tf.decode_step(params, cfg, caches, toks[:, t:t + 1],
                                    jnp.asarray(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    rel = float(jnp.abs(dec - logits[:, :8]).max()
                / jnp.abs(logits[:, :8]).max())
    assert rel < 0.05, rel


def test_serve_attn_dh_rule_only_for_indivisible_kv():
    shd._FSDP_SIZE.update({"data": 16, "model": 16})
    cfg = registry.get("deepseek-67b")        # kv=8, indivisible by 16
    path = (jax.tree_util.DictKey("stages"), jax.tree_util.SequenceKey(0),
            jax.tree_util.DictKey("mixer"), jax.tree_util.DictKey("wk"))
    base = shd._spec_for_param(path, (95, 8192, 8, 128), cfg, 16)
    opt = shd._spec_for_param(path, (95, 8192, 8, 128), cfg, 16,
                              serve_attn_dh=True)
    assert "model" not in base                 # kv heads indivisible
    assert opt[-1] == "model"                  # head_dim sharded instead
    cfg2 = registry.get("qwen1.5-0.5b")        # kv=16, divisible
    opt2 = shd._spec_for_param(path, (24, 1024, 16, 64), cfg2, 16,
                               serve_attn_dh=True)
    assert opt2[-2] == "model"                 # unchanged: heads sharded


def test_expert_grid_spec():
    shd._FSDP_SIZE.update({"data": 16, "model": 16})
    cfg = registry.get("deepseek-v3-671b")
    path = (jax.tree_util.DictKey("stages"), jax.tree_util.SequenceKey(1),
            jax.tree_util.DictKey("ffn"), jax.tree_util.DictKey("w_gate"))
    spec = shd._spec_for_param(path, (58, 256, 7168, 2048), cfg, 16,
                               expert_grid=True)
    assert spec[1] == ("data", "model")
    base = shd._spec_for_param(path, (58, 256, 7168, 2048), cfg, 16)
    assert base[1] == "model"


def test_constrain_batch_noop_without_mesh(dense_cfg):
    shd.enable_activation_constraints(None)
    x = jnp.ones((4, 8, 16))
    assert shd.constrain_batch(x) is x
