"""Per-architecture smoke tests (harness mandate): REDUCED variant of each
assigned architecture family (<=2 layers / one hybrid period, d_model<=256,
<=4 experts) runs one forward + one train step on CPU; shapes + finiteness
asserted. Full configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as tf
from repro.models.config import InputShape
from repro.optim.adamw import AdamW

ARCHS = registry.list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_structure(arch):
    cfg = registry.get(arch)
    specs = cfg.layer_specs()
    assert len(specs) == cfg.num_layers
    assert sum(r * len(g) for g, r in cfg.stages()) == cfg.num_layers
    assert cfg.param_count() > 0
    assert cfg.active_param_count() <= cfg.param_count()


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_forward_and_train(arch):
    cfg = registry.reduced(registry.get(arch))
    shape = InputShape("smoke", seq_len=32, global_batch=2, kind="train")
    batch = SyntheticCorpus(cfg, shape, seed=0).batch(0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    params = tf.init_params(cfg, jax.random.PRNGKey(0))

    logits, aux = tf.forward(params, cfg, batch["inputs"])
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    opt = AdamW(learning_rate=1e-3)
    step = tf.make_train_step(cfg, opt, microbatches=1)
    params2, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[3]
    l1 = jax.tree_util.tree_leaves(params2)[3]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_smoke_decode(arch):
    cfg = registry.reduced(registry.get(arch))
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    caches = tf.init_cache(cfg, b, s)
    # decode is dropless; compare against a dropless forward for MoE archs
    cf = (cfg.num_experts / cfg.experts_per_token) if cfg.num_experts else None
    logits, _ = tf.forward(params, cfg, toks, capacity_factor=cf)
    outs = []
    for t in range(6):
        lg, caches = tf.decode_step(params, cfg, caches, toks[:, t:t + 1],
                                    jnp.asarray(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1).astype(jnp.float32)
    assert dec.shape == (b, 6, cfg.vocab_size)
    assert bool(jnp.isfinite(dec).all())
    err = float(jnp.abs(dec - logits[:, :6].astype(jnp.float32)).max())
    assert err < 5e-3, err  # reduced cfgs run f32 -> decode == forward


@pytest.mark.parametrize("arch", ["deepseek-67b", "recurrentgemma-9b",
                                  "falcon-mamba-7b"])
def test_reduced_windowed_decode(arch):
    """Sliding-window serve variant (long_500k path) decodes finitely and
    matches full attention while pos < window."""
    cfg = registry.reduced(registry.get(arch))
    window = cfg.sliding_window or 0
    params = tf.init_params(cfg, jax.random.PRNGKey(2))
    b = 2
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 24)), jnp.int32)
    logits, _ = tf.forward(params, cfg, toks)
    caches = tf.init_cache(cfg, b, 24, window=window)
    outs = []
    for t in range(10):
        lg, caches = tf.decode_step(params, cfg, caches, toks[:, t:t + 1],
                                    jnp.asarray(t), window=window)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1).astype(jnp.float32)
    assert bool(jnp.isfinite(dec).all())
    if window == 0 or window >= 10:
        err = float(jnp.abs(dec - logits[:, :10].astype(jnp.float32)).max())
        assert err < 5e-3, err


def test_vlm_embeddings_input():
    cfg = registry.reduced(registry.get("internvl2-26b"))
    assert cfg.input_mode == "embeddings"
    shape = InputShape("smoke", seq_len=16, global_batch=2, kind="train")
    batch = SyntheticCorpus(cfg, shape, seed=0).batch(0)
    assert batch["inputs"].shape == (2, 16, cfg.d_model)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    logits, _ = tf.forward(params, cfg, jnp.asarray(batch["inputs"]))
    assert logits.shape == (2, 16, cfg.vocab_size)


def test_mtp_loss_included_for_dsv3():
    cfg = registry.reduced(registry.get("deepseek-v3-671b"))
    assert cfg.mtp_depth == 1
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    assert "mtp" in params
    rng = np.random.default_rng(0)
    batch = {"inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                   jnp.int32),
             "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                                    jnp.int32)}
    full = float(tf.loss_fn(params, cfg, batch, remat=False))
    no_mtp = {k: v for k, v in params.items() if k != "mtp"}
    base = float(tf.loss_fn(no_mtp, cfg, batch, remat=False))
    assert full > base  # MTP adds a positive CE term
