"""SLO control plane: admission, degradation ladder, priorities, adaptive B.

The load-bearing guarantees:
  * the accept path of an SLO server is bit-identical to the plain
    admit-all server (the control plane prices, it never perturbs);
  * a degraded response is bit-identical to a Session configured with the
    same rung's knobs directly (the ladder is views, not approximations);
  * hopeless requests become Rejections (or late responses when
    ``reject_hopeless=False``) — never silent drops;
  * priority reordering never crosses a graph update (mutation visibility
    stays FIFO-consistent);
  * the adaptive batch controller converges to the efficiency-optimal
    batch size on a known curve and respects deadline slack.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import Engine, GraphDelta, Request, Server, UpdateRequest, slo
from repro.api import traces
from repro.api.server import Response, UpdateResponse
from repro.api.session import Session
from repro.api.slo import (AdaptiveBatchController, DegradationLevel,
                           Rejection, SLOPolicy)
from repro.core import simulation
from repro.gnn import datasets, models


@pytest.fixture(scope="module")
def setup():
    g = datasets.load("siot", scale=0.06, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 32, 8])
    plan = Engine((params, "gcn"), cluster="1A+2B+1C",
                  compressor="daq").compile(g)
    return g, params, plan


def _svc(plan, **knobs):
    """Level service time for one request on the sim executor."""
    return plan.session(**knobs).account("sim").total_latency


# ----------------------------------------------------------------------------
# Ladder construction
# ----------------------------------------------------------------------------

def test_default_ladder_for_daq_plan(setup):
    g, params, plan = setup
    ladder = slo.default_ladder(plan.session())
    # auto-aggregation resolves to segment_sum off-TPU: no pallas rung;
    # daq plan: uniform8 rung; then layer truncation down to 1.
    assert [r.name for r in ladder] == ["uniform8", "layers1"]
    assert ladder[0].compressor == "uniform8"
    assert ladder[1].knobs() == {"compressor": "uniform8", "num_layers": 1}


def test_default_ladder_strict_pallas_gets_segment_sum_rung(setup):
    g, params, plan = setup
    ladder = slo.default_ladder(plan.session(aggregation="pallas"))
    assert ladder[0].name == "segment_sum"
    assert ladder[0].aggregation == "segment_sum"
    # cumulative: later rungs keep the aggregation fallback
    assert all(r.aggregation == "segment_sum" for r in ladder)


# ----------------------------------------------------------------------------
# Admission: accept / degrade / reject
# ----------------------------------------------------------------------------

def test_accept_path_bit_identical_to_plain_server(setup):
    g, params, plan = setup
    trace = traces.poisson(10, rate=50.0, seed=1, deadline=1e3)
    plain = plan.server(max_batch=4, max_wait=0.05).replay(trace)
    gated = plan.server(max_batch=4, max_wait=0.05, slo=True).replay(trace)
    assert len(plain) == len(gated)
    for a, b in zip(plain, gated):
        assert np.array_equal(a.embeddings, b.embeddings)
        assert a.batch_size == b.batch_size
        assert a.latency == pytest.approx(b.latency)
        assert b.degradation == 0 and b.deadline_met is True


def test_degraded_response_bit_identical_to_configured_session(setup):
    g, params, plan = setup
    s_levels = [_svc(plan),
                _svc(plan, compressor="uniform8"),
                _svc(plan, compressor="uniform8", num_layers=1)]
    assert s_levels[2] < min(s_levels[:2])   # the layer rung is the lever
    deadline = (s_levels[2] + min(s_levels[:2])) / 2.0
    server = plan.server(max_batch=1, slo=True)
    [resp] = server.replay([Request(arrival_time=0.0, deadline=deadline)])
    assert isinstance(resp, Response)
    assert resp.degradation == 2          # smallest rung that fits
    assert resp.deadline_met is True
    assert resp.latency <= deadline + 1e-9
    direct = plan.session(compressor="uniform8", num_layers=1).query()
    assert np.array_equal(resp.embeddings, direct.embeddings)
    assert resp.latency == pytest.approx(direct.latency)


def test_hopeless_request_rejected(setup):
    g, params, plan = setup
    best = _svc(plan, compressor="uniform8", num_layers=1)
    server = plan.server(max_batch=1, slo=True)
    [rej] = server.replay([Request(arrival_time=0.0, deadline=best / 10)])
    assert isinstance(rej, Rejection)
    assert rej.kind == "query" and rej.reason == "deadline"
    assert rej.estimated_latency > rej.deadline


def test_reject_hopeless_false_serves_late_at_last_rung(setup):
    g, params, plan = setup
    best = _svc(plan, compressor="uniform8", num_layers=1)
    policy = SLOPolicy(reject_hopeless=False)
    server = plan.server(max_batch=1, slo=policy)
    [resp] = server.replay([Request(arrival_time=0.0, deadline=best / 10)])
    assert isinstance(resp, Response)
    assert resp.deadline_met is False
    assert resp.degradation == len(server.ladder)


def test_rejection_rescues_batch_neighbours(setup):
    g, params, plan = setup
    s1 = _svc(plan)   # one-request service at the native rung
    # Two simultaneous arrivals: one impossible, one with room for a
    # b=1 native serve but not for b=2. Rejecting the hopeless member
    # must rescue the other at degradation 0.
    trace = [Request(arrival_time=0.0, deadline=1e-6),
             Request(arrival_time=0.0, deadline=s1 * 1.5)]
    out = plan.server(max_batch=2, max_wait=1e9, slo=True).replay(trace)
    kinds = {type(r) for r in out}
    assert kinds == {Rejection, Response}
    resp = next(r for r in out if isinstance(r, Response))
    assert resp.batch_size == 1 and resp.deadline_met is True


def test_best_effort_requests_never_rejected_or_degraded(setup):
    g, params, plan = setup
    out = plan.server(max_batch=4, slo=True).replay(
        traces.poisson(8, rate=100.0, seed=3))   # no deadlines: overload ok
    assert all(isinstance(r, Response) for r in out)
    assert all(r.degradation == 0 and r.deadline_met is None for r in out)


# ----------------------------------------------------------------------------
# Priority ordering
# ----------------------------------------------------------------------------

def test_priority_classes_served_high_first(setup):
    g, params, plan = setup
    prios = [0, 3, 1, 3, 0]
    trace = [Request(arrival_time=0.0, priority=p) for p in prios]
    out = plan.server(max_batch=1, slo=True).replay(trace)
    assert [r.request_id for r in out] == [1, 3, 2, 0, 4]
    starts = [r.service_start for r in out]
    assert starts == sorted(starts)


def test_priority_never_crosses_update_boundary(setup):
    g, params, plan = setup
    delta = GraphDelta(feature_ids=[0], feature_values=g.features[:1] * 2.0)
    server = plan.server(max_batch=1, slo=True)
    server.submit(Request(arrival_time=0.0, priority=0))
    server.submit(Request(arrival_time=0.0, priority=9))
    server.submit(UpdateRequest(delta=delta, arrival_time=0.5))
    server.submit(Request(arrival_time=0.6, priority=0))
    server.submit(Request(arrival_time=0.6, priority=9))
    out = server.drain()
    # Simultaneous arrivals reorder [0, 9] -> [9, 0] within each segment;
    # the update keeps its arrival position between them.
    assert [type(r).__name__ for r in out] == [
        "Response", "Response", "UpdateResponse", "Response", "Response"]
    assert [r.priority for r in out if isinstance(r, Response)] == [9, 0,
                                                                    9, 0]


def test_backlogged_update_not_preempted_by_priority(setup):
    g, params, plan = setup
    delta = GraphDelta(feature_ids=[0], feature_values=g.features[:1] * 2.0)
    server = plan.server(max_batch=1, slo=True)
    # The first query occupies the pipeline past both later arrivals, so
    # by the time the update is schedulable the high-priority query is
    # queued too — it still must not jump the update barrier.
    server.submit(Request(arrival_time=0.0, priority=0))
    server.submit(UpdateRequest(delta=delta, arrival_time=0.01))
    server.submit(Request(arrival_time=0.02, priority=9))
    out = server.drain()
    assert [type(r).__name__ for r in out] == [
        "Response", "UpdateResponse", "Response"]


def test_future_arrival_does_not_starve_queued_work(setup):
    g, params, plan = setup
    s1 = _svc(plan)
    server = plan.server(max_batch=1, slo=True)
    # A low-priority request queued now beats a high-priority request
    # that only arrives later: priority is not a time machine.
    server.submit(Request(arrival_time=0.0, priority=0))
    server.submit(Request(arrival_time=10 * s1, priority=9))
    out = server.drain()
    assert [r.priority for r in out] == [0, 9]
    assert out[0].service_start < 10 * s1


# ----------------------------------------------------------------------------
# Priced updates
# ----------------------------------------------------------------------------

def test_update_is_priced_on_the_serving_clock(setup):
    g, params, plan = setup
    delta = GraphDelta(feature_ids=[0], feature_values=g.features[:1] * 2.0)
    server = plan.server(max_batch=1, slo=True)
    server.submit(UpdateRequest(delta=delta, arrival_time=0.0))
    server.submit(Request(arrival_time=0.0))
    upd, resp = server.drain()
    assert isinstance(upd, UpdateResponse) and upd.applied
    assert upd.service_time >= simulation.UPDATE_BASE_S
    assert upd.finish_time == pytest.approx(upd.service_time)
    # The repair occupied the pipeline: the query finishes after it.
    assert resp.finish_time > upd.finish_time


def test_update_free_without_control_plane(setup):
    g, params, plan = setup
    delta = GraphDelta(feature_ids=[0], feature_values=g.features[:1] * 2.0)
    server = plan.server(max_batch=1)
    [upd] = server.replay([UpdateRequest(delta=delta, arrival_time=0.0)])
    assert upd.service_time == 0.0 and upd.finish_time == 0.0


def test_hopeless_update_rejected_without_mutating_graph(setup):
    g, params, plan = setup
    v = plan.graph.num_vertices
    delta = GraphDelta(add_features=np.zeros((1, g.feature_dim), np.float32),
                       add_edges=[[v, 0]])
    server = plan.server(max_batch=1, slo=True)
    baseline = plan.session().query().embeddings
    [rej] = server.replay([UpdateRequest(delta=delta, arrival_time=0.0,
                                         deadline=1e-6)])
    assert isinstance(rej, Rejection) and rej.kind == "update"
    assert server.session.plan.graph.num_vertices == v
    [resp] = server.replay([Request(arrival_time=0.0)])
    assert np.array_equal(resp.embeddings, baseline)


# ----------------------------------------------------------------------------
# Deadline-aware batch close (active even without a policy)
# ----------------------------------------------------------------------------

def test_deadline_closes_open_batch_early(setup):
    g, params, plan = setup
    s1, s2 = (plan.session().account("sim", batch_size=b).total_latency
              for b in (1, 2))
    deadline = (s1 + s2) / 2.0   # fits alone, not as a pair
    trace = [Request(arrival_time=0.0, deadline=deadline),
             Request(arrival_time=0.0)]
    out = plan.server(max_batch=8, max_wait=1e9).replay(trace)
    assert out[0].batch_size == 1 and out[0].deadline_met is True
    # Control: without the deadline the same trace coalesces.
    out2 = plan.server(max_batch=8, max_wait=1e9).replay(
        [Request(arrival_time=0.0), Request(arrival_time=0.0)])
    assert out2[0].batch_size == 2


# ----------------------------------------------------------------------------
# Adaptive batch controller
# ----------------------------------------------------------------------------

def _quad(b, a=0.09, c=0.01):
    return a + c * b * b   # efficiency b/s(b) peaks at b = sqrt(a/c) = 3


def test_controller_converges_to_efficiency_optimum():
    ctl = AdaptiveBatchController(max_batch=8)
    assert ctl.pick(8) == 8   # cold: optimistic full backlog
    for _ in range(3):
        for b in range(1, 9):
            ctl.observe(b, _quad(b))
    assert ctl.pick(8) == 3
    assert ctl.pick(2) == 2   # backlog-capped
    assert ctl.estimate(5) == pytest.approx(_quad(5), rel=1e-6)


def test_controller_respects_deadline_slack():
    ctl = AdaptiveBatchController(max_batch=8)
    for b in range(1, 9):
        ctl.observe(b, _quad(b))
    # Only b in {1, 2} fit the slack; 2 is the more efficient of those.
    assert ctl.pick(8, slack=_quad(2) + 1e-9) == 2
    # Nothing fits: serve the fastest thing possible.
    assert ctl.pick(8, slack=_quad(1) / 2) == 1


def test_controller_seed_curve_rescales_onto_observations():
    seed = {b: 2.0 * _quad(b) for b in (1, 2, 4, 8)}   # wrong scale, right shape
    ctl = AdaptiveBatchController(max_batch=8, seed_curve=seed)
    ctl.observe(4, _quad(4))
    assert ctl.estimate(8) == pytest.approx(_quad(8), rel=0.05)
    # Seed grid is {1,2,4,8}: interpolation at b=3 overestimates the
    # convex curve slightly, so the pick lands on the optimum's grid
    # neighbourhood rather than exactly sqrt(a/c)=3.
    assert ctl.pick(8) in (3, 4)


def test_load_bench_curve_reads_repo_benchmark():
    curve = slo.load_bench_curve()
    if curve:   # seeded repos carry BENCH_serving.json
        assert all(isinstance(b, int) and s > 0 for b, s in curve.items())
    assert slo.load_bench_curve("/nonexistent/BENCH.json") == {}


def test_load_bench_curve_falls_back_with_warning(tmp_path):
    """An unswept (executor, aggregation) pair must warn and seed from
    the closest available curve instead of silently starting cold."""
    import json
    import warnings as _warnings
    path = tmp_path / "BENCH_serving.json"
    rows = [{"executor": "sim", "aggregation": "segment_sum",
             "batch": b, "batched_s": 0.001 * b} for b in (1, 2, 4)]
    rows += [{"executor": "sim", "aggregation": "pallas",
              "batch": b, "batched_s": 0.002 * b} for b in (1, 2, 4)]
    path.write_text(json.dumps({"rows": rows}))
    # exact match: no warning
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        exact = slo.load_bench_curve(str(path), executor="sim",
                                     aggregation="pallas")
    assert exact == {b: 0.002 * b for b in (1, 2, 4)}
    # same executor, unswept aggregation: warn + a (sim, *) curve
    # (ties break lexicographically, so "pallas" wins over "segment_sum")
    with pytest.warns(RuntimeWarning, match="falling back"):
        curve = slo.load_bench_curve(str(path), executor="sim",
                                     aggregation="bogus")
    assert curve == {b: 0.002 * b for b in (1, 2, 4)}
    # unswept executor, swept aggregation: warn + same-aggregation curve
    with pytest.warns(RuntimeWarning, match="falling back"):
        curve = slo.load_bench_curve(str(path), executor="mesh-bsp",
                                     aggregation="pallas")
    assert curve == {b: 0.002 * b for b in (1, 2, 4)}
    # nothing related: warn + any curve rather than {}
    with pytest.warns(RuntimeWarning, match="falling back"):
        curve = slo.load_bench_curve(str(path), executor="mesh-bsp",
                                     aggregation="bogus")
    assert curve


def test_adaptive_server_integration(setup):
    g, params, plan = setup
    server = plan.server(max_batch=8, max_wait=1e9,
                         adaptive_batch=AdaptiveBatchController(max_batch=8))
    out = server.replay([Request(arrival_time=0.0) for _ in range(8)])
    assert len(out) == 8
    assert server.batch_controller._obs   # the loop closed
    out2 = server.replay([Request(arrival_time=100.0) for _ in range(8)])
    assert all(1 <= r.batch_size <= 8 for r in out2)
    serial = plan.session().query()
    assert all(np.array_equal(r.embeddings, serial.embeddings)
               for r in out + out2)      # numerics untouched by batching


# ----------------------------------------------------------------------------
# Session override knobs (the ladder's mechanism)
# ----------------------------------------------------------------------------

def test_session_override_validation(setup):
    g, params, plan = setup
    with pytest.raises(ValueError, match="num_layers"):
        Session(plan, num_layers=0)
    with pytest.raises(ValueError, match="num_layers"):
        Session(plan, num_layers=plan.model.num_layers + 1)
    with pytest.raises(Exception):
        Session(plan, compressor="definitely-not-a-codec")
    # Full-depth / same-codec overrides are no-ops sharing the plan.
    assert Session(plan, num_layers=plan.model.num_layers).plan is plan
    assert Session(plan, compressor=plan.config.compressor).plan is plan


def test_plan_with_overrides_shares_buffers(setup):
    g, params, plan = setup
    derived = plan.with_overrides(compressor="uniform8", num_layers=1)
    assert derived.graph is plan.graph
    assert derived.partitioned is plan.partitioned
    assert derived.placement is plan.placement
    assert derived.config.compressor == "uniform8"
    assert derived.model.num_layers == 1
    assert derived.cluster.k_layers == 1


# ----------------------------------------------------------------------------
# Trace annotations + summarize
# ----------------------------------------------------------------------------

def test_traces_carry_slo_annotations():
    for fn in (traces.poisson, traces.constant, traces.bursty):
        trace = fn(6, 4.0, deadline=0.5, priority=2)
        assert all(r.deadline == 0.5 and r.priority == 2 for r in trace)
    slo_fn = slo.slo_classes([(0.5, 2, 0.1), (0.5, 0, None)])
    trace = traces.poisson(64, 4.0, seed=7, slo_fn=slo_fn)
    assert {r.priority for r in trace} == {0, 2}
    assert all((r.deadline == 0.1) == (r.priority == 2) for r in trace)


def test_mixed_trace_annotates_updates(setup):
    g, params, plan = setup
    delta_fn = lambda i, rng: GraphDelta(
        feature_ids=[0], feature_values=g.features[:1])
    trace = traces.mixed(32, 4.0, delta_fn=delta_fn, update_fraction=0.4,
                         seed=5, deadline=0.25, priority=1)
    upds = [r for r in trace if isinstance(r, UpdateRequest)]
    assert upds and all(u.deadline == 0.25 and u.priority == 1 for u in upds)


def test_summarize_reports_slo_metrics(setup):
    g, params, plan = setup
    slo_fn = slo.slo_classes([(0.4, 2, 0.05), (0.6, 0, None)])
    trace = traces.poisson(24, rate=60.0, seed=9, slo_fn=slo_fn)
    out = plan.server(max_batch=4, slo=True).replay(trace)
    summary = Server.summarize(out)
    assert summary["requests"] + summary["rejected"] == 24
    assert 0.0 <= summary["deadline_miss_rate"] <= 1.0
    assert summary["goodput_rps"] <= summary["throughput_rps"] + 1e-9
    assert (summary["latency_p50_s"] <= summary["latency_p95_s"]
            <= summary["latency_p99_s"])
    classes = summary["priority_classes"]
    assert set(classes) <= {"0", "2"}
    assert sum(c["requests"] for c in classes.values()) == summary["requests"]
    assert sum(c["rejected"] for c in classes.values()) == summary["rejected"]


def test_slo_classes_validation():
    with pytest.raises(ValueError):
        slo.slo_classes([])
    with pytest.raises(ValueError):
        slo.slo_classes([(0.0, 1, 0.1)])


def test_server_rejects_bad_policy_type(setup):
    g, params, plan = setup
    with pytest.raises(TypeError, match="SLOPolicy"):
        plan.server(slo="yes please")
