"""The Pallas aggregation path == segment_sum, on every registry executor.

Covers the PR-3 tentpole: per-executor parity of ``aggregation="pallas"``
against ``aggregation="segment_sum"`` (mesh-bsp via subprocess so the
forced-host-device XLA flag never leaks), the knob's resolution/validation
rules, block-CSR edge cases (empty partition, single-vertex shard, block
size not dividing the vertex count) and the DAQ round-trip through the
fused ``dequant_spmm`` kernel.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Engine
from repro.core import partition
from repro.core.compression import _quantize_rows
from repro.gnn import datasets, models
from repro.kernels.daq_dequant import dequant_spmm
from repro.kernels.gather_aggregate import block_spmm, build_block_csr
from repro.runtime import bsp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _graph(scale=0.05, seed=0):
    return datasets.load("siot", scale=scale, seed=seed)


# ----------------------------------------------------------------------------
# Engine-level parity, every single-program registry executor
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["sim", "single", "cloud"])
@pytest.mark.parametrize("kind", ["gcn", "sage"])
def test_pallas_parity_single_program_executors(executor, kind):
    g = _graph()
    params = models.gnn_init(jax.random.PRNGKey(0), kind,
                             [g.feature_dim, 16, 8])

    def emb(agg):
        plan = Engine((params, kind), compressor="none", executor=executor,
                      aggregation=agg).compile(g)
        return plan.session().query().embeddings

    np.testing.assert_allclose(emb("pallas"), emb("segment_sum"),
                               rtol=1e-4, atol=1e-5)


def test_pallas_parity_mesh_bsp_subprocess():
    """mesh-bsp: kernel path == segment_sum path == single-device reference,
    and the DAQ-fused halo wire stays within 8-bit quantization error."""
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.api import Engine
        from repro.gnn import datasets, models
        g = datasets.load('siot', scale=0.05, seed=0)
        params = models.gnn_init(jax.random.PRNGKey(0), 'gcn',
                                 [g.feature_dim, 16, 8])
        def emb(agg, compressor):
            plan = Engine((params, 'gcn'), cluster='1A+2B+1C',
                          compressor=compressor, executor='mesh-bsp',
                          aggregation=agg).compile(g)
            return plan.session().query().embeddings
        seg = emb('segment_sum', 'none')
        pal = emb('pallas', 'none')
        err = float(np.abs(pal - seg).max())
        assert err < 5e-4, ('pallas', err)
        ref = emb('segment_sum', 'none')
        assert np.abs(ref - seg).max() == 0.0
        # DAQ plan: halo crosses the wire quantized, dequantized in-kernel.
        daq = emb('pallas', 'daq')
        daq_seg = emb('segment_sum', 'daq')
        err = float(np.abs(daq - daq_seg).max())
        scale = float(np.abs(daq_seg).max())
        assert err <= 5e-2 * max(scale, 1.0), ('daq-fused', err, scale)
        print('OK')
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


def test_session_and_server_aggregation_override():
    g = _graph()
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    plan = Engine((params, "gcn"), compressor="none",
                  aggregation="segment_sum").compile(g)
    base = plan.session().query().embeddings
    over = plan.session(aggregation="pallas").query().embeddings
    np.testing.assert_allclose(over, base, rtol=1e-4, atol=1e-5)
    # Server front-end forwards the session override through run_many.
    resp = plan.server(max_batch=4, aggregation="pallas").replay(3)
    for r in resp:
        np.testing.assert_allclose(r.embeddings, base, rtol=1e-4, atol=1e-5)


# ----------------------------------------------------------------------------
# Knob resolution / validation
# ----------------------------------------------------------------------------

def test_aggregation_knob_validation():
    g = _graph()
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 8])
    with pytest.raises(ValueError, match="unknown aggregation"):
        Engine((params, "gcn"), aggregation="segmentsum")
    gat = models.gnn_init(jax.random.PRNGKey(0), "gat", [g.feature_dim, 8])
    with pytest.raises(ValueError, match="pallas"):
        Engine((gat, "gat"), aggregation="pallas")
    # "auto" degrades gracefully for unsupported kinds.
    plan = Engine((gat, "gat"), compressor="none",
                  aggregation="auto").compile(g)
    assert plan.session().query().embeddings.shape == (g.num_vertices, 8)
    with pytest.raises(ValueError, match="halo"):
        bsp.resolve_aggregation("pallas", "gcn", exchange="allgather")
    # Off-TPU, "auto" stays on the portable path.
    if jax.default_backend() != "tpu":
        assert bsp.resolve_aggregation("auto", "gcn",
                                       exchange="halo") == "segment_sum"
    assert bsp.resolve_aggregation("pallas", "sage",
                                   exchange="halo") == "pallas"


def test_exchange_bytes_wire_formats():
    g = _graph()
    a = partition.bgp(g, 4, seed=0)
    pg = bsp.build_partitioned(g, a, build_blocks=False)
    f32 = bsp.exchange_bytes(pg, g.feature_dim, "halo", 4, 0)
    daq = bsp.exchange_bytes(pg, g.feature_dim, "halo", 1, 8)
    assert daq < f32
    assert daq == pg.n * pg.boundary_slots * (g.feature_dim + 8)


# ----------------------------------------------------------------------------
# Block-CSR shard edge cases (structure-level, no mesh needed)
# ----------------------------------------------------------------------------

def _kernel_shard_aggregate(g, pg):
    """Run each shard's local+halo SpMM exactly as shard_fn does and
    scatter the results back to original vertex order."""
    f = g.feature_dim
    halo = np.zeros((pg.n, pg.boundary_slots, f), np.float32)
    for q in range(pg.n):
        halo[q] = pg.feats[q][pg.boundary_rows[q]] * \
            pg.boundary_mask[q][:, None]
    halo_tab = halo.reshape(-1, f)
    out = np.zeros((pg.n, pg.slots, f), np.float32)
    for p in range(pg.n):
        loc = np.zeros((pg.local_csr.src_rows, f), np.float32)
        loc[:pg.slots] = pg.feats[p]
        hal = np.zeros((pg.halo_csr.src_rows, f), np.float32)
        hal[:halo_tab.shape[0]] = halo_tab
        agg = np.asarray(block_spmm(
            jnp.asarray(pg.local_csr.blocks[p]),
            jnp.asarray(pg.local_csr.cols[p]),
            jnp.asarray(pg.local_csr.mask[p]), jnp.asarray(loc)))
        agg = agg + np.asarray(block_spmm(
            jnp.asarray(pg.halo_csr.blocks[p]),
            jnp.asarray(pg.halo_csr.cols[p]),
            jnp.asarray(pg.halo_csr.mask[p]), jnp.asarray(hal)))
        out[p] = agg[:pg.slots]
    return pg.unpermute(out)


def _assert_shards_match_ground_truth(g, assignment):
    pg = bsp.build_partitioned(g, assignment)
    got = _kernel_shard_aggregate(g, pg)
    want = np.zeros_like(g.features)
    np.add.at(want, g.receivers, g.features[g.senders])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    return pg


def _random_graph(v, e, f, seed):
    from repro.gnn.graph import Graph
    rng = np.random.default_rng(seed)
    s = rng.integers(0, v, e).astype(np.int32)
    r = rng.integers(0, v, e).astype(np.int32)
    order = np.lexsort((s, r))
    s, r = s[order], r[order]
    indptr = np.zeros(v + 1, np.int64)
    np.add.at(indptr, r + 1, 1)
    indptr = np.cumsum(indptr)
    feats = rng.normal(size=(v, f)).astype(np.float32)
    return Graph(num_vertices=v, senders=s, receivers=r, indptr=indptr,
                 indices=s, features=feats)


def test_block_csr_empty_partition():
    g = _random_graph(60, 300, 12, seed=0)
    assignment = np.where(np.arange(60) < 30, 0, 2)   # part 1 is empty
    pg = _assert_shards_match_ground_truth(g, assignment)
    assert pg.n == 3
    assert pg.vertex_mask[1].sum() == 0


def test_block_csr_single_vertex_shard():
    g = _random_graph(50, 250, 8, seed=1)
    assignment = np.zeros(50, np.int64)
    assignment[7] = 1                                 # one-vertex shard
    pg = _assert_shards_match_ground_truth(g, assignment)
    assert pg.vertex_mask[1].sum() == 1


def test_block_csr_block_not_dividing_vertices():
    # 130 vertices over 2 parts -> slots = 72: neither the shard size nor
    # the halo table is a multiple of the 128-wide MXU block.
    g = _random_graph(130, 700, 20, seed=2)
    assignment = (np.arange(130) % 2).astype(np.int64)
    pg = _assert_shards_match_ground_truth(g, assignment)
    assert pg.slots % 128 != 0
    assert pg.local_csr.src_rows % 128 == 0
    assert pg.halo_csr.src_rows % 128 == 0


def test_block_csr_rectangular_source_space():
    """Column blocks beyond the row-block count (the rectangular case that
    used to collide in the (rb, cb) key packing)."""
    rng = np.random.default_rng(3)
    rows, src = 100, 700                  # 1 row-block, 6 source blocks
    s = rng.integers(0, src, 2000).astype(np.int32)
    r = rng.integers(0, rows, 2000).astype(np.int32)
    blocks, cols, mask, pv = build_block_csr(s, r, rows)
    assert cols.max() == src // 128
    h = rng.normal(size=(-(-src // 128) * 128, 16)).astype(np.float32)
    out = np.asarray(block_spmm(jnp.asarray(blocks), jnp.asarray(cols),
                                jnp.asarray(mask), jnp.asarray(h)))[:rows]
    want = np.zeros((rows, 16), np.float32)
    np.add.at(want, r, h[s])
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-3)


def test_daq_roundtrip_through_dequant_spmm():
    """8-bit DAQ codes aggregated by the fused kernel == dequantize-then-
    aggregate with segment-style numpy, within kernel float tolerance."""
    g = _random_graph(200, 1200, 24, seed=4)
    blocks, cols, mask, pv = build_block_csr(g.senders, g.receivers,
                                             g.num_vertices)
    q, mins, scales = _quantize_rows(g.features.astype(np.float64), 8)
    cp = np.zeros((pv, 24), np.uint8)
    cp[:200] = q
    sp = np.zeros(pv, np.float32)
    sp[:200] = scales
    mp = np.zeros(pv, np.float32)
    mp[:200] = mins
    fused = np.asarray(dequant_spmm(
        jnp.asarray(blocks), jnp.asarray(cols), jnp.asarray(mask),
        jnp.asarray(cp), jnp.asarray(sp), jnp.asarray(mp)))[:200]
    deq = q.astype(np.float32) * scales[:, None].astype(np.float32) \
        + mins[:, None].astype(np.float32)
    want = np.zeros((200, 24), np.float32)
    np.add.at(want, g.receivers, deq[g.senders])
    np.testing.assert_allclose(fused, want, rtol=1e-4, atol=1e-3)
    # and the dequantized features themselves are within the 8-bit bound
    row_range = g.features.max(axis=1) - g.features.min(axis=1)
    assert np.all(np.abs(deq - g.features).max(axis=1)
                  <= row_range / 255 + 1e-5)
