"""Distributed BSP runtime == single-device inference (multi-device via
subprocess so the 8-device XLA flag never leaks into other tests)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import partition
from repro.gnn import datasets
from repro.runtime import bsp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_build_partitioned_invariants():
    g = datasets.load("yelp", scale=0.05, seed=0)
    a = partition.bgp(g, 4, seed=0)
    pg = bsp.build_partitioned(g, a)
    assert pg.n == 4
    assert pg.feats.shape[0] == 4
    # every vertex appears exactly once at (part, slot)
    seen = set()
    for v in range(g.num_vertices):
        key = (int(pg.part_of[v]), int(pg.slot_of[v]))
        assert key not in seen
        seen.add(key)
    # all real edges preserved
    assert int(pg.edge_mask.sum()) == g.num_edges
    # halo: boundary rows cover all cross-partition senders
    for p in range(4):
        cross = (pg.part_of[g.senders] == p) & (pg.part_of[g.receivers] != p)
        assert pg.boundary_mask[p].sum() == len(np.unique(g.senders[cross]))


def test_exchange_bytes_halo_less_than_allgather():
    g = datasets.load("siot", scale=0.05, seed=1)
    a = partition.bgp(g, 4, seed=0)
    pg = bsp.build_partitioned(g, a)
    assert bsp.exchange_bytes(pg, 52, "halo") <= \
        bsp.exchange_bytes(pg, 52, "allgather")


@pytest.mark.parametrize("kind", ["gcn", "gat", "sage"])
def test_bsp_equals_single_device_subprocess(kind):
    """Run the 4-device check in a subprocess with forced host devices."""
    code = textwrap.dedent(f"""
        import numpy as np, jax
        from repro.gnn import datasets, models
        from repro.gnn.layers import EdgeList
        from repro.core import partition
        from repro.runtime import bsp
        g = datasets.load('yelp', scale=0.06, seed=3)
        assign = partition.bgp(g, 4, seed=0)
        params = models.gnn_init(jax.random.PRNGKey(0), '{kind}',
                                 [g.feature_dim, 32, 8])
        ref = np.asarray(models.gnn_apply(params, '{kind}', g.features,
                                          EdgeList.from_graph(g)))
        for ex in ['allgather', 'halo']:
            out = bsp.bsp_infer(params, '{kind}', g, assign, exchange=ex)
            err = float(np.abs(out - ref).max())
            assert err < 5e-4, (ex, err)
        print('OK')
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout
