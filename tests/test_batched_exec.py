"""Batch-axis executor parity: one fused dispatch == the serial loop.

Covers the PR-5 tentpole: for every executor backend and model kind, the
natively batched ``run_many`` (batch-grid Pallas kernels for GCN/SAGE's
kernel path, the vmapped edge-weighted path for GAT and segment-sum)
must be BIT-IDENTICAL to the serial per-request loop, and the kernel path
must still agree with segment_sum within float tolerance. Plus edge
cases: B=1 falls back to the serial path, empty shards inside a batch,
block shapes that do not divide the vertex count, the DAQ quantized halo
round-trip under the batch axis, and the keyed BlockCsr cache satellite.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Engine, Request
from repro.api.registry import EXECUTORS
from repro.core import partition
from repro.gnn import datasets, models
from repro.gnn.graph import Graph
from repro.kernels import ops
from repro.kernels.daq_dequant import dequant_spmm, dequant_spmm_batched
from repro.kernels.gather_aggregate import (block_spmm, block_spmm_batched,
                                            build_block_csr)
from repro.runtime import bsp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    g = datasets.load("siot", scale=0.05, seed=0)
    return g


def _feats(g, b, seed=0):
    rng = np.random.default_rng(seed)
    return [(g.features + rng.normal(
        scale=0.01, size=g.features.shape)).astype(np.float32)
        for _ in range(b)]


# ----------------------------------------------------------------------------
# Single-program executors: batched == serial per aggregation path
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["sim", "single", "cloud"])
@pytest.mark.parametrize("kind,aggregation", [
    ("gcn", "pallas"), ("sage", "pallas"),
    ("gcn", "segment_sum"), ("gat", "segment_sum")])
def test_batched_bit_identical_to_serial(setup, executor, kind, aggregation):
    g = setup
    params = models.gnn_init(jax.random.PRNGKey(0), kind,
                             [g.feature_dim, 16, 8])
    plan = Engine((params, kind), cluster="1A+2B+1C",
                  executor=executor, aggregation=aggregation).compile(g)
    backend = EXECUTORS.resolve(executor)
    feats = _feats(g, 3)
    batched = backend.run_many(plan, np.stack(feats),
                               plan.placement.assignment, plan.partitioned,
                               "halo", aggregation=aggregation)
    serial = [backend.run(plan, f, plan.placement.assignment,
                          plan.partitioned, "halo", aggregation=aggregation)
              for f in feats]
    assert len(batched) == 3
    for a, b in zip(batched, serial):
        assert np.array_equal(a, b)


@pytest.mark.parametrize("kind", ["gcn", "sage"])
def test_batched_pallas_matches_segment_sum(setup, kind):
    """The fused batched kernel path still agrees with the portable
    segment-sum numerics (per-request, within float tolerance)."""
    g = setup
    params = models.gnn_init(jax.random.PRNGKey(0), kind,
                             [g.feature_dim, 16, 8])
    plan = Engine((params, kind), cluster="1A+2B+1C").compile(g)
    backend = EXECUTORS.resolve("sim")
    feats = _feats(g, 3)
    pal = backend.run_many(plan, np.stack(feats), plan.placement.assignment,
                           plan.partitioned, "halo", aggregation="pallas")
    seg = backend.run_many(plan, np.stack(feats), plan.placement.assignment,
                           plan.partitioned, "halo",
                           aggregation="segment_sum")
    for a, b in zip(pal, seg):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_b1_takes_serial_path_and_reproduces_run(setup):
    g = setup
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)
    backend = EXECUTORS.resolve("sim")
    f = _feats(g, 1)[0]
    for agg in ("segment_sum", "pallas"):
        one = backend.run_many(plan, np.stack([f]),
                               plan.placement.assignment, plan.partitioned,
                               "halo", aggregation=agg)
        ref = backend.run(plan, f, plan.placement.assignment,
                          plan.partitioned, "halo", aggregation=agg)
        assert len(one) == 1
        assert np.array_equal(one[0], ref)


def test_run_many_accepts_list_and_stack(setup):
    g = setup
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)
    backend = EXECUTORS.resolve("sim")
    feats = _feats(g, 3)
    a = backend.run_many(plan, feats, plan.placement.assignment,
                         plan.partitioned, "halo")
    b = backend.run_many(plan, np.stack(feats), plan.placement.assignment,
                         plan.partitioned, "halo")
    for x, y in zip(a, b):
        assert np.array_equal(x, y)


def test_server_batched_pallas_bit_identical_to_session(setup):
    """End to end: the Server's stacked micro-batch through the batched
    kernel path == serial Session.query, bit for bit."""
    g = setup
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    plan = Engine((params, "gcn"), cluster="1A+2B+1C",
                  compressor="daq").compile(g)
    feats = [None] + _feats(g, 3)
    serial = [plan.session(aggregation="pallas").query(f) for f in feats]
    server = plan.server(max_batch=4, max_wait=1e9, aggregation="pallas")
    batched = server.replay([Request(features=f, arrival_time=0.0)
                             for f in feats])
    assert max(r.batch_size for r in batched) > 1
    for b, s in zip(batched, serial):
        assert np.array_equal(b.embeddings, s.embeddings)


# ----------------------------------------------------------------------------
# mesh-bsp: batched == serial on a real device mesh (subprocess so the
# forced-host-device XLA flag never leaks)
# ----------------------------------------------------------------------------

def test_mesh_bsp_batched_parity_subprocess():
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.api import Engine, Request
        from repro.gnn import datasets, models
        g = datasets.load('siot', scale=0.05, seed=0)
        for kind, compressor in [('gcn', 'none'), ('sage', 'daq'),
                                 ('gat', 'none')]:
            agg = 'segment_sum' if kind == 'gat' else 'pallas'
            params = models.gnn_init(jax.random.PRNGKey(0), kind,
                                     [g.feature_dim, 16, 8])
            plan = Engine((params, kind), cluster='1A+2B+1C',
                          compressor=compressor, executor='mesh-bsp',
                          aggregation=agg).compile(g)
            serial = [plan.session().query() for _ in range(3)]
            batched = plan.server(max_batch=4, max_wait=1e9).replay(
                [Request(arrival_time=0.0) for _ in range(3)])
            assert batched[0].batch_size == 3
            for b, s in zip(batched, serial):
                assert np.array_equal(b.embeddings, s.embeddings), kind
        print('OK')
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


# ----------------------------------------------------------------------------
# Structural edge cases (kernel level, no mesh needed)
# ----------------------------------------------------------------------------

def _random_graph(v, e, f, seed):
    rng = np.random.default_rng(seed)
    s = rng.integers(0, v, e).astype(np.int32)
    r = rng.integers(0, v, e).astype(np.int32)
    order = np.lexsort((s, r))
    s, r = s[order], r[order]
    indptr = np.zeros(v + 1, np.int64)
    np.add.at(indptr, r + 1, 1)
    indptr = np.cumsum(indptr)
    feats = rng.normal(size=(v, f)).astype(np.float32)
    return Graph(num_vertices=v, senders=s, receivers=r, indptr=indptr,
                 indices=s, features=feats)


def _batched_shard_aggregate(pg, stack):
    """Run each shard's batched local+halo SpMM exactly as the batched
    shard_fn does; returns [B, V, F] in original vertex order."""
    b, _, f = stack.shape
    feats = pg.feature_stack(stack)                       # [n, B, P, F]
    halo = np.zeros((pg.n, b, pg.boundary_slots, f), np.float32)
    for q in range(pg.n):
        halo[q] = feats[q][:, pg.boundary_rows[q]] * \
            pg.boundary_mask[q][:, None]
    halo_tab = np.moveaxis(halo, 0, 1).reshape(b, -1, f)  # [B, n*B, F]
    out = np.zeros((pg.n, b, pg.slots, f), np.float32)
    for p in range(pg.n):
        loc = np.zeros((b, pg.local_csr.src_rows, f), np.float32)
        loc[:, :pg.slots] = feats[p]
        hal = np.zeros((b, pg.halo_csr.src_rows, f), np.float32)
        hal[:, :halo_tab.shape[1]] = halo_tab
        agg = np.asarray(block_spmm_batched(
            jnp.asarray(pg.local_csr.blocks[p]),
            jnp.asarray(pg.local_csr.cols[p]),
            jnp.asarray(pg.local_csr.mask[p]), jnp.asarray(loc)))
        agg = agg + np.asarray(block_spmm_batched(
            jnp.asarray(pg.halo_csr.blocks[p]),
            jnp.asarray(pg.halo_csr.cols[p]),
            jnp.asarray(pg.halo_csr.mask[p]), jnp.asarray(hal)))
        out[p] = agg[:, :pg.slots]
    return pg.unpermute_stack(out)


def _assert_batched_shards_match(g, assignment, b=3, seed=0):
    rng = np.random.default_rng(seed)
    stack = rng.normal(size=(b, g.num_vertices,
                             g.feature_dim)).astype(np.float32)
    pg = bsp.build_partitioned(g, assignment)
    got = _batched_shard_aggregate(pg, stack)
    for k in range(b):
        want = np.zeros_like(stack[k])
        np.add.at(want, g.receivers, stack[k][g.senders])
        np.testing.assert_allclose(got[k], want, rtol=1e-4, atol=1e-4)
    return pg


def test_batched_kernels_empty_shard_in_batch():
    g = _random_graph(60, 300, 12, seed=0)
    assignment = np.where(np.arange(60) < 30, 0, 2)   # part 1 is empty
    pg = _assert_batched_shards_match(g, assignment)
    assert pg.vertex_mask[1].sum() == 0


def test_batched_kernels_block_not_dividing_vertices():
    g = _random_graph(130, 700, 20, seed=2)
    assignment = (np.arange(130) % 2).astype(np.int64)
    pg = _assert_batched_shards_match(g, assignment)
    assert pg.slots % 128 != 0


def test_batched_aggregate_traced_matches_per_example():
    """ops.BlockCsr.aggregate_traced on a [B, V, F] stack == per-example
    calls, bit for bit (non-128-multiple V and F)."""
    g = _random_graph(200, 1200, 24, seed=3)
    csr = ops.BlockCsr(g)
    rng = np.random.default_rng(4)
    stack = rng.normal(size=(4, 200, 24)).astype(np.float32)
    got = np.asarray(csr.aggregate_traced(jnp.asarray(stack)))
    assert got.shape == (4, 200, 24)
    for k in range(4):
        one = np.asarray(csr.aggregate_traced(jnp.asarray(stack[k])))
        assert np.array_equal(got[k], one)


def test_daq_halo_roundtrip_under_batch_axis():
    """dequant_spmm_batched == per-example dequant_spmm (bitwise) and ==
    dequantize-then-aggregate ground truth (quantization-bounded)."""
    from repro.core.compression import _quantize_rows
    g = _random_graph(200, 1200, 24, seed=5)
    blocks, cols, mask, pv = build_block_csr(g.senders, g.receivers,
                                             g.num_vertices)
    rng = np.random.default_rng(6)
    b = 3
    cp = np.zeros((b, pv, 24), np.uint8)
    sp = np.zeros((b, pv), np.float32)
    mp = np.zeros((b, pv), np.float32)
    raw = np.zeros((b, 200, 24), np.float64)
    for k in range(b):
        raw[k] = g.features + rng.normal(scale=0.01, size=g.features.shape)
        q, mins, scales = _quantize_rows(raw[k], 8)
        cp[k, :200] = q
        sp[k, :200] = scales
        mp[k, :200] = mins
    fused = np.asarray(dequant_spmm_batched(
        jnp.asarray(blocks), jnp.asarray(cols), jnp.asarray(mask),
        jnp.asarray(cp), jnp.asarray(sp), jnp.asarray(mp)))
    for k in range(b):
        one = np.asarray(dequant_spmm(
            jnp.asarray(blocks), jnp.asarray(cols), jnp.asarray(mask),
            jnp.asarray(cp[k]), jnp.asarray(sp[k]), jnp.asarray(mp[k])))
        assert np.array_equal(fused[k], one)
        deq = cp[k, :200].astype(np.float32) * sp[k, :200, None] \
            + mp[k, :200, None]
        want = np.zeros((200, 24), np.float32)
        np.add.at(want, g.receivers, deq[g.senders])
        np.testing.assert_allclose(fused[k, :200], want, rtol=1e-4,
                                   atol=1e-3)


# ----------------------------------------------------------------------------
# Keyed BlockCsr cache (satellite)
# ----------------------------------------------------------------------------

def test_block_csr_cache_shared_across_graph_copies():
    g = _random_graph(150, 800, 16, seed=7)
    a = ops.block_csr_for(g)
    assert ops.block_csr_for(g) is a            # same adjacency -> cached
    g2 = dataclasses.replace(
        g, features=np.zeros_like(g.features))  # features don't matter
    assert ops.block_csr_for(g2) is a
    # a changed adjacency can never alias the cached operands
    g3 = dataclasses.replace(g, senders=g.receivers, receivers=g.senders)
    assert ops.block_csr_for(g3) is not a


def test_block_csr_cache_invalidation():
    g = _random_graph(150, 800, 16, seed=8)
    a = ops.block_csr_for(g)
    assert ops.invalidate_block_csr(g) == 1
    assert ops.invalidate_block_csr(g) == 0     # already gone
    assert ops.block_csr_for(g) is not a        # rebuilt on demand


def test_session_override_does_not_rebuild_per_query(setup, monkeypatch):
    """A Session aggregation override must hit the keyed cache on every
    query instead of silently re-blocking the whole graph."""
    g = setup
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    plan = Engine((params, "gcn"), cluster="1A+2B+1C",
                  aggregation="segment_sum").compile(g)
    builds = []
    orig = ops.BlockCsr.__init__

    def counting(self, *a, **kw):
        builds.append(1)
        return orig(self, *a, **kw)

    monkeypatch.setattr(ops.BlockCsr, "__init__", counting)
    ops.invalidate_block_csr(g)                 # cold start
    sess = plan.session(aggregation="pallas")
    for _ in range(3):
        sess.query()
    assert sum(builds) == 1                     # built once, then cached


def test_apply_delta_invalidates_block_csr_cache(setup):
    g = setup
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    engine = Engine((params, "gcn"), cluster="1A+2B+1C")
    plan = engine.compile(g)
    ops.block_csr_for(plan.graph)
    from repro.api import GraphDelta
    delta = GraphDelta(add_features=np.zeros((1, g.feature_dim),
                                             np.float32),
                       add_edges=np.array([[g.num_vertices, 0]]))
    engine.apply_delta(plan, delta)
    # The pre-update adjacency's entry was dropped eagerly.
    assert ops.invalidate_block_csr(plan.graph) == 0
