"""Differential fuzz harness for incremental delta-driven inference.

The contract under test: a ``Session(activation_cache=True)`` serving a
stream of random ``GraphDelta``s answers every query **bit-identically**
to a from-scratch ``Engine.compile`` + query on the same mutated graph —
whether the cache served the empty-frontier fast path, an incremental
k-hop dirty-frontier recompute, or a full capturing fallback.

Three layers of defence:

  * a seeded numpy case generator driving >=100 randomized cases across
    sim/single/cloud x segment_sum/pallas x gcn/sage (runs everywhere);
  * a hypothesis property over the same case runner (extra shrinking
    power when the optional dep is installed — see _hypothesis_compat);
  * a mesh-bsp subprocess spot-check (multi-device layouts are
    assignment-dependent, so its reference is a cache-less session on
    the same plan chain rather than a fresh compile).

Plus frontier oracle tests (hand-computed k-hop balls incl. removed-edge
invalidation) and a cache-staleness regression for deferred sessions.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st  # optional dep:
# property tests skip cleanly when hypothesis is missing.

import jax

from repro.api import Engine, GraphDelta
from repro.core import frontier
from repro.gnn import models
from repro.gnn.graph import from_edge_list

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: every single-program combo the incremental path claims support for
#: (gat rides along in a dedicated fallback test below).
COMBOS = [(e, a, k)
          for e in ("sim", "single", "cloud")
          for a in ("segment_sum", "pallas")
          for k in ("gcn", "sage")]

CASES_PER_COMBO = 9   # 12 combos x 9 = 108 generated cases


# ----------------------------------------------------------------------------
# case generation
# ----------------------------------------------------------------------------

def _random_graph(rng):
    """Sparse connected graph: random spanning tree + a few chords."""
    v = int(rng.integers(24, 72))
    parents = [int(rng.integers(0, i)) for i in range(1, v)]
    edges = [(i, p) for i, p in enumerate(parents, start=1)]
    for _ in range(int(rng.integers(0, v // 3))):
        a, b = (int(x) for x in rng.integers(0, v, size=2))
        if a != b:
            edges.append((a, b))
    feats = rng.normal(size=(v, 4)).astype(np.float32)
    return from_edge_list(v, np.array(edges, np.int64), feats)


def _random_delta(g, rng):
    """A random GraphDelta: any mix of vertex/edge churn and feature
    upserts; ~10% of draws are completely empty."""
    v, f = g.num_vertices, g.feature_dim
    if rng.random() < 0.1:
        return GraphDelta()
    kw = {}
    removed = np.empty(0, np.int64)
    if rng.random() < 0.25:
        n_rm = int(rng.integers(1, 3))
        if rng.random() < 0.3:
            # the remove-last-vertex special case: the compaction must
            # shrink the trailing shard and the cache must follow.
            removed = np.unique(np.concatenate(
                [[v - 1], rng.choice(v - 1, size=n_rm - 1,
                                     replace=False)])) if n_rm > 1 \
                else np.array([v - 1])
        else:
            removed = rng.choice(v, size=n_rm, replace=False)
        kw["remove_vertices"] = removed
    if rng.random() < 0.55:
        # upserts may not target a vertex the same delta removes
        pool = np.setdiff1d(np.arange(v), removed)
        k = min(int(rng.integers(1, max(2, v // 8))), len(pool))
        if k:
            ids = rng.choice(pool, size=k, replace=False)
            kw["feature_ids"] = ids
            kw["feature_values"] = rng.normal(size=(k, f)).astype(
                np.float32)
    if rng.random() < 0.4:
        n_new = int(rng.integers(1, 3))
        kw["add_features"] = rng.normal(size=(n_new, f)).astype(np.float32)
        kw["add_edges"] = [(v + i, int(t)) for i, t in
                           enumerate(rng.choice(v, size=n_new))]
    if rng.random() < 0.4:
        a, b = (int(x) for x in rng.integers(0, v, size=2))
        if a != b:
            kw.setdefault("add_edges", [])
            kw["add_edges"] = list(kw["add_edges"]) + [(a, b), (b, a)]
    if rng.random() < 0.3 and g.num_edges:
        e = int(rng.integers(0, g.num_edges))
        s, r = int(g.senders[e]), int(g.receivers[e])
        kw["remove_edges"] = [(s, r), (r, s)]
    return GraphDelta(**kw)


def _fresh_reference(params, kind, executor, aggregation, g, feats):
    """From-scratch recompute: a brand-new Engine.compile on the mutated
    graph, queried through a cache-less session. Single-program numerics
    are partition-independent, so this is the strongest possible oracle."""
    eng = Engine((params, kind), cluster="1A+2B+1C", executor=executor,
                 aggregation=aggregation)
    return np.asarray(eng.compile(g).session().query(feats).embeddings)


def _run_case(seed, executor, aggregation, kind):
    rng = np.random.default_rng(seed)
    g = _random_graph(rng)
    params = models.gnn_init(jax.random.PRNGKey(seed % 97), kind,
                             [g.feature_dim, 8, 4])
    eng = Engine((params, kind), cluster="1A+2B+1C", executor=executor,
                 aggregation=aggregation)
    # max_fraction=1.0 forces the frontier path whenever it is sound —
    # the fuzzer wants maximal incremental coverage, not fallbacks.
    sess = eng.compile(g).session(activation_cache=True,
                                  frontier_max_fraction=1.0)
    got = np.asarray(sess.query().embeddings)
    want = _fresh_reference(params, kind, executor, aggregation,
                            sess.plan.graph, None)
    assert np.array_equal(got, want), (
        f"cold-cache parity break: seed={seed} {executor}/{aggregation}/"
        f"{kind}")
    for step in range(int(rng.integers(1, 4))):
        delta = _random_delta(sess.plan.graph, rng)
        sess.update(delta)
        g2 = sess.plan.graph
        feats = None
        if rng.random() < 0.5:   # per-query feature override
            feats = rng.normal(size=(g2.num_vertices,
                                     g2.feature_dim)).astype(np.float32)
        got = np.asarray(sess.query(feats).embeddings)
        want = _fresh_reference(params, kind, executor, aggregation,
                                g2, feats)
        assert np.array_equal(got, want), (
            f"parity break: seed={seed} step={step} {executor}/"
            f"{aggregation}/{kind} incremental="
            f"{sess.last_frontier is not None}")


# ----------------------------------------------------------------------------
# the fuzz harness (seeded — runs without hypothesis)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("executor,aggregation,kind", COMBOS)
def test_incremental_differential_fuzz(executor, aggregation, kind):
    """>=100 randomized delta streams across every supported combo, each
    asserting bit-parity of cached-incremental vs fresh-compile."""
    base = COMBOS.index((executor, aggregation, kind)) * 1000
    for i in range(CASES_PER_COMBO):
        _run_case(base + i, executor, aggregation, kind)


def test_incremental_fuzz_takes_frontier_path():
    """Meta-check on the harness itself: the incremental path must
    actually fire (a fuzzer that always falls back proves nothing)."""
    rng = np.random.default_rng(7)
    v = 64
    edges = np.array([(i, i + 1) for i in range(v - 1)], np.int64)
    g = from_edge_list(v, edges,
                       rng.normal(size=(v, 4)).astype(np.float32))
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 8, 4])
    eng = Engine((params, "gcn"), cluster="1A+2B+1C", executor="sim",
                 aggregation="segment_sum")
    sess = eng.compile(g).session(activation_cache=True,
                                  frontier_max_fraction=1.0)
    sess.query()
    sess.update(GraphDelta(feature_ids=[3], feature_values=np.ones(
        (1, g.feature_dim), np.float32)))
    sess.query()
    assert sess.last_frontier is not None
    assert len(sess.last_frontier.rows[-1]) < v   # genuinely partial


def test_gat_falls_back_and_stays_exact():
    """GAT re-weights edges per layer, so it has no frontier support —
    the cache must serve it through full passes (and the empty-frontier
    fast path) without ever diverging."""
    for seed in range(4):
        _run_case(90_000 + seed, "sim", "segment_sum", "gat")


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=25, deadline=None, derandomize=True)
def test_incremental_fuzz_hypothesis(seed):
    """Property form of the same runner (runs when hypothesis is
    installed; see _hypothesis_compat)."""
    executor, aggregation, kind = COMBOS[seed % len(COMBOS)]
    _run_case(seed, executor, aggregation, kind)


# ----------------------------------------------------------------------------
# mesh-bsp spot-check (multi-device layouts need their own process)
# ----------------------------------------------------------------------------

def test_incremental_query_mesh_bsp_subprocess():
    """mesh-bsp, both aggregations: cached incremental queries are
    bit-identical to a cache-less session fed the same delta stream.
    (Mesh numerics are layout-dependent, so the reference shares the
    plan chain instead of re-partitioning from scratch.)"""
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.api import Engine, GraphDelta
        from repro.gnn import models
        from repro.gnn.graph import from_edge_list
        rng = np.random.default_rng(0)
        v = 256
        edges = np.array([(i, (i + 1) % v) for i in range(v)], np.int64)
        g = from_edge_list(v, edges,
                           rng.normal(size=(v, 4)).astype(np.float32))
        params = models.gnn_init(jax.random.PRNGKey(0), 'gcn',
                                 [g.feature_dim, 8, 4])
        for aggregation in ('segment_sum', 'pallas'):
            eng = Engine((params, 'gcn'), cluster='4B',
                         executor='mesh-bsp', aggregation=aggregation)
            inc = eng.compile(g).session(activation_cache=True,
                                         frontier_max_fraction=1.0)
            ref = eng.compile(g).session()
            assert np.array_equal(inc.query().embeddings,
                                  ref.query().embeddings), aggregation
            deltas = [
                GraphDelta(feature_ids=[7], feature_values=np.ones(
                    (1, g.feature_dim), np.float32)),        # frontier path
                GraphDelta(add_edges=[(0, 9), (9, 0)]),      # structural
                GraphDelta(feature_ids=[40], feature_values=-np.ones(
                    (1, g.feature_dim), np.float32)),        # re-armed
            ]
            hits = 0
            for d in deltas:
                inc.update(d)
                ref.update(d)
                a = np.asarray(inc.query().embeddings)
                b = np.asarray(ref.query().embeddings)
                assert np.array_equal(a, b), aggregation
                hits += inc.last_frontier is not None
            assert hits >= 2, (aggregation, hits)
        print('OK')
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


# ----------------------------------------------------------------------------
# frontier oracle: hand-computed k-hop balls
# ----------------------------------------------------------------------------

def _graph_of(v, edge_pairs):
    feats = np.zeros((v, 2), np.float32)
    return from_edge_list(v, np.array(edge_pairs, np.int64).reshape(-1, 2),
                          feats)


def _rows(graph, seeds, layers, extra=None):
    extra = (np.empty((0, 2), np.int64) if extra is None
             else np.asarray(extra, np.int64))
    return [set(r.tolist()) for r in frontier.expand_frontier(
        graph, np.asarray(seeds, np.int64), extra, layers)]


def test_frontier_oracle_path_graph():
    # 0-1-2-3-4-5: seeds {2} -> D1 = {1,2,3}, D2 = {0..4}
    g = _graph_of(6, [(i, i + 1) for i in range(5)])
    assert _rows(g, [2], 2) == [{1, 2, 3}, {0, 1, 2, 3, 4}]


def test_frontier_oracle_star_graph():
    # hub 0, leaves 1..5: seed {1} -> D1 = {0,1}, D2 = everything
    g = _graph_of(6, [(0, i) for i in range(1, 6)])
    assert _rows(g, [1], 2) == [{0, 1}, {0, 1, 2, 3, 4, 5}]
    # seed at the hub floods in one hop
    assert _rows(g, [0], 1) == [{0, 1, 2, 3, 4, 5}]


def test_frontier_oracle_disconnected_components():
    # two triangles 0-1-2 and 3-4-5: dirt never crosses components
    g = _graph_of(6, [(0, 1), (1, 2), (2, 0), (3, 4), (4, 5), (5, 3)])
    assert _rows(g, [0], 3) == [{0, 1, 2}] * 3


def test_frontier_oracle_self_loop():
    g = _graph_of(3, [(0, 0), (0, 1), (1, 2)])
    # from_edge_list drops self loops; 0's ball grows along 0-1-2 only
    assert _rows(g, [0], 2) == [{0, 1}, {0, 1, 2}]


def test_frontier_oracle_extra_edges_bridge_removed():
    # path 0-1-2-3; pretend 1-2 was just removed: the union adjacency
    # must still carry dirt across the cut in BOTH directions.
    g = _graph_of(4, [(0, 1), (2, 3)])
    assert _rows(g, [1], 2, extra=[(1, 2), (2, 1)]) == [
        {0, 1, 2}, {0, 1, 2, 3}]
    assert _rows(g, [2], 2, extra=[(1, 2), (2, 1)]) == [
        {1, 2, 3}, {0, 1, 2, 3}]


def test_removed_edge_dirties_both_former_endpoints():
    """Removing edge (1,2) from 0-1-2-3 must dirty BOTH former
    endpoints' l-hop neighborhoods — vertex 3 (one hop from 2) changes
    at layer 2 even though it is two hops from the nearer endpoint."""
    g = _graph_of(4, [(0, 1), (1, 2), (2, 3)])
    fu = frontier.fold_delta_frontier(
        g, [GraphDelta(remove_edges=[(1, 2), (2, 1)])])
    assert set(fu.seeds.tolist()) == {1, 2}
    assert fu.structural and not fu.removed_vertices
    pairs = {tuple(p) for p in fu.extra_edges.tolist()}
    assert {(1, 2), (2, 1)} <= pairs
    rows = [set(r.tolist()) for r in frontier.expand_frontier(
        fu.graph, fu.seeds, fu.extra_edges, 2)]
    assert rows[0] == {0, 1, 2, 3}      # 1-hop: both sides of the cut
    assert rows[1] == {0, 1, 2, 3}


def test_removed_vertex_dirties_former_neighbors():
    # star: removing the hub must seed every leaf (renumbered).
    g = _graph_of(4, [(0, 1), (0, 2), (0, 3)])
    fu = frontier.fold_delta_frontier(g, [GraphDelta(remove_vertices=[0])])
    assert fu.removed_vertices and fu.structural
    # leaves 1,2,3 renumber to 0,1,2 and all were the hub's neighbors
    assert set(fu.seeds.tolist()) == {0, 1, 2}


def test_fold_composes_vertex_maps_across_deltas():
    g = _graph_of(5, [(i, i + 1) for i in range(4)])
    fu = frontier.fold_delta_frontier(g, [
        GraphDelta(feature_ids=[4], feature_values=np.ones((1, 2),
                                                           np.float32)),
        GraphDelta(remove_vertices=[0]),   # everything shifts down by 1
    ])
    # old vertex 4 is now 3 and must still be dirty; old 1 (ex-neighbor
    # of removed 0) is now 0.
    assert 3 in fu.seeds.tolist()
    assert 0 in fu.seeds.tolist()
    assert fu.vmap[0] == -1 and fu.vmap[4] == 3


# ----------------------------------------------------------------------------
# cache staleness: deferred consistency
# ----------------------------------------------------------------------------

def _line_session(**kw):
    rng = np.random.default_rng(3)
    v = 48
    g = from_edge_list(v, np.array([(i, i + 1) for i in range(v - 1)],
                                   np.int64),
                       rng.normal(size=(v, 4)).astype(np.float32))
    params = models.gnn_init(jax.random.PRNGKey(3), "gcn",
                             [g.feature_dim, 8, 4])
    eng = Engine((params, "gcn"), cluster="1A+2B+1C", executor="sim",
                 aggregation="segment_sum")
    return params, eng.compile(g).session(**kw)


def test_deferred_session_does_not_serve_stale_cache_across_flush():
    """updates='deferred' buffers deltas: pre-flush queries legitimately
    serve the old graph (cache included), but the first query after the
    coalesced flush must reflect the repaired graph bit-exactly."""
    params, sess = _line_session(activation_cache=True,
                                 frontier_max_fraction=1.0,
                                 updates="deferred")
    before = np.asarray(sess.query().embeddings)
    delta = GraphDelta(
        add_edges=[(0, 20), (20, 0)],
        feature_ids=[5],
        feature_values=np.full((1, 4), 2.0, np.float32))
    sess.update(delta)                     # buffered, NOT applied
    stale = np.asarray(sess.query().embeddings)
    # deferred semantics: consistently stale — identical to pre-update
    assert np.array_equal(before, stale)
    sess.flush_updates()
    after = np.asarray(sess.query().embeddings)
    g2 = sess.plan.graph
    eng = Engine((params, "gcn"), cluster="1A+2B+1C", executor="sim",
                 aggregation="segment_sum")
    want = np.asarray(eng.compile(g2).session().query().embeddings)
    assert np.array_equal(after, want)
    assert not np.array_equal(after, before)   # the delta really landed


def test_sync_session_cache_survives_adapt():
    """adapt() re-assignment must not corrupt single-family caches
    (their numerics are assignment-independent)."""
    params, sess = _line_session(activation_cache=True,
                                 frontier_max_fraction=1.0)
    sess.query()
    for _ in range(3):
        sess.adapt()
    got = np.asarray(sess.query().embeddings)
    g2 = sess.plan.graph
    eng = Engine((params, "gcn"), cluster="1A+2B+1C", executor="sim",
                 aggregation="segment_sum")
    want = np.asarray(eng.compile(g2).session().query().embeddings)
    assert np.array_equal(got, want)
