"""End-to-end behaviour tests for the Fograph serving system.

These assert the paper's *qualitative claims* hold in our reproduction:
fog beats cloud, Fograph beats straw-man fog, DAQ costs <1% accuracy,
pipelining lifts throughput, and the full five-step workflow runs.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import compression, placement, simulation
from repro.gnn import datasets, models
from repro.gnn.layers import EdgeList
from repro.runtime import serving


@pytest.fixture(scope="module")
def siot_setup():
    g = datasets.load("siot", scale=0.15, seed=0)
    params, _ = models.train_node_classifier(
        jax.random.PRNGKey(0), "gcn", g, steps=80)
    return g, params


def test_fog_beats_cloud_and_fograph_beats_strawman(siot_setup):
    """Paper Fig. 3 + Fig. 11 orderings."""
    g, params = siot_setup
    cluster = simulation.make_cluster("1A+4B+1C", "4g", g)
    fogs = cluster.fog_specs(seed=0)
    cloud = simulation.simulate_cloud(cluster)
    single = simulation.simulate_single_fog(cluster)
    strawman = simulation.simulate_multi_fog(
        cluster, placement.iep_place(g, fogs, strategy="random", seed=0,
                                     sync_cost=cluster.sync_cost))
    fograph = simulation.simulate_multi_fog(
        cluster, placement.iep_place(g, fogs, strategy="iep", seed=0,
                                     sync_cost=cluster.sync_cost),
        compress="daq")
    assert single.total_latency < cloud.total_latency
    assert fograph.total_latency < strawman.total_latency
    assert fograph.total_latency < cloud.total_latency
    assert fograph.throughput > cloud.throughput
    # cloud execution is a tiny fraction (paper: <2%)
    assert cloud.breakdown()["execute"] / cloud.total_latency < 0.05


def test_collection_reduction_matches_paper_band(siot_setup):
    """Fog data collection ~60-70% lower than cloud (paper: 64/67/61%)."""
    g, _ = siot_setup
    # at the reduced test scale the log-tail term is relatively heavier
    # than at paper scale, so the band is wider than the paper's 61-67%
    for net, lo, hi in [("4g", 0.5, 0.85), ("5g", 0.5, 0.85),
                        ("wifi", 0.45, 0.85)]:
        cluster = simulation.make_cluster("1A+4B+1C", net, g)
        c = simulation.simulate_cloud(cluster).collect[0]
        f = simulation.simulate_single_fog(cluster).collect[0]
        red = 1 - f / c
        assert lo <= red <= hi, (net, red)


def test_daq_accuracy_drop_below_one_percent(siot_setup):
    """Paper Table IV: <0.1% drop on SIoT, <1% generally."""
    g, params = siot_setup
    edges = EdgeList.from_graph(g)
    ref = np.asarray(models.gnn_apply(params, "gcn", g.features, edges))
    packed = compression.daq_pack(g.features.astype(np.float64), g.degrees)
    rec = compression.daq_unpack(packed)
    out = np.asarray(models.gnn_apply(params, "gcn", rec, edges))
    acc_ref = float(models.accuracy(ref, g.labels))
    acc_daq = float(models.accuracy(out, g.labels))
    assert acc_ref - acc_daq < 0.01


def test_full_workflow_deploy_serve_adapt(siot_setup):
    g, params = siot_setup
    svc = serving.deploy(g, params, "gcn", cluster_spec="1A+2B+1C",
                         network="wifi", compress="daq")
    r1 = serving.serve_query(svc)
    assert r1.embeddings.shape == (g.num_vertices, int(g.labels.max()) + 1)
    assert r1.latency > 0 and r1.throughput > 0
    mode = serving.adapt(svc)
    assert mode == "none"  # balanced cluster -> no action
    # overload one node -> diffusion or replan must fire
    svc.cluster.nodes[0].background_load = 3.0
    mode = serving.adapt(svc, lam=1.2)
    assert mode != "none"
    r2 = serving.serve_query(svc)
    assert np.isfinite(r2.latency)


def test_compression_reduces_wire_bytes_not_accuracy(siot_setup):
    g, params = siot_setup
    svc_raw = serving.deploy(g, params, "gcn", compress=None)
    svc_daq = serving.deploy(g, params, "gcn", compress="daq")
    r_raw = serving.serve_query(svc_raw)
    r_daq = serving.serve_query(svc_daq)
    assert r_daq.wire_bytes < 0.5 * r_raw.wire_bytes
    agree = np.mean(r_raw.embeddings.argmax(-1) == r_daq.embeddings.argmax(-1))
    assert agree > 0.99


def test_scalability_more_fogs_not_slower():
    """Paper Fig. 17: latency shrinks (or saturates) with more fog nodes."""
    g = datasets.load("rmat-20k", scale=0.1, seed=0)
    lat = {}
    for n in (2, 4, 6):
        cluster = simulation.make_cluster(f"{n}B", "wifi", g)
        fogs = cluster.fog_specs(seed=0)
        pl = placement.iep_place(g, fogs, seed=0,
                                 sync_cost=cluster.sync_cost)
        lat[n] = simulation.simulate_multi_fog(cluster, pl,
                                               compress="daq").total_latency
    assert lat[6] <= lat[2] * 1.05
