"""Substrate tests: optimizer, data pipeline, checkpointing, sharding rules,
HLO analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.checkpoint import io as ckpt
from repro.configs import registry
from repro.data.pipeline import SyntheticCorpus, input_specs
from repro.launch import hlo_analysis as ha
from repro.models import sharding as shd
from repro.models import transformer as tfm
from repro.models.config import INPUT_SHAPES, InputShape
from repro.optim.adamw import AdamW, warmup_cosine


# ---------------------------------------------------------------- optimizer

def test_adamw_optimizes_quadratic():
    opt = AdamW(learning_rate=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state = opt.apply(params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(state.step) == 200


def test_adamw_bf16_moments_and_weight_decay():
    opt = AdamW(learning_rate=0.01, weight_decay=0.5,
                moment_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    p2, _ = opt.apply(params, {"w": jnp.zeros((4, 4))}, state)
    assert float(p2["w"].mean()) < 1.0  # decay applied with zero grads


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(5))) == pytest.approx(5e-4)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=1e-2)
    assert float(lr(jnp.asarray(100))) < 2e-4


# ------------------------------------------------------------ data pipeline

def test_pipeline_deterministic_and_shaped():
    cfg = registry.reduced(registry.get("granite-3-2b"))
    shape = InputShape("t", seq_len=64, global_batch=4, kind="train")
    c1 = SyntheticCorpus(cfg, shape, seed=7)
    c2 = SyntheticCorpus(cfg, shape, seed=7)
    b1, b2 = c1.batch(3), c2.batch(3)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert b1["inputs"].shape == (4, 64)
    assert b1["targets"].shape == (4, 64)
    assert (b1["inputs"][:, 1:] == b1["targets"][:, :-1]).all()
    assert b1["inputs"].max() < cfg.vocab_size
    b4 = c1.batch(4)
    assert not np.array_equal(b1["inputs"], b4["inputs"])


def test_pipeline_learnable_structure():
    """A model must be able to beat uniform loss on the synthetic corpus."""
    cfg = registry.reduced(registry.get("qwen1.5-0.5b"))
    shape = InputShape("t", seq_len=64, global_batch=8, kind="train")
    corpus = SyntheticCorpus(cfg, shape, seed=0)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(learning_rate=3e-3)
    step = jax.jit(tfm.make_train_step(cfg, opt, microbatches=1))
    state = opt.init(params)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in corpus.batch(i).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3  # actually learning


def test_input_specs_all_combinations():
    for arch in registry.list_archs():
        cfg = registry.get(arch)
        for shape in INPUT_SHAPES.values():
            spec = input_specs(cfg, shape)
            assert all(hasattr(v, "shape") for v in spec.values())
            if shape.kind == "decode":
                assert spec["tokens"].shape == (shape.global_batch, 1)
            elif cfg.input_mode == "embeddings":
                assert spec["inputs"].shape[-1] == cfg.d_model


# ------------------------------------------------------------- checkpointing

def test_checkpoint_roundtrip(tmp_path):
    cfg = registry.reduced(registry.get("granite-3-2b"))
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW()
    state = opt.init(params)
    d = str(tmp_path / "ckpt")
    ckpt.save(d, 7, {"params": params, "opt": state})
    assert ckpt.latest_step(d) == 7
    target = jax.eval_shape(lambda: {"params": params, "opt": state})
    restored = ckpt.restore(d, target)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_gc_keeps_latest(tmp_path):
    d = str(tmp_path / "c")
    for s in range(6):
        ckpt.save(d, s, {"x": jnp.ones(3) * s}, keep=2)
    assert ckpt.latest_step(d) == 5
    files = sorted(os.listdir(d))
    assert len(files) == 2


# ----------------------------------------------------------------- sharding

@pytest.fixture(scope="module")
def mesh4():
    devs = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, ("data", "model"))


def test_param_specs_divisibility_invariant():
    """Every sharded dim must be divisible by the mesh axis it maps to."""
    import jax.sharding as jsh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in registry.list_archs():
        cfg = registry.get(arch)
        abs_p = tfm.abstract_params(cfg)
        # simulate 16-way model axis via the rule function directly
        flat = jax.tree_util.tree_flatten_with_path(abs_p)[0]
        for path, leaf in flat:
            spec = shd._spec_for_param(path, leaf.shape, cfg, 16)
            for ax, part in enumerate(spec):
                if part == "model":
                    assert leaf.shape[ax] % 16 == 0, (arch, path, leaf.shape)


def test_fsdp_specs_add_data_axis():
    cfg = registry.get("deepseek-v3-671b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert shd.needs_fsdp(cfg, jax.make_mesh((16, 16), ("data", "model"))
                          if False else mesh, train=True) in (True, False)
    # direct rule check: expert weights get both axes at 16x16 sizes
    shd._FSDP_SIZE.update({"data": 16, "model": 16})
    spec = shd._spec_for_param(
        (jax.tree_util.DictKey("stages"), jax.tree_util.SequenceKey(0),
         jax.tree_util.DictKey("ffn"), jax.tree_util.DictKey("w_gate")),
        (58, 256, 7168, 2048), cfg, 16, fsdp_axes=("data",))
    assert "model" in spec and "data" in spec


def test_batch_spec_fallback():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # on a trivial mesh batch=1 may map to the size-1 data axis or replicate
    assert shd.batch_spec(mesh, 1, 2) in (P(None, None), P(("data",), None),
                                          P("data", None))
    # batch=3 on a size-1 data axis: 3 % 1 == 0, also fine; the invariant
    # is that any named axis has size dividing the batch
    spec = shd.batch_spec(mesh, 3, 2)
    for part in spec:
        if part:
            axes = part if isinstance(part, tuple) else (part,)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert 3 % total == 0


# -------------------------------------------------------------- hlo analysis

SAMPLE_HLO = """\
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant({...})
  %d = f32[8,8]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,8]{1,0} all-gather(%d), dimensions={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ag)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> (s32[], f32[8,8]) {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(%z, %a)
  ROOT %w0 = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_hlo_analyzer_scales_by_trip_count():
    cost = ha.analyze(SAMPLE_HLO)
    # one 8x8x8 dot per iteration, 10 iterations
    assert cost.flops == pytest.approx(10 * 2 * 8 * 8 * 8)
    assert cost.collective_bytes["all-gather"] == pytest.approx(
        10 * 8 * 8 * 4)


def test_hlo_analyzer_on_real_module():
    """Analyzer FLOPs for a compiled scan-matmul ~= analytic count."""
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=12)
        return h

    x = jnp.ones((32, 64))
    w = jnp.ones((64, 64))
    compiled = jax.jit(f).lower(x, w).compile()
    cost = ha.analyze(compiled.as_text())
    expect = 12 * 2 * 32 * 64 * 64
    assert cost.flops == pytest.approx(expect, rel=0.05)
