"""Dynamic-graph update subsystem: GraphDelta semantics, incremental
repair / dirty-shard rebuild parity against full Engine.compile, session
consistency policies, mixed update/query serving, and the batched
run_many fast path."""
import dataclasses
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep:
# property tests skip cleanly when hypothesis is not installed

from repro.api import (Engine, GraphDelta, PARTITIONERS, UpdateRequest,
                       traces)
from repro.api.registry import EXECUTORS
from repro.api.server import Response, UpdateResponse
from repro.core import incremental
from repro.gnn import datasets, models
from repro.runtime import bsp

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    g = datasets.load("siot", scale=0.05, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    return g, params


def _random_delta(g, rng, frac=0.02, structural=True):
    v = g.num_vertices
    k = max(1, int(frac * v))
    feats = rng.normal(size=(k, g.feature_dim)).astype(np.float32)
    fanout = rng.integers(1, 4, size=k)
    add_edges = np.stack([np.repeat(v + np.arange(k), fanout),
                          rng.integers(0, v, int(fanout.sum()))], axis=1)
    removed = rng.choice(v, size=max(1, k // 2), replace=False)
    eidx = rng.integers(0, g.num_edges, size=k)
    rem_edges = np.stack([g.senders[eidx], g.receivers[eidx]], axis=1)
    upd = np.setdiff1d(rng.choice(v, size=k, replace=False), removed)
    if not structural:
        return GraphDelta(feature_ids=upd, feature_values=rng.normal(
            size=(len(upd), g.feature_dim)))
    return GraphDelta(add_features=feats, add_edges=add_edges,
                      remove_vertices=removed, remove_edges=rem_edges,
                      feature_ids=upd,
                      feature_values=rng.normal(
                          size=(len(upd), g.feature_dim)))


# ----------------------------------------------------------------------------
# GraphDelta semantics
# ----------------------------------------------------------------------------

def test_graphdelta_validation(setup):
    g, _ = setup
    v, f = g.num_vertices, g.feature_dim
    with pytest.raises(ValueError, match="add_features"):
        GraphDelta(add_features=np.ones((2, f + 1))).validate(v, f)
    with pytest.raises(ValueError, match="remove_vertices"):
        GraphDelta(remove_vertices=[v + 5]).validate(v, f)
    with pytest.raises(ValueError, match="add_edges"):
        GraphDelta(add_edges=[[0, v]]).validate(v, f)  # no vertex added
    with pytest.raises(ValueError, match="feature_ids"):
        GraphDelta(feature_ids=[v], feature_values=np.ones((1, f))
                   ).validate(v, f)
    with pytest.raises(ValueError, match="same delta removes"):
        GraphDelta(remove_vertices=[3], feature_ids=[3],
                   feature_values=np.ones((1, f))).validate(v, f)
    with pytest.raises(ValueError, match="together"):
        GraphDelta(feature_ids=[1])
    with pytest.raises(ValueError, match="m, 2"):
        GraphDelta(add_edges=np.ones((2, 3)))
    # mis-shaped upserts must raise, not silently reshape
    with pytest.raises(ValueError, match="feature_values"):
        GraphDelta(feature_ids=[1, 2], feature_values=np.zeros((1, 4)))
    # an empty upsert set (ids filtered down to nothing) is a no-op
    empty_upd = GraphDelta(feature_ids=np.array([]),
                           feature_values=np.zeros((0, f)))
    assert empty_upd.is_empty
    # a single 1-D row is accepted for a single id
    one = GraphDelta(feature_ids=[2], feature_values=np.zeros(f))
    assert one.feature_values.shape == (1, f)
    assert GraphDelta().is_empty and not GraphDelta().is_structural
    d = GraphDelta(add_edges=[[0, 1]])
    assert d.is_structural and not d.is_empty


def test_mutate_graph_semantics(setup):
    g, _ = setup
    v, f = g.num_vertices, g.feature_dim
    delta = GraphDelta(
        add_features=np.full((2, f), 7.0, np.float32),
        remove_vertices=[0, 5],
        add_edges=[[v, 1], [v + 1, v], [v, 0]],   # last touches removed 0
        feature_ids=[1], feature_values=np.full((1, f), -3.0))
    g2, vmap = incremental.mutate_graph(g, delta)
    assert g2.num_vertices == v - 2 + 2
    assert vmap[0] == -1 and vmap[5] == -1
    assert vmap[1] == 0                       # survivors renumber in order
    assert vmap[v] == v - 2 and vmap[v + 1] == v - 1
    np.testing.assert_array_equal(g2.features[vmap[1]], -3.0)
    np.testing.assert_array_equal(g2.features[vmap[v]], 7.0)
    # the new edges exist (both directions); the edge to removed 0 dropped
    key = set(map(tuple, np.stack([g2.senders, g2.receivers], 1).tolist()))
    assert (vmap[v], vmap[1]) in key and (vmap[1], vmap[v]) in key
    assert (vmap[v], vmap[v + 1]) in key
    g2.validate()


# ----------------------------------------------------------------------------
# apply_delta parity vs full compile (the acceptance property)
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["sim", "single", "cloud"])
@pytest.mark.parametrize("aggregation", ["segment_sum", "pallas"])
def test_apply_delta_bit_identical_to_full_compile(setup, executor,
                                                   aggregation):
    """Incremental repair + query == full Engine.compile on the mutated
    graph, bit-for-bit, across executors and both aggregation paths."""
    g, params = setup
    eng = Engine((params, "gcn"), cluster="1A+2B+1C", executor=executor,
                 aggregation=aggregation)
    plan = eng.compile(g)
    rng = np.random.default_rng(7)
    delta = _random_delta(g, rng)
    plan2 = eng.apply_delta(plan, delta)
    assert plan2.provenance == "incremental"
    assert plan2.update_report.mode == "incremental"
    g2, _ = incremental.mutate_graph(g, delta)
    full = eng.compile(g2)
    r_inc = plan2.session().query()
    r_full = full.session().query()
    assert np.array_equal(r_inc.embeddings, r_full.embeddings)
    # plan cost metadata was refreshed for the mutated topology
    assert plan2.est_makespan > 0
    assert plan2.cluster.graph is plan2.graph
    assert plan2.graph.num_vertices == g2.num_vertices


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_apply_delta_property_randomized(setup, seed):
    """Seeded stand-in for the hypothesis property below: random delta
    chains stay bit-identical to full recompiles (runs even without
    hypothesis installed)."""
    g, params = setup
    eng = Engine((params, "gcn"), cluster="1A+2B+1C")
    plan = eng.compile(g)
    rng = np.random.default_rng(seed)
    # Each delta in the chain addresses the graph produced by the previous
    # one (the deferred-update contract); fold them by hand for the
    # full-compile reference.
    deltas, g_ref = [], plan.graph
    for j in range(3):
        d = _random_delta(g_ref, rng, frac=0.01,
                          structural=(seed % 2 == 0) or j > 0)
        deltas.append(d)
        g_ref, _ = incremental.mutate_graph(g_ref, d)
    plan2 = eng.apply_delta(plan, deltas)
    assert np.array_equal(plan2.session().query().embeddings,
                          eng.compile(g_ref).session().query().embeddings)


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_apply_delta_property_hypothesis(seed):
    """Property: for random deltas, the incrementally rebuilt partition
    buffers equal a from-scratch build of the mutated graph exactly."""
    g = datasets.load("siot", scale=0.03, seed=1)
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 8, 4])
    eng = Engine((params, "gcn"), cluster="1A+2B+1C", executor="mesh-bsp",
                 aggregation="pallas")
    plan = eng.compile(g)
    rng = np.random.default_rng(seed)
    delta = _random_delta(g, rng, frac=0.03)
    plan2 = eng.apply_delta(plan, delta)
    if plan2.provenance != "incremental":
        return   # threshold fallback: nothing incremental to compare
    ref = bsp.build_partitioned(plan2.graph, plan2.placement.assignment,
                                n=plan2.num_fogs, build_blocks=True)
    pg = plan2.partitioned
    for name in ("feats", "vertex_mask", "senders_global", "senders_halo",
                 "receivers_local", "edge_mask", "boundary_rows",
                 "boundary_mask", "part_of", "slot_of"):
        assert np.array_equal(getattr(ref, name), getattr(pg, name)), name
    for attr in ("local_csr", "halo_csr"):
        a, b = getattr(ref, attr), getattr(pg, attr)
        for f in ("blocks", "cols", "mask"):
            assert np.array_equal(getattr(a, f), getattr(b, f)), (attr, f)
        assert (a.src_rows, a.out_rows) == (b.src_rows, b.out_rows)


def test_apply_delta_mesh_bsp_subprocess():
    """mesh-bsp executor, both aggregation paths: a query on the repaired
    plan is bit-identical to one on a full recompile (same assignment)."""
    code = textwrap.dedent("""
        import dataclasses
        import numpy as np, jax
        from repro.api import Engine, GraphDelta
        from repro.core import incremental
        from repro.runtime import bsp
        from repro.gnn import datasets, models
        g = datasets.load('siot', scale=0.04, seed=0)
        params = models.gnn_init(jax.random.PRNGKey(0), 'sage',
                                 [g.feature_dim, 16, 8])
        rng = np.random.default_rng(3)
        v = g.num_vertices
        delta = GraphDelta(
            add_features=rng.normal(size=(6, g.feature_dim)),
            add_edges=np.stack([v + rng.integers(0, 6, 12),
                                rng.integers(0, v, 12)], 1),
            remove_vertices=rng.choice(v, 4, replace=False))
        for aggregation in ('segment_sum', 'pallas'):
            eng = Engine((params, 'sage'), cluster='4B',
                         executor='mesh-bsp', aggregation=aggregation)
            plan = eng.compile(g)
            plan2 = eng.apply_delta(plan, delta)
            assert plan2.update_report.mode == 'incremental'
            # full rebuild of the partition buffers at the same repaired
            # assignment: the dirty-shard path must be bit-identical
            full_pg = bsp.build_partitioned(
                plan2.graph, plan2.placement.assignment, n=plan2.num_fogs,
                build_blocks=aggregation == 'pallas')
            full = dataclasses.replace(plan2, partitioned=full_pg)
            r_inc = plan2.session().query()
            r_full = full.session().query()
            assert np.array_equal(r_inc.embeddings, r_full.embeddings), \\
                aggregation
            assert r_inc.exchange_bytes == r_full.exchange_bytes > 0
        print('OK')
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


# ----------------------------------------------------------------------------
# Edge cases and fallback
# ----------------------------------------------------------------------------

def test_empty_delta_is_noop(setup):
    g, params = setup
    eng = Engine((params, "gcn"), cluster="1A+2B+1C")
    plan = eng.compile(g)
    plan2 = eng.apply_delta(plan, GraphDelta())
    assert plan2.update_report.mode == "noop"
    assert plan2.partitioned is plan.partitioned
    assert plan2.graph is plan.graph
    assert np.array_equal(plan2.session().query().embeddings,
                          plan.session().query().embeddings)
    # force='recompile' must win over the noop short-circuit
    forced = eng.apply_delta(plan, GraphDelta(), force="recompile")
    assert forced.provenance == "recompile"
    assert forced.partitioned is not plan.partitioned


def test_poisoned_update_does_not_wedge_server(setup):
    """A delta rejected at apply time is consumed, not requeued: the
    requests behind it are still served on the next drain."""
    g, params = setup
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)
    srv = plan.server(max_batch=1)
    srv.submit(None, arrival_time=0.1)
    srv.submit(UpdateRequest(delta=GraphDelta(remove_vertices=[10 ** 6]),
                             arrival_time=0.2))
    srv.submit(None, arrival_time=0.3)
    with pytest.raises(ValueError, match="remove_vertices") as ei:
        srv.drain()
    # responses produced before the failure ride on the exception
    partial = ei.value.partial_responses
    assert [type(r).__name__ for r in partial] == ["Response"]
    out = srv.drain()    # bad update was dropped, queue unwedged
    assert [type(r).__name__ for r in out] == ["Response"]
    with pytest.raises(TypeError, match="GraphDelta"):
        srv.submit(UpdateRequest(delta="oops"))


def test_feature_only_delta_reuses_layout(setup):
    g, params = setup
    eng = Engine((params, "gcn"), cluster="1A+2B+1C")
    plan = eng.compile(g)
    rng = np.random.default_rng(0)
    delta = _random_delta(g, rng, structural=False)
    plan2 = eng.apply_delta(plan, delta)
    assert plan2.update_report.mode == "features"
    assert plan2.update_report.shards_rebuilt == 0
    g2, _ = incremental.mutate_graph(g, delta)
    assert np.array_equal(plan2.session().query().embeddings,
                          eng.compile(g2).session().query().embeddings)


def test_remove_last_vertex_in_shard(setup):
    """Emptying a whole partition keeps the plan serveable and the empty
    shard padded; parity with a full compile-side rebuild holds."""
    g, params = setup
    eng = Engine((params, "gcn"), cluster="1A+2B+1C")
    plan = eng.compile(g)
    smallest = int(np.argmin(plan.vertices_per_fog()))
    doomed = np.flatnonzero(plan.placement.assignment == smallest)
    plan2 = eng.apply_delta(plan, GraphDelta(remove_vertices=doomed))
    assert plan2.update_report.mode == "incremental"
    assert plan2.vertices_per_fog()[smallest] == 0
    assert plan2.partitioned.n == plan.num_fogs   # shard survives, empty
    g2, _ = incremental.mutate_graph(g, GraphDelta(remove_vertices=doomed))
    full = eng.compile(g2)
    assert np.array_equal(plan2.session().query().embeddings,
                          full.session().query().embeddings)


def test_threshold_fallback_to_recompile(setup):
    g, params = setup
    eng = Engine((params, "gcn"), cluster="1A+2B+1C")
    plan = eng.compile(g)
    rng = np.random.default_rng(0)
    delta = _random_delta(g, rng, frac=0.02)
    # The imbalance knob bounds degradation relative to the pre-update
    # imbalance (floored at 1.0) — a sub-1 factor always trips it.
    tight = eng.apply_delta(plan, delta, max_imbalance=0.25)
    assert tight.provenance == "recompile"
    assert "imbalance" in tight.update_report.reason
    assert tight.update_report.imbalance_before > 0
    forced = eng.apply_delta(plan, delta, force="recompile")
    assert forced.provenance == "recompile"
    assert forced.update_report.reason == "forced"
    # a recompiled plan still answers bit-identically (single-program
    # numerics are partition-independent)
    g2, _ = incremental.mutate_graph(g, delta)
    assert np.array_equal(forced.session().query().embeddings,
                          eng.compile(g2).session().query().embeddings)
    with pytest.raises(ValueError, match="force"):
        eng.apply_delta(plan, delta, force="maybe")
    # knobs ride on the config
    assert plan.config.update_max_imbalance == 2.0


def test_heterogeneous_skew_alone_does_not_trip_fallback(setup):
    """IEP sizes partitions to capability; that intended skew must not
    force a recompile on every delta (the knob bounds *degradation*)."""
    g, params = setup
    eng = Engine((params, "gcn"), cluster="1A+2B+1C")
    plan = eng.compile(g)
    before = incremental.imbalance_of(plan.placement.assignment,
                                      plan.num_fogs)
    delta = GraphDelta(add_edges=[[0, 9]])
    # knob barely above 1: passes whenever the repair does not degrade
    # balance, regardless of how skewed the compiled plan already is
    plan2 = eng.apply_delta(plan, delta, max_imbalance=1.01)
    assert plan2.provenance == "incremental"
    assert plan2.update_report.imbalance <= 1.01 * max(1.0, before)


def test_apply_delta_repairs_adapted_assignment(setup):
    """Repairs starting from a session-adapted assignment must not reuse
    the plan's shard layout (it was built for a different assignment)."""
    g, params = setup
    eng = Engine((params, "gcn"), cluster="1A+2B+1C", executor="mesh-bsp",
                 aggregation="pallas")
    plan = eng.compile(g)
    # simulate an adaptation: migrate a handful of vertices between fogs
    adapted = plan.placement.assignment.copy()
    movers = np.flatnonzero(adapted == 0)[:3]
    adapted[movers] = 1
    rng = np.random.default_rng(11)
    delta = _random_delta(g, rng, frac=0.01)
    plan2 = eng.apply_delta(plan, delta, assignment=adapted)
    assert plan2.update_report.mode == "incremental"
    ref = bsp.build_partitioned(plan2.graph, plan2.placement.assignment,
                                n=plan2.num_fogs, build_blocks=True)
    for attr in ("local_csr", "halo_csr"):
        a, b = getattr(ref, attr), getattr(plan2.partitioned, attr)
        for f in ("blocks", "cols", "mask"):
            assert np.array_equal(getattr(a, f), getattr(b, f)), (attr, f)
    # feature-only deltas on an adapted base also relayout for it
    fd = GraphDelta(feature_ids=[1], feature_values=np.ones(
        (1, g.feature_dim)))
    plan3 = eng.apply_delta(plan, fd, assignment=adapted)
    assert np.array_equal(plan3.partitioned.part_of, adapted)


def test_sync_update_failure_does_not_poison_the_buffer(setup):
    g, params = setup
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)
    s = plan.session()
    with pytest.raises(ValueError, match="remove_vertices"):
        s.update(GraphDelta(remove_vertices=[10 ** 9]))
    assert s.pending_updates == 0           # rejected at admission
    rep = s.update(GraphDelta(add_edges=[[0, 9]]))   # not blocked
    assert rep is not None
    # deferred admission also validates against the projected graph
    s2 = plan.session(updates="deferred")
    v = s2.plan.graph.num_vertices
    s2.update(GraphDelta(remove_vertices=[v - 1]))
    with pytest.raises(ValueError, match="remove_vertices"):
        s2.update(GraphDelta(remove_vertices=[v - 1]))  # gone post-delta-1
    assert s2.pending_updates == 1
    assert s2.flush_updates().mode == "incremental"


def test_untimed_update_keeps_fifo_position(setup):
    """A bare submit(delta) (no arrival time) must not jump ahead of
    previously submitted timed queries."""
    g, params = setup
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)
    srv = plan.server(max_batch=1)
    v0 = plan.graph.num_vertices
    srv.submit(None, arrival_time=0.5)
    srv.submit(None, arrival_time=1.0)
    srv.submit(GraphDelta(add_features=np.ones((1, g.feature_dim),
                                               np.float32),
                          add_edges=[[v0, 0]]))
    out = srv.drain()
    assert [type(r).__name__ for r in out] == ["Response", "Response",
                                               "UpdateResponse"]
    # both queries were served against the pre-update graph
    assert all(r.embeddings.shape[0] == v0 for r in out[:2])


def test_delta_cannot_starve_partitions(setup):
    g, params = setup
    eng = Engine((params, "gcn"), cluster="1A+2B+1C")
    plan = eng.compile(g)
    with pytest.raises(ValueError, match="fog partitions"):
        eng.apply_delta(plan, GraphDelta(
            remove_vertices=np.arange(g.num_vertices - 2)))


# ----------------------------------------------------------------------------
# Session + Server integration
# ----------------------------------------------------------------------------

def test_session_sync_policy(setup):
    g, params = setup
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)
    s = plan.session()
    v0 = s.plan.graph.num_vertices
    rep = s.update(GraphDelta(
        add_features=np.ones((3, g.feature_dim), np.float32),
        add_edges=[[v0, 0], [v0 + 1, 1], [v0 + 2, 2]]))
    assert rep is not None and rep.mode == "incremental"
    assert s.pending_updates == 0
    assert s.plan.graph.num_vertices == v0 + 3
    r = s.query()
    assert r.embeddings.shape[0] == v0 + 3
    with pytest.raises(TypeError, match="GraphDelta"):
        s.update("not a delta")
    with pytest.raises(ValueError, match="updates"):
        plan.session(updates="eventually")


def test_session_deferred_policy_coalesces(setup):
    g, params = setup
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)
    s = plan.session(updates="deferred")
    for i in range(3):
        assert s.update(GraphDelta(add_edges=[[i, i + 7]])) is None
    assert s.pending_updates == 3
    assert s.plan.graph is plan.graph          # still stale
    rep = s.flush_updates()
    assert rep is not None and rep.num_deltas == 3
    assert s.pending_updates == 0
    assert s.flush_updates() is None


def test_server_mixed_stream_sync_vs_deferred(setup):
    g, params = setup
    plan = Engine((params, "gcn"), cluster="1A+2B+1C").compile(g)

    def delta_fn(i, rng):
        u = int(rng.integers(0, 40))
        return GraphDelta(add_edges=[[u, (u + 41) % 80]])

    trace = traces.mixed(24, rate=8.0, delta_fn=delta_fn,
                         update_fraction=0.25, seed=5)
    n_upd = sum(isinstance(t, UpdateRequest) for t in trace)
    assert 0 < n_upd < len(trace)
    assert all(t.arrival_time >= 0 for t in trace)

    out_sync = plan.server(max_batch=4, updates="sync").replay(list(trace))
    ups = [r for r in out_sync if isinstance(r, UpdateResponse)]
    assert len(ups) == n_upd and all(u.applied for u in ups)

    srv = plan.server(max_batch=4, updates="deferred")
    out_def = srv.replay(list(trace))
    ups = [r for r in out_def if isinstance(r, UpdateResponse)]
    assert all(not u.applied for u in ups)
    assert srv.last_update_report is not None
    assert srv.last_update_report.num_deltas == n_upd
    assert srv.session.pending_updates == 0    # drained flush

    # query responses agree request-by-request? No — sync queries see the
    # mutated graph earlier. But both policies serve every query, and the
    # summary counts both kinds.
    q_sync = [r for r in out_sync if isinstance(r, Response)]
    q_def = [r for r in out_def if isinstance(r, Response)]
    assert len(q_sync) == len(q_def) == len(trace) - n_upd
    summary = srv.summarize(out_def)
    assert summary["updates"] == n_upd
    assert summary["requests"] == len(trace) - n_upd


def test_traces_mixed_validation():
    with pytest.raises(ValueError, match="update_fraction"):
        traces.mixed(4, 1.0, delta_fn=lambda i, r: GraphDelta(),
                     update_fraction=1.5)
    with pytest.raises(ValueError, match="rate"):
        traces.mixed(4, 0.0, delta_fn=lambda i, r: GraphDelta())


# ----------------------------------------------------------------------------
# Satellites: batched run_many, metis, lz4
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gcn", "sage", "gat"])
def test_run_many_batched_fast_path_bit_identical(setup, kind):
    """Every kind joins batched execution — GAT through the vmapped
    edge-weighted path (its attention softmax re-weights edges per layer,
    so it cannot use the pre-blocked kernel grid)."""
    g, _ = setup
    params = models.gnn_init(jax.random.PRNGKey(1), kind,
                             [g.feature_dim, 16, 8])
    plan = Engine((params, kind), cluster="1A+2B+1C").compile(g)
    backend = EXECUTORS.resolve("single")
    rng = np.random.default_rng(0)
    feats = [g.features + rng.normal(
        scale=0.01, size=g.features.shape).astype(np.float32)
        for _ in range(3)]
    fast = backend.run_many(plan, feats, plan.placement.assignment,
                            plan.partitioned, "halo")
    slow = [backend.run(plan, f, plan.placement.assignment,
                        plan.partitioned, "halo") for f in feats]
    assert len(fast) == 3
    for a, b in zip(fast, slow):
        assert np.array_equal(a, b)


def test_metis_partitioner_registry_entry(setup):
    pymetis = pytest.importorskip("pymetis")
    del pymetis
    g, params = setup
    assert "metis" in PARTITIONERS
    from repro.core.partition import bgp, metis, partition_stats
    a_metis = metis(g, 4)
    assert a_metis.shape == (g.num_vertices,)
    assert set(np.unique(a_metis)) <= set(range(4))
    # parity with bgp: comparable balance and cut quality
    s_metis = partition_stats(g, a_metis)
    s_bgp = partition_stats(g, bgp(g, 4))
    assert s_metis["imbalance"] < 2.0
    assert s_metis["cut_fraction"] <= max(3 * s_bgp["cut_fraction"], 0.9)
    # and the full pipeline runs through the registry key
    plan = Engine((params, "gcn"), cluster="1A+2B+1C",
                  partitioner="metis").compile(g)
    r = plan.session().query()
    assert r.embeddings.shape == (g.num_vertices, 8)


def test_metis_missing_is_a_helpful_absence():
    try:
        import pymetis  # noqa: F401
        pytest.skip("pymetis installed; absence path cannot trip")
    except ImportError:
        pass
    assert "metis" not in PARTITIONERS
    from repro.core.partition import metis
    with pytest.raises(ImportError, match="pymetis"):
        metis(datasets.load("siot", scale=0.02, seed=0), 2)


def test_lz4_codec_stage(setup):
    g, _ = setup
    from repro.core import compression
    feats = np.asarray(g.features, np.float64)
    have_lz4 = compression._lz4frame is not None
    if have_lz4:
        packed = compression.daq_pack(feats, g.degrees, codec="lz4")
        assert packed.lossless_codec == "lz4"
        assert 0 < packed.nbytes(True) < feats.nbytes
    else:
        with pytest.warns(RuntimeWarning, match="falling back to zlib"):
            packed = compression.daq_pack(feats, g.degrees, codec="lz4")
        assert packed.lossless_codec == "zlib"
    # numerics are codec-independent (lossless stage only shrinks bytes)
    ref = compression.daq_pack(feats, g.degrees)
    assert np.array_equal(compression.daq_unpack(packed),
                          compression.daq_unpack(ref))
    with pytest.raises(ValueError, match="unknown lossless codec"):
        compression.daq_pack(feats, g.degrees, codec="zstd")
    # auto resolves to whatever is available without warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        auto = compression.daq_pack(feats, g.degrees, codec="auto")
    assert auto.lossless_codec == ("lz4" if have_lz4 else "zlib")


def test_daq_lz4_compressor_end_to_end(setup):
    g, params = setup
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        r = Engine((params, "gcn"), cluster="1A+2B+1C",
                   compressor="daq_lz4").compile(g).session().query()
        ref = Engine((params, "gcn"), cluster="1A+2B+1C",
                     compressor="daq").compile(g).session().query()
    assert np.array_equal(r.embeddings, ref.embeddings)
    assert r.wire_bytes > 0
