"""Request-level Server: batching semantics, pipeline timings, traces.

The load-bearing guarantees:
  * batched Server responses are numerically IDENTICAL (bit-for-bit) to
    the same requests served one-by-one via Session.query, per executor;
  * queue/batch/overlap timing fields are internally consistent;
  * pipelined micro-batching beats the serial Session.stream loop on a
    Poisson trace (the paper's §III-D speedup, acceptance criterion).
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import numpy as np
import pytest

from repro.api import Engine, Request, Server, traces
from repro.core import simulation
from repro.gnn import datasets, models

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    g = datasets.load("siot", scale=0.08, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 32, 8])
    plan = Engine((params, "gcn"), cluster="1A+2B+1C",
                  compressor="daq").compile(g)
    return g, params, plan


# ----------------------------------------------------------------------------
# Batching semantics: batched == serial, bit for bit
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("executor", ["sim", "single", "cloud"])
def test_batched_responses_identical_to_serial_queries(setup, executor):
    g, params, plan = setup
    rng = np.random.default_rng(0)
    feats = [None] + [g.features + rng.normal(scale=0.01, size=g.features.shape)
                      for _ in range(5)]
    serial = [plan.session(executor=executor).query(f) for f in feats]
    server = plan.server(max_batch=4, max_wait=1e9, executor=executor)
    batched = server.replay([Request(features=f, arrival_time=0.0)
                             for f in feats])
    assert len(batched) == len(serial)
    assert max(r.batch_size for r in batched) > 1   # coalescing happened
    for b, s in zip(batched, serial):
        assert np.array_equal(b.embeddings, s.embeddings)   # bit-identical
        assert b.backend == s.backend == executor


def test_mesh_bsp_batched_identical_subprocess():
    """mesh-bsp through the Server: batched == serial, real device mesh."""
    code = textwrap.dedent("""
        import numpy as np, jax
        from repro.api import Engine, Request
        g_mod = __import__('repro.gnn.datasets', fromlist=['load'])
        from repro.gnn import datasets, models
        g = datasets.load('yelp', scale=0.06, seed=3)
        params = models.gnn_init(jax.random.PRNGKey(0), 'sage',
                                 [g.feature_dim, 16, 8])
        plan = Engine((params, 'sage'), cluster='4B', compressor='daq',
                      executor='mesh-bsp').compile(g)
        serial = [plan.session().query() for _ in range(3)]
        batched = plan.server(max_batch=4, max_wait=1e9).replay(
            [Request(arrival_time=0.0) for _ in range(3)])
        assert batched[0].batch_size == 3
        for b, s in zip(batched, serial):
            assert np.array_equal(b.embeddings, s.embeddings)
        print('OK')
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


def test_mixed_executor_requests_do_not_coalesce(setup):
    g, params, plan = setup
    reqs = [Request(arrival_time=0.0),
            Request(arrival_time=0.0, executor="single"),
            Request(arrival_time=0.0)]
    out = plan.server(max_batch=8, max_wait=1e9).replay(reqs)
    assert [r.backend for r in out] == ["sim", "single", "sim"]
    # FIFO batching: the incompatible request splits the batch
    assert all(r.batch_size == 1 for r in out)


# ----------------------------------------------------------------------------
# Timing-field consistency
# ----------------------------------------------------------------------------

@pytest.mark.parametrize("trace_fn", [traces.poisson, traces.constant,
                                      traces.bursty])
def test_response_timing_fields_consistent(setup, trace_fn):
    g, params, plan = setup
    server = plan.server(max_batch=4, max_wait=0.05)
    responses = server.replay(trace_fn(20, 8.0, seed=2))
    assert len(responses) == 20
    assert sorted(r.request_id for r in responses) == list(range(20))
    for r in responses:
        assert r.queue_delay >= 0.0
        assert r.collect_time > 0.0 and r.execute_time > 0.0
        assert r.latency >= max(r.collect_time, r.execute_time)
        assert r.latency >= r.queue_delay
        assert r.service_start >= r.arrival_time
        assert r.finish_time == pytest.approx(r.arrival_time + r.latency)
        assert r.overlap_saved >= 0.0
        assert 1 <= r.batch_size <= 4
        assert r.breakdown["total"] == pytest.approx(r.latency)
    # batches never overlap in their collect stage and execute in order
    by_batch = {}
    for r in responses:
        by_batch.setdefault(r.batch_index, r)
    starts = [by_batch[k].service_start for k in sorted(by_batch)]
    assert starts == sorted(starts)


def test_batch_accounting_amortizes_costs(setup):
    """B=1 reproduces single-query pricing exactly; B>1 is cheaper than B
    serial queries (coalesced tail + one sync round), never cheaper than
    one."""
    g, params, plan = setup
    one = simulation.simulate("multi", plan.cluster, plan.placement,
                              compress="daq")
    ref = simulation.simulate("multi", plan.cluster, plan.placement,
                              compress="daq", batch_size=1)
    assert ref.total_latency == one.total_latency
    assert ref.wire_bytes == one.wire_bytes
    for b in (2, 4, 8):
        res = simulation.simulate("multi", plan.cluster, plan.placement,
                                  compress="daq", batch_size=b)
        assert one.total_latency < res.total_latency < b * one.total_latency
        assert res.wire_bytes == pytest.approx(b * one.wire_bytes)
        assert res.throughput > one.throughput


def test_pipeline_schedule_overlap_model():
    # Two batches: second's collection fully overlaps first's execution.
    sched = simulation.pipeline_schedule(
        [(0.0, 1.0, 2.0), (0.0, 1.0, 2.0), (0.0, 1.0, 2.0)])
    assert [s.collect_start for s in sched] == [0.0, 1.0, 2.0]
    assert sched[-1].execute_end == 1.0 + 3 * 2.0     # steady state: max(C,E)
    assert sched[1].overlap_saved == 1.0              # fully hidden collect
    serial = simulation.pipeline_schedule(
        [(0.0, 1.0, 2.0)] * 3, pipelined=False)
    assert serial[-1].execute_end == 3 * 3.0
    for s in serial:
        assert s.overlap_saved == 0.0


# ----------------------------------------------------------------------------
# Throughput: pipelined micro-batching beats the serial loop
# ----------------------------------------------------------------------------

def test_server_beats_serial_stream_on_poisson_trace(setup):
    g, params, plan = setup
    trace = traces.poisson(24, rate=10.0, seed=1)
    serial = plan.server(max_batch=1, pipelined=False).replay(list(trace))
    piped = plan.server(max_batch=8, max_wait=0.05).replay(list(trace))
    s0, s1 = Server.summarize(serial), Server.summarize(piped)
    assert s1["makespan_s"] < s0["makespan_s"]
    assert s1["throughput_rps"] > s0["throughput_rps"]
    assert s1["latency_mean_s"] < s0["latency_mean_s"]
    assert s1["mean_batch"] > 1.0
    assert s1["overlap_saved_s"] > 0.0
    # and the numerics still agree request-by-request
    for a, b in zip(serial, piped):
        assert np.array_equal(a.embeddings, b.embeddings)


# ----------------------------------------------------------------------------
# Session stage split + stream shim
# ----------------------------------------------------------------------------

def test_session_stages_compose_to_query(setup):
    g, params, plan = setup
    sess = plan.session()
    feats = sess.collect()
    emb = sess.execute(feats)
    res = sess.account()
    q = plan.session().query()
    assert np.array_equal(emb, q.embeddings)
    assert res.total_latency == pytest.approx(q.latency)


def test_stream_shim_matches_query_and_warns(setup):
    g, params, plan = setup
    q = plan.session().query()
    with pytest.warns(DeprecationWarning, match="Server.replay|Server"):
        rs = list(plan.session().stream(3))
    assert len(rs) == 3
    for r in rs:
        assert np.array_equal(r.embeddings, q.embeddings)
        assert r.latency == pytest.approx(q.latency)   # serial accounting
        assert r.queue_delay == 0.0 and r.batch_size == 1


def test_stream_shim_stays_lazy(setup):
    """The deprecated shim serves one query per next(), like the old loop."""
    g, params, plan = setup
    sess = plan.session()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        it = sess.stream(5)
        assert sess.num_queries == 0    # nothing served until consumed
        next(it)
    assert sess.num_queries == 1


def test_stream_forwards_executor_override(setup):
    """Regression: stream used to drop the per-query executor override."""
    g, params, plan = setup
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        rs = list(plan.session().stream(2, executor="single"))
    assert [r.backend for r in rs] == ["single", "single"]
    # per-request override through replay wins over the replay-wide one
    out = plan.server(max_batch=1).replay(
        [Request(executor="cloud"), Request()], executor="single")
    assert [r.backend for r in out] == ["cloud", "single"]


def test_server_adapt_every_ticks_per_request(setup):
    g, params, plan = setup
    server = plan.server(max_batch=4, max_wait=1e9, adapt_every=2, lam=1.5)
    server.replay([Request(arrival_time=0.0) for _ in range(4)])
    assert server.session.num_queries == 4
    assert len(server.session.state.mode_history) == 2


def test_request_ids_stay_unique_across_replays(setup):
    g, params, plan = setup
    server = plan.server(max_batch=2)
    a = server.replay(traces.poisson(4, 8.0, seed=0))
    b = server.replay(traces.poisson(4, 8.0, seed=0))
    assert sorted(r.request_id for r in a + b) == list(range(8))


def test_bad_requests_rejected_at_admission_and_drain_requeues(setup):
    from repro.api import UnknownComponentError
    g, params, plan = setup
    server = plan.server(max_batch=1)
    with pytest.raises(UnknownComponentError, match="executor"):
        server.submit(executor="nope")          # rejected before queueing
    assert not server._pending
    # a failure mid-drain (here: wrongly shaped features) requeues the
    # failing and the not-yet-served requests instead of dropping them
    server.submit(arrival_time=0.0)
    server.submit(np.zeros((3, 3)), arrival_time=0.0)
    server.submit(arrival_time=0.0)
    with pytest.raises(Exception):
        server.drain()
    assert len(server._pending) == 2


def test_submit_drain_roundtrip_and_clock_persistence(setup):
    g, params, plan = setup
    server = plan.server(max_batch=2)
    server.submit(arrival_time=0.0)
    server.submit(arrival_time=0.0)
    first = server.drain()
    assert len(first) == 2 and first[0].batch_size == 2
    # the simulated clock persists: a new arrival at t=0 queues behind the
    # first batch's collection (though it may overlap its execution)
    late = server.replay([Request(arrival_time=0.0)])
    assert (late[0].service_start
            >= first[-1].service_start + first[-1].collect_time - 1e-9)
    assert late[0].queue_delay > 0.0
    assert server.num_batches == 2
