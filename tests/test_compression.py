"""DAQ + lossless compression: Thm 2 exactness, round-trip error bounds."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # optional dep:
# property tests skip cleanly when hypothesis is not installed

from repro.core import compression as comp
from repro.gnn import datasets
from repro.gnn.graph import degree_cdf


@given(st.integers(0, 5000), st.integers(16, 400))
@settings(max_examples=25, deadline=None)
def test_theorem2_matches_measured_bits(seed, n):
    """Thm 2's closed-form ratio == measured quantized payload bits."""
    rng = np.random.default_rng(seed)
    degrees = rng.zipf(1.5, size=n).astype(np.int64)
    feats = rng.normal(size=(n, 8))
    th = comp.quantile_thresholds(degrees)
    packed = comp.daq_pack(feats, degrees, thresholds=th, lossless=False)
    ratio = comp.theorem2_ratio(degree_cdf_of(degrees), th)
    assert packed.measured_ratio == pytest.approx(ratio, rel=1e-12)


def degree_cdf_of(degrees):
    hist = np.bincount(degrees).astype(np.float64)
    cdf = np.cumsum(hist) / hist.sum()

    def F(d):
        d = np.asarray(d, np.int64)
        return np.where(d < 0, 0.0, cdf[np.minimum(d, len(cdf) - 1)])

    return F


def test_theorem2_limits():
    """All-low-degree -> ratio 1 (q0=64); all-high -> q3/Q = 8/64."""
    lo = np.full(100, 1)
    hi = np.full(100, 1000)
    f_lo = degree_cdf_of(lo)
    f_hi = degree_cdf_of(hi)
    assert comp.theorem2_ratio(f_lo, (500, 600, 700)) == pytest.approx(1.0)
    assert comp.theorem2_ratio(f_hi, (2, 3, 4)) == pytest.approx(8 / 64)


@given(st.integers(0, 1000))
@settings(max_examples=20, deadline=None)
def test_daq_roundtrip_error_bounds(seed):
    """Dequant error per element <= scale/2 = range/(2(2^b - 1))."""
    rng = np.random.default_rng(seed)
    n, f = 64, 16
    feats = rng.normal(size=(n, f)) * 10
    degrees = rng.zipf(1.5, size=n).astype(np.int64)
    packed = comp.daq_pack(feats, degrees, lossless=False)
    rec = comp.daq_unpack(packed).astype(np.float64)
    rng_row = feats.max(1) - feats.min(1)
    for b in (8, 16):
        ids = np.flatnonzero(packed.bits_per_vertex == b)
        if ids.size:
            bound = rng_row[ids] / (2 * (2 ** b - 1)) + 1e-9
            err = np.abs(rec[ids] - feats[ids]).max(axis=1)
            assert (err <= bound * 1.001).all()
    # 64-bit bin is lossless
    ids = np.flatnonzero(packed.bits_per_vertex == 64)
    if ids.size:
        assert np.abs(rec[ids] - feats[ids]).max() < 1e-6


def test_quantile_binning_assigns_all_four_levels():
    g = datasets.load("siot", scale=0.05, seed=0)
    bits = comp.assign_bits(g.degrees)
    assert set(np.unique(bits)) <= {8, 16, 32, 64}
    assert len(set(np.unique(bits))) >= 3  # heavy tail hits several bins


def test_high_degree_gets_fewer_bits():
    degrees = np.array([0, 10, 100, 1000])
    bits = comp.assign_bits(degrees, thresholds=(5, 50, 500))
    assert list(bits) == [64, 32, 16, 8]


def test_lossless_stage_helps_on_sparse_onehot():
    """SIoT-style one-hot features compress heavily after byte shuffle."""
    g = datasets.load("siot", scale=0.05, seed=0)
    sizes = comp.end_to_end_sizes(g.features.astype(np.float64), g.degrees)
    assert sizes["wire_bytes"] < 0.1 * sizes["raw_bytes"]
    assert sizes["daq_bytes"] < 0.6 * sizes["raw_bytes"]


def test_uniform8_smaller_but_lossier_than_daq():
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(128, 32))
    degrees = rng.zipf(1.5, size=128).astype(np.int64)
    daq = comp.daq_pack(feats, degrees, lossless=False)
    uni = comp.uniform_pack(feats, 8, lossless=False)
    assert uni.quant_bits <= daq.quant_bits
    err_daq = np.abs(comp.daq_unpack(daq) - feats).mean()
    err_uni = np.abs(comp.daq_unpack(uni) - feats).mean()
    assert err_daq <= err_uni + 1e-9
