#!/usr/bin/env bash
# Tier-1 verification: full test suite from a clean checkout.
# pyproject.toml's [tool.pytest.ini_options] pythonpath handles src/, so no
# PYTHONPATH incantation is needed for pytest itself.
set -euo pipefail
cd "$(dirname "$0")/.."
python -m compileall -q src
# Lint stage: ruff (when available — config in pyproject.toml) plus the
# static plan/kernel/cache verifier over every partitioner x compressor x
# executor demo plan, so a broken invariant fails CI before any benchmark.
if command -v ruff >/dev/null 2>&1; then
    ruff check src tests
else
    echo "ci.sh: ruff not installed; skipping style lint" >&2
fi
PYTHONPATH=src python -m repro.analysis --demo --strict
python -m pytest -x -q "$@"
# Keep the throughput benchmark entry point from rotting: tiny sweep with a
# built-in pass/fail guard (pipelined server must beat the serial loop).
PYTHONPATH=src python benchmarks/throughput.py --smoke
# Aggregation roofline: the Pallas kernel paths must match segment_sum on
# every shard (exact for the float path, quantization-bounded for DAQ).
PYTHONPATH=src python benchmarks/roofline.py --smoke
# Dynamic-graph updates: incremental apply_delta must stay bit-identical
# to a full Engine.compile of the mutated graph.
PYTHONPATH=src python benchmarks/updates.py --smoke
# Incremental queries: the activation-cache dirty-frontier path must stay
# bit-identical to full recompute and take the frontier path every round.
PYTHONPATH=src python benchmarks/updates.py --smoke-incremental
# Batch-axis executor dispatch: batched run_many must stay bit-identical
# to the serial per-request loop (and beat it at B>=8).
PYTHONPATH=src python benchmarks/serving_latency.py --smoke
# SLO control plane: under >= 2x overload the deadline/priority/degradation
# server must beat admit-all on goodput AND high-priority tail latency.
PYTHONPATH=src python benchmarks/slo.py --smoke
# Geo-distributed fleet: at >= 2 sites the fleet must beat the all-cloud
# baseline on p95, and one injected site failure must drop zero requests.
PYTHONPATH=src python benchmarks/fleet.py --smoke
# Node-level fault tolerance: seeded chaos must drop zero requests at every
# crash rate, an installed-but-empty schedule must cost <= 5% on p95, and a
# failover plan must equal a fresh compile on the surviving cluster.
PYTHONPATH=src python benchmarks/faults.py --smoke
