#!/usr/bin/env bash
# Tier-1 verification: full test suite from a clean checkout.
# pyproject.toml's [tool.pytest.ini_options] pythonpath handles src/, so no
# PYTHONPATH incantation is needed.
set -euo pipefail
cd "$(dirname "$0")/.."
python -m pytest -x -q "$@"
