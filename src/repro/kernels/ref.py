"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors its kernel's exact input layout so tests can sweep
shapes/dtypes and assert_allclose kernel-vs-oracle directly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_spmm_ref(blocks: jnp.ndarray, block_cols: jnp.ndarray,
                   block_mask: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """Block-CSR (ELL-over-blocks) SpMM: out = A @ h.

    blocks:     f32[VB, M, B, B]  dense adjacency tiles (row-block major)
    block_cols: i32[VB, M]        column-block index of each tile
    block_mask: f32[VB, M]        1 for real tiles, 0 for padding
    h:          f32[SB*B, F]      source table (SB >= max col block + 1;
                                  SB == VB in the square case)
    returns     f32[VB*B, F]
    """
    vb, m, b, _ = blocks.shape
    f = h.shape[1]
    hb = h.reshape(-1, b, f)

    def row_block(i):
        tiles = blocks[i]                      # [M, B, B]
        cols = block_cols[i]                   # [M]
        mask = block_mask[i]                   # [M]
        gathered = hb[cols]                    # [M, B, F]
        out = jnp.einsum("mij,mjf->if", tiles * mask[:, None, None], gathered)
        return out

    return jax.vmap(row_block)(jnp.arange(vb)).reshape(vb * b, f)


def block_spmm_batched_ref(blocks, block_cols, block_mask,
                           h: jnp.ndarray) -> jnp.ndarray:
    """Feature-stack SpMM: out[b] = A @ h[b] for h f32[B, SB*B, F]."""
    return jax.vmap(
        lambda hb: block_spmm_ref(blocks, block_cols, block_mask, hb))(h)


def dequant_ref(codes: jnp.ndarray, scales: jnp.ndarray,
                mins: jnp.ndarray) -> jnp.ndarray:
    """Row-wise linear dequantization: out[v, f] = codes[v, f]*scale[v]+min[v].

    codes: uint{8,16,32}[V, F];  scales/mins: f32[V].
    """
    return (codes.astype(jnp.float32) * scales[:, None] + mins[:, None])


def dequant_spmm_ref(blocks, block_cols, block_mask, codes, scales,
                     mins) -> jnp.ndarray:
    """Fused dequant + aggregate: out = A @ dequant(codes)."""
    h = dequant_ref(codes, scales, mins)
    return block_spmm_ref(blocks, block_cols, block_mask, h)


def dequant_spmm_batched_ref(blocks, block_cols, block_mask, codes, scales,
                             mins) -> jnp.ndarray:
    """Fused batched variant: out[b] = A @ dequant(codes[b]).

    codes uint[B, V, F]; scales/mins f32[B, V].
    """
    return jax.vmap(lambda c, s, m: dequant_spmm_ref(
        blocks, block_cols, block_mask, c, s, m))(codes, scales, mins)


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """Plain-softmax oracle for the flash kernel: q [BH,S,dh], k/v [BH,T,·]."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bsd,btd->bst", qf, kf) / jnp.sqrt(q.shape[-1])
    sq, t = q.shape[1], k.shape[1]
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(t)[None, :]
    ok = jnp.ones((sq, t), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(ok[None], p, 0.0)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)).astype(q.dtype)
