"""Pallas TPU flash attention (forward): online-softmax tiling so the
[S, T] probability matrix never reaches HBM.

Motivation straight from the roofline table (EXPERIMENTS.md §Roofline):
every train/prefill combo is memory-bound because XLA materializes the
chunked attention probabilities — e.g. deepseek-67b train_4k spends 67 s
in the memory term vs 11.7 s compute. Flash tiling removes the prob
traffic entirely: per (batch·head, q-block) grid step, K/V stream through
VMEM in BK-sized tiles while running max/sum statistics rescale a VMEM
accumulator (Dao et al., adapted to MXU 128-aligned tiles).

Layout: q [BH, S, dh], k/v [BH, T, dh] (the ops.py wrapper folds batch and
heads, expanding GQA kv heads to query heads). Causal masking is done with
iota arithmetic inside the kernel; ``window > 0`` gives the banded variant
(long_500k serve path). Validated in interpret mode against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1.0e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                  t: int, scale: float, causal: bool, window: int,
                  q_offset_blocks: int):
    j = pl.program_id(1)                      # q-block index
    q = q_ref[...].astype(jnp.float32) * scale          # [BQ, dh]
    q_pos = (j + q_offset_blocks) * bq + jax.lax.iota(jnp.int32, bq)

    acc = jnp.zeros((bq, v_ref.shape[-1]), jnp.float32)
    m_i = jnp.full((bq,), NEG_INF, jnp.float32)
    l_i = jnp.zeros((bq,), jnp.float32)

    def body(kb, carry):
        acc, m_i, l_i = carry
        k_blk = k_ref[pl.dslice(kb * bk, bk), :].astype(jnp.float32)
        v_blk = v_ref[pl.dslice(kb * bk, bk), :].astype(jnp.float32)
        s = q @ k_blk.T                                   # [BQ, BK]
        k_pos = kb * bk + jax.lax.iota(jnp.int32, bk)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= k_pos[None, :] <= q_pos[:, None]
        if window:
            ok &= k_pos[None, :] > (q_pos[:, None] - window)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m_i, s.max(axis=1))
        # guard fully-masked rows (exp(NEG_INF - NEG_INF) -> exp(0))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(ok, p, 0.0)
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ v_blk
        return acc, m_new, l_new

    acc, m_i, l_i = jax.lax.fori_loop(0, t // bk, body, (acc, m_i, l_i))
    o_ref[...] = (acc / jnp.maximum(l_i, 1e-20)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal", "window",
                                             "q_offset", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    bq: int = 128, bk: int = 128, causal: bool = True,
                    window: int = 0, q_offset: int = 0,
                    interpret: bool = True) -> jnp.ndarray:
    """q [BH, S, dh], k/v [BH, T, dh] -> [BH, S, dv].

    ``q_offset`` shifts query positions (chunked prefill: queries at
    absolute positions q_offset..q_offset+S attending a length-T cache).
    VMEM per grid step: BQ·dh + 2·BK·dh + BQ·dv floats — independent of T.
    """
    bh, s, dh = q.shape
    t = k.shape[1]
    dv = v.shape[-1]
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, bq, t, bk)
    assert q_offset % bq == 0, "q_offset must be a multiple of bq"
    grid = (bh, s // bq)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, t=t, scale=1.0 / np.sqrt(dh),
        causal=causal, window=window, q_offset_blocks=q_offset // bq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, t, dh), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((None, t, dv), lambda i, j: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dv), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        interpret=interpret,
    )(q, k, v)


def gqa_flash(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              window: int = 0, interpret: bool = True,
              bq: int = 128, bk: int = 128) -> jnp.ndarray:
    """Model-layout wrapper: q [B,S,H,dh], k/v [B,T,KV,dh] -> [B,S,H,dv].

    Expands GQA kv heads to query heads (a view-cost copy here; on TPU the
    kernel would index kv = h // group instead)."""
    b, s, h, dh = q.shape
    t, kv = k.shape[1], k.shape[2]
    group = h // kv
    kx = jnp.repeat(k, group, axis=2)
    vx = jnp.repeat(v, group, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kf = kx.transpose(0, 2, 1, 3).reshape(b * h, t, dh)
    vf = vx.transpose(0, 2, 1, 3).reshape(b * h, t, vx.shape[-1])
    o = flash_attention(qf, kf, vf, window=window, interpret=interpret,
                        bq=bq, bk=bk)
    return o.reshape(b, h, s, -1).transpose(0, 2, 1, 3)
