"""Public jit'd wrappers around the Pallas kernels.

These are the entry points the rest of the framework uses: they handle host
layout conversion (COO -> block-CSR, row quantization), padding, and
un-padding, and fall back to interpret mode on CPU automatically (the
kernels target TPU; `interpret=True` executes the same kernel body on CPU).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.gnn.graph import Graph
from repro.kernels import ref
from repro.kernels.daq_dequant import dequant, dequant_spmm
from repro.kernels.gather_aggregate import (BLOCK, block_spmm,
                                            block_spmm_batched,
                                            build_block_csr,
                                            padded_feature_dim)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class BlockCsr:
    """Prepared adjacency for repeated kernel aggregations."""

    def __init__(self, g: Graph, block: int = BLOCK,
                 normalize: Optional[str] = None):
        weights = None
        if normalize == "mean":
            deg = np.maximum(g.degrees[g.receivers], 1)
            weights = (1.0 / deg).astype(np.float32)
        blocks, cols, mask, padded_v = build_block_csr(
            g.senders, g.receivers, g.num_vertices, block, weights)
        self.block = block
        self.num_vertices = g.num_vertices
        self.padded_v = padded_v
        self.blocks = jnp.asarray(blocks)
        self.cols = jnp.asarray(cols)
        self.mask = jnp.asarray(mask)

    def pad_features(self, h: np.ndarray) -> jnp.ndarray:
        v, f = h.shape
        f_pad = -(-f // 128) * 128
        out = np.zeros((self.padded_v, f_pad), np.float32)
        out[:v, :f] = h
        return jnp.asarray(out)

    def aggregate_traced(self, h: jnp.ndarray,
                         interpret: Optional[bool] = None) -> jnp.ndarray:
        """sum-aggregate, jnp in / jnp out (traceable inside jit).

        Pads rows to the prepared block grid and features to the kernel's
        lane multiple with ``jnp.pad``, so it composes with the model's
        layer functions as a drop-in ``aggregate=`` backend. ``h`` may be
        a single [V, F] feature table or a stacked [B, V, F] micro-batch —
        the stacked form runs ``block_spmm_batched`` (one fused dispatch
        with B as an extra grid axis) and returns [B, V, F], with each
        ``out[b]`` bit-identical to the single-query call on ``h[b]``.
        """
        if interpret is None:
            interpret = not _on_tpu()
        v, f = h.shape[-2:]
        f_pad = padded_feature_dim(f)
        pad = ((0, self.padded_v - v), (0, f_pad - f))
        if h.ndim == 3:
            out = block_spmm_batched(
                self.blocks, self.cols, self.mask,
                jnp.pad(h.astype(jnp.float32), ((0, 0),) + pad),
                interpret=interpret)
            return out[:, :v, :f]
        out = block_spmm(self.blocks, self.cols, self.mask,
                         jnp.pad(h.astype(jnp.float32), pad),
                         interpret=interpret)
        return out[:v, :f]

    def aggregate(self, h: np.ndarray, interpret: Optional[bool] = None
                  ) -> np.ndarray:
        """sum-aggregate: returns [V, F] (unpadded)."""
        return np.asarray(self.aggregate_traced(jnp.asarray(h), interpret))

    def aggregate_quantized(self, codes: np.ndarray, scales: np.ndarray,
                            mins: np.ndarray,
                            interpret: Optional[bool] = None) -> np.ndarray:
        """Fused dequant + sum-aggregate over quantized features."""
        if interpret is None:
            interpret = not _on_tpu()
        v, f = codes.shape
        f_pad = -(-f // 128) * 128
        cp = np.zeros((self.padded_v, f_pad), codes.dtype)
        cp[:v, :f] = codes
        sp = np.zeros((self.padded_v,), np.float32)
        sp[:v] = scales
        mp = np.zeros((self.padded_v,), np.float32)
        mp[:v] = mins
        out = dequant_spmm(self.blocks, self.cols, self.mask,
                           jnp.asarray(cp), jnp.asarray(sp), jnp.asarray(mp),
                           interpret=interpret)
        return np.asarray(out)[:v, :f]


# ----------------------------------------------------------------------------
# Keyed BlockCsr cache (shared by every single-program executor backend)
# ----------------------------------------------------------------------------

#: LRU of prepared block-CSR operands, keyed by
#: (graph adjacency fingerprint, aggregation normalization, block shape).
#: Keying on content (not Graph identity) means a Session aggregation
#: override, a fresh ``with_features``-style Graph copy, or two plans over
#: the same topology all share one prepared operand instead of silently
#: re-blocking per query.
_BLOCK_CSR_CACHE: "OrderedDict[tuple, BlockCsr]" = OrderedDict()
_BLOCK_CSR_CACHE_MAX = 16

#: Field names of the _BLOCK_CSR_CACHE key tuple, in order; audited by
#: repro.analysis.cache_audit against the live cache.
BLOCK_CSR_KEY_FIELDS = ("adjacency_fingerprint", "normalize", "block")


def graph_fingerprint(g: Graph) -> str:
    """Content hash of a graph's *adjacency* (features excluded).

    Vertex count and edge endpoints feed the digest; that covers
    everything the block-CSR operands depend on (mean-normalization
    degrees are the receiver counts of those same edges), so a mutated
    graph can never alias a stale cache entry.

    The digest is O(E) to compute, so it is memoized on the Graph
    instance — adjacency arrays are treated as immutable everywhere in
    this codebase (mutation goes through ``incremental.mutate_graph``,
    which builds a new Graph) — keeping the per-query cache lookup O(1).
    """
    fp = getattr(g, "_adjacency_fingerprint", None)
    if fp is None:
        d = hashlib.blake2b(digest_size=16)
        d.update(np.int64(g.num_vertices).tobytes())
        d.update(np.ascontiguousarray(g.senders, np.int64).tobytes())
        d.update(np.ascontiguousarray(g.receivers, np.int64).tobytes())
        fp = d.hexdigest()
        g._adjacency_fingerprint = fp
    return fp


def block_csr_for(g: Graph, block: int = BLOCK,
                  normalize: Optional[str] = None) -> BlockCsr:
    """Cached :class:`BlockCsr` for ``g`` (build once per adjacency).

    The cache is a small process-wide LRU; ``invalidate_block_csr`` drops
    a graph's entries eagerly (``Engine.apply_delta`` calls it for the
    pre-update graph on structural deltas so retired operands don't pin
    memory until eviction).
    """
    key = (graph_fingerprint(g), normalize, block)
    csr = _BLOCK_CSR_CACHE.get(key)
    if csr is None:
        csr = BlockCsr(g, block=block, normalize=normalize)
        _BLOCK_CSR_CACHE[key] = csr
        while len(_BLOCK_CSR_CACHE) > _BLOCK_CSR_CACHE_MAX:
            _BLOCK_CSR_CACHE.popitem(last=False)
    else:
        _BLOCK_CSR_CACHE.move_to_end(key)
    return csr


def invalidate_block_csr(g: Graph) -> int:
    """Drop every cached BlockCsr built for ``g``'s adjacency; returns the
    number of entries removed."""
    fp = graph_fingerprint(g)
    stale = [k for k in _BLOCK_CSR_CACHE if k[0] == fp]
    for k in stale:
        del _BLOCK_CSR_CACHE[k]
    return len(stale)


def dequantize_features(codes: np.ndarray, scales: np.ndarray,
                        mins: np.ndarray,
                        interpret: Optional[bool] = None) -> np.ndarray:
    """Kernel-backed row-wise dequantization with pad/unpad handling."""
    if interpret is None:
        interpret = not _on_tpu()
    v, f = codes.shape
    v_pad = -(-v // 256) * 256
    f_pad = -(-f // 128) * 128
    cp = np.zeros((v_pad, f_pad), codes.dtype)
    cp[:v, :f] = codes
    sp = np.zeros((v_pad,), np.float32)
    sp[:v] = scales
    mp = np.zeros((v_pad,), np.float32)
    mp[:v] = mins
    out = dequant(jnp.asarray(cp), jnp.asarray(sp), jnp.asarray(mp),
                  interpret=interpret)
    return np.asarray(out)[:v, :f]
