"""Public jit'd wrappers around the Pallas kernels.

These are the entry points the rest of the framework uses: they handle host
layout conversion (COO -> block-CSR, row quantization), padding, and
un-padding, and fall back to interpret mode on CPU automatically (the
kernels target TPU; `interpret=True` executes the same kernel body on CPU).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.gnn.graph import Graph
from repro.kernels import ref
from repro.kernels.daq_dequant import dequant, dequant_spmm
from repro.kernels.gather_aggregate import (BLOCK, block_spmm,
                                            build_block_csr,
                                            padded_feature_dim)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


class BlockCsr:
    """Prepared adjacency for repeated kernel aggregations."""

    def __init__(self, g: Graph, block: int = BLOCK,
                 normalize: Optional[str] = None):
        weights = None
        if normalize == "mean":
            deg = np.maximum(g.degrees[g.receivers], 1)
            weights = (1.0 / deg).astype(np.float32)
        blocks, cols, mask, padded_v = build_block_csr(
            g.senders, g.receivers, g.num_vertices, block, weights)
        self.block = block
        self.num_vertices = g.num_vertices
        self.padded_v = padded_v
        self.blocks = jnp.asarray(blocks)
        self.cols = jnp.asarray(cols)
        self.mask = jnp.asarray(mask)

    def pad_features(self, h: np.ndarray) -> jnp.ndarray:
        v, f = h.shape
        f_pad = -(-f // 128) * 128
        out = np.zeros((self.padded_v, f_pad), np.float32)
        out[:v, :f] = h
        return jnp.asarray(out)

    def aggregate_traced(self, h: jnp.ndarray,
                         interpret: Optional[bool] = None) -> jnp.ndarray:
        """sum-aggregate, jnp in / jnp out (traceable inside jit).

        Pads rows to the prepared block grid and features to the kernel's
        lane multiple with ``jnp.pad``, so it composes with the model's
        layer functions as a drop-in ``aggregate=`` backend.
        """
        if interpret is None:
            interpret = not _on_tpu()
        v, f = h.shape
        f_pad = padded_feature_dim(f)
        hp = jnp.pad(h.astype(jnp.float32),
                     ((0, self.padded_v - v), (0, f_pad - f)))
        out = block_spmm(self.blocks, self.cols, self.mask, hp,
                         interpret=interpret)
        return out[:v, :f]

    def aggregate(self, h: np.ndarray, interpret: Optional[bool] = None
                  ) -> np.ndarray:
        """sum-aggregate: returns [V, F] (unpadded)."""
        return np.asarray(self.aggregate_traced(jnp.asarray(h), interpret))

    def aggregate_quantized(self, codes: np.ndarray, scales: np.ndarray,
                            mins: np.ndarray,
                            interpret: Optional[bool] = None) -> np.ndarray:
        """Fused dequant + sum-aggregate over quantized features."""
        if interpret is None:
            interpret = not _on_tpu()
        v, f = codes.shape
        f_pad = -(-f // 128) * 128
        cp = np.zeros((self.padded_v, f_pad), codes.dtype)
        cp[:v, :f] = codes
        sp = np.zeros((self.padded_v,), np.float32)
        sp[:v] = scales
        mp = np.zeros((self.padded_v,), np.float32)
        mp[:v] = mins
        out = dequant_spmm(self.blocks, self.cols, self.mask,
                           jnp.asarray(cp), jnp.asarray(sp), jnp.asarray(mp),
                           interpret=interpret)
        return np.asarray(out)[:v, :f]


def dequantize_features(codes: np.ndarray, scales: np.ndarray,
                        mins: np.ndarray,
                        interpret: Optional[bool] = None) -> np.ndarray:
    """Kernel-backed row-wise dequantization with pad/unpad handling."""
    if interpret is None:
        interpret = not _on_tpu()
    v, f = codes.shape
    v_pad = -(-v // 256) * 256
    f_pad = -(-f // 128) * 128
    cp = np.zeros((v_pad, f_pad), codes.dtype)
    cp[:v, :f] = codes
    sp = np.zeros((v_pad,), np.float32)
    sp[:v] = scales
    mp = np.zeros((v_pad,), np.float32)
    mp[:v] = mins
    out = dequant(jnp.asarray(cp), jnp.asarray(sp), jnp.asarray(mp),
                  interpret=interpret)
    return np.asarray(out)[:v, :f]
