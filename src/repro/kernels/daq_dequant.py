"""Pallas TPU kernels for degree-aware-quantized feature streaming.

The paper's DAQ (§III-D) shrinks the *device -> fog* link payload. The TPU
analogue of that bottleneck is HBM bandwidth: storing vertex features
quantized in HBM and dequantizing inside VMEM tiles cuts the memory-roofline
term of the aggregation by the compression ratio.

Two kernels:
  * ``dequant``        — standalone row-wise linear dequantization
                         out[v,f] = codes[v,f] * scale[v] + min[v]
  * ``dequant_spmm``   — BEYOND-PAPER fusion: block-CSR aggregation directly
                         over quantized features; the dense feature panel
                         never materializes in HBM (dequantized per VMEM
                         tile right before the MXU matmul).

Both validated in interpret mode against repro.kernels.ref oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gather_aggregate import BLOCK


def _dequant_kernel(codes_ref, scales_ref, mins_ref, out_ref):
    """One (v_tile, f_tile) VMEM tile: out = codes * scale[row] + min[row]."""
    codes = codes_ref[...].astype(jnp.float32)
    out_ref[...] = codes * scales_ref[...][:, None] + mins_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("v_tile", "f_tile", "interpret"))
def dequant(codes: jnp.ndarray, scales: jnp.ndarray, mins: jnp.ndarray, *,
            v_tile: int = 256, f_tile: int = 128,
            interpret: bool = True) -> jnp.ndarray:
    """Row-wise linear dequantization, tiled (v_tile x f_tile) over VMEM."""
    v, f = codes.shape
    v_tile = min(v_tile, v)
    f_tile = min(f_tile, f)
    assert v % v_tile == 0 and f % f_tile == 0, (codes.shape, v_tile, f_tile)
    grid = (v // v_tile, f // f_tile)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_tile, f_tile), lambda i, j: (i, j)),
            pl.BlockSpec((v_tile,), lambda i, j: (i,)),
            pl.BlockSpec((v_tile,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((v_tile, f_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((v, f), jnp.float32),
        interpret=interpret,
    )(codes, scales, mins)


def _dequant_spmm_kernel(cols_ref, mask_ref, blocks_ref, codes_ref,
                         scales_ref, mins_ref, out_ref, *, m: int,
                         block: int):
    """One (row-block, feature-tile) grid step: the [B, TF] source panel is
    dequantized in VMEM right before each MXU matmul, so the dense feature
    table never materializes in HBM."""
    acc = jnp.zeros_like(out_ref)

    def body(k, acc):
        tile = blocks_ref[k]                                    # [B, B]
        col = cols_ref[k]
        msk = mask_ref[k]
        codes = codes_ref[pl.dslice(col * block, block), :]     # [B, TF]
        sc = scales_ref[pl.dslice(col * block, block)]          # [B]
        mn = mins_ref[pl.dslice(col * block, block)]            # [B]
        panel = codes.astype(jnp.float32) * sc[:, None] + mn[:, None]
        return acc + msk * jnp.dot(tile, panel,
                                   preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, m, body, acc)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "f_tile", "interpret"))
def dequant_spmm(blocks: jnp.ndarray, block_cols: jnp.ndarray,
                 block_mask: jnp.ndarray, codes: jnp.ndarray,
                 scales: jnp.ndarray, mins: jnp.ndarray, *,
                 block: int = BLOCK, f_tile: int = 128,
                 interpret: bool = True) -> jnp.ndarray:
    """out = A @ dequant(codes): fused aggregation over quantized features.

    Same block layout as ``gather_aggregate.block_spmm`` (including the
    rectangular case: ``codes`` is the source table, any multiple of
    ``block`` rows covering every ``block_cols`` entry; the output has
    ``vb * block`` rows). ``codes`` is an unsigned-int array (uint8/16/32),
    ``scales``/``mins`` are f32[v] row parameters; zero-padded source rows
    (codes == 0, scale == min == 0) dequantize to exactly 0 and therefore
    contribute nothing. Output is f32.
    """
    vb, m, b, _ = blocks.shape
    v, f = codes.shape
    assert b == block and v % block == 0
    f_tile = min(f_tile, f)
    assert f % f_tile == 0
    grid = (vb, f // f_tile)
    kernel = functools.partial(_dequant_spmm_kernel, m=m, block=block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, m), lambda i, j: (i, 0)),
            pl.BlockSpec((None, m), lambda i, j: (i, 0)),
            pl.BlockSpec((None, m, block, block), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((v, f_tile), lambda i, j: (0, j)),   # codes panel
            pl.BlockSpec((v,), lambda i, j: (0,)),
            pl.BlockSpec((v,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block, f_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((vb * block, f), jnp.float32),
        interpret=interpret,
    )(block_cols, block_mask, blocks, codes, scales, mins)


def _dequant_spmm_batched_kernel(cols_ref, mask_ref, blocks_ref, codes_ref,
                                 scales_ref, mins_ref, out_ref, *, m: int,
                                 block: int):
    """One (row-block, feature-tile, batch) grid step; ``cols_ref`` is the
    scalar-prefetched [VB, M] table (fetched once per launch, not per batch
    element)."""
    i = pl.program_id(0)
    acc = jnp.zeros_like(out_ref)

    def body(k, acc):
        tile = blocks_ref[k]                                    # [B, B]
        col = cols_ref[i, k]
        msk = mask_ref[k]
        codes = codes_ref[pl.dslice(col * block, block), :]     # [B, TF]
        sc = scales_ref[pl.dslice(col * block, block)]          # [B]
        mn = mins_ref[pl.dslice(col * block, block)]            # [B]
        panel = codes.astype(jnp.float32) * sc[:, None] + mn[:, None]
        return acc + msk * jnp.dot(tile, panel,
                                   preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, m, body, acc)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "f_tile", "interpret"))
def dequant_spmm_batched(blocks: jnp.ndarray, block_cols: jnp.ndarray,
                         block_mask: jnp.ndarray, codes: jnp.ndarray,
                         scales: jnp.ndarray, mins: jnp.ndarray, *,
                         block: int = BLOCK, f_tile: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """out[b] = A @ dequant(codes[b]): the fused kernel over a quantized
    [B, V, F] feature stack (``scales``/``mins`` are f32[B, V]).

    Batch-axis variant of :func:`dequant_spmm`, mirroring
    ``block_spmm_batched``: one dispatch for the whole micro-batch, shared
    block-CSR operands, scalar-prefetched ``block_cols``, B innermost in
    the grid so adjacency tiles amortize across the batch. Per-element
    results are bit-identical to the unbatched kernel.
    """
    vb, m, blk, _ = blocks.shape
    b, v, f = codes.shape
    assert blk == block and v % block == 0
    assert scales.shape == mins.shape == (b, v), (scales.shape, codes.shape)
    f_tile = min(f_tile, f)
    assert f % f_tile == 0
    grid = (vb, f // f_tile, b)
    kernel = functools.partial(_dequant_spmm_batched_kernel, m=m, block=block)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,           # block_cols
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, m), lambda i, j, k, cols: (i, 0)),
            pl.BlockSpec((None, m, block, block),
                         lambda i, j, k, cols: (i, 0, 0, 0)),
            pl.BlockSpec((None, v, f_tile),
                         lambda i, j, k, cols: (k, 0, j)),   # codes[b]
            pl.BlockSpec((None, v), lambda i, j, k, cols: (k, 0)),
            pl.BlockSpec((None, v), lambda i, j, k, cols: (k, 0)),
        ],
        out_specs=pl.BlockSpec((None, block, f_tile),
                               lambda i, j, k, cols: (k, i, j)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, vb * block, f), jnp.float32),
        interpret=interpret,
    )(block_cols, block_mask, blocks, codes, scales, mins)
