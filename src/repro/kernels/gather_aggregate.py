"""Pallas TPU kernel: blocked CSR neighbor aggregation (the GNN hot-spot).

TPU adaptation of the paper's kernel-level aggregation (Fograph §III-E wraps
PyG's CUDA gather/scatter kernels). GPU gather/scatter does not transfer to
the TPU's systolic MXU, so we *re-block* the computation:

  * the adjacency is laid out as block-CSR: dense B x B tiles (B = 128,
    MXU-native) listed per row-block (ELL-padded to M tiles per row-block);
  * aggregation out = A @ H becomes a sequence of MXU matmuls
    acc += tile[m] @ H[cols[m]] — every operand is a VMEM-resident,
    128-aligned tile; the irregular gather collapses to *block-row* dynamic
    slices instead of per-edge scatter.

VMEM budget per grid step: M·B·B·4 (tiles) + V·TF·4 (feature panel)
+ B·TF·4 (acc). The feature panel is tiled on F only — the kernel targets
per-partition local graphs (Fograph shards the global graph across fogs), so
V here is |V|/n_fogs and the panel fits VMEM for the paper's scales.

Kernel body is validated in interpret mode on CPU against ref.block_spmm_ref.

Both SpMM kernels come in two flavours: the single-query [V, F] form and a
[B, V, F] *feature-stack* form (``block_spmm_batched``) that serves a whole
serving micro-batch in one fused dispatch — B is an extra (fastest-varying)
grid axis so the block-CSR operand loads amortize across the batch, and the
``block_cols`` table moves to scalar prefetch (``PrefetchScalarGridSpec``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 128  # MXU-native tile edge


def padded_feature_dim(f: int) -> int:
    """Feature count the SpMM kernels' f-tiling accepts for ``f`` columns.

    ``f_tile`` is clamped to ``min(128, f)``, so any ``f <= 128`` passes
    unpadded; wider tables must be a multiple of the 128-lane tile.
    """
    return f if f <= 128 else -(-f // 128) * 128


def build_block_csr(senders: np.ndarray, receivers: np.ndarray,
                    num_vertices: int, block: int = BLOCK,
                    weights: np.ndarray = None):
    """Host-side: COO edges -> ELL-over-blocks block-CSR.

    Layout contract (shared by ``block_spmm`` and ``dequant_spmm``):

      * The output-row space is ``receivers`` (``num_vertices`` rows,
        padded up to ``VB = ceil(num_vertices / block)`` row-blocks).
      * The source-column space is ``senders`` and may be a *different*
        index space (e.g. a gathered halo table): column-block ids are
        ``senders // block``, unbounded by ``num_vertices``. The feature
        table handed to the SpMM must cover ``(max(senders)//block + 1)
        * block`` rows (zero-pad to a multiple of ``block``).
      * Each row-block lists exactly ``M`` tiles (ELL padding): real tiles
        carry ``block_mask == 1``, padding tiles are all-zero with
        ``block_mask == 0`` and ``block_cols == 0`` (they multiply the
        first source panel by a zero tile — harmless but not free).
      * Duplicate edges accumulate (tile entries count multiplicity), and
        ``weights`` (f32[E], default 1) scales each edge's contribution —
        e.g. 1/deg(receiver) bakes mean-aggregation into the adjacency.

    Returns ``(blocks f32[VB, M, B, B], block_cols i32[VB, M],
    block_mask f32[VB, M], padded_v = VB * block)``. Zero edges are legal
    and yield a single all-padding tile per row-block (M == 1).
    """
    vb = -(-num_vertices // block)
    padded_v = vb * block
    if weights is None:
        weights = np.ones(len(senders), np.float32)
    rb = receivers // block
    cb = senders // block
    # Unique (row-block, col-block) pairs. The column-block count follows
    # the senders' index space, which may be wider than the row space.
    ncb = int(cb.max()) + 1 if len(cb) else 1
    key = rb.astype(np.int64) * ncb + cb
    uniq, inv = np.unique(key, return_inverse=True)
    nb = len(uniq)
    tiles = np.zeros((nb, block, block), np.float32)
    np.add.at(tiles, (inv, receivers % block, senders % block), weights)
    tile_rb = (uniq // ncb).astype(np.int64)
    tile_cb = (uniq % ncb).astype(np.int32)
    counts = np.bincount(tile_rb, minlength=vb)
    m = max(1, int(counts.max()))
    blocks = np.zeros((vb, m, block, block), np.float32)
    block_cols = np.zeros((vb, m), np.int32)
    block_mask = np.zeros((vb, m), np.float32)
    slot = np.zeros(vb, np.int64)
    for t in range(nb):
        i = tile_rb[t]
        j = slot[i]
        blocks[i, j] = tiles[t]
        block_cols[i, j] = tile_cb[t]
        block_mask[i, j] = 1.0
        slot[i] += 1
    return blocks, block_cols, block_mask, padded_v


def _spmm_kernel(cols_ref, mask_ref, blocks_ref, h_ref, out_ref, *, m: int,
                 block: int):
    """One (row-block, feature-tile) grid step."""
    acc = jnp.zeros_like(out_ref)

    def body(k, acc):
        tile = blocks_ref[k]                      # [B, B]
        col = cols_ref[k]
        msk = mask_ref[k]
        panel = h_ref[pl.dslice(col * block, block), :]   # [B, TF]
        return acc + msk * jnp.dot(tile, panel,
                                   preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, m, body, acc)
    out_ref[...] = acc


def _spmm_batched_kernel(cols_ref, mask_ref, blocks_ref, h_ref, out_ref, *,
                         m: int, block: int):
    """One (row-block, feature-tile, batch) grid step.

    ``cols_ref`` is the *whole* [VB, M] column-index table, scalar-prefetched
    (SMEM-resident) once for the entire launch — the batch axis iterates
    fastest, so the adjacency tiles and index rows of a block row are
    fetched once and reused for all B feature stacks.
    """
    i = pl.program_id(0)
    acc = jnp.zeros_like(out_ref)

    def body(k, acc):
        tile = blocks_ref[k]                      # [B, B]
        col = cols_ref[i, k]
        msk = mask_ref[k]
        panel = h_ref[pl.dslice(col * block, block), :]   # [B, TF]
        return acc + msk * jnp.dot(tile, panel,
                                   preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, m, body, acc)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "f_tile", "interpret"))
def block_spmm_batched(blocks: jnp.ndarray, block_cols: jnp.ndarray,
                       block_mask: jnp.ndarray, h: jnp.ndarray, *,
                       block: int = BLOCK, f_tile: int = 128,
                       interpret: bool = True) -> jnp.ndarray:
    """out[b] = A @ h[b] for a [B, V, F] feature stack — one fused dispatch.

    Batch-axis variant of :func:`block_spmm`: the same ELL-block-CSR
    operands serve every element of the micro-batch, with the batch as an
    extra (fastest-varying) grid dimension so the adjacency tiles loaded
    for a block row are amortized across all B stacks, and ``block_cols``
    moved to ``PrefetchScalarGridSpec`` scalar prefetch so the column-index
    table is resident once per launch instead of refetched per batch
    element. Per-(row-block, feature-tile) arithmetic is the exact op
    sequence of the unbatched kernel, so each ``out[b]`` is bit-identical
    to ``block_spmm(..., h[b])``.
    """
    vb, m, blk, _ = blocks.shape
    b, v, f = h.shape
    assert blk == block and v % block == 0, (blocks.shape, h.shape)
    f_tile = min(f_tile, f)
    assert f % f_tile == 0, (f, f_tile)
    grid = (vb, f // f_tile, b)
    kernel = functools.partial(_spmm_batched_kernel, m=m, block=block)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,           # block_cols: whole table, SMEM
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, m), lambda i, j, k, cols: (i, 0)),   # mask
            pl.BlockSpec((None, m, block, block),
                         lambda i, j, k, cols: (i, 0, 0, 0)),        # tiles
            pl.BlockSpec((None, v, f_tile),
                         lambda i, j, k, cols: (k, 0, j)),           # h[b]
        ],
        out_specs=pl.BlockSpec((None, block, f_tile),
                               lambda i, j, k, cols: (k, i, j)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, vb * block, f), jnp.float32),
        interpret=interpret,
    )(block_cols, block_mask, blocks, h)


@functools.partial(jax.jit, static_argnames=("block", "f_tile", "interpret"))
def block_spmm(blocks: jnp.ndarray, block_cols: jnp.ndarray,
               block_mask: jnp.ndarray, h: jnp.ndarray, *,
               block: int = BLOCK, f_tile: int = 128,
               interpret: bool = True) -> jnp.ndarray:
    """out = A @ h with A in ELL-block-CSR layout (see build_block_csr).

    ``A`` may be rectangular: ``h`` is the *source* table (``v`` rows, any
    multiple of ``block`` covering every ``block_cols`` entry) while the
    output has ``vb * block`` rows — the shard-local serving path feeds a
    local+halo source table that is wider than the shard's own row space.
    ``h`` must be f32 with ``f % f_tile == 0`` (``f_tile`` is clamped to
    ``f``, so any ``f <= 128`` needs no feature padding); output is f32.
    """
    vb, m, b, _ = blocks.shape
    v, f = h.shape
    assert b == block and v % block == 0, (blocks.shape, h.shape)
    f_tile = min(f_tile, f)
    assert f % f_tile == 0, (f, f_tile)
    grid = (vb, f // f_tile)
    kernel = functools.partial(_spmm_kernel, m=m, block=block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, m), lambda i, j: (i, 0)),            # cols
            pl.BlockSpec((None, m), lambda i, j: (i, 0)),            # mask
            pl.BlockSpec((None, m, block, block), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((v, f_tile), lambda i, j: (0, j)),          # h panel
        ],
        out_specs=pl.BlockSpec((block, f_tile), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((vb * block, f), jnp.float32),
        interpret=interpret,
    )(block_cols, block_mask, blocks, h)
