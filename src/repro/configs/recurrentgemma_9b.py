"""RecurrentGemma-9B: RG-LRU + local attention 1:2 hybrid (Griffin)
[arXiv:2402.19427]. MQA (kv=1) with head_dim 256; window 2048."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="hybrid", source="arXiv:2402.19427",
    num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
    head_dim=256, d_ff=12288, vocab_size=256000,
    hybrid_pattern=("rglru", "rglru", "local_attn"), local_window=2048,
    lru_width=4096, ssm_conv=4,
)
