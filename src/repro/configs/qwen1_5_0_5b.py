"""Qwen1.5-0.5B: dense decoder with QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense", source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    head_dim=64, d_ff=2816, vocab_size=151936, qkv_bias=True,
    rope_theta=1_000_000.0, sliding_window=4096,
)
