"""Granite-3.0-2B base: dense GQA [hf:ibm-granite/granite-3.0-2b-base]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense",
    source="hf:ibm-granite/granite-3.0-2b-base",
    num_layers=40, d_model=2048, num_heads=32, num_kv_heads=8,
    head_dim=64, d_ff=8192, vocab_size=49155,
    rope_theta=10000.0, sliding_window=4096,
)
