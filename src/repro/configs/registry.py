"""Architecture registry: ``get(name)`` / ``list_archs()`` resolve the 10
assigned architectures (one module per arch) plus test configs.

``reduced(cfg)`` derives the CI smoke variant mandated by the harness:
<=2 layers (hybrids keep one full pattern period), d_model<=512, <=4
experts — same family/code paths, laptop-scale shapes.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import List

from repro.models.config import ArchConfig

ARCH_IDS = [
    "deepseek-67b", "qwen1_5-0_5b", "falcon-mamba-7b", "grok-1-314b",
    "internvl2-26b", "starcoder2-3b", "deepseek-v3-671b",
    "recurrentgemma-9b", "granite-3-2b", "musicgen-medium",
]

_ALIASES = {
    "qwen1.5-0.5b": "qwen1_5-0_5b",
}


def canonical(name: str) -> str:
    return _ALIASES.get(name, name.replace(".", "_"))


def get(name: str) -> ArchConfig:
    mod = importlib.import_module(
        f"repro.configs.{canonical(name).replace('-', '_')}")
    return mod.CONFIG


def list_archs() -> List[str]:
    return list(ARCH_IDS)


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Reduced smoke variant of the same family."""
    d_model = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4) or 0
    kv = min(cfg.num_kv_heads, heads) or 0
    if heads and heads % max(kv, 1):
        kv = 1
    layers = len(cfg.hybrid_pattern) if cfg.hybrid_pattern else 2
    changes = dict(
        num_layers=max(2, layers),
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=min(cfg.head_dim, 64),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        local_window=min(cfg.local_window, 32),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        lru_width=min(cfg.rglru_width, d_model) if cfg.lru_width else 0,
        param_dtype="float32",
        activation_dtype="float32",
    )
    if cfg.num_experts:
        changes.update(num_experts=4, experts_per_token=2,
                       moe_d_ff=min(cfg.expert_d_ff, 128),
                       num_shared_experts=min(cfg.num_shared_experts, 1),
                       first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.use_mla:
        changes.update(q_lora_rank=64 if cfg.q_lora_rank else 0,
                       kv_lora_rank=64, qk_nope_head_dim=32,
                       qk_rope_head_dim=16, v_head_dim=32)
    if cfg.mtp_depth:
        changes.update(mtp_depth=1)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **changes)
