"""InternVL2-26B language decoder (InternLM2-20B backbone) with stubbed
InternViT-6B frontend [arXiv:2404.16821]. input_specs() supplies patch
embeddings — the harness VLM carve-out."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm", source="arXiv:2404.16821",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=16384, vocab_size=92553,
    input_mode="embeddings", sliding_window=4096,
)
