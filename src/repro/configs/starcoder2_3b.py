"""StarCoder2-3B: dense GQA kv=2, RoPE, non-gated GELU MLP
[arXiv:2402.19173]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense", source="arXiv:2402.19173",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    head_dim=128, d_ff=12288, vocab_size=49152,
    rope_theta=100000.0, sliding_window=4096,
)
