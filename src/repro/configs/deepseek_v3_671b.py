"""DeepSeek-V3 671B: MLA + 1 shared / 256 routed top-8 MoE + MTP
[arXiv:2412.19437]. First 3 layers dense (d_ff 18432); expert width 2048.

bf16 params + bf16 moments: 671B at f32 AdamW (12 B/param = 8 TB) exceeds a
256-chip v5e pod's 4 TB HBM — physically, not as an artifact of sharding.
See EXPERIMENTS.md §Dry-run notes."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b", family="moe", source="arXiv:2412.19437",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=18432, vocab_size=129280,
    num_experts=256, experts_per_token=8, num_shared_experts=1,
    moe_d_ff=2048, first_dense_layers=3,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    mtp_depth=1, sliding_window=4096, param_dtype="bfloat16",
)
