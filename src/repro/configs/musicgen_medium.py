"""MusicGen-medium decoder over EnCodec tokens [arXiv:2306.05284].
Sinusoidal positions, non-gated GELU MLP; the EnCodec frontend is a stub —
input_specs() supplies codec token ids (the decoder's true input)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio", source="arXiv:2306.05284",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
    head_dim=64, d_ff=6144, vocab_size=2048,
    pos_embedding="sinusoidal", sliding_window=4096,
)
