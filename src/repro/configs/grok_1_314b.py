"""Grok-1 314B: MoE 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe", source="hf:xai-org/grok-1",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=32768, vocab_size=131072,
    num_experts=8, experts_per_token=2, moe_d_ff=32768,
    sliding_window=4096, param_dtype="bfloat16",
)
