"""Falcon-Mamba-7B: attention-free Mamba-1 SSM [arXiv:2410.05355]."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", source="arXiv:2410.05355",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=65024, pos_embedding="none",
    ssm_state=16, ssm_conv=4, ssm_expand=2, dt_rank=256,
)
