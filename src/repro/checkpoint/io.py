"""Checkpointing: flat-key .npz save/restore for arbitrary param/opt pytrees.

Keys are '/'-joined pytree paths; restore rebuilds into a provided target
structure (so dtypes/shardings of the live tree are preserved — values are
device_put with the target's sharding when one is attached). Writes are
atomic (tmp file + rename) so an interrupted save never corrupts the latest
checkpoint. Steps are retained with a configurable keep count.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_seg(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _seg(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(directory: str, step: int, tree: Any, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    _gc(directory, keep)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(directory: str, target: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of ``target`` (shapes must match)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}.npz")
    data = np.load(path)
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target)
    out = []
    for pth, leaf in leaves_p:
        key = "/".join(_seg(p) for p in pth)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        val = data[key]
        if hasattr(leaf, "shape") and tuple(leaf.shape) != tuple(val.shape):
            raise ValueError(f"{key}: shape {val.shape} != {leaf.shape}")
        if hasattr(leaf, "sharding") and hasattr(leaf, "dtype"):
            val = jax.device_put(val.astype(leaf.dtype), leaf.sharding)
        out.append(val)
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), out)


def _gc(directory: str, keep: int) -> None:
    files = sorted(f for f in os.listdir(directory)
                   if re.match(r"step_\d+\.npz$", f))
    for f in files[:-keep]:
        os.remove(os.path.join(directory, f))
