"""Composable decoder transformer covering all assigned architectures.

Key structural decisions (see DESIGN.md §4):
  * layers grouped into (group, repeats) *stages*; repeats run under
    ``lax.scan`` with stacked params -> HLO size and compile time are
    depth-independent (deepseek-67b's 95 layers compile as one body);
  * three entry points sharing parameters:
      - ``forward``      full-sequence logits (training / evaluation)
      - ``prefill``      full-sequence + returns decode caches
      - ``decode_step``  one token against caches (serve_step)
  * attention is query-chunked (blockwise causal) so 32k-prefill and
    4k-train never materialize an S x S score matrix;
  * MoE aux losses ride the scan carry; MTP (deepseek-v3) is an optional
    extra predict head over shifted positions.

VLM / audio frontends are stubs per the harness carve-out: ``forward``
accepts either int token ids or precomputed [B, S, D] embeddings.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ArchConfig, LayerSpec
from repro.models.sharding import constrain_batch
from repro.models.layers import (dtype_of, embed, init_embed, init_linear,
                                 init_mlp, init_rms, linear, mlp, rms_norm,
                                 sinusoidal_embedding, unembed)


# ----------------------------------------------------------------------------
# Parameter construction
# ----------------------------------------------------------------------------

def _init_mixer(key, spec: LayerSpec, cfg: ArchConfig, dtype):
    if spec.mixer in ("gqa", "local_attn"):
        return attn.init_gqa(key, cfg, dtype)
    if spec.mixer == "mla":
        return attn.init_mla(key, cfg, dtype)
    if spec.mixer == "mamba":
        return ssm_lib.init_mamba(key, cfg, dtype)
    if spec.mixer == "rglru":
        return ssm_lib.init_rglru(key, cfg, dtype)
    raise ValueError(spec.mixer)


def _init_block(key, spec: LayerSpec, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 3)
    p = {"norm1": init_rms(cfg.d_model, dtype),
         "mixer": _init_mixer(ks[0], spec, cfg, dtype)}
    if spec.ffn == "mlp":
        p["norm2"] = init_rms(cfg.d_model, dtype)
        p["ffn"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype,
                            gated=cfg.mlp_gated)
    elif spec.ffn == "moe":
        p["norm2"] = init_rms(cfg.d_model, dtype)
        p["ffn"] = moe_lib.init_moe(ks[1], cfg, dtype)
    return p


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    dtype = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, len(cfg.stages()) + 3)
    params: Dict[str, Any] = {}
    params["embed"] = init_embed(keys[0], cfg.vocab_size, cfg.d_model, dtype)
    stages = []
    for si, (group, repeats) in enumerate(cfg.stages()):
        gkeys = jax.random.split(keys[si + 1], repeats)

        def init_one(k, _group=group):
            sks = jax.random.split(k, len(_group))
            return tuple(_init_block(sk, spec, cfg, dtype)
                         for sk, spec in zip(sks, _group))

        stages.append(jax.vmap(init_one)(gkeys))
    params["stages"] = stages
    params["final_norm"] = init_rms(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = init_linear(keys[-1], cfg.d_model, cfg.vocab_size,
                                     dtype)
    if cfg.mtp_depth:
        mk = jax.random.split(keys[-2], 3)
        params["mtp"] = {
            "proj": init_linear(mk[0], 2 * cfg.d_model, cfg.d_model, dtype),
            "block": _init_block(mk[1], LayerSpec(
                "mla" if cfg.use_mla else "gqa", "mlp"), cfg, dtype),
            "norm": init_rms(cfg.d_model, dtype),
        }
    return params


def abstract_params(cfg: ArchConfig, key=None):
    """ShapeDtypeStruct pytree (no allocation) for dry-run lowering."""
    k = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda: init_params(cfg, k))


# ----------------------------------------------------------------------------
# Block application (shared by all modes)
# ----------------------------------------------------------------------------

def _mixer_forward(p, spec: LayerSpec, x, cfg: ArchConfig, window: int):
    if spec.mixer == "gqa":
        return attn.gqa_forward(p, x, cfg, window=window)
    if spec.mixer == "local_attn":
        return attn.gqa_forward(p, x, cfg, window=cfg.local_window)
    if spec.mixer == "mla":
        return attn.mla_forward(p, x, cfg, window=window)
    if spec.mixer == "mamba":
        return ssm_lib.mamba_forward(p, x, cfg)
    if spec.mixer == "rglru":
        return ssm_lib.rglru_forward(p, x, cfg)
    raise ValueError(spec.mixer)


def _apply_block(p, spec: LayerSpec, x, cfg: ArchConfig, window: int,
                 capacity_factor=None):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    x = x + _mixer_forward(p["mixer"], spec, h, cfg, window).astype(x.dtype)
    aux = jnp.zeros((), jnp.float32)
    if spec.ffn is not None:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.ffn == "moe":
            kw = {} if capacity_factor is None else {
                "capacity_factor": capacity_factor}
            y, aux = moe_lib.moe_ffn(p["ffn"], h, cfg, **kw)
        else:
            act = "gelu" if "w_gate" not in p["ffn"] else "silu"
            y = mlp(p["ffn"], h, activation=act)
        x = x + y.astype(x.dtype)
    return x, aux


def _run_stages(params, cfg: ArchConfig, x, window: int,
                remat: bool = False, capacity_factor=None):
    """Apply every stage via lax.scan; returns (x, total_aux)."""
    total_aux = jnp.zeros((), jnp.float32)
    for (group, repeats), stage_p in zip(cfg.stages(), params["stages"]):

        def body(carry, block_ps, _group=group):
            h, aux = carry
            h = constrain_batch(h)
            for bp, spec in zip(block_ps, _group):
                h, a = _apply_block(bp, spec, h, cfg, window,
                                    capacity_factor=capacity_factor)
                aux = aux + a
            return (constrain_batch(h), aux), None

        if remat:
            body = jax.checkpoint(body,
                                  policy=jax.checkpoint_policies.nothing_saveable)
        (x, total_aux), _ = jax.lax.scan(body, (x, total_aux), stage_p)
    return x, total_aux


def _embed_inputs(params, cfg: ArchConfig, inputs):
    dtype = dtype_of(cfg.activation_dtype)
    if inputs.dtype in (jnp.int32, jnp.int64):
        x = embed(params["embed"], inputs).astype(dtype)
    else:
        x = inputs.astype(dtype)
    if cfg.pos_embedding == "sinusoidal":
        s = x.shape[1]
        pos = sinusoidal_embedding(jnp.arange(s), cfg.d_model)
        x = x + pos[None].astype(dtype)
    return constrain_batch(x)


def _logits(params, cfg: ArchConfig, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return unembed(params["embed"], x)
    return linear(params["head"], x)


def forward(params, cfg: ArchConfig, inputs, *, window: int = 0,
            remat: bool = False, capacity_factor=None):
    """inputs: int tokens [B,S] or embeddings [B,S,D] -> (logits, aux)."""
    x = _embed_inputs(params, cfg, inputs)
    x, aux = _run_stages(params, cfg, x, window, remat=remat,
                         capacity_factor=capacity_factor)
    return _logits(params, cfg, x), aux


# ----------------------------------------------------------------------------
# Loss / train step
# ----------------------------------------------------------------------------

def _ce_loss(logits, targets):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(params, cfg: ArchConfig, batch, *, remat: bool = True):
    # Single trunk pass shared by the main head and the MTP head (the MTP
    # module re-uses the final hidden states — recomputing the trunk for
    # MTP would double train compute; see EXPERIMENTS.md SSPerf extras).
    x = _embed_inputs(params, cfg, batch["inputs"])
    x, aux = _run_stages(params, cfg, x, window=0, remat=remat)
    logits = _logits(params, cfg, x)
    loss = _ce_loss(logits, batch["targets"])
    if cfg.num_experts:
        loss = loss + 0.01 * aux
    if cfg.mtp_depth and "mtp" in params:
        loss = loss + 0.3 * _mtp_loss(params, cfg, batch, x)
    return loss


def _mtp_loss(params, cfg: ArchConfig, batch, trunk_x):
    """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from the
    trunk state at t combined with the embedding of token t+1."""
    inputs, targets = batch["inputs"], batch["targets"]
    if inputs.dtype not in (jnp.int32, jnp.int64):
        return jnp.zeros((), jnp.float32)
    # Combine trunk state h_t with emb(x_{t+1}); predict target_{t+1} (=x_{t+2}).
    h_t = trunk_x[:, :-1]
    e_next = _embed_inputs(params, cfg, inputs[:, 1:])
    z = jnp.concatenate([rms_norm(h_t, params["mtp"]["norm"], cfg.norm_eps),
                         e_next], axis=-1)
    z = linear(params["mtp"]["proj"], z)
    spec = LayerSpec("mla" if cfg.use_mla else "gqa", "mlp")
    z, _ = _apply_block(params["mtp"]["block"], spec, z, cfg, 0)
    logits = _logits(params, cfg, z)
    return _ce_loss(logits, targets[:, 1:])


def make_train_step(cfg: ArchConfig, optimizer, *, microbatches: int = 1,
                    remat: bool = True):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). Gradient accumulation over ``microbatches`` splits of the
    global batch (sequential lax.scan -> peak activation memory divides)."""

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch,
                                                      remat=remat)
        else:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)

            def acc(carry, mbatch):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, cfg, mbatch,
                                                   remat=remat)
                grad_acc = jax.tree_util.tree_map(jnp.add, grad_acc, g)
                return (loss_acc + l, grad_acc), None

            zero_grads = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), zero_grads),
                                            mb)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
        params, opt_state = optimizer.apply(params, grads, opt_state)
        metrics = {"loss": loss,
                   "grad_norm": jax.tree_util.tree_reduce(
                       lambda a, g: a + jnp.sum(
                           jnp.square(g.astype(jnp.float32))),
                       grads, jnp.zeros(())) ** 0.5}
        return params, opt_state, metrics

    return train_step


# ----------------------------------------------------------------------------
# Serving: prefill + decode
# ----------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, cache_len: int, *,
               window: int = 0, quantized: bool = False) -> List[Any]:
    """Decode caches mirroring the stage structure (stacked over repeats).

    ``quantized=True`` builds int8 QuantKVCache for attention layers — the
    DAQ-inspired serving variant (SSPerf)."""
    dtype = dtype_of(cfg.activation_dtype)
    kv_cls = attn.QuantKVCache if quantized else attn.KVCache
    caches = []
    for group, repeats in cfg.stages():
        def one(_, _group=group):
            out = []
            for spec in _group:
                if spec.mixer in ("gqa",):
                    t = min(window, cache_len) if window else cache_len
                    out.append(kv_cls.zeros(
                        batch, t, cfg.num_kv_heads, cfg.head_dim, dtype))
                elif spec.mixer == "local_attn":
                    t = min(cfg.local_window, cache_len)
                    out.append(kv_cls.zeros(
                        batch, t, cfg.num_kv_heads, cfg.head_dim, dtype))
                elif spec.mixer == "mla":
                    t = min(window, cache_len) if window else cache_len
                    out.append(attn.MLACache.zeros(
                        batch, t, cfg.kv_lora_rank, cfg.qk_rope_head_dim,
                        dtype))
                elif spec.mixer == "mamba":
                    out.append(ssm_lib.MambaState.zeros(batch, cfg, dtype))
                elif spec.mixer == "rglru":
                    out.append(ssm_lib.RGLRUState.zeros(batch, cfg, dtype))
            return tuple(out)

        caches.append(jax.vmap(one)(jnp.arange(repeats)))
    return caches


def _decode_block(p, spec: LayerSpec, x, cache, pos, cfg: ArchConfig,
                  window: int):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == "gqa":
        y, cache = attn.gqa_decode(p["mixer"], h, cache, pos, cfg,
                                   window=window)
    elif spec.mixer == "local_attn":
        y, cache = attn.gqa_decode(p["mixer"], h, cache, pos, cfg,
                                   window=cfg.local_window)
    elif spec.mixer == "mla":
        y, cache = attn.mla_decode(p["mixer"], h, cache, pos, cfg,
                                   window=window)
    elif spec.mixer == "mamba":
        y, cache = ssm_lib.mamba_decode(p["mixer"], h, cache, cfg)
    elif spec.mixer == "rglru":
        y, cache = ssm_lib.rglru_decode(p["mixer"], h, cache, cfg)
    x = x + y.astype(x.dtype)
    if spec.ffn is not None:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.ffn == "moe":
            # Dropless at decode: single-token batches must never drop.
            cf = cfg.num_experts / max(cfg.experts_per_token, 1)
            y, _ = moe_lib.moe_ffn(p["ffn"], h, cfg, capacity_factor=cf)
        else:
            act = "gelu" if "w_gate" not in p["ffn"] else "silu"
            y = mlp(p["ffn"], h, activation=act)
        x = x + y.astype(x.dtype)
    return x, cache


def decode_step(params, cfg: ArchConfig, caches, tokens, pos, *,
                window: int = 0):
    """One serving step: tokens [B,1] int (or [B,1,D] embeddings), absolute
    position ``pos`` -> (logits [B,1,V], new caches)."""
    dtype = dtype_of(cfg.activation_dtype)
    if tokens.dtype in (jnp.int32, jnp.int64):
        x = embed(params["embed"], tokens).astype(dtype)
    else:
        x = tokens.astype(dtype)
    if cfg.pos_embedding == "sinusoidal":
        pos_vec = jnp.reshape(pos, (1,))
        x = x + sinusoidal_embedding(pos_vec, cfg.d_model)[None].astype(dtype)
    new_caches = []
    for (group, repeats), stage_p, stage_c in zip(cfg.stages(),
                                                  params["stages"], caches):

        def body(h, xs, _group=group):
            block_ps, block_cs = xs
            new_cs = []
            for bp, bc, spec in zip(block_ps, block_cs, _group):
                h, nc = _decode_block(bp, spec, h, bc, pos, cfg, window)
                new_cs.append(nc)
            return h, tuple(new_cs)

        x, nc = jax.lax.scan(body, x, (stage_p, stage_c))
        new_caches.append(nc)
    return _logits(params, cfg, x), new_caches


def prefill(params, cfg: ArchConfig, inputs, *, window: int = 0,
            cache_len: int = 0):
    """Full-sequence prefill: returns (last-token logits, caches filled for
    positions [0, S)). ``cache_len`` > S pre-allocates decode headroom."""
    s = inputs.shape[1]
    cache_len = max(cache_len, s)
    x = _embed_inputs(params, cfg, inputs)
    caches = []
    for (group, repeats), stage_p in zip(cfg.stages(), params["stages"]):

        def body(h, block_ps, _group=group):
            new_cs = []
            h = constrain_batch(h)
            for bp, spec in zip(block_ps, _group):
                hn = rms_norm(h, bp["norm1"], cfg.norm_eps)
                if spec.mixer in ("gqa", "local_attn", "mla"):
                    y = _mixer_forward(bp["mixer"], spec, hn, cfg, window)
                    new_cs.append(_prefill_cache(bp["mixer"], spec, hn, cfg,
                                                 window, cache_len))
                else:
                    y = _mixer_forward(bp["mixer"], spec, hn, cfg, window)
                    new_cs.append(_prefill_state(bp["mixer"], spec, hn, cfg))
                h = h + y.astype(h.dtype)
                if spec.ffn is not None:
                    h2 = rms_norm(h, bp["norm2"], cfg.norm_eps)
                    if spec.ffn == "moe":
                        y2, _ = moe_lib.moe_ffn(bp["ffn"], h2, cfg)
                    else:
                        act = "gelu" if "w_gate" not in bp["ffn"] else "silu"
                        y2 = mlp(bp["ffn"], h2, activation=act)
                    h = h + y2.astype(h.dtype)
            return h, tuple(new_cs)

        x, cs = jax.lax.scan(body, x, stage_p)
        caches.append(cs)
    return _logits(params, cfg, x[:, -1:]), caches


def _pad_time(x, t: int):
    """Zero-pad axis 1 (time) up to t entries."""
    if x.shape[1] >= t:
        return x
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, t - x.shape[1])
    return jnp.pad(x, pad)


def _prefill_cache(p, spec, h, cfg: ArchConfig, window: int, cache_len: int):
    """Recompute K/V (cheap projections) to fill the decode cache."""
    s = h.shape[1]
    cdt = dtype_of(cfg.activation_dtype)
    positions = jnp.arange(s)[None, :]
    if spec.mixer == "mla":
        r_kv = cfg.kv_lora_rank
        ckv = jnp.einsum("bsd,dr->bsr", h, p["wkv_a"])
        c_kv, k_rope = ckv[..., :r_kv], ckv[..., r_kv:]
        k_rope = attn.apply_rope(k_rope[:, :, None, :], positions,
                                 cfg.rope_theta)[:, :, 0]
        if window:
            w = min(window, cache_len)
            return attn.MLACache(_ring_pack(c_kv, w).astype(cdt),
                                 _ring_pack(k_rope, w).astype(cdt))
        return attn.MLACache(_pad_time(c_kv, cache_len).astype(cdt),
                             _pad_time(k_rope, cache_len).astype(cdt))
    q, k, v = attn._qkv(p, h, cfg, positions)
    k, v = k.astype(cdt), v.astype(cdt)
    w = 0
    if spec.mixer == "local_attn":
        w = min(cfg.local_window, cache_len)
    elif window:
        w = min(window, cache_len)
    if w:
        return attn.KVCache(_ring_pack(k, w), _ring_pack(v, w))
    return attn.KVCache(_pad_time(k, cache_len), _pad_time(v, cache_len))


def _ring_pack(x, w: int):
    """Place the last w timesteps at their ring-buffer slots (pos % w) so a
    subsequent windowed decode continues seamlessly."""
    s = x.shape[1]
    if s <= w:
        return _pad_time(x, w)
    tail = x[:, s - w:]
    shift = (s - w) % w
    return jnp.roll(tail, shift, axis=1)


def _prefill_state(p, spec, h, cfg: ArchConfig):
    """Final recurrent state after a full-sequence pass (recomputes the
    scan; XLA CSEs against the forward pass)."""
    b, s, _ = h.shape
    if spec.mixer == "mamba":
        dc = cfg.ssm_conv
        xz = h @ p["in_proj"]
        xin, z = jnp.split(xz, 2, axis=-1)
        xp = jnp.pad(xin, ((0, 0), (dc - 1, 0), (0, 0)))
        xc = sum(xp[:, i:i + s] * p["conv_w"][i] for i in range(dc))
        xc = jax.nn.silu(xc + p["conv_b"])
        h0 = jnp.zeros((b, cfg.ssm_d_inner, cfg.ssm_state), jnp.float32)
        _, h_last = ssm_lib._mamba_inner(p, xc, z, cfg, h0)
        cdt = dtype_of(cfg.activation_dtype)
        return ssm_lib.MambaState(conv=xin[:, -(dc - 1):].astype(cdt),
                                  ssm=h_last)
    if spec.mixer == "rglru":
        dc = cfg.ssm_conv
        xb = h @ p["in_x"]
        xp = jnp.pad(xb, ((0, 0), (dc - 1, 0), (0, 0)))
        xc = sum(xp[:, i:i + s] * p["conv_w"][i] for i in range(dc))
        xc = xc + p["conv_b"]
        h0 = jnp.zeros((b, cfg.rglru_width), jnp.float32)
        _, h_last = ssm_lib._rglru_scan(p, xc, h0)
        cdt = dtype_of(cfg.activation_dtype)
        return ssm_lib.RGLRUState(conv=xb[:, -(dc - 1):].astype(cdt),
                                  h=h_last)
    raise ValueError(spec.mixer)
