"""Attention mixers: GQA (with RoPE / bias / sliding window / local banding)
and MLA (DeepSeek-V3 latent attention with compressed KV cache).

Three execution modes share each mixer's parameters:
  * ``forward``      — full-sequence causal attention (train / prefill)
  * ``decode``       — one token against a KV cache (decode_32k)
  * windowed decode  — ring-buffer cache of ``window`` entries (long_500k)

Caches are explicit pytrees so lax.scan can carry them through stacked
layers, and their shardings are set by the same path rules as parameters.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, init_linear, linear

NEG_INF = -2.0e38


# ----------------------------------------------------------------------------
# GQA
# ----------------------------------------------------------------------------

def init_gqa(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    sc = (2.0 / (d + h * dh)) ** 0.5
    p = {
        "wq": jax.random.normal(ks[0], (d, h, dh), dtype) * sc,
        "wk": jax.random.normal(ks[1], (d, kv, dh), dtype) * sc,
        "wv": jax.random.normal(ks[2], (d, kv, dh), dtype) * sc,
        "wo": jax.random.normal(ks[3], (h, dh, d), dtype) * sc,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((kv, dh), dtype)
        p["bv"] = jnp.zeros((kv, dh), dtype)
    return p


def _qkv(params, x, cfg: ArchConfig, positions):
    dt = x.dtype  # keep projections in activation dtype (bf16 in prod)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, num_kv_groups: int):
    """q [B,S,H,dh], k/v [B,T,KV,dh], additive mask broadcastable to
    [B,KV,G,S,T]. Direct (unchunked) path — used for decode (S=1)."""
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    q = q.reshape(b, s, kvh, num_kv_groups, dh)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / np.sqrt(dh)
    scores = scores + mask                       # mask broadcast [B,1,1,S,T]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, dh)


DEFAULT_Q_CHUNK = 512


def chunked_causal_attention(q, k, v, num_kv_groups: int, *, window: int = 0,
                             q_chunk: int = DEFAULT_Q_CHUNK):
    """Blockwise causal attention: scan over query chunks so peak score
    memory is [B,KV,G,QC,T] instead of [B,KV,G,S,S]; the mask is computed
    from iotas (never a materialized S x S table)."""
    b, s, h, dh = q.shape
    t, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = num_kv_groups
    qc = min(q_chunk, s)
    if s % qc:
        qc = s  # fallback: irregular sizes go unchunked
    n_chunks = s // qc
    qs = q.reshape(b, n_chunks, qc, kvh, g, dh).transpose(1, 0, 2, 3, 4, 5)
    j = jnp.arange(t)

    def one_chunk(ci, q_blk):
        # q_blk [B,QC,KV,G,dh]
        i = ci * qc + jnp.arange(qc)
        ok = j[None, :] <= i[:, None]
        if window:
            ok &= j[None, :] > (i[:, None] - window)
        m = jnp.where(ok, 0.0, NEG_INF)[None, None, None]   # [1,1,1,QC,T]
        scores = jnp.einsum("bskgd,btkd->bkgst", q_blk, k,
                            preferred_element_type=jnp.float32) / np.sqrt(dh)
        probs = jax.nn.softmax(scores + m, axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgst,btkd->bskgd", probs, v)      # [B,QC,KV,G,dh]
        return out

    def scan_body(ci, q_blk):
        return ci + 1, one_chunk(ci, q_blk)

    # scan with a counter carry (not an iota xs): mixing a replicated iota
    # into the xs tuple makes GSPMD replicate the whole loop batch.
    _, outs = jax.lax.scan(scan_body, jnp.zeros((), jnp.int32), qs)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dv)
    return out


def causal_mask(s: int, window: int = 0) -> jnp.ndarray:
    """[1,1,1,S,S] additive causal (optionally banded) mask."""
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    ok = j <= i
    if window:
        ok &= j > i - window
    return jnp.where(ok, 0.0, NEG_INF)[None, None, None]


def gqa_forward(params, x, cfg: ArchConfig, *, window: int = 0,
                positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = _qkv(params, x, cfg, positions)
    if cfg.attn_impl == "flash":
        from repro.kernels.flash_attention import gqa_flash
        out = gqa_flash(q, k, v, window=window,
                        interpret=jax.default_backend() != "tpu")
    else:
        out = chunked_causal_attention(q, k, v,
                                       cfg.num_heads // cfg.num_kv_heads,
                                       window=window)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


class KVCache(NamedTuple):
    k: jnp.ndarray       # [B, T, KV, dh]
    v: jnp.ndarray       # [B, T, KV, dh]

    @classmethod
    def zeros(cls, b, t, kv, dh, dtype):
        return cls(jnp.zeros((b, t, kv, dh), dtype),
                   jnp.zeros((b, t, kv, dh), dtype))


class QuantKVCache(NamedTuple):
    """int8 KV cache with per-(token, head) scales — Fograph's degree-aware
    quantization (SSIII-D) transplanted to the dominant serving payload.
    4x less cache HBM residency; dequantization fuses into the VMEM tile
    stream on TPU (see kernels/daq_dequant.py for the fused pattern)."""
    k_q: jnp.ndarray       # int8 [B, T, KV, dh]
    v_q: jnp.ndarray       # int8 [B, T, KV, dh]
    k_scale: jnp.ndarray   # f32  [B, T, KV]
    v_scale: jnp.ndarray   # f32  [B, T, KV]

    @classmethod
    def zeros(cls, b, t, kv, dh, dtype=None):
        return cls(jnp.zeros((b, t, kv, dh), jnp.int8),
                   jnp.zeros((b, t, kv, dh), jnp.int8),
                   jnp.zeros((b, t, kv), jnp.float32),
                   jnp.zeros((b, t, kv), jnp.float32))


def _quantize_heads(x):
    """x [B,S,KV,dh] -> (int8 codes, f32 scales [B,S,KV])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_heads(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def gqa_decode(params, x, cache, pos, cfg: ArchConfig, *,
               window: int = 0):
    """One-token decode. ``pos`` int32[] absolute position. With window>0
    the cache is a ring buffer of ``window`` entries. Accepts KVCache or
    QuantKVCache (int8 + scales)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _qkv(params, x, cfg, positions)     # [B,1,·,dh]
    t = (cache.k if isinstance(cache, KVCache) else cache.k_q).shape[1]
    slot = (pos % window) if window else pos
    if isinstance(cache, QuantKVCache):
        kq, ks = _quantize_heads(k)
        vq, vs = _quantize_heads(v)
        dus = jax.lax.dynamic_update_slice_in_dim
        new_cache = QuantKVCache(
            dus(cache.k_q, kq, slot, 1), dus(cache.v_q, vq, slot, 1),
            dus(cache.k_scale, ks, slot, 1), dus(cache.v_scale, vs, slot, 1))
        k_full = _dequantize_heads(new_cache.k_q, new_cache.k_scale, x.dtype)
        v_full = _dequantize_heads(new_cache.v_q, new_cache.v_scale, x.dtype)
    else:
        k = k.astype(cache.k.dtype)
        v = v.astype(cache.v.dtype)
        new_k = jax.lax.dynamic_update_slice_in_dim(cache.k, k, slot, axis=1)
        new_v = jax.lax.dynamic_update_slice_in_dim(cache.v, v, slot, axis=1)
        new_cache = KVCache(new_k, new_v)
        k_full, v_full = new_k, new_v
    idx = jnp.arange(t)
    if window:
        valid = idx < jnp.minimum(pos + 1, window)
    else:
        valid = idx <= pos
    mask = jnp.where(valid, 0.0, NEG_INF)[None, None, None, None, :]
    out = _sdpa(q, k_full, v_full, mask, cfg.num_heads // cfg.num_kv_heads)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, new_cache


# ----------------------------------------------------------------------------
# MLA (DeepSeek-V3, arXiv:2412.19437 §2.1)
# ----------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 8)
    d, h = cfg.d_model, cfg.num_heads
    r_q = cfg.q_lora_rank or 0
    r_kv = cfg.kv_lora_rank
    qk_n, qk_r, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    sc = lambda i, o: (2.0 / (i + o)) ** 0.5
    p = {}
    if r_q:
        p["wq_a"] = jax.random.normal(ks[0], (d, r_q), dtype) * sc(d, r_q)
        p["wq_b"] = jax.random.normal(ks[1], (r_q, h, qk_n + qk_r),
                                      dtype) * sc(r_q, h * (qk_n + qk_r))
    else:
        p["wq"] = jax.random.normal(ks[1], (d, h, qk_n + qk_r),
                                    dtype) * sc(d, h * (qk_n + qk_r))
    # KV joint compression: c_kv = x @ wkv_a[:, :r_kv]; k_rope shared 1 head.
    p["wkv_a"] = jax.random.normal(ks[2], (d, r_kv + qk_r),
                                   dtype) * sc(d, r_kv + qk_r)
    p["wk_b"] = jax.random.normal(ks[3], (r_kv, h, qk_n),
                                  dtype) * sc(r_kv, h * qk_n)
    p["wv_b"] = jax.random.normal(ks[4], (r_kv, h, dv),
                                  dtype) * sc(r_kv, h * dv)
    p["wo"] = jax.random.normal(ks[5], (h, dv, d), dtype) * sc(h * dv, d)
    return p


def _mla_q(params, x, cfg: ArchConfig, positions):
    dt = x.dtype
    qk_n, qk_r = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if "wq_a" in params:
        q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(dt))
        q = jnp.einsum("bsr,rhk->bshk", q, params["wq_b"].astype(dt))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    q_nope, q_rope = q[..., :qk_n], q[..., qk_n:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(params, x, cfg: ArchConfig, *, window: int = 0,
                positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence MLA (naive/uncompressed materialization)."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    qk_n, qk_r = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    r_kv = cfg.kv_lora_rank
    dt = x.dtype
    q_nope, q_rope = _mla_q(params, x, cfg, positions)
    ckv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(dt))
    c_kv, k_rope = ckv[..., :r_kv], ckv[..., r_kv:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"].astype(dt))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"].astype(dt))
    # Fold the rope part into a combined head dim and reuse the chunked
    # path; its 1/sqrt(qk_n + qk_r) scale is exactly MLA's.
    q_all = jnp.concatenate([q_nope, q_rope], axis=-1)
    h = q_nope.shape[2]
    k_all = jnp.concatenate([k_nope,
                             jnp.broadcast_to(k_rope, (b, s, h, qk_r))],
                            axis=-1)
    out = chunked_causal_attention(q_all, k_all, v, 1, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


class MLACache(NamedTuple):
    c_kv: jnp.ndarray     # [B, T, r_kv]   compressed latent
    k_rope: jnp.ndarray   # [B, T, qk_rope]

    @classmethod
    def zeros(cls, b, t, r_kv, qk_r, dtype):
        return cls(jnp.zeros((b, t, r_kv), dtype),
                   jnp.zeros((b, t, qk_r), dtype))


def mla_decode(params, x, cache: MLACache, pos, cfg: ArchConfig, *,
               window: int = 0) -> Tuple[jnp.ndarray, MLACache]:
    """Weight-absorbed decode: attention runs in the latent space, so the
    cache stores only (r_kv + qk_rope) per token — MLA's whole point."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    qk_n, qk_r = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    r_kv = cfg.kv_lora_rank
    dt = x.dtype
    q_nope, q_rope = _mla_q(params, x, cfg, positions)   # [B,1,H,·]
    ckv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(dt))
    c_new, kr_new = ckv[..., :r_kv], ckv[..., r_kv:]
    kr_new = apply_rope(kr_new[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]
    t = cache.c_kv.shape[1]
    slot = (pos % window) if window else pos
    c_new = c_new.astype(cache.c_kv.dtype)
    kr_new = kr_new.astype(cache.k_rope.dtype)
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new, slot, 1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new, slot, 1)
    # Absorb wk_b into the query: q_lat [B,1,H,r_kv].
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"].astype(dt))
    scale = 1.0 / np.sqrt(qk_n + qk_r)
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bhst", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    idx = jnp.arange(t)
    valid = (idx < jnp.minimum(pos + 1, window)) if window else (idx <= pos)
    scores = scores + jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    attn_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv)     # [B,1,H,r_kv]
    out = jnp.einsum("bshr,rhk->bshk", attn_lat, params["wv_b"].astype(dt))
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, MLACache(c_kv, k_rope)
