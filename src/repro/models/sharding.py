"""Path/shape-based sharding rules for params, optimizer state, caches, and
batches on the production meshes.

Philosophy (MaxText-style): a small table maps parameter *names* to the
logical dimension that carries model parallelism; dimensions shard on the
``model`` axis only when evenly divisible (GSPMD could pad, but uneven
shards waste the padded fraction on every op — we replicate instead and
note it). Batch axes shard over (``pod``,) ``data``. Optimizer moments
inherit their parameter's spec verbatim; decode caches shard batch on data
and heads/state on model.

Negative dim indices make the rules agnostic to the leading stage-stacking
axis that lax.scan adds.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig

# name -> candidate (negative) dims for the `data` axis under FSDP
# (ZeRO-3-style): the dim NOT taken by model parallelism, so giants shard
# over the full chip grid. Weight all-gathers are inserted by GSPMD.
_FSDP_DIM_RULES: Dict[str, Tuple[int, ...]] = {
    "wq": (-3,), "wk": (-3,), "wv": (-3,), "wo": (-1,),
    "wq_a": (-2,), "wq_b": (-3,), "wkv_a": (-2,), "wk_b": (-3,),
    "wv_b": (-3,),
    "w_gate": (-2,), "w_up": (-2,), "w_down": (-1,),
    "in_proj": (-2,), "x_proj": (-1,), "dt_proj": (-2,),
    "out_proj": (-1,),
    "in_x": (-2,), "in_gate": (-2,), "out": (-1,),
    "table": (-1,), "w": (-2,),
}

# name -> candidate (negative) dims to try sharding on `model`, in order.
_MODEL_DIM_RULES: Dict[str, Tuple[int, ...]] = {
    # attention
    "wq": (-2,), "wk": (-2,), "wv": (-2,), "wo": (-3,),
    "bq": (-2,), "bk": (-2,), "bv": (-2,),
    # MLA
    "wq_a": (), "wq_b": (-2,), "wkv_a": (), "wk_b": (-2,), "wv_b": (-2,),
    # dense mlp (also MoE shared expert)
    "w_gate": (-1,), "w_up": (-1,), "w_down": (-2,),
    # moe router
    "router": (),
    # mamba
    "in_proj": (-1,), "conv_w": (-1,), "conv_b": (-1,),
    "x_proj": (-2,), "dt_proj": (-1,), "dt_bias": (-1,),
    "a_log": (-2,), "d_skip": (-1,), "out_proj": (-2,),
    # rglru
    "in_x": (-1,), "in_gate": (-1,), "w_input_gate": (-1,),
    "w_rec_gate": (-1,), "lambda_p": (-1,), "out": (-2,),
    # embedding / head
    "table": (-2,), "w": (-1,), "b": (-1,),
    # norms
    "norm1": (), "norm2": (), "final_norm": (), "scale": (),
}


def _path_names(path) -> list:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return out


# Serving variant (§Perf iteration): shard attention projections on the
# head_dim instead of heads, so kv-indivisible GQA decodes with partial
# scores + a small all-reduce instead of all-gathering the KV cache.
_ATTN_DH_RULES: Dict[str, Tuple[int, ...]] = {
    "wq": (-1,), "wk": (-1,), "wv": (-1,), "wo": (-2,),
    "bq": (-1,), "bk": (-1,), "bv": (-1,),
}


def _spec_for_param(path, shape, cfg: ArchConfig, model_size: int,
                    fsdp_axes: Tuple[str, ...] = (),
                    serve_attn_dh: bool = False,
                    expert_grid: bool = False) -> P:
    names = _path_names(path)
    name = names[-1] if names else ""
    ndim = len(shape)
    spec = [None] * ndim
    # MoE expert weights: leading expert dim takes priority.
    if cfg.num_experts and "ffn" in names and name in ("w_gate", "w_up",
                                                       "w_down"):
        # stacked moe expert weights: [..., E, d, f] — find the expert dim.
        grid = 1
        for a in ("data", "model"):
            grid *= _FSDP_SIZE.get(a, 1)
        for ax in range(ndim):
            # the expert dim is the 3rd-from-last at most (E, d, f tail)
            if shape[ax] == cfg.num_experts and ndim - ax == 3:
                if expert_grid and cfg.num_experts % grid == 0:
                    # one expert (group) per chip: token all-to-all replaces
                    # FSDP weight gathers entirely (§Perf pair B)
                    spec[ax] = ("data", "model")
                    return P(*spec)
                if cfg.num_experts % model_size == 0:
                    spec[ax] = "model"
                break  # found the expert dim (sharded or indivisible)
    rules = _MODEL_DIM_RULES
    if serve_attn_dh and cfg.num_kv_heads and \
            cfg.num_kv_heads % model_size != 0 and name in _ATTN_DH_RULES:
        rules = {**_MODEL_DIM_RULES, **_ATTN_DH_RULES}
    if not any(spec):
        for nd in rules.get(name, ()):
            ax = ndim + nd
            if 0 <= ax < ndim and shape[ax] % model_size == 0 \
                    and shape[ax] >= model_size:
                spec[ax] = "model"
                break
    if fsdp_axes:
        import numpy as _np
        fs = 1
        for a in fsdp_axes:
            fs *= _FSDP_SIZE.get(a, 1)
        for nd in _FSDP_DIM_RULES.get(name, ()):
            ax = ndim + nd
            if 0 <= ax < ndim and spec[ax] is None                     and shape[ax] % fs == 0 and shape[ax] >= fs:
                spec[ax] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                break
    return P(*spec)


_FSDP_SIZE: Dict[str, int] = {}


def param_shardings(cfg: ArchConfig, params_abstract, mesh: Mesh,
                    fsdp: bool = False, serve_attn_dh: bool = False,
                    expert_grid: bool = False):
    """NamedSharding pytree for a param (or optimizer-moment) pytree.

    ``fsdp=True`` additionally shards a second weight dim over the data
    (and pod) axes — required for the giants (grok-1, deepseek-v3) whose
    TP-only shards exceed HBM. ``serve_attn_dh`` / ``expert_grid`` are the
    SSPerf serving variants (see EXPERIMENTS.md).
    """
    model_size = mesh.shape["model"]
    fsdp_axes = data_axes(mesh) if fsdp else ()
    for a in mesh.shape:
        _FSDP_SIZE[a] = mesh.shape[a]

    def one(path, leaf):
        spec = _spec_for_param(path, leaf.shape, cfg, model_size, fsdp_axes,
                               serve_attn_dh=serve_attn_dh,
                               expert_grid=expert_grid)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_abstract)


def needs_fsdp(cfg: ArchConfig, mesh: Mesh, train: bool) -> bool:
    """Do TP-only weights (+moments at train) overflow a 16 GB chip?"""
    bytes_per_param = {"float32": 4, "bfloat16": 2}[cfg.param_dtype]
    if train:
        bytes_per_param += 2 * (2 if cfg.param_count() > 1.5e11 else 4)
    per_dev = cfg.param_count() * bytes_per_param / mesh.shape["model"]
    return per_dev > 8e9


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh, global_batch: int, rank: int,
               batch_axis: int = 0) -> P:
    """Shard the batch dim over (pod, data) when divisible, else replicate."""
    axes = data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    spec = [None] * rank
    if global_batch % total == 0 and global_batch >= total:
        spec[batch_axis] = axes
    elif "data" in mesh.shape and global_batch % mesh.shape["data"] == 0 \
            and global_batch >= mesh.shape["data"]:
        spec[batch_axis] = "data"
    return P(*spec)


def batch_shardings(mesh: Mesh, batch_abstract):
    def one(leaf):
        rank = len(leaf.shape)
        if rank == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, batch_spec(mesh, leaf.shape[0], rank))

    return jax.tree_util.tree_map(one, batch_abstract)


def cache_shardings(cfg: ArchConfig, cache_abstract, mesh: Mesh,
                    global_batch: int):
    """Decode-cache shardings: axis 1 is batch (axis 0 = stage stacking);
    kv-heads / state dims go on `model` when divisible."""
    model_size = mesh.shape["model"]

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = leaf.shape
        rank = len(shape)
        spec = [None] * rank
        # batch axis: stacked caches are [R, B, ...]; unstacked [B, ...].
        baxis = 1 if rank >= 2 and shape[0] != global_batch else 0
        if name in ("k_scale", "v_scale"):
            # quant-cache scales: small, batch-sharded only (their KV dim
            # is usually indivisible and time must stay local for the
            # ring-buffer update)
            bspec = batch_spec(mesh, shape[baxis], rank, baxis)                 if rank > baxis else P()
            return NamedSharding(mesh, bspec)
        if rank > baxis:
            bspec = batch_spec(mesh, shape[baxis], rank, baxis)
            spec = list(bspec)
        # model axis: try kv-heads ([..., KV, dh] -> -2) then trailing
        # state dims (mamba d_inner at -2 for ssm, -1 for conv; rglru w
        # at -1).
        sharded_model = False
        for nd in (-2, -1):
            ax = rank + nd
            if ax <= baxis or spec[ax] is not None:
                continue
            dim = shape[ax]
            if dim % model_size == 0 and dim >= model_size and dim not in (
                    cfg.head_dim, cfg.qk_rope_head_dim):
                # shard the first eligible (heads / d_inner / width) dim
                if (nd == -2 and dim in (cfg.num_kv_heads, cfg.ssm_d_inner)
                        ) or (nd == -1 and dim in (
                            cfg.ssm_d_inner, cfg.rglru_width,
                            cfg.kv_lora_rank)):
                    spec[ax] = "model"
                    sharded_model = True
                    break
        if not sharded_model and rank >= 3:
            # Feature-sharded KV cache: when kv-heads don't divide the model
            # axis (kv=8 vs 16, MQA kv=1, MLA latent), shard the trailing
            # feature dim (head_dim / kv_lora_rank / rope dim) instead. The
            # QK contraction over the sharded dim lowers to partial scores +
            # one small all-reduce per layer, while the ring-buffer
            # dynamic-update-slice stays LOCAL (sharding the time axis would
            # turn the O(1) append into an O(cache) masked rewrite).
            lax_ = rank - 1
            if spec[lax_] is None and shape[lax_] % model_size == 0 \
                    and shape[lax_] >= model_size:
                spec[lax_] = "model"
            else:
                tax = baxis + 1
                if spec[tax] is None and shape[tax] % model_size == 0 \
                        and shape[tax] >= 1024:
                    spec[tax] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_abstract)


def replicated(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)


# ----------------------------------------------------------------------------
# Activation sharding constraints (enabled by the launcher; no-ops in plain
# CPU tests). GSPMD propagation alone can drop the batch sharding through
# deep scan bodies (observed on MoE prefill, see EXPERIMENTS.md SSPerf), so
# the launcher pins the residual-stream batch axis explicitly — the same
# discipline MaxText applies with logical axis rules.
# ----------------------------------------------------------------------------

_BATCH_AXES: Optional[Tuple[str, ...]] = None
_SEQ_PARALLEL: bool = False


def enable_activation_constraints(batch_axes: Optional[Tuple[str, ...]],
                                  seq_parallel: bool = False):
    global _BATCH_AXES, _SEQ_PARALLEL
    _BATCH_AXES = tuple(batch_axes) if batch_axes else None
    _SEQ_PARALLEL = seq_parallel


def constrain_batch(x, batch_axis: int = 0):
    """Pin x's batch axis to the data axes (no-op when disabled or when the
    batch does not divide). With seq_parallel, additionally shard the
    sequence axis of the residual stream over `model` — GSPMD then emits
    all-gather before each mixer and reduce-scatter after it (Megatron-SP),
    halving the per-layer activation collective bytes vs all-reduce."""
    if _BATCH_AXES is None:
        return x
    size = 1
    for a in _BATCH_AXES:
        size *= _FSDP_SIZE.get(a, 1)
    if size <= 1 or x.shape[batch_axis] % size:
        return x
    spec = [None] * x.ndim
    spec[batch_axis] = _BATCH_AXES if len(_BATCH_AXES) > 1 else _BATCH_AXES[0]
    if _SEQ_PARALLEL and x.ndim >= 3:
        seq_ax = batch_axis + 1
        m = _FSDP_SIZE.get("model", 1)
        if m > 1 and x.shape[seq_ax] % m == 0 and x.shape[seq_ax] >= m:
            spec[seq_ax] = "model"
    return jax.lax.with_sharding_constraint(x, P(*spec))
