"""Recurrent mixers: Mamba-1 selective SSM (falcon-mamba) and RG-LRU
(recurrentgemma), each with full-sequence and single-step decode paths.

TPU adaptation note (DESIGN.md §2): the CUDA Mamba kernel fuses a chunked
parallel scan in shared memory. Our full-sequence path uses ``lax.scan``
over time with an O(B·d_inner·d_state) carry — HLO-compact (one body) and
memory-light; the chunked-associative-scan variant is the §Perf knob for
SSM archs. Decode is the natural O(1)-state update, which is exactly why
SSMs are the ideal long_500k serving architecture.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import init_linear, linear


# ----------------------------------------------------------------------------
# Mamba-1 (arXiv:2312.00752; falcon-mamba arXiv:2410.05355)
# ----------------------------------------------------------------------------

def init_mamba(key, cfg: ArchConfig, dtype):
    d, di = cfg.d_model, cfg.ssm_d_inner
    st, dc, dtr = cfg.ssm_state, cfg.ssm_conv, cfg.ssm_dt_rank
    ks = jax.random.split(key, 7)
    sc = lambda i, o: (2.0 / (i + o)) ** 0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * sc(d, 2 * di),
        "conv_w": jax.random.normal(ks[1], (dc, di), dtype) * 0.2,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": jax.random.normal(ks[2], (di, dtr + 2 * st),
                                    dtype) * sc(di, dtr + 2 * st),
        "dt_proj": jax.random.normal(ks[3], (dtr, di), dtype) * sc(dtr, di),
        "dt_bias": jnp.zeros((di,), dtype),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32),
                                  (di, 1))),                    # [di, st]
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[4], (di, d), dtype) * sc(di, d),
    }


class MambaState(NamedTuple):
    conv: jnp.ndarray   # [B, dc-1, di] rolling conv inputs
    ssm: jnp.ndarray    # [B, di, st]

    @classmethod
    def zeros(cls, b, cfg: ArchConfig, dtype):
        return cls(jnp.zeros((b, cfg.ssm_conv - 1, cfg.ssm_d_inner), dtype),
                   jnp.zeros((b, cfg.ssm_d_inner, cfg.ssm_state), jnp.float32))


def _mamba_inner(params, xc: jnp.ndarray, z: jnp.ndarray, cfg: ArchConfig,
                 h0: jnp.ndarray):
    """xc: post-conv activations [B,S,di]; returns (y [B,S,di], h_last)."""
    st, dtr = cfg.ssm_state, cfg.ssm_dt_rank
    xdbc = xc @ params["x_proj"].astype(xc.dtype)                     # [B,S,dtr+2st]
    dt = (xdbc[..., :dtr] @ params["dt_proj"].astype(xdbc.dtype)
          + params["dt_bias"])
    dt = jax.nn.softplus(dt.astype(jnp.float32))     # [B,S,di]
    bmat = xdbc[..., dtr:dtr + st].astype(jnp.float32)   # [B,S,st]
    cmat = xdbc[..., dtr + st:].astype(jnp.float32)      # [B,S,st]
    a = -jnp.exp(params["a_log"])                    # [di, st]

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp                    # [B,di],[B,st],[B,st],[B,di]
        da = jnp.exp(dt_t[..., None] * a)            # [B,di,st]
        db = dt_t[..., None] * b_t[:, None, :]       # [B,di,st]
        h = da * h + db * x_t[..., None].astype(jnp.float32)
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (dt.transpose(1, 0, 2), bmat.transpose(1, 0, 2),
          cmat.transpose(1, 0, 2),
          xc.transpose(1, 0, 2))
    h_last, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2)                        # [B,S,di]
    y = y + xc.astype(jnp.float32) * params["d_skip"]
    return (y.astype(xc.dtype) * jax.nn.silu(z)), h_last


def mamba_forward(params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    b, s, d = x.shape
    di, dc = cfg.ssm_d_inner, cfg.ssm_conv
    xz = x @ params["in_proj"].astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    # Causal depthwise conv over time.
    xp = jnp.pad(xin, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + s] * params["conv_w"][i] for i in range(dc))
    xc = jax.nn.silu(xc + params["conv_b"])
    h0 = jnp.zeros((b, di, cfg.ssm_state), jnp.float32)
    y, _ = _mamba_inner(params, xc, z, cfg, h0)
    return y @ params["out_proj"].astype(y.dtype)


def mamba_decode(params, x: jnp.ndarray, state: MambaState,
                 cfg: ArchConfig) -> Tuple[jnp.ndarray, MambaState]:
    """x: [B,1,D] one token; constant-size state update."""
    dc = cfg.ssm_conv
    xz = x @ params["in_proj"].astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)               # [B,1,di]
    hist = jnp.concatenate([state.conv, xin], axis=1)   # [B,dc,di]
    xc = sum(hist[:, i] * params["conv_w"][i] for i in range(dc))[:, None]
    xc = jax.nn.silu(xc + params["conv_b"])
    y, h_last = _mamba_inner(params, xc, z, cfg, state.ssm)
    out = y @ params["out_proj"].astype(y.dtype)
    return out, MambaState(conv=hist[:, 1:], ssm=h_last)


# ----------------------------------------------------------------------------
# RG-LRU (recurrentgemma, arXiv:2402.19427 §2.4)
# ----------------------------------------------------------------------------

_LRU_C = 8.0


def init_rglru(key, cfg: ArchConfig, dtype):
    d, w, dc = cfg.d_model, cfg.rglru_width, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    sc = lambda i, o: (2.0 / (i + o)) ** 0.5
    return {
        "in_x": jax.random.normal(ks[0], (d, w), dtype) * sc(d, w),
        "in_gate": jax.random.normal(ks[1], (d, w), dtype) * sc(d, w),
        "conv_w": jax.random.normal(ks[2], (dc, w), dtype) * 0.2,
        "conv_b": jnp.zeros((w,), dtype),
        "w_input_gate": jax.random.normal(ks[3], (w,), jnp.float32) * 0.5,
        "w_rec_gate": jax.random.normal(ks[4], (w,), jnp.float32) * 0.5,
        "lambda_p": jnp.full((w,), 2.0, jnp.float32),  # a = sigmoid(lambda)
        "out": jax.random.normal(ks[5], (w, d), dtype) * sc(w, d),
    }


class RGLRUState(NamedTuple):
    conv: jnp.ndarray   # [B, dc-1, w]
    h: jnp.ndarray      # [B, w] float32

    @classmethod
    def zeros(cls, b, cfg: ArchConfig, dtype):
        return cls(jnp.zeros((b, cfg.ssm_conv - 1, cfg.rglru_width), dtype),
                   jnp.zeros((b, cfg.rglru_width), jnp.float32))


def _rglru_scan(params, xc: jnp.ndarray, h0: jnp.ndarray):
    """xc: [B,S,w] conv output; diagonal gated linear recurrence."""
    xf = xc.astype(jnp.float32)
    i_gate = jax.nn.sigmoid(xf * params["w_input_gate"])
    r_gate = jax.nn.sigmoid(xf * params["w_rec_gate"])
    log_a = -_LRU_C * jax.nn.softplus(params["lambda_p"]) * r_gate  # [B,S,w]
    a = jnp.exp(log_a)
    gated_x = i_gate * xf
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))

    def step(h, inp):
        a_t, gx_t, m_t = inp
        h = a_t * h + m_t * gx_t
        return h, h

    xs = (a.transpose(1, 0, 2), gated_x.transpose(1, 0, 2),
          mult.transpose(1, 0, 2))
    h_last, hs = jax.lax.scan(step, h0, xs)
    return hs.transpose(1, 0, 2), h_last             # [B,S,w], [B,w]


def rglru_forward(params, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    b, s, d = x.shape
    dc = cfg.ssm_conv
    xb = x @ params["in_x"].astype(x.dtype)
    gate = x @ params["in_gate"].astype(x.dtype)
    xp = jnp.pad(xb, ((0, 0), (dc - 1, 0), (0, 0)))
    xc = sum(xp[:, i:i + s] * params["conv_w"][i] for i in range(dc))
    xc = xc + params["conv_b"]
    h0 = jnp.zeros((b, cfg.rglru_width), jnp.float32)
    hs, _ = _rglru_scan(params, xc, h0)
    y = hs.astype(x.dtype) * jax.nn.gelu(gate)
    return y @ params["out"].astype(y.dtype)


def rglru_decode(params, x: jnp.ndarray, state: RGLRUState,
                 cfg: ArchConfig) -> Tuple[jnp.ndarray, RGLRUState]:
    dc = cfg.ssm_conv
    xb = x @ params["in_x"].astype(x.dtype)          # [B,1,w]
    gate = x @ params["in_gate"].astype(x.dtype)
    hist = jnp.concatenate([state.conv, xb], axis=1)
    xc = (sum(hist[:, i] * params["conv_w"][i] for i in range(dc))
          + params["conv_b"])[:, None]
    hs, h_last = _rglru_scan(params, xc, state.h)
    y = hs.astype(x.dtype) * jax.nn.gelu(gate)
    return (y @ params["out"].astype(y.dtype),
            RGLRUState(conv=hist[:, 1:], h=h_last))
