"""Architecture configuration schema for the serving/training substrate.

One ``ArchConfig`` describes any of the assigned architecture families:
dense GQA decoders, MoE (top-k routed, optional shared expert, optional MLA
latent attention, optional MTP head), SSM (Mamba-1), hybrid (RG-LRU + local
attention), and the VLM/audio decoders whose modality frontends are stubs
(the harness carve-out: ``input_specs`` hands the decoder precomputed
patch/frame embeddings of the right shape).

Layers are described as a sequence of *stages*: ``(group, repeats)`` where
``group`` is a short tuple of LayerSpecs. Consecutive repeats are executed
with ``jax.lax.scan`` over stacked parameters, so compile time and HLO size
are independent of depth (a 95-layer model compiles one layer per stage).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One decoder layer: a temporal mixer + an optional FFN."""
    mixer: str          # 'gqa' | 'mla' | 'mamba' | 'rglru' | 'local_attn'
    ffn: Optional[str]  # 'mlp' | 'moe' | None


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    source: str                  # citation (paper / model card)
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    qkv_bias: bool = False
    pos_embedding: str = "rope"  # rope | sinusoidal | none
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0            # per-expert FFN width (defaults to d_ff)
    first_dense_layers: int = 0  # leading dense layers before MoE (dsv3: 3)
    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0         # 0 = no Q compression
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- MTP (deepseek-v3) ---
    mtp_depth: int = 0
    # --- SSM (mamba-1) ---
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0             # 0 -> ceil(d_model / 16)
    # --- hybrid (recurrentgemma) ---
    hybrid_pattern: Tuple[str, ...] = ()   # e.g. ('rglru','rglru','local_attn')
    local_window: int = 2048
    lru_width: int = 0           # 0 -> d_model
    # --- serving ---
    sliding_window: int = 0      # >0: windowed-attention serve variant
    # --- modality frontend stub ---
    input_mode: str = "tokens"   # tokens | embeddings
    # --- numerics ---
    param_dtype: str = "float32"     # giants use bfloat16 (HBM budget)
    activation_dtype: str = "bfloat16"
    # --- attention implementation ---
    attn_impl: str = "chunked"   # chunked (XLA) | flash (Pallas kernel)

    # ------------------------------------------------------------------
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_dt_rank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    @property
    def rglru_width(self) -> int:
        return self.lru_width or self.d_model

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def mlp_gated(self) -> bool:
        """SwiGLU/GeGLU (3 matrices) vs plain GELU MLP (2 matrices)."""
        return self.family != "audio" and not self.name.startswith(
            "starcoder2")

    def layer_specs(self) -> List[LayerSpec]:
        """Expanded per-layer specs."""
        out: List[LayerSpec] = []
        for i in range(self.num_layers):
            if self.family == "ssm":
                out.append(LayerSpec("mamba", None))
            elif self.hybrid_pattern:
                mixer = self.hybrid_pattern[i % len(self.hybrid_pattern)]
                out.append(LayerSpec(mixer, "mlp"))
            elif self.num_experts:
                mixer = "mla" if self.use_mla else "gqa"
                ffn = "mlp" if i < self.first_dense_layers else "moe"
                out.append(LayerSpec(mixer, ffn))
            else:
                mixer = "mla" if self.use_mla else "gqa"
                out.append(LayerSpec(mixer, "mlp"))
        return out

    def stages(self) -> List[Tuple[Tuple[LayerSpec, ...], int]]:
        """Group layers into scan-able (group, repeats) stages.

        Periodic patterns (hybrid 1:2) group a full period; otherwise runs of
        identical specs form one stage each.
        """
        specs = self.layer_specs()
        if self.hybrid_pattern:
            p = len(self.hybrid_pattern)
            full = self.num_layers // p
            stages: List[Tuple[Tuple[LayerSpec, ...], int]] = []
            if full:
                stages.append((tuple(specs[:p]), full))
            rem = self.num_layers - full * p
            if rem:
                stages.append((tuple(specs[full * p:]), 1))
            return stages
        stages = []
        i = 0
        while i < len(specs):
            j = i
            while j < len(specs) and specs[j] == specs[i]:
                j += 1
            stages.append(((specs[i],), j - i))
            i = j
        return stages

    def param_count(self) -> int:
        """Approximate parameter count (for 6ND model-FLOP accounting)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        for spec in self.layer_specs():
            if spec.mixer == "gqa" or spec.mixer == "local_attn":
                hd = self.head_dim
                total += d * self.num_heads * hd          # q
                total += 2 * d * self.num_kv_heads * hd    # k, v
                total += self.num_heads * hd * d           # o
            elif spec.mixer == "mla":
                r_kv, r_q = self.kv_lora_rank, self.q_lora_rank or self.d_model
                qk = self.qk_nope_head_dim + self.qk_rope_head_dim
                total += d * r_q + r_q * self.num_heads * qk
                total += d * (r_kv + self.qk_rope_head_dim)
                total += r_kv * self.num_heads * (self.qk_nope_head_dim
                                                  + self.v_head_dim)
                total += self.num_heads * self.v_head_dim * d
            elif spec.mixer == "mamba":
                di, st = self.ssm_d_inner, self.ssm_state
                total += d * 2 * di + self.ssm_conv * di
                total += di * self.ssm_dt_rank + self.ssm_dt_rank * di
                total += di * 2 * st + di + di * d
            elif spec.mixer == "rglru":
                w = self.rglru_width
                total += 2 * d * w + 2 * w * 4 + w * d + 3 * w
            if spec.ffn == "mlp":
                total += (3 if self.mlp_gated else 2) * d * f
            elif spec.ffn == "moe":
                fe = self.expert_d_ff
                total += 3 * d * fe * (self.num_experts
                                       + self.num_shared_experts)
                total += d * self.num_experts  # router
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k experts count)."""
        if not self.num_experts:
            return self.param_count()
        d, fe = self.d_model, self.expert_d_ff
        dense_all = self.param_count()
        moe_layers = sum(1 for s in self.layer_specs() if s.ffn == "moe")
        inactive = 3 * d * fe * (self.num_experts - self.experts_per_token)
        return int(dense_all - moe_layers * inactive)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned global input shapes."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
