"""Shared neural layers: norms, positional encodings, dense FFNs.

Everything is a pure function over explicit param pytrees (dicts), so stages
stack/scan cleanly and shardings attach via path-based rules
(models/sharding.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return out.astype(dt) * scale.astype(dt)


def init_rms(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


# ----------------------------------------------------------------------------
# Rotary position embedding
# ----------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., S, H, Dh]; positions: [..., S] int32."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)                       # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]                        # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """[..., S] -> [..., S, D] fixed sinusoidal table (musicgen-style)."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ----------------------------------------------------------------------------
# Dense FFN (gated SwiGLU/GeGLU or plain 2-matrix MLP)
# ----------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype, gated: bool = True):
    ks = jax.random.split(key, 3)
    sc_in = (2.0 / (d_model + d_ff)) ** 0.5
    p = {"w_up": jax.random.normal(ks[0], (d_model, d_ff), dtype) * sc_in,
         "w_down": jax.random.normal(ks[1], (d_ff, d_model), dtype) * sc_in}
    if gated:
        p["w_gate"] = jax.random.normal(ks[2], (d_model, d_ff), dtype) * sc_in
    return p


def mlp(params, x: jnp.ndarray, activation: str = "silu") -> jnp.ndarray:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[activation]
    dt = x.dtype
    up = x @ params["w_up"].astype(dt)
    if "w_gate" in params:
        up = act(x @ params["w_gate"].astype(dt)) * up
    else:
        up = act(up)
    return up @ params["w_down"].astype(dt)


# ----------------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------------

def init_embed(key, vocab: int, d_model: int, dtype):
    return {"table": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed(params, tokens: jnp.ndarray) -> jnp.ndarray:
    return params["table"][tokens]


def unembed(params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ params["table"].T


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False):
    sc = (2.0 / (d_in + d_out)) ** 0.5
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * sc}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y
