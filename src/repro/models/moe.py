"""Mixture-of-Experts FFN with capacity-based scatter dispatch.

Expert-parallel design: expert weights carry a leading ``E`` axis that the
sharding rules place on the ``model`` mesh axis, so dispatch/combine lower
to all-to-all style collectives — the transformer-side analogue of
Fograph's cross-fog data exchange (DESIGN.md §5).

Dispatch is *gather/scatter based*, not one-hot-matmul based: one-hot
dispatch einsums cost O(T^2 k d) FLOPs and would swamp the roofline with
fake compute. Here routing costs only integer bookkeeping + scatter, so the
compiled FLOPs reflect real expert work (2 * T * k * 3 * d * d_ff per layer)
— this is what makes MODEL_FLOPS / HLO_FLOPs meaningful for MoE archs.

Top-k router with softmax-after-topk normalization (DeepSeek-V3 style) and
optional shared experts (always-on, no routing).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import init_mlp, mlp


def init_moe(key, cfg: ArchConfig, dtype):
    d, fe, e = cfg.d_model, cfg.expert_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    sc = (2.0 / (d + fe)) ** 0.5
    p = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * 0.02,
        "w_gate": jax.random.normal(ks[1], (e, d, fe), dtype) * sc,
        "w_up": jax.random.normal(ks[2], (e, d, fe), dtype) * sc,
        "w_down": jax.random.normal(ks[3], (e, fe, d), dtype) * sc,
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d,
                               fe * cfg.num_shared_experts, dtype)
    return p


def moe_ffn(params, x: jnp.ndarray, cfg: ArchConfig, *,
            capacity_factor: float = 1.25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss []).

    Capacity per expert C = ceil(T*k/E * capacity_factor); overflowing
    tokens are dropped (their contribution is zero), standard for
    capacity-based dispatch.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.experts_per_token
    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ params["router"])         # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_i = jax.lax.top_k(probs, k)                     # [T, k]
    topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style).
    me = probs.mean(axis=0)                                       # [E]
    one_hot = jax.nn.one_hot(topk_i[:, 0], e, dtype=jnp.float32)
    ce = one_hot.mean(axis=0)
    aux = e * jnp.sum(me * ce)

    capacity = int(max(1, (t * k) / e * capacity_factor))
    # Position of each (token, slot) within its expert queue.
    flat_e = topk_i.reshape(-1)                                   # [T*k]
    eo = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)               # [T*k, E]
    pos_in_e = (jnp.cumsum(eo, axis=0) - eo)                      # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], 1)[:, 0]  # [T*k]
    keep = pos < capacity
    safe_pos = jnp.where(keep, pos, capacity - 1)

    # Scatter tokens into [E, C, D] buffers.
    xe = jnp.zeros((e, capacity, d), x.dtype)
    src = jnp.repeat(xf, k, axis=0)                                # [T*k, D]
    w_flat = (topk_p.reshape(-1) * keep).astype(x.dtype)           # [T*k]
    xe = xe.at[flat_e, safe_pos].add(src * (keep[:, None]).astype(x.dtype))

    # Expert FFN (einsum over the expert axis -> expert-parallel matmuls).
    dt = x.dtype
    act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe,
                                 params["w_gate"].astype(dt)))
    up = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", act * up,
                    params["w_down"].astype(dt))                   # [E, C, D]

    # Combine: gather each (token, slot)'s expert output, weight, and sum.
    out_slots = ye[flat_e, safe_pos] * w_flat[:, None]             # [T*k, D]
    out = out_slots.reshape(t, k, d).sum(axis=1)

    if "shared" in params:
        out = out + mlp(params["shared"], xf)
    return out.reshape(b, s, d), aux
