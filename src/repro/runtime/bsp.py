"""Distributed BSP GNN inference runtime (paper §III-E) on a JAX mesh.

The paper's runtime: each fog holds a vertex partition; every GNN layer runs
Aggregate/Update over local vertices, pulling neighbor activations from
other fogs in a Bulk-Synchronous-Parallel step (K syncs for K layers).

TPU/JAX adaptation: fogs = devices along a ``fog`` mesh axis, executed with
``shard_map``. The per-layer cross-fog exchange supports two strategies:

  * ``"allgather"``  — all_gather the full [P, F] partition activations
    (straw-man exchange; O(n·P·F) bytes per device per layer).
  * ``"halo"``       — all_gather only the *boundary rows* (vertices that any
    other partition reads), packed into a [B, F] buffer (B = max boundary
    size). This is the paper's "exchange vertices data when needed",
    and the §Perf knob for the collective roofline term.
  * ``"halo_async"`` — the stale-tolerant variant for WAN-separated fleet
    sites: a *fresh* serve runs the exact ``"halo"`` program (same cached
    shard_map program, bit for bit) while the per-layer gathered halo
    tables are recorded host-side (``build_halo_tables``); a *stale* serve
    (``bsp_infer_stale`` / ``bsp_infer_stale_many``) replays those tables
    as replicated operands instead of stalling the superstep on a live
    collective — local rows always read CURRENT features, only
    cross-partition reads may be up to ``staleness_bound`` versions old.

All synchronous modes produce identical results; tests assert equality
against single-device execution. Per-partition buffers are padded to common
static shapes so the whole computation jits once.

Shard-local aggregation runs on one of two numerically equivalent paths,
selected by the ``aggregation`` knob (plumbed from ``Engine`` through the
EXECUTORS entries):

  * ``"segment_sum"`` — gather + ``jax.ops.segment_sum`` over the padded
    COO edge list (the portable baseline).
  * ``"pallas"``      — the block-CSR Pallas kernels: each shard's
    adjacency is pre-blocked at ``build_partitioned`` time into *two*
    ELL-block-CSR operands — one over the local slot space and one over
    the gathered halo table — and the per-layer aggregate becomes
    ``block_spmm(local) + block_spmm(halo)`` (MXU matmuls instead of
    scatter-adds). When the serving plan compresses uploads with DAQ, the
    halo rows additionally cross the collective *quantized* (uint8 codes
    + per-row scale/min) and are dequantized inside the fused
    ``dequant_spmm`` kernel, shrinking the BSP wire term by ~4x.
  * ``"auto"``        — ``"pallas"`` wherever it is supported *and* the
    program runs on a real TPU backend (off-TPU the kernels execute in
    interpret mode, which is only useful for correctness); otherwise
    ``"segment_sum"``.

The kernel path supports the sum/mean aggregations of GCN and GraphSAGE
under the ``"halo"`` exchange; GAT's attention-weighted aggregation and the
``"allgather"`` straw-man stay on ``segment_sum`` (requesting ``"pallas"``
for those raises, ``"auto"`` silently falls back).

Buffer conventions: all feature math is float32; padded vertex rows, edge
slots, boundary rows and ELL tiles are zero-filled and masked (``*_mask``
arrays, 1.0 = real), so every code path may blindly multiply-accumulate.

Micro-batches run through ``bsp_apply_many`` / ``bsp_infer_many``: a
stacked [B, V, F] feature batch becomes one [n, B, P, F] partition table
(``PartitionedGraph.feature_stack``) and ONE shard_map launch serves the
whole batch — one halo collective per layer, the batch-grid Pallas
kernels on the GCN/SAGE kernel path, vmapped per-example layers on the
segment-sum/GAT path — with every example bit-identical to the serial
``bsp_apply`` (see docs/architecture.md §5 "Batched execution").
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # older releases keep it under experimental
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.api.registry import EXCHANGES
from repro.gnn.graph import Graph
from repro.gnn.layers import (EdgeList, LAYER_FNS, apply_layer_with_sum,
                              masked_degree)
from repro.kernels.daq_dequant import dequant_spmm, dequant_spmm_batched
from repro.kernels.gather_aggregate import (BLOCK, block_spmm,
                                            block_spmm_batched,
                                            build_block_csr,
                                            padded_feature_dim)

#: legal values of the Engine/Session ``aggregation`` knob.
AGGREGATIONS = ("segment_sum", "pallas", "auto")

#: GNN kinds whose neighborhood aggregation is a static (weighted) sum and
#: can therefore be pre-blocked into an SpMM. GAT re-weights edges per layer
#: with attention, so its aggregation stays on segment_sum.
KERNEL_KINDS = ("gcn", "sage")


def resolve_aggregation(mode: str, kind: str, *,
                        exchange: Optional[str] = None) -> str:
    """Resolve the ``aggregation`` knob to a concrete path for one run.

    ``exchange=None`` means "no cross-fog exchange involved" (the
    single-program executors). ``"pallas"`` is strict — unsupported
    combinations raise; ``"auto"`` degrades to ``"segment_sum"`` off-TPU
    or wherever the kernels do not apply.
    """
    if mode not in AGGREGATIONS:
        raise ValueError(f"unknown aggregation {mode!r}; available: "
                         f"{', '.join(AGGREGATIONS)}")
    # halo_async serves (fresh or stale) read the same halo-table row space
    # the block-CSR shards are built over, so the kernel path applies.
    supported = (kind in KERNEL_KINDS
                 and exchange in (None, "halo", "halo_async"))
    if mode == "pallas":
        if kind not in KERNEL_KINDS:
            raise ValueError(
                f"aggregation='pallas' supports kinds {KERNEL_KINDS} "
                f"(static-sum aggregation); {kind!r} re-weights edges per "
                f"layer — use aggregation='segment_sum' or 'auto'")
        if exchange is not None and exchange not in ("halo", "halo_async"):
            raise ValueError(
                "aggregation='pallas' requires the 'halo' exchange (the "
                f"block-CSR shards are built over the halo table), got "
                f"exchange={exchange!r}")
        return "pallas"
    if mode == "segment_sum":
        return "segment_sum"
    on_tpu = jax.default_backend() == "tpu"
    return "pallas" if (supported and on_tpu) else "segment_sum"


def _wire_exchange(exchange: str) -> str:
    """The synchronous program behind an exchange mode.  ``halo_async``'s
    fresh path IS the ``halo`` program (same ``_program_key``, same cached
    shard_map program), which is what makes its ``staleness_bound=0`` mode
    bit-identical to the synchronous exchange by construction."""
    return "halo" if exchange == "halo_async" else exchange


@dataclasses.dataclass
class BlockShardCsr:
    """Per-shard ELL-block-CSR adjacency, stacked over all partitions.

    One entry per sender index space: tile ``[p, i, m]`` scatters source
    rows ``cols[p, i, m]*B .. +B`` of that space into local output rows
    ``i*B .. +B`` of partition ``p``. ``mask`` is 1.0 for real tiles, 0.0
    for ELL padding (all-zero tiles pointing at source block 0). All
    partitions share one ``M`` (max tiles per row-block across shards).
    """
    blocks: np.ndarray   # f32[n, VB, M, B, B]
    cols: np.ndarray     # i32[n, VB, M]
    mask: np.ndarray     # f32[n, VB, M]
    src_rows: int        # padded source-table rows (multiple of B)
    out_rows: int        # VB * B (>= slots; slice back to slots)


def _stack_block_shards(edge_sets, out_size: int, src_size: int,
                        block: int = BLOCK,
                        prev: Optional[BlockShardCsr] = None,
                        clean: Optional[np.ndarray] = None) -> BlockShardCsr:
    """Build one block-CSR per partition and ELL-pad them to a common M.

    ``prev``/``clean`` enable the dirty-shard rebuild: for partitions with
    ``clean[p]`` True, the (expensive) ``build_block_csr`` call is skipped
    and shard ``p``'s tiles are sliced out of ``prev`` instead.  Reuse is
    only legal when the stacked layout is compatible (same partition count,
    padded output rows and padded source rows); otherwise everything is
    rebuilt.  Real tiles are packed first per row-block, so slicing the
    first ``M_p`` tile slots of a clean shard carries them all.
    """
    vb = -(-out_size // block)
    n = len(edge_sets)
    src_rows = int(-(-src_size // block) * block)
    reuse = (prev is not None and clean is not None
             and prev.blocks.shape[0] == n
             and prev.out_rows == vb * block and prev.src_rows == src_rows)
    built = {}
    per_shard_m = np.zeros(n, np.int64)
    for p, (s, r) in enumerate(edge_sets):
        if reuse and clean[p]:
            per_shard_m[p] = max(1, int(prev.mask[p].sum(axis=1).max()))
        else:
            built[p] = build_block_csr(s, r, out_size, block)
            per_shard_m[p] = built[p][0].shape[1]
    m = int(per_shard_m.max())
    blocks = np.zeros((n, vb, m, block, block), np.float32)
    cols = np.zeros((n, vb, m), np.int32)
    mask = np.zeros((n, vb, m), np.float32)
    for p in range(n):
        mp = int(per_shard_m[p])
        if p in built:
            b, c, k, _ = built[p]
        else:
            b, c, k = (prev.blocks[p, :, :mp], prev.cols[p, :, :mp],
                       prev.mask[p, :, :mp])
        blocks[p, :, :mp] = b
        cols[p, :, :mp] = c
        mask[p, :, :mp] = k
    # The SpMM kernels index the source table by block with no bounds
    # check — guarantee here (where cols are concrete) that a table padded
    # to src_rows covers every referenced column block.
    assert int(cols.max()) < src_rows // block, (cols.max(), src_rows)
    return BlockShardCsr(blocks=blocks, cols=cols, mask=mask,
                         src_rows=src_rows, out_rows=vb * block)


@dataclasses.dataclass
class PartitionedGraph:
    """Static-shape per-partition buffers for shard_map execution."""
    n: int                      # number of partitions (mesh size)
    slots: int                  # P: padded vertices per partition
    edges_per_part: int         # E: padded edges per partition
    boundary_slots: int         # B: padded boundary rows per partition
    feats: np.ndarray           # [n, P, F] local features (padded rows = 0)
    vertex_mask: np.ndarray     # [n, P] 1 for real vertices
    # Edge connectivity, partitioned by the *receiver*'s owner:
    senders_global: np.ndarray  # [n, E] index into flattened [n*P] table
    senders_halo: np.ndarray    # [n, E] index into flattened [n*B] boundary table
    receivers_local: np.ndarray # [n, E] 0..P-1
    edge_mask: np.ndarray       # [n, E]
    # Boundary packing: rows each partition contributes to the halo table.
    boundary_rows: np.ndarray   # [n, B] local slot ids (padded w/ 0)
    boundary_mask: np.ndarray   # [n, B]
    # Self-edges for GAT (senders point at own row in the gathered table).
    self_senders_global: np.ndarray  # [n, P]
    self_senders_halo: np.ndarray    # [n, P]
    # Inverse permutation: result row for global vertex v lives at
    # (part[v], slot[v]).
    part_of: np.ndarray         # [V]
    slot_of: np.ndarray         # [V]
    # Pre-blocked shard-local adjacency for the Pallas aggregation path:
    # sum-aggregate = local_csr @ h_local + halo_csr @ gathered_halo.
    # None when build_partitioned ran with build_blocks=False.
    local_csr: Optional[BlockShardCsr] = None
    halo_csr: Optional[BlockShardCsr] = None

    def unpermute(self, out: np.ndarray) -> np.ndarray:
        """[n, P, D] stacked partition outputs -> [V, D] original order."""
        return out[self.part_of, self.slot_of]

    def unpermute_stack(self, out: np.ndarray) -> np.ndarray:
        """[n, B, P, D] batched partition outputs -> [B, V, D]."""
        return np.moveaxis(out[self.part_of, :, self.slot_of], 0, 1)

    def feature_stack(self, features: np.ndarray) -> np.ndarray:
        """[B, V, F] micro-batch -> [n, B, P, F] per-partition tables.

        The batched counterpart of ``with_features``: every example is
        scattered into the same padded slot layout (padded rows zero), so
        one shard_map launch serves the whole batch.
        """
        features = np.asarray(features, np.float32)
        b, v, f = features.shape
        feats = np.zeros((self.n, b, self.slots, f), np.float32)
        feats[self.part_of, :, self.slot_of] = np.moveaxis(features, 0, 1)
        return feats

    def with_features(self, features: np.ndarray) -> "PartitionedGraph":
        """Same layout (and block-CSR shards), fresh per-vertex features.

        Serving calls this once per query — the partition structure is
        feature-independent, so only the [n, P, F] table is rebuilt.
        """
        features = np.asarray(features, np.float32)
        feats = np.zeros((self.n, self.slots, features.shape[1]), np.float32)
        feats[self.part_of, self.slot_of] = features
        return dataclasses.replace(self, feats=feats)


def build_partitioned(g: Graph, assignment: np.ndarray,
                      pad_multiple: int = 8,
                      build_blocks: bool = True,
                      n: Optional[int] = None,
                      prev: Optional["PartitionedGraph"] = None,
                      dirty_local: Optional[np.ndarray] = None,
                      dirty_halo: Optional[np.ndarray] = None
                      ) -> PartitionedGraph:
    """Lay the graph out per-partition with static padded shapes.

    Padding conventions: every partition shares one slot count P (max
    partition size rounded up to ``pad_multiple``), one edge capacity E
    and one boundary capacity B; padded rows/edges carry zeroed features
    and 0.0 masks. Empty partitions (``assignment`` skipping a part id)
    and single-vertex shards are legal — they simply pad everywhere.
    ``n`` pins the partition count (needed when trailing partitions may be
    empty, e.g. after a graph update empties a shard).

    ``build_blocks=True`` additionally pre-blocks each shard's adjacency
    into the two ELL-block-CSR operands of the Pallas aggregation path
    (``local_csr`` over the P local slots, ``halo_csr`` over the [n*B]
    gathered halo table); pass False to skip that host-side work when only
    the segment-sum path will run.

    Dirty-shard rebuild: ``prev`` (a layout for the *previous* revision of
    the graph) plus ``dirty_local`` / ``dirty_halo`` (partition ids whose
    operands a graph delta invalidated — see
    ``core.incremental.dirty_partitions``) reuse every clean shard's
    pre-blocked operands instead of re-blocking them.  The cheap padded COO
    buffers are always recomputed, so the result is bit-identical to a
    from-scratch build; reuse silently degrades to a full re-block when the
    padded layout is incompatible (slot/boundary capacity changed).
    """
    assignment = np.asarray(assignment, np.int64)
    n = (int(assignment.max()) + 1) if n is None else int(n)
    parts: List[np.ndarray] = [np.flatnonzero(assignment == p) for p in range(n)]
    sizes = np.array([len(p) for p in parts])
    slots = int(-(-sizes.max() // pad_multiple) * pad_multiple)

    part_of = assignment
    slot_of = np.zeros(g.num_vertices, np.int64)
    for p, vs in enumerate(parts):
        slot_of[vs] = np.arange(len(vs))

    f = g.feature_dim
    feats = np.zeros((n, slots, f), np.float32)
    vmask = np.zeros((n, slots), np.float32)
    for p, vs in enumerate(parts):
        feats[p, :len(vs)] = g.features[vs]
        vmask[p, :len(vs)] = 1.0

    # Edges grouped by receiver's partition.
    recv_part = part_of[g.receivers]
    edge_lists = [np.flatnonzero(recv_part == p) for p in range(n)]
    e_max = max(1, max(len(e) for e in edge_lists))
    e_pad = int(-(-e_max // pad_multiple) * pad_multiple)

    # Boundary rows: vertices read by any foreign partition.
    boundary_ids = []
    for p in range(n):
        cross = (part_of[g.senders] == p) & (recv_part != p)
        boundary_ids.append(np.unique(g.senders[cross]))
    b_max = max(1, max(len(b) for b in boundary_ids))
    b_pad = int(-(-b_max // pad_multiple) * pad_multiple)

    # halo index of vertex v (valid only if v is in its owner's boundary set)
    halo_slot = np.zeros(g.num_vertices, np.int64)
    for p, bs in enumerate(boundary_ids):
        halo_slot[bs] = np.arange(len(bs))

    senders_global = np.zeros((n, e_pad), np.int32)
    senders_halo = np.zeros((n, e_pad), np.int32)
    receivers_local = np.zeros((n, e_pad), np.int32)
    edge_mask = np.zeros((n, e_pad), np.float32)
    boundary_rows = np.zeros((n, b_pad), np.int32)
    boundary_mask = np.zeros((n, b_pad), np.float32)
    local_edges, halo_edges = [], []
    for p in range(n):
        eids = edge_lists[p]
        s, r = g.senders[eids], g.receivers[eids]
        k = len(eids)
        senders_global[p, :k] = part_of[s] * slots + slot_of[s]
        # local senders also appear in the halo table? no — local senders are
        # read from the local shard directly in halo mode: point them at the
        # *own* boundary copy when they are boundary rows, else we route local
        # edges through the local table. To keep a single gather, halo mode
        # uses a combined table [local P rows | n*B halo rows]; local senders
        # use their local slot, remote senders use P + their halo position.
        local = part_of[s] == p
        senders_halo[p, :k] = np.where(
            local, slot_of[s],
            slots + part_of[s] * b_pad + halo_slot[s]).astype(np.int32)
        receivers_local[p, :k] = slot_of[r]
        edge_mask[p, :k] = 1.0
        bs = boundary_ids[p]
        boundary_rows[p, :len(bs)] = slot_of[bs]
        boundary_mask[p, :len(bs)] = 1.0
        # Unpadded per-shard edge splits for the block-CSR (kernel) path:
        # local senders read the shard's own rows, remote senders read the
        # gathered [n*B] halo table.
        local_edges.append((slot_of[s[local]], slot_of[r[local]]))
        halo_edges.append((part_of[s[~local]] * b_pad + halo_slot[s[~local]],
                           slot_of[r[~local]]))

    self_g = np.zeros((n, slots), np.int32)
    self_h = np.zeros((n, slots), np.int32)
    for p in range(n):
        self_g[p] = p * slots + np.arange(slots)
        self_h[p] = np.arange(slots)  # local rows in combined halo table

    local_csr = halo_csr = None
    if build_blocks:
        # Clean masks for shard reuse: with no prev layout (or no dirty
        # information) everything is rebuilt; shard-level compatibility
        # guards live in _stack_block_shards.
        prev_l = prev_h = clean_l = clean_h = None
        if (prev is not None and prev.n == n and prev.slots == slots
                and dirty_local is not None and dirty_halo is not None):
            if prev.local_csr is not None:
                prev_l = prev.local_csr
                clean_l = np.ones(n, bool)
                clean_l[np.asarray(dirty_local, np.int64)] = False
            if prev.halo_csr is not None and prev.boundary_slots == b_pad:
                prev_h = prev.halo_csr
                clean_h = np.ones(n, bool)
                clean_h[np.asarray(dirty_halo, np.int64)] = False
        local_csr = _stack_block_shards(local_edges, slots, slots,
                                        prev=prev_l, clean=clean_l)
        halo_csr = _stack_block_shards(halo_edges, slots, n * b_pad,
                                       prev=prev_h, clean=clean_h)

    return PartitionedGraph(
        n=n, slots=slots, edges_per_part=e_pad, boundary_slots=b_pad,
        feats=feats, vertex_mask=vmask,
        senders_global=senders_global, senders_halo=senders_halo,
        receivers_local=receivers_local, edge_mask=edge_mask,
        boundary_rows=boundary_rows, boundary_mask=boundary_mask,
        self_senders_global=self_g, self_senders_halo=self_h,
        part_of=part_of, slot_of=slot_of,
        local_csr=local_csr, halo_csr=halo_csr)


def _layer_edges(slots: int, senders, kind: str, self_senders,
                 receivers, emask, vmask):
    """EdgeList for one partition; GAT gets explicit self-edges."""
    if kind == "gat":
        s = jnp.concatenate([senders, self_senders])
        r = jnp.concatenate([receivers,
                             jnp.arange(slots, dtype=receivers.dtype)])
        m = jnp.concatenate([emask, vmask])
        return EdgeList(s, r, m, slots)
    return EdgeList(senders, receivers, emask, slots)


def _wire_quantize(h: jnp.ndarray, levels: float = 255.0):
    """Per-row linear quantization of the halo wire payload (jit-safe).

    Mirrors ``compression._quantize_rows`` at 8 bits: uint8 codes plus one
    f32 (scale, min) pair per row. All-zero (masked padding) rows get
    code 0 / scale ~0 / min 0 and dequantize to exactly 0. ``h`` may carry
    leading batch axes (rows are the second-to-last axis): the reduction
    runs over the feature (last) axis either way, so batched quantization
    is bit-identical per row to the single-query call.
    """
    mins = h.min(axis=-1)
    scales = jnp.maximum(h.max(axis=-1) - mins, 1e-12) / levels
    codes = jnp.clip(jnp.round((h - mins[..., None]) / scales[..., None]),
                     0, levels).astype(jnp.uint8)
    return codes, scales, mins


def _kernel_pad(x: jnp.ndarray, rows: int) -> jnp.ndarray:
    """Zero-pad a source table to the kernel grid: ``rows`` source rows
    (multiple of BLOCK) and a feature count the f-tiling accepts. ``x``
    may be a [V, F] table or a stacked [B, V, F] micro-batch."""
    v, f = x.shape[-2:]
    pad = ((0, rows - v), (0, padded_feature_dim(f) - f))
    if x.ndim == 3:
        return jnp.pad(x, ((0, 0),) + pad)
    return jnp.pad(x, pad)


def _gathered_stack(x: jnp.ndarray) -> jnp.ndarray:
    """[n, B, R, F...] all_gather output -> [B, n*R, F...] per-example
    tables (pure data movement; rows land in the same order the serial
    path's ``.reshape(-1, f)`` produces)."""
    n, b = x.shape[:2]
    return jnp.moveaxis(x, 0, 1).reshape((b, n * x.shape[2]) + x.shape[3:])


#: Compiled shard_map programs, keyed by everything a program bakes in
#: statically (model kind, exchange, aggregation path, mesh devices, the
#: PartitionedGraph's static slot/row geometry). The model params and
#: every per-partition buffer are traced *operands*, so one cached
#: program serves every query — and every micro-batch size, since jit
#: re-specializes on operand shapes under the same wrapper — instead of
#: re-tracing and re-compiling the whole BSP program per call.
_PROGRAM_CACHE: "OrderedDict[tuple, object]" = OrderedDict()
_PROGRAM_CACHE_MAX = 32


def _cached_program(key: tuple, build):
    fn = _PROGRAM_CACHE.get(key)
    if fn is None:
        fn = build()
        _PROGRAM_CACHE[key] = fn
        while len(_PROGRAM_CACHE) > _PROGRAM_CACHE_MAX:
            _PROGRAM_CACHE.popitem(last=False)
    else:
        _PROGRAM_CACHE.move_to_end(key)
    return fn


#: Field names of the _program_key tuple, in order. The cache audit
#: (repro.analysis.cache_audit) checks every live key against this and
#: maps each EngineConfig knob onto the field that carries it — keep the
#: three in sync when adding a knob that changes lowering.
PROGRAM_KEY_FIELDS = ("tag", "kind", "axis", "exchange", "use_kernels",
                      "halo_quant", "interpret", "geometry", "mesh_key")


def _program_key(tag: str, kind: str, pg: PartitionedGraph, mesh: Mesh,
                 axis: str, exchange: str, use_kernels: bool,
                 halo_quant: bool, interpret: bool) -> tuple:
    """Everything the shard program closes over statically."""
    geometry = (pg.n, pg.slots, pg.boundary_slots,
                None if pg.local_csr is None else pg.local_csr.src_rows,
                None if pg.halo_csr is None else pg.halo_csr.src_rows)
    mesh_key = (tuple(d.id for d in mesh.devices.flat),
                tuple(mesh.axis_names))
    return (tag, kind, axis, exchange, use_kernels, halo_quant, interpret,
            geometry, mesh_key)


def bsp_apply(params, kind: str, pg: PartitionedGraph, mesh: Mesh,
              axis: str = "fog", exchange: str = "halo",
              aggregation: str = "segment_sum",
              halo_quant: bool = False) -> jnp.ndarray:
    """Distributed K-layer GNN inference; returns [n, P, D] device outputs.

    ``aggregation`` selects the shard-local aggregation path (see module
    docstring); ``halo_quant=True`` (kernel path only) quantizes the halo
    rows to uint8 *before* the all_gather and dequantizes them inside the
    fused ``dequant_spmm`` kernel — the wire carries 1 byte/feature plus
    8 bytes/row instead of 4 bytes/feature.
    """
    _, layer_fn = LAYER_FNS[kind]
    mode = resolve_aggregation(aggregation, kind, exchange=exchange)
    exchange = _wire_exchange(exchange)
    use_kernels = mode == "pallas"
    if use_kernels and (pg.local_csr is None or pg.halo_csr is None):
        raise ValueError(
            "aggregation='pallas' needs the block-CSR shards; rebuild the "
            "PartitionedGraph with build_partitioned(..., build_blocks=True)")
    if halo_quant and not use_kernels:
        raise ValueError("halo_quant requires the 'pallas' aggregation path")
    interpret = jax.default_backend() != "tpu"
    # Bind the layout statics to locals: shard_fn must NOT close over the
    # PartitionedGraph itself, or the cached program (_PROGRAM_CACHE)
    # would pin retired graphs' feature/tile buffers until LRU eviction.
    slots = pg.slots
    local_rows = None if pg.local_csr is None else pg.local_csr.src_rows
    halo_rows = None if pg.halo_csr is None else pg.halo_csr.src_rows

    def shard_fn(params, feats, vmask, s_g, s_h, recv, emask, brows, bmask,
                 self_g, self_h, *kops):
        nlayers = len(params)
        # shard_map blocks: feats [1, P, F] etc. — squeeze the leading axis.
        h = feats[0]
        vm, sg, sh = vmask[0], s_g[0], s_h[0]
        rc, em = recv[0], emask[0]
        br, bm = brows[0], bmask[0]
        selg, selh = self_g[0], self_h[0]
        if use_kernels:
            lblk, lcol, lmsk, hblk, hcol, hmsk = (a[0] for a in kops)
        for li, p in enumerate(params):
            act_last = li == nlayers - 1
            kwargs = {}
            if exchange == "allgather":
                h_all = jax.lax.all_gather(h, axis)          # [n, P, F]
                h_src = h_all.reshape(-1, h.shape[-1])
                edges = _layer_edges(slots, sg, kind, selg, rc, em, vm)
            elif exchange == "halo":
                hb = h[br] * bm[:, None]                      # [B, F]
                edges = _layer_edges(slots, sh, kind, selh, rc, em, vm)
                if use_kernels:
                    # Kernel path: keep local and halo operands separate —
                    # sum-aggregate = local SpMM + halo SpMM — instead of
                    # concatenating one combined gather table.
                    f = h.shape[-1]
                    h_src = None
                    if halo_quant:
                        codes, sc, mn = _wire_quantize(hb)
                        codes = jax.lax.all_gather(
                            codes, axis).reshape(-1, f)
                        # One collective for both row parameters.
                        sm = jax.lax.all_gather(
                            jnp.stack([sc, mn], axis=-1), axis).reshape(-1, 2)
                        rows = halo_rows
                        codes = _kernel_pad(codes, rows)
                        sm = jnp.pad(sm, ((0, rows - sm.shape[0]), (0, 0)))
                        sc, mn = sm[:, 0], sm[:, 1]

                        def halo_agg(_f=f):
                            return dequant_spmm(
                                hblk, hcol, hmsk, codes, sc, mn,
                                interpret=interpret)[:slots, :_f]
                    else:
                        halo = jax.lax.all_gather(
                            hb, axis).reshape(-1, h.shape[-1])
                        halo = _kernel_pad(halo, halo_rows)

                        def halo_agg(_f=f):
                            return block_spmm(
                                hblk, hcol, hmsk, halo,
                                interpret=interpret)[:slots, :_f]

                    def kernel_sum(h_loc, edges_, h_src_=None, _f=f,
                                   _halo_agg=halo_agg):
                        loc = _kernel_pad(h_loc, local_rows)
                        out = block_spmm(lblk, lcol, lmsk, loc,
                                         interpret=interpret)
                        return out[:slots, :_f] + _halo_agg()

                    if kind == "sage":   # SAGE aggregates the mean
                        def kernel_agg(h_loc, edges_, h_src_=None,
                                       _sum=kernel_sum):
                            deg = masked_degree(edges_)
                            return (_sum(h_loc, edges_, h_src_)
                                    / jnp.maximum(deg, 1.0)[:, None])
                    else:
                        kernel_agg = kernel_sum
                    kwargs["aggregate"] = kernel_agg
                else:
                    halo = jax.lax.all_gather(hb, axis)       # [n, B, F]
                    h_src = jnp.concatenate(
                        [h, halo.reshape(-1, h.shape[-1])], axis=0)
            else:
                raise ValueError(exchange)
            if act_last:
                h = layer_fn(p, h, edges, activation=None, h_src=h_src,
                             **kwargs)
            else:
                h = layer_fn(p, h, edges, h_src=h_src, **kwargs)
            h = h * vm[:, None]  # keep padded rows at zero
        return h[None]

    spec = P(axis, None, None)
    spec2 = P(axis, None)
    # P() as a pytree-prefix spec: the model params ride along as a fully
    # replicated *operand* (not a closure constant), so the compiled
    # program below is reusable across queries and plans.
    in_specs = [P(), spec, spec2, spec2, spec2, spec2, spec2, spec2, spec2,
                spec2, spec2]
    operands = [jnp.asarray(pg.feats), jnp.asarray(pg.vertex_mask),
                jnp.asarray(pg.senders_global), jnp.asarray(pg.senders_halo),
                jnp.asarray(pg.receivers_local), jnp.asarray(pg.edge_mask),
                jnp.asarray(pg.boundary_rows), jnp.asarray(pg.boundary_mask),
                jnp.asarray(pg.self_senders_global),
                jnp.asarray(pg.self_senders_halo)]
    if use_kernels:
        for csr in (pg.local_csr, pg.halo_csr):
            for arr in (csr.blocks, csr.cols, csr.mask):
                operands.append(jnp.asarray(arr))
                in_specs.append(P(axis, *([None] * (arr.ndim - 1))))
    smap_kw = {}
    if use_kernels:
        # pallas_call has no shard_map replication rule; every operand and
        # output here is explicitly partitioned, so the check adds nothing.
        smap_kw["check_rep"] = False
    fn = _cached_program(
        _program_key("apply", kind, pg, mesh, axis, exchange, use_kernels,
                     halo_quant, interpret),
        lambda: jax.jit(_shard_map(shard_fn, mesh=mesh,
                                   in_specs=tuple(in_specs),
                                   out_specs=spec, **smap_kw)))
    return fn(list(params), *operands)


def bsp_apply_many(params, kind: str, pg: PartitionedGraph,
                   feat_stack: np.ndarray, mesh: Mesh, axis: str = "fog",
                   exchange: str = "halo", aggregation: str = "segment_sum",
                   halo_quant: bool = False) -> jnp.ndarray:
    """Distributed inference over a whole micro-batch in ONE traced call.

    ``feat_stack`` is the [n, B, P, F] table from
    ``PartitionedGraph.feature_stack``; returns [n, B, P, D]. The batch
    rides every stage of the per-layer BSP step:

      * collectives ship the stacked boundary rows — one all_gather per
        layer for the whole batch instead of B (the wire payload is B x
        bigger per sync, but the K*delta sync count stays that of a single
        query);
      * the kernel path aggregates with the batch-axis grid kernels
        (``block_spmm_batched`` / ``dequant_spmm_batched``): one fused
        dispatch per (layer, local/halo operand) with the block-CSR
        operands and scalar-prefetched column table shared across the
        batch, and the GCN/SAGE layer update broadcasting over the leading
        axis;
      * the segment-sum path (and GAT's per-layer attention re-weighting)
        runs the per-example layer under ``jax.vmap`` — the vmapped edge-
        weighted path — which XLA batches into one program.

    Every per-example result is bit-identical to the serial ``bsp_apply``
    (asserted by tests/test_batched_exec.py): vmap, broadcast dense
    algebra and the batched kernels all preserve the per-example op
    sequence.
    """
    _, layer_fn = LAYER_FNS[kind]
    mode = resolve_aggregation(aggregation, kind, exchange=exchange)
    exchange = _wire_exchange(exchange)
    use_kernels = mode == "pallas"
    if use_kernels and (pg.local_csr is None or pg.halo_csr is None):
        raise ValueError(
            "aggregation='pallas' needs the block-CSR shards; rebuild the "
            "PartitionedGraph with build_partitioned(..., build_blocks=True)")
    if halo_quant and not use_kernels:
        raise ValueError("halo_quant requires the 'pallas' aggregation path")
    interpret = jax.default_backend() != "tpu"
    # Bind the layout statics to locals: shard_fn must NOT close over the
    # PartitionedGraph itself, or the cached program (_PROGRAM_CACHE)
    # would pin retired graphs' feature/tile buffers until LRU eviction.
    slots = pg.slots
    local_rows = None if pg.local_csr is None else pg.local_csr.src_rows
    halo_rows = None if pg.halo_csr is None else pg.halo_csr.src_rows

    def shard_fn(params, feats, vmask, s_g, s_h, recv, emask, brows, bmask,
                 self_g, self_h, *kops):
        nlayers = len(params)
        h = feats[0]                                   # [B, P, F]
        vm, sg, sh = vmask[0], s_g[0], s_h[0]
        rc, em = recv[0], emask[0]
        br, bm = brows[0], bmask[0]
        selg, selh = self_g[0], self_h[0]
        if use_kernels:
            lblk, lcol, lmsk, hblk, hcol, hmsk = (a[0] for a in kops)
        for li, p in enumerate(params):
            act_last = li == nlayers - 1
            kwargs = {}
            if exchange == "allgather":
                h_all = jax.lax.all_gather(h, axis)    # [n, B, P, F]
                h_src = _gathered_stack(h_all)          # [B, n*P, F]
                edges = _layer_edges(slots, sg, kind, selg, rc, em, vm)
            elif exchange == "halo":
                hb = h[:, br] * bm[:, None]             # [B, Bnd, F]
                edges = _layer_edges(slots, sh, kind, selh, rc, em, vm)
                if use_kernels:
                    f = h.shape[-1]
                    h_src = None
                    if halo_quant:
                        codes, sc, mn = _wire_quantize(hb)
                        codes = _gathered_stack(
                            jax.lax.all_gather(codes, axis))   # [B, nB, F]
                        sm = _gathered_stack(jax.lax.all_gather(
                            jnp.stack([sc, mn], axis=-1), axis))  # [B,nB,2]
                        rows = halo_rows
                        codes = _kernel_pad(codes, rows)
                        sm = jnp.pad(
                            sm, ((0, 0), (0, rows - sm.shape[1]), (0, 0)))
                        sc, mn = sm[..., 0], sm[..., 1]

                        def halo_agg(_f=f):
                            return dequant_spmm_batched(
                                hblk, hcol, hmsk, codes, sc, mn,
                                interpret=interpret)[:, :slots, :_f]
                    else:
                        halo = _gathered_stack(
                            jax.lax.all_gather(hb, axis))
                        halo = _kernel_pad(halo, halo_rows)

                        def halo_agg(_f=f):
                            return block_spmm_batched(
                                hblk, hcol, hmsk, halo,
                                interpret=interpret)[:, :slots, :_f]

                    def kernel_sum(h_loc, _f=f, _halo_agg=halo_agg):
                        loc = _kernel_pad(h_loc, local_rows)
                        out = block_spmm_batched(lblk, lcol, lmsk, loc,
                                                 interpret=interpret)
                        return out[:, :slots, :_f] + _halo_agg()
                else:
                    halo = jax.lax.all_gather(hb, axis)   # [n, B, Bnd, F]
                    h_src = jnp.concatenate(
                        [h, _gathered_stack(halo)], axis=1)
            else:
                raise ValueError(exchange)
            if act_last:
                kwargs["activation"] = None
            if use_kernels:
                # Grid-axis kernel path: ONE fused batched SpMM dispatch
                # computes every example's neighbor sum, then the shared
                # dense tail (vmapped per example — see
                # layers.apply_layer_with_sum for the bit-identity
                # rationale).
                h = apply_layer_with_sum(kind, p, h, edges, kernel_sum(h),
                                         last=act_last)
            else:
                # Vmapped edge-weighted path: gathers/segment ops (and
                # GAT's attention softmax) index vertex rows, so the
                # per-example layer runs under vmap.
                h = jax.vmap(lambda hh, ss, _p=p, _kw=kwargs: layer_fn(
                    _p, hh, edges, h_src=ss, **_kw))(h, h_src)
            h = h * vm[:, None]  # [B, P, F] * [P, 1]: padded rows stay 0
        return h[None]

    spec = P(axis, None, None, None)
    spec2 = P(axis, None)
    # Params ride as a replicated operand (P() pytree-prefix spec) so the
    # compiled program is reusable — see _PROGRAM_CACHE.
    in_specs = [P(), spec, spec2, spec2, spec2, spec2, spec2, spec2, spec2,
                spec2, spec2]
    operands = [jnp.asarray(feat_stack), jnp.asarray(pg.vertex_mask),
                jnp.asarray(pg.senders_global), jnp.asarray(pg.senders_halo),
                jnp.asarray(pg.receivers_local), jnp.asarray(pg.edge_mask),
                jnp.asarray(pg.boundary_rows), jnp.asarray(pg.boundary_mask),
                jnp.asarray(pg.self_senders_global),
                jnp.asarray(pg.self_senders_halo)]
    if use_kernels:
        for csr in (pg.local_csr, pg.halo_csr):
            for arr in (csr.blocks, csr.cols, csr.mask):
                operands.append(jnp.asarray(arr))
                in_specs.append(P(axis, *([None] * (arr.ndim - 1))))
    smap_kw = {}
    if use_kernels:
        smap_kw["check_rep"] = False
    fn = _cached_program(
        _program_key("apply_many", kind, pg, mesh, axis, exchange,
                     use_kernels, halo_quant, interpret),
        lambda: jax.jit(_shard_map(shard_fn, mesh=mesh,
                                   in_specs=tuple(in_specs),
                                   out_specs=spec, **smap_kw)))
    return fn(list(params), *operands)


def _bsp_apply_layers(params, kind: str, pg: PartitionedGraph, feats_op,
                      mesh: Mesh, axis: str = "fog", exchange: str = "halo",
                      aggregation: str = "segment_sum",
                      halo_quant: bool = False, many: bool = False,
                      dirty=None, cached=None):
    """Capture / frontier variants of ``bsp_apply`` / ``bsp_apply_many``.

    Runs the same per-layer BSP step as the plain programs but returns a
    tuple of EVERY layer's [n, (B,) P, F_l] activations (the last entry is
    the plain program's output, bit for bit — same op sequence modulo dead
    code).  With ``dirty`` / ``cached`` it becomes the frontier-restricted
    shard apply: ``dirty`` is a [n, K, P] per-layer dirty-row mask,
    ``cached`` a list of K [n, P, F_l] activation tables from the last
    full pass, and each layer

      * segment path: masks edges to dirty receivers (``em * dirty[rc]``)
        — a dirty row keeps its FULL incoming edge subsequence, so its
        segment sums and masked degree accumulate in the full pass's
        order;
      * kernel path: zeroes the tile masks of clean 128-row blocks so the
        Pallas SpMM only accumulates dirty row-blocks (the edge mask
        stays full: degrees must be exact), and merges at row-block
        granularity — every row of a live block sees its full tile set,
        so its value equals the full pass's;

    then scatter-merges recomputed rows into the cached table with
    ``jnp.where`` (an elementwise select: clean rows keep the cached
    bits, including -0.0 signs, which an arithmetic blend would flip).
    The next layer's halo exchange reads the MERGED table, so the result
    is bit-identical to a from-scratch pass by induction — provided the
    dirty mask is a sound k-hop closure (``core.frontier``) and the
    cached tables came from this graph revision (the Session's
    ``ActivationCache`` tags enforce both).
    """
    _, layer_fn = LAYER_FNS[kind]
    mode = resolve_aggregation(aggregation, kind, exchange=exchange)
    exchange = _wire_exchange(exchange)
    use_kernels = mode == "pallas"
    frontier = dirty is not None
    if use_kernels and (pg.local_csr is None or pg.halo_csr is None):
        raise ValueError(
            "aggregation='pallas' needs the block-CSR shards; rebuild the "
            "PartitionedGraph with build_partitioned(..., build_blocks=True)")
    if halo_quant and not use_kernels:
        raise ValueError("halo_quant requires the 'pallas' aggregation path")
    if frontier and kind not in KERNEL_KINDS:
        raise ValueError(
            f"frontier execution supports kinds {KERNEL_KINDS} (static-sum "
            f"aggregation); {kind!r} re-weights edges per layer")
    interpret = jax.default_backend() != "tpu"
    # Bind layout statics to locals (never close over pg — see bsp_apply).
    slots = pg.slots
    local_rows = None if pg.local_csr is None else pg.local_csr.src_rows
    halo_rows = None if pg.halo_csr is None else pg.halo_csr.src_rows
    out_rows = None if pg.local_csr is None else pg.local_csr.out_rows

    def shard_fn(params, *ops):
        feats, vmask, s_g, s_h, recv, emask = ops[:6]
        brows, bmask, self_g, self_h = ops[6:10]
        rest = ops[10:]
        dm = cch = None
        if frontier:
            dm = rest[0][0]                    # [K, P]
            cch = [c[0] for c in rest[1]]      # K tables [P, F_l]
            rest = rest[2:]
        if use_kernels:
            lblk, lcol, lmsk, hblk, hcol, hmsk = (a[0] for a in rest)
        nlayers = len(params)
        h = feats[0]                           # [P, F] or [B, P, F]
        vm, sg, sh = vmask[0], s_g[0], s_h[0]
        rc, em = recv[0], emask[0]
        br, bm = brows[0], bmask[0]
        selg, selh = self_g[0], self_h[0]
        outs = []
        for li, p in enumerate(params):
            act_last = li == nlayers - 1
            kwargs = {}
            em_l = em
            lmsk_l = hmsk_l = merge_row = None
            if use_kernels:
                lmsk_l, hmsk_l = lmsk, hmsk
            if frontier:
                drow = dm[li]                  # [P]
                if use_kernels:
                    dblk = jnp.pad(drow, (0, out_rows - slots)) \
                        .reshape(-1, BLOCK).max(axis=1)
                    lmsk_l = lmsk * dblk[:, None]
                    hmsk_l = hmsk * dblk[:, None]
                    merge_row = jnp.repeat(dblk, BLOCK)[:slots]
                else:
                    em_l = em * drow[rc]
                    merge_row = drow
            if exchange == "allgather":
                h_all = jax.lax.all_gather(h, axis)
                h_src = (_gathered_stack(h_all) if many
                         else h_all.reshape(-1, h.shape[-1]))
                edges = _layer_edges(slots, sg, kind, selg, rc, em_l, vm)
            elif exchange == "halo":
                hb = (h[:, br] if many else h[br]) * bm[:, None]
                edges = _layer_edges(slots, sh, kind, selh, rc, em_l, vm)
                if use_kernels:
                    f = h.shape[-1]
                    h_src = None
                    if halo_quant:
                        codes, sc, mn = _wire_quantize(hb)
                        if many:
                            codes = _gathered_stack(
                                jax.lax.all_gather(codes, axis))
                            sm = _gathered_stack(jax.lax.all_gather(
                                jnp.stack([sc, mn], axis=-1), axis))
                            codes = _kernel_pad(codes, halo_rows)
                            sm = jnp.pad(sm, ((0, 0),
                                              (0, halo_rows - sm.shape[1]),
                                              (0, 0)))
                            sc, mn = sm[..., 0], sm[..., 1]

                            def halo_agg(_f=f, _m=hmsk_l, _c=codes,
                                         _s=sc, _n=mn):
                                return dequant_spmm_batched(
                                    hblk, hcol, _m, _c, _s, _n,
                                    interpret=interpret)[:, :slots, :_f]
                        else:
                            codes = jax.lax.all_gather(
                                codes, axis).reshape(-1, f)
                            sm = jax.lax.all_gather(
                                jnp.stack([sc, mn], axis=-1),
                                axis).reshape(-1, 2)
                            codes = _kernel_pad(codes, halo_rows)
                            sm = jnp.pad(sm, ((0, halo_rows - sm.shape[0]),
                                              (0, 0)))
                            sc, mn = sm[:, 0], sm[:, 1]

                            def halo_agg(_f=f, _m=hmsk_l, _c=codes,
                                         _s=sc, _n=mn):
                                return dequant_spmm(
                                    hblk, hcol, _m, _c, _s, _n,
                                    interpret=interpret)[:slots, :_f]
                    else:
                        if many:
                            halo = _gathered_stack(
                                jax.lax.all_gather(hb, axis))
                            halo = _kernel_pad(halo, halo_rows)

                            def halo_agg(_f=f, _m=hmsk_l, _h=halo):
                                return block_spmm_batched(
                                    hblk, hcol, _m, _h,
                                    interpret=interpret)[:, :slots, :_f]
                        else:
                            halo = jax.lax.all_gather(
                                hb, axis).reshape(-1, h.shape[-1])
                            halo = _kernel_pad(halo, halo_rows)

                            def halo_agg(_f=f, _m=hmsk_l, _h=halo):
                                return block_spmm(
                                    hblk, hcol, _m, _h,
                                    interpret=interpret)[:slots, :_f]
                    if many:
                        def kernel_sum(h_loc, _f=f, _m=lmsk_l,
                                       _halo_agg=halo_agg):
                            loc = _kernel_pad(h_loc, local_rows)
                            out = block_spmm_batched(lblk, lcol, _m, loc,
                                                     interpret=interpret)
                            return out[:, :slots, :_f] + _halo_agg()
                    else:
                        def kernel_sum(h_loc, edges_, h_src_=None, _f=f,
                                       _m=lmsk_l, _halo_agg=halo_agg):
                            loc = _kernel_pad(h_loc, local_rows)
                            out = block_spmm(lblk, lcol, _m, loc,
                                             interpret=interpret)
                            return out[:slots, :_f] + _halo_agg()
                else:
                    halo = jax.lax.all_gather(hb, axis)
                    if many:
                        h_src = jnp.concatenate(
                            [h, _gathered_stack(halo)], axis=1)
                    else:
                        h_src = jnp.concatenate(
                            [h, halo.reshape(-1, h.shape[-1])], axis=0)
            else:
                raise ValueError(exchange)
            if use_kernels and not many:
                if kind == "sage":
                    def kernel_agg(h_loc, edges_, h_src_=None,
                                   _sum=kernel_sum):
                        deg = masked_degree(edges_)
                        return (_sum(h_loc, edges_, h_src_)
                                / jnp.maximum(deg, 1.0)[:, None])
                else:
                    kernel_agg = kernel_sum
                kwargs["aggregate"] = kernel_agg
            if many:
                if act_last:
                    kwargs["activation"] = None
                if use_kernels:
                    h_new = apply_layer_with_sum(kind, p, h, edges,
                                                 kernel_sum(h),
                                                 last=act_last)
                else:
                    h_new = jax.vmap(
                        lambda hh, ss, _p=p, _kw=kwargs: layer_fn(
                            _p, hh, edges, h_src=ss, **_kw))(h, h_src)
            elif act_last:
                h_new = layer_fn(p, h, edges, activation=None, h_src=h_src,
                                 **kwargs)
            else:
                h_new = layer_fn(p, h, edges, h_src=h_src, **kwargs)
            h_new = h_new * vm[:, None]
            if frontier:
                h = jnp.where(merge_row[:, None] > 0, h_new, cch[li])
            else:
                h = h_new
            outs.append(h[None])
        return tuple(outs)

    spec = P(axis, None, None, None) if many else P(axis, None, None)
    spec2 = P(axis, None)
    spec3 = P(axis, None, None)
    in_specs = [P(), spec, spec2, spec2, spec2, spec2, spec2, spec2, spec2,
                spec2, spec2]
    operands = [jnp.asarray(feats_op), jnp.asarray(pg.vertex_mask),
                jnp.asarray(pg.senders_global), jnp.asarray(pg.senders_halo),
                jnp.asarray(pg.receivers_local), jnp.asarray(pg.edge_mask),
                jnp.asarray(pg.boundary_rows), jnp.asarray(pg.boundary_mask),
                jnp.asarray(pg.self_senders_global),
                jnp.asarray(pg.self_senders_halo)]
    if frontier:
        # The dirty masks ride as ONE [n, K, P] operand; the cached tables
        # as a list operand under a pytree-prefix spec (variable K / F_l
        # re-specialize jit under the same cached shard_map wrapper).
        operands.append(jnp.asarray(dirty, jnp.float32))
        in_specs.append(spec3)
        operands.append([jnp.asarray(c, jnp.float32) for c in cached])
        in_specs.append(spec3)
    if use_kernels:
        for csr in (pg.local_csr, pg.halo_csr):
            for arr in (csr.blocks, csr.cols, csr.mask):
                operands.append(jnp.asarray(arr))
                in_specs.append(P(axis, *([None] * (arr.ndim - 1))))
    smap_kw = {}
    if use_kernels:
        smap_kw["check_rep"] = False
    tag = ("frontier" if frontier else "capture") + ("_many" if many else "")
    fn = _cached_program(
        _program_key(tag, kind, pg, mesh, axis, exchange, use_kernels,
                     halo_quant, interpret),
        lambda: jax.jit(_shard_map(shard_fn, mesh=mesh,
                                   in_specs=tuple(in_specs),
                                   out_specs=spec, **smap_kw)))
    return fn(list(params), *operands)


def _default_mesh(pg: PartitionedGraph, axis: str) -> Mesh:
    devs = np.array(jax.devices()[:pg.n])
    if len(devs) != pg.n:
        raise ValueError(
            f"need {pg.n} devices for {pg.n} partitions, have "
            f"{len(jax.devices())} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={pg.n}")
    return Mesh(devs, (axis,))


def bsp_infer_capture(params, kind: str, g: Graph, assignment: np.ndarray,
                      mesh: Optional[Mesh] = None, exchange: str = "halo",
                      axis: str = "fog", aggregation: str = "segment_sum",
                      halo_quant: bool = False,
                      pg: Optional[PartitionedGraph] = None):
    """``bsp_infer`` returning every layer: K arrays [V, F_l] in original
    vertex order (the last is the plain ``bsp_infer`` output, bit for
    bit). Feeds the Session's activation cache."""
    if pg is None:
        mode = resolve_aggregation(aggregation, kind, exchange=exchange)
        pg = build_partitioned(g, assignment, build_blocks=mode == "pallas")
    else:
        pg = pg.with_features(g.features)
    if mesh is None:
        mesh = _default_mesh(pg, axis)
    outs = _bsp_apply_layers(params, kind, pg, pg.feats, mesh, axis,
                             exchange, aggregation, halo_quant, many=False)
    return [pg.unpermute(np.asarray(o)) for o in outs]


def bsp_infer_capture_many(params, kind: str, feats: np.ndarray,
                           pg: PartitionedGraph,
                           mesh: Optional[Mesh] = None,
                           exchange: str = "halo", axis: str = "fog",
                           aggregation: str = "segment_sum",
                           halo_quant: bool = False):
    """Batched capture: [B, V, F] micro-batch -> K arrays [B, V, F_l]."""
    stack = pg.feature_stack(np.asarray(feats, np.float32))
    if mesh is None:
        mesh = _default_mesh(pg, axis)
    outs = _bsp_apply_layers(params, kind, pg, stack, mesh, axis, exchange,
                             aggregation, halo_quant, many=True)
    return [pg.unpermute_stack(np.asarray(o)) for o in outs]


def build_halo_tables(pg: PartitionedGraph, layer_inputs) -> List[np.ndarray]:
    """Pre-gathered per-layer halo tables for the stale-serve path.

    ``layer_inputs[l]`` is the [V, F_l] table of layer ``l``'s INPUT
    activations in original vertex order — layer 0's input is the raw
    feature matrix, layer ``l>0``'s input is layer ``l-1``'s output (e.g.
    from ``bsp_infer_capture``).  Returns K ``[n*B, F_l]`` tables laid out
    exactly like the synchronous exchange's
    ``all_gather(h[br] * bm[:, None]).reshape(-1, f)``: row ``p*B + i``
    carries partition ``p``'s i-th boundary row times its mask, padded
    rows zero.  Pure data movement through part_of/slot_of (no
    arithmetic), so replaying a table built from the same activations the
    fresh exchange shipped reproduces that exchange bit for bit.
    """
    tables = []
    brows = pg.boundary_rows.astype(np.int64)
    for act in layer_inputs:
        act = np.asarray(act, np.float32)
        f = act.shape[-1]
        shard = np.zeros((pg.n, pg.slots, f), np.float32)
        shard[pg.part_of, pg.slot_of] = act
        rows = np.take_along_axis(shard, brows[:, :, None], axis=1)
        rows = rows * pg.boundary_mask[:, :, None]
        tables.append(np.ascontiguousarray(
            rows.reshape(pg.n * pg.boundary_slots, f)))
    return tables


def _bsp_apply_stale(params, kind: str, pg: PartitionedGraph, feats_op,
                     halo_tables, mesh: Mesh, axis: str = "fog",
                     aggregation: str = "segment_sum", many: bool = False):
    """The ``halo_async`` stale serve: cross-partition reads come from the
    pre-gathered per-layer ``halo_tables`` (replicated operands) instead of
    a live per-layer collective, so no superstep stalls on the WAN.  Local
    rows always read the CURRENT features in ``feats_op``; only the halo
    rows are stale.  ``halo_quant`` does not apply — nothing crosses the
    wire.  Returns [n, (B,) P, D] device outputs like the plain programs.
    """
    _, layer_fn = LAYER_FNS[kind]
    mode = resolve_aggregation(aggregation, kind, exchange="halo_async")
    use_kernels = mode == "pallas"
    if use_kernels and (pg.local_csr is None or pg.halo_csr is None):
        raise ValueError(
            "aggregation='pallas' needs the block-CSR shards; rebuild the "
            "PartitionedGraph with build_partitioned(..., build_blocks=True)")
    if len(halo_tables) != len(params):
        raise ValueError(
            f"stale serve needs one halo table per layer: got "
            f"{len(halo_tables)} tables for {len(params)} layers")
    interpret = jax.default_backend() != "tpu"
    # Bind layout statics to locals (never close over pg — see bsp_apply).
    slots = pg.slots
    local_rows = None if pg.local_csr is None else pg.local_csr.src_rows
    halo_rows = None if pg.halo_csr is None else pg.halo_csr.src_rows

    def shard_fn(params, halos, feats, vmask, s_g, s_h, recv, emask, brows,
                 bmask, self_g, self_h, *kops):
        nlayers = len(params)
        h = feats[0]                               # [P, F] or [B, P, F]
        vm, sh = vmask[0], s_h[0]
        rc, em = recv[0], emask[0]
        selh = self_h[0]
        if use_kernels:
            lblk, lcol, lmsk, hblk, hcol, hmsk = (a[0] for a in kops)
        for li, p in enumerate(params):
            act_last = li == nlayers - 1
            kwargs = {}
            stale = halos[li]                      # [n*B, F_l] replicated
            edges = _layer_edges(slots, sh, kind, selh, rc, em, vm)
            if use_kernels:
                f = h.shape[-1]
                h_src = None
                halo = _kernel_pad(stale, halo_rows)
                if many:
                    halo = jnp.broadcast_to(halo, (h.shape[0],) + halo.shape)

                    def halo_agg(_f=f, _h=halo):
                        return block_spmm_batched(
                            hblk, hcol, hmsk, _h,
                            interpret=interpret)[:, :slots, :_f]

                    def kernel_sum(h_loc, _f=f, _halo_agg=halo_agg):
                        loc = _kernel_pad(h_loc, local_rows)
                        out = block_spmm_batched(lblk, lcol, lmsk, loc,
                                                 interpret=interpret)
                        return out[:, :slots, :_f] + _halo_agg()
                else:
                    def halo_agg(_f=f, _h=halo):
                        return block_spmm(hblk, hcol, hmsk, _h,
                                          interpret=interpret)[:slots, :_f]

                    def kernel_sum(h_loc, edges_, h_src_=None, _f=f,
                                   _halo_agg=halo_agg):
                        loc = _kernel_pad(h_loc, local_rows)
                        out = block_spmm(lblk, lcol, lmsk, loc,
                                         interpret=interpret)
                        return out[:slots, :_f] + _halo_agg()
            elif many:
                h_src = jnp.concatenate(
                    [h, jnp.broadcast_to(stale, (h.shape[0],) + stale.shape)],
                    axis=1)
            else:
                h_src = jnp.concatenate([h, stale], axis=0)
            if many:
                if act_last:
                    kwargs["activation"] = None
                if use_kernels:
                    h = apply_layer_with_sum(kind, p, h, edges,
                                             kernel_sum(h), last=act_last)
                else:
                    h = jax.vmap(lambda hh, ss, _p=p, _kw=kwargs: layer_fn(
                        _p, hh, edges, h_src=ss, **_kw))(h, h_src)
            else:
                if use_kernels:
                    if kind == "sage":
                        def kernel_agg(h_loc, edges_, h_src_=None,
                                       _sum=kernel_sum):
                            deg = masked_degree(edges_)
                            return (_sum(h_loc, edges_, h_src_)
                                    / jnp.maximum(deg, 1.0)[:, None])
                    else:
                        kernel_agg = kernel_sum
                    kwargs["aggregate"] = kernel_agg
                if act_last:
                    h = layer_fn(p, h, edges, activation=None, h_src=h_src,
                                 **kwargs)
                else:
                    h = layer_fn(p, h, edges, h_src=h_src, **kwargs)
            h = h * vm[:, None]
        return h[None]

    spec = P(axis, None, None, None) if many else P(axis, None, None)
    spec2 = P(axis, None)
    # Params AND the stale halo tables ride as replicated operands (P()
    # pytree-prefix specs) so the compiled program is reusable — see
    # _PROGRAM_CACHE.  The tables are graph state shared by every shard
    # and (in the batched program) every example.
    in_specs = [P(), P(), spec, spec2, spec2, spec2, spec2, spec2, spec2,
                spec2, spec2, spec2]
    operands = [jnp.asarray(feats_op), jnp.asarray(pg.vertex_mask),
                jnp.asarray(pg.senders_global), jnp.asarray(pg.senders_halo),
                jnp.asarray(pg.receivers_local), jnp.asarray(pg.edge_mask),
                jnp.asarray(pg.boundary_rows), jnp.asarray(pg.boundary_mask),
                jnp.asarray(pg.self_senders_global),
                jnp.asarray(pg.self_senders_halo)]
    if use_kernels:
        for csr in (pg.local_csr, pg.halo_csr):
            for arr in (csr.blocks, csr.cols, csr.mask):
                operands.append(jnp.asarray(arr))
                in_specs.append(P(axis, *([None] * (arr.ndim - 1))))
    smap_kw = {}
    if use_kernels:
        smap_kw["check_rep"] = False
    tag = "stale_many" if many else "stale"
    fn = _cached_program(
        _program_key(tag, kind, pg, mesh, axis, "halo_async", use_kernels,
                     False, interpret),
        lambda: jax.jit(_shard_map(shard_fn, mesh=mesh,
                                   in_specs=tuple(in_specs),
                                   out_specs=spec, **smap_kw)))
    tables = [jnp.asarray(t, jnp.float32) for t in halo_tables]
    return fn(list(params), tables, *operands)


def bsp_infer_stale(params, kind: str, feats: np.ndarray,
                    pg: PartitionedGraph, halo_tables,
                    mesh: Optional[Mesh] = None, axis: str = "fog",
                    aggregation: str = "segment_sum") -> np.ndarray:
    """Stale-halo distributed inference -> [V, D] in original vertex order.

    ``feats`` are the CURRENT [V, F] features (local reads stay fresh);
    ``halo_tables`` the recorded per-layer exchange payloads
    (``build_halo_tables``) a bounded-staleness serve may replay.
    """
    pg = pg.with_features(np.asarray(feats, np.float32))
    if mesh is None:
        mesh = _default_mesh(pg, axis)
    out = np.asarray(_bsp_apply_stale(params, kind, pg, pg.feats,
                                      halo_tables, mesh, axis, aggregation))
    return pg.unpermute(out)


def bsp_infer_stale_many(params, kind: str, feats: np.ndarray,
                         pg: PartitionedGraph, halo_tables,
                         mesh: Optional[Mesh] = None, axis: str = "fog",
                         aggregation: str = "segment_sum") -> np.ndarray:
    """Batched stale-halo inference: [B, V, F] micro-batch -> [B, V, D];
    every example shares the same recorded halo tables (graph state, not
    per-request state)."""
    stack = pg.feature_stack(np.asarray(feats, np.float32))
    if mesh is None:
        mesh = _default_mesh(pg, axis)
    out = np.asarray(_bsp_apply_stale(params, kind, pg, stack, halo_tables,
                                      mesh, axis, aggregation, many=True))
    return pg.unpermute_stack(out)


def _scatter_frontier(pg: PartitionedGraph, rows_per_layer, cached_layers):
    """Global frontier/cache state -> per-partition shard operands.

    Pure data movement through part_of/slot_of (no arithmetic), so the
    shard tables carry exactly the cached bits."""
    k = len(cached_layers)
    dm = np.zeros((pg.n, k, pg.slots), np.float32)
    for li, rows in enumerate(rows_per_layer):
        rows = np.asarray(rows, np.int64)
        dm[pg.part_of[rows], li, pg.slot_of[rows]] = 1.0
    ct = []
    for cl in cached_layers:
        cl = np.asarray(cl, np.float32)
        t = np.zeros((pg.n, pg.slots, cl.shape[-1]), np.float32)
        t[pg.part_of, pg.slot_of] = cl
        ct.append(t)
    return dm, ct


def bsp_infer_frontier(params, kind: str, feats: np.ndarray,
                       pg: PartitionedGraph, rows_per_layer, cached_layers,
                       mesh: Optional[Mesh] = None, exchange: str = "halo",
                       axis: str = "fog", aggregation: str = "segment_sum",
                       halo_quant: bool = False):
    """Frontier-restricted distributed inference.

    ``rows_per_layer[l]`` are the global vertex ids layer ``l`` must
    recompute (a sound closure from ``core.frontier``), ``cached_layers``
    the last full pass's K [V, F_l] tables for THIS graph revision.
    Returns the K merged tables in original vertex order; the last one is
    bit-identical to a full ``bsp_infer`` pass.
    """
    pg = pg.with_features(np.asarray(feats, np.float32))
    dm, ct = _scatter_frontier(pg, rows_per_layer, cached_layers)
    if mesh is None:
        mesh = _default_mesh(pg, axis)
    outs = _bsp_apply_layers(params, kind, pg, pg.feats, mesh, axis,
                             exchange, aggregation, halo_quant, many=False,
                             dirty=dm, cached=ct)
    return [pg.unpermute(np.asarray(o)) for o in outs]


def bsp_infer_frontier_many(params, kind: str, feats: np.ndarray,
                            pg: PartitionedGraph, rows_per_layer,
                            cached_layers, mesh: Optional[Mesh] = None,
                            exchange: str = "halo", axis: str = "fog",
                            aggregation: str = "segment_sum",
                            halo_quant: bool = False):
    """Batched frontier pass over a stacked [B, V, F] micro-batch sharing
    one (unioned) dirty frontier; returns K merged [B, V, F_l] stacks."""
    stack = pg.feature_stack(np.asarray(feats, np.float32))
    dm, ct = _scatter_frontier(pg, rows_per_layer, cached_layers)
    if mesh is None:
        mesh = _default_mesh(pg, axis)
    outs = _bsp_apply_layers(params, kind, pg, stack, mesh, axis, exchange,
                             aggregation, halo_quant, many=True,
                             dirty=dm, cached=ct)
    return [pg.unpermute_stack(np.asarray(o)) for o in outs]


def bsp_infer(params, kind: str, g: Graph, assignment: np.ndarray,
              mesh: Optional[Mesh] = None, exchange: str = "halo",
              axis: str = "fog", aggregation: str = "segment_sum",
              halo_quant: bool = False,
              pg: Optional[PartitionedGraph] = None) -> np.ndarray:
    """End-to-end distributed inference -> [V, D] in original vertex order.

    With ``mesh=None`` a mesh over all available devices is built; the
    number of partitions must equal the mesh size. ``pg`` reuses prebuilt
    partition buffers (the features are refreshed from ``g``), which is
    what the serving path does per query.
    """
    if pg is None:
        mode = resolve_aggregation(aggregation, kind, exchange=exchange)
        pg = build_partitioned(g, assignment,
                               build_blocks=mode == "pallas")
    else:
        pg = pg.with_features(g.features)
    if mesh is None:
        devs = np.array(jax.devices()[:pg.n])
        if len(devs) != pg.n:
            raise ValueError(
                f"need {pg.n} devices for {pg.n} partitions, have "
                f"{len(jax.devices())} — run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={pg.n}")
        mesh = Mesh(devs, (axis,))
    out = np.asarray(bsp_apply(params, kind, pg, mesh, axis, exchange,
                               aggregation=aggregation,
                               halo_quant=halo_quant))
    return pg.unpermute(out)


def bsp_infer_many(params, kind: str, feats: np.ndarray,
                   pg: PartitionedGraph, mesh: Optional[Mesh] = None,
                   exchange: str = "halo", axis: str = "fog",
                   aggregation: str = "segment_sum",
                   halo_quant: bool = False) -> np.ndarray:
    """Batched end-to-end distributed inference -> [B, V, D].

    ``feats`` is a [B, V, F] stacked micro-batch; the prebuilt ``pg``
    supplies the layout (and block-CSR shards for the kernel path). One
    shard_map launch serves the whole batch — see ``bsp_apply_many``.
    """
    feats = np.asarray(feats, np.float32)
    if feats.ndim != 3:
        raise ValueError(f"bsp_infer_many takes a [B, V, F] stack, got "
                         f"shape {feats.shape}")
    stack = pg.feature_stack(feats)
    if mesh is None:
        devs = np.array(jax.devices()[:pg.n])
        if len(devs) != pg.n:
            raise ValueError(
                f"need {pg.n} devices for {pg.n} partitions, have "
                f"{len(jax.devices())} — run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={pg.n}")
        mesh = Mesh(devs, (axis,))
    out = np.asarray(bsp_apply_many(params, kind, pg, stack, mesh, axis,
                                    exchange, aggregation=aggregation,
                                    halo_quant=halo_quant))
    return pg.unpermute_stack(out)


def exchange_bytes(pg: PartitionedGraph, feature_dim: int,
                   exchange: str, dtype_bytes: int = 4,
                   row_overhead_bytes: int = 0) -> int:
    """Collective payload per BSP sync (for the communication roofline).

    ``dtype_bytes``/``row_overhead_bytes`` describe the wire format: the
    float32 exchange is (4, 0); the DAQ-fused kernel path ships uint8
    codes plus one f32 (scale, min) pair per row, i.e. (1, 8).
    """
    per_row = feature_dim * dtype_bytes + row_overhead_bytes
    if exchange == "allgather":
        return pg.n * pg.slots * per_row
    return pg.n * pg.boundary_slots * per_row


@dataclasses.dataclass(frozen=True)
class ExchangeSpec:
    """An EXCHANGES registry entry: one per-layer cross-fog exchange.

    ``stale_tolerant`` marks modes whose serves may replay recorded halo
    tables up to a staleness bound instead of running the collective
    (``EngineConfig.staleness_bound`` only applies to those entries).

    ``retryable`` + the retry knobs are the tier-1 fault-recovery hook:
    a transient loss of this exchange is retried with exponential
    backoff (``backoff_base_s * backoff_mult**k`` after failed attempt
    ``k``), bounded by ``max_retries`` attempts and a ``retry_timeout_s``
    hard deadline; :meth:`recovery_cost` prices the walk on the
    simulated clock. Exhausting the budget escalates to the next tier
    (stale ride-through, then shard failover).
    """
    name: str
    stale_tolerant: bool = False
    retryable: bool = False
    max_retries: int = 4
    backoff_base_s: float = 0.02
    backoff_mult: float = 2.0
    retry_timeout_s: float = 1.0

    def bytes_per_sync(self, pg: PartitionedGraph, feature_dim: int,
                       dtype_bytes: int = 4,
                       row_overhead_bytes: int = 0) -> int:
        """Wire bytes of one FRESH sync (a stale halo_async serve ships
        zero bytes — it replays recorded tables)."""
        return exchange_bytes(pg, feature_dim, _wire_exchange(self.name),
                              dtype_bytes, row_overhead_bytes)

    def recovery_cost(self, losses: int, sync_cost: float
                      ) -> "Tuple[float, int, bool]":
        """Price recovering ``losses`` consecutive transient losses of
        this exchange: ``(seconds, attempts, succeeded)``. A
        non-retryable exchange fails immediately at zero cost (the
        caller escalates straight past tier 1)."""
        if not self.retryable:
            return 0.0, 0, False
        from repro.core import simulation   # lazy: keep module load light
        return simulation.simulate_retry(
            losses, sync_cost=sync_cost, base=self.backoff_base_s,
            mult=self.backoff_mult, max_attempts=self.max_retries,
            timeout=self.retry_timeout_s)


EXCHANGES.register("halo", ExchangeSpec("halo", retryable=True))
EXCHANGES.register("allgather", ExchangeSpec("allgather", retryable=True))
EXCHANGES.register("halo_async", ExchangeSpec("halo_async",
                                              stale_tolerant=True,
                                              retryable=True))
