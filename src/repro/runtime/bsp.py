"""Distributed BSP GNN inference runtime (paper §III-E) on a JAX mesh.

The paper's runtime: each fog holds a vertex partition; every GNN layer runs
Aggregate/Update over local vertices, pulling neighbor activations from
other fogs in a Bulk-Synchronous-Parallel step (K syncs for K layers).

TPU/JAX adaptation: fogs = devices along a ``fog`` mesh axis, executed with
``shard_map``. The per-layer cross-fog exchange supports two strategies:

  * ``"allgather"``  — all_gather the full [P, F] partition activations
    (straw-man exchange; O(n·P·F) bytes per device per layer).
  * ``"halo"``       — all_gather only the *boundary rows* (vertices that any
    other partition reads), packed into a [B, F] buffer (B = max boundary
    size). This is the paper's "exchange vertices data when needed",
    and the §Perf knob for the collective roofline term.

Both produce identical results; tests assert equality against single-device
execution. Per-partition buffers are padded to common static shapes so the
whole computation jits once.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # older releases keep it under experimental
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.api.registry import EXCHANGES
from repro.gnn.graph import Graph
from repro.gnn.layers import EdgeList, LAYER_FNS


@dataclasses.dataclass
class PartitionedGraph:
    """Static-shape per-partition buffers for shard_map execution."""
    n: int                      # number of partitions (mesh size)
    slots: int                  # P: padded vertices per partition
    edges_per_part: int         # E: padded edges per partition
    boundary_slots: int         # B: padded boundary rows per partition
    feats: np.ndarray           # [n, P, F] local features (padded rows = 0)
    vertex_mask: np.ndarray     # [n, P] 1 for real vertices
    # Edge connectivity, partitioned by the *receiver*'s owner:
    senders_global: np.ndarray  # [n, E] index into flattened [n*P] table
    senders_halo: np.ndarray    # [n, E] index into flattened [n*B] boundary table
    receivers_local: np.ndarray # [n, E] 0..P-1
    edge_mask: np.ndarray       # [n, E]
    # Boundary packing: rows each partition contributes to the halo table.
    boundary_rows: np.ndarray   # [n, B] local slot ids (padded w/ 0)
    boundary_mask: np.ndarray   # [n, B]
    # Self-edges for GAT (senders point at own row in the gathered table).
    self_senders_global: np.ndarray  # [n, P]
    self_senders_halo: np.ndarray    # [n, P]
    # Inverse permutation: result row for global vertex v lives at
    # (part[v], slot[v]).
    part_of: np.ndarray         # [V]
    slot_of: np.ndarray         # [V]

    def unpermute(self, out: np.ndarray) -> np.ndarray:
        """[n, P, D] stacked partition outputs -> [V, D] original order."""
        return out[self.part_of, self.slot_of]


def build_partitioned(g: Graph, assignment: np.ndarray,
                      pad_multiple: int = 8) -> PartitionedGraph:
    """Lay the graph out per-partition with static padded shapes."""
    assignment = np.asarray(assignment, np.int64)
    n = int(assignment.max()) + 1
    parts: List[np.ndarray] = [np.flatnonzero(assignment == p) for p in range(n)]
    sizes = np.array([len(p) for p in parts])
    slots = int(-(-sizes.max() // pad_multiple) * pad_multiple)

    part_of = assignment
    slot_of = np.zeros(g.num_vertices, np.int64)
    for p, vs in enumerate(parts):
        slot_of[vs] = np.arange(len(vs))

    f = g.feature_dim
    feats = np.zeros((n, slots, f), np.float32)
    vmask = np.zeros((n, slots), np.float32)
    for p, vs in enumerate(parts):
        feats[p, :len(vs)] = g.features[vs]
        vmask[p, :len(vs)] = 1.0

    # Edges grouped by receiver's partition.
    recv_part = part_of[g.receivers]
    edge_lists = [np.flatnonzero(recv_part == p) for p in range(n)]
    e_max = max(1, max(len(e) for e in edge_lists))
    e_pad = int(-(-e_max // pad_multiple) * pad_multiple)

    # Boundary rows: vertices read by any foreign partition.
    boundary_ids = []
    for p in range(n):
        cross = (part_of[g.senders] == p) & (recv_part != p)
        boundary_ids.append(np.unique(g.senders[cross]))
    b_max = max(1, max(len(b) for b in boundary_ids))
    b_pad = int(-(-b_max // pad_multiple) * pad_multiple)

    # halo index of vertex v (valid only if v is in its owner's boundary set)
    halo_slot = np.zeros(g.num_vertices, np.int64)
    for p, bs in enumerate(boundary_ids):
        halo_slot[bs] = np.arange(len(bs))

    senders_global = np.zeros((n, e_pad), np.int32)
    senders_halo = np.zeros((n, e_pad), np.int32)
    receivers_local = np.zeros((n, e_pad), np.int32)
    edge_mask = np.zeros((n, e_pad), np.float32)
    boundary_rows = np.zeros((n, b_pad), np.int32)
    boundary_mask = np.zeros((n, b_pad), np.float32)
    for p in range(n):
        eids = edge_lists[p]
        s, r = g.senders[eids], g.receivers[eids]
        k = len(eids)
        senders_global[p, :k] = part_of[s] * slots + slot_of[s]
        # local senders also appear in the halo table? no — local senders are
        # read from the local shard directly in halo mode: point them at the
        # *own* boundary copy when they are boundary rows, else we route local
        # edges through the local table. To keep a single gather, halo mode
        # uses a combined table [local P rows | n*B halo rows]; local senders
        # use their local slot, remote senders use P + their halo position.
        local = part_of[s] == p
        senders_halo[p, :k] = np.where(
            local, slot_of[s],
            slots + part_of[s] * b_pad + halo_slot[s]).astype(np.int32)
        receivers_local[p, :k] = slot_of[r]
        edge_mask[p, :k] = 1.0
        bs = boundary_ids[p]
        boundary_rows[p, :len(bs)] = slot_of[bs]
        boundary_mask[p, :len(bs)] = 1.0

    self_g = np.zeros((n, slots), np.int32)
    self_h = np.zeros((n, slots), np.int32)
    for p in range(n):
        self_g[p] = p * slots + np.arange(slots)
        self_h[p] = np.arange(slots)  # local rows in combined halo table

    return PartitionedGraph(
        n=n, slots=slots, edges_per_part=e_pad, boundary_slots=b_pad,
        feats=feats, vertex_mask=vmask,
        senders_global=senders_global, senders_halo=senders_halo,
        receivers_local=receivers_local, edge_mask=edge_mask,
        boundary_rows=boundary_rows, boundary_mask=boundary_mask,
        self_senders_global=self_g, self_senders_halo=self_h,
        part_of=part_of, slot_of=slot_of)


def _layer_edges(pg: PartitionedGraph, senders, kind: str, self_senders,
                 receivers, emask, vmask):
    """EdgeList for one partition; GAT gets explicit self-edges."""
    if kind == "gat":
        s = jnp.concatenate([senders, self_senders])
        r = jnp.concatenate([receivers,
                             jnp.arange(pg.slots, dtype=receivers.dtype)])
        m = jnp.concatenate([emask, vmask])
        return EdgeList(s, r, m, pg.slots)
    return EdgeList(senders, receivers, emask, pg.slots)


def bsp_apply(params, kind: str, pg: PartitionedGraph, mesh: Mesh,
              axis: str = "fog", exchange: str = "halo") -> jnp.ndarray:
    """Distributed K-layer GNN inference; returns [n, P, D] device outputs."""
    _, layer_fn = LAYER_FNS[kind]
    nlayers = len(params)

    def shard_fn(feats, vmask, s_g, s_h, recv, emask, brows, bmask,
                 self_g, self_h):
        # shard_map blocks: feats [1, P, F] etc. — squeeze the leading axis.
        h = feats[0]
        vm, sg, sh = vmask[0], s_g[0], s_h[0]
        rc, em = recv[0], emask[0]
        br, bm = brows[0], bmask[0]
        selg, selh = self_g[0], self_h[0]
        for li, p in enumerate(params):
            act_last = li == nlayers - 1
            if exchange == "allgather":
                h_all = jax.lax.all_gather(h, axis)          # [n, P, F]
                h_src = h_all.reshape(-1, h.shape[-1])
                edges = _layer_edges(pg, sg, kind, selg, rc, em, vm)
            elif exchange == "halo":
                hb = h[br] * bm[:, None]                      # [B, F]
                halo = jax.lax.all_gather(hb, axis)           # [n, B, F]
                h_src = jnp.concatenate(
                    [h, halo.reshape(-1, h.shape[-1])], axis=0)
                edges = _layer_edges(pg, sh, kind, selh, rc, em, vm)
            else:
                raise ValueError(exchange)
            if act_last:
                h = layer_fn(p, h, edges, activation=None, h_src=h_src)
            else:
                h = layer_fn(p, h, edges, h_src=h_src)
            h = h * vm[:, None]  # keep padded rows at zero
        return h[None]

    spec = P(axis, None, None)
    spec2 = P(axis, None)
    fn = jax.jit(_shard_map(
        shard_fn, mesh=mesh,
        in_specs=(spec, spec2, spec2, spec2, spec2, spec2, spec2, spec2,
                  spec2, spec2),
        out_specs=spec))
    return fn(jnp.asarray(pg.feats), jnp.asarray(pg.vertex_mask),
              jnp.asarray(pg.senders_global), jnp.asarray(pg.senders_halo),
              jnp.asarray(pg.receivers_local), jnp.asarray(pg.edge_mask),
              jnp.asarray(pg.boundary_rows), jnp.asarray(pg.boundary_mask),
              jnp.asarray(pg.self_senders_global),
              jnp.asarray(pg.self_senders_halo))


def bsp_infer(params, kind: str, g: Graph, assignment: np.ndarray,
              mesh: Optional[Mesh] = None, exchange: str = "halo",
              axis: str = "fog") -> np.ndarray:
    """End-to-end distributed inference -> [V, D] in original vertex order.

    With ``mesh=None`` a mesh over all available devices is built; the
    number of partitions must equal the mesh size.
    """
    pg = build_partitioned(g, assignment)
    if mesh is None:
        devs = np.array(jax.devices()[:pg.n])
        if len(devs) != pg.n:
            raise ValueError(
                f"need {pg.n} devices for {pg.n} partitions, have "
                f"{len(jax.devices())} — run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={pg.n}")
        mesh = Mesh(devs, (axis,))
    out = np.asarray(bsp_apply(params, kind, pg, mesh, axis, exchange))
    return pg.unpermute(out)


def exchange_bytes(pg: PartitionedGraph, feature_dim: int,
                   exchange: str, dtype_bytes: int = 4) -> int:
    """Collective payload per BSP sync (for the communication roofline)."""
    if exchange == "allgather":
        return pg.n * pg.slots * feature_dim * dtype_bytes
    return pg.n * pg.boundary_slots * feature_dim * dtype_bytes


@dataclasses.dataclass(frozen=True)
class ExchangeSpec:
    """An EXCHANGES registry entry: one per-layer cross-fog exchange."""
    name: str

    def bytes_per_sync(self, pg: PartitionedGraph, feature_dim: int,
                       dtype_bytes: int = 4) -> int:
        return exchange_bytes(pg, feature_dim, self.name, dtype_bytes)


EXCHANGES.register("halo", ExchangeSpec("halo"))
EXCHANGES.register("allgather", ExchangeSpec("allgather"))
