"""Deprecated Fograph serving entry points (pre-Engine API).

.. deprecated::
   ``deploy`` / ``serve_query`` / ``adapt`` are thin shims over the unified
   ``repro.api`` Engine/Plan/Session pipeline and will be removed in a
   future PR. New code should use::

       from repro.api import Engine
       plan = Engine((params, kind), cluster="1A+4B+1C",
                     compressor="daq").compile(graph)
       session = plan.session()
       result = session.query()          # serving
       session.adapt()                   # adaptive-scheduler tick

   See docs/api.md for the full migration table.
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.api.plan import Plan
from repro.api.session import QueryResult, Session
from repro.core import simulation

__all__ = ["FographService", "QueryResult", "deploy", "serve_query", "adapt"]


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.runtime.serving.{old} is deprecated; use {new} "
        "(see docs/api.md)", DeprecationWarning, stacklevel=3)


class FographService:
    """Legacy service handle — now a thin view over an api.Session.

    Keeps the old attribute surface (``cluster``, ``fogs``, ``params``,
    ``kind``, ``placement``, ``state``, ``compress``, ``exchange``) so
    existing call sites keep working while they migrate. The knobs the old
    dataclass let callers reassign between queries (``compress``,
    ``exchange``, ``state``) stay writable and take effect on the next
    ``serve_query``; ``params``/``kind`` are frozen into the compiled plan
    (re-``deploy`` to change the model).
    """

    def __init__(self, session: Session):
        self.session = session

    @property
    def plan(self) -> Plan:
        return self.session.plan

    @property
    def cluster(self) -> simulation.FogCluster:
        return self.session.plan.cluster

    @property
    def fogs(self):
        return self.session.fogs

    @property
    def params(self):
        return list(self.session.plan.model.params)

    @property
    def kind(self) -> str:
        return self.session.plan.model.kind

    @property
    def placement(self):
        return self.session.placement

    @property
    def state(self):
        return self.session.state

    @state.setter
    def state(self, value) -> None:
        self.session.state = value
        self.session._partitioned = None  # layout may have changed

    @property
    def compress(self) -> Optional[str]:
        key = self.session._compressor.name
        return None if key == "none" else key

    @compress.setter
    def compress(self, key: Optional[str]) -> None:
        from repro.api.registry import COMPRESSORS
        self.session._compressor = COMPRESSORS.resolve(
            "none" if key is None else key)

    @property
    def exchange(self) -> str:
        return self.session._exchange.name

    @exchange.setter
    def exchange(self, key: str) -> None:
        from repro.api.registry import EXCHANGES
        self.session._exchange = EXCHANGES.resolve(key)


def deploy(graph, params, kind: str, *, cluster_spec: str = "1A+4B+1C",
           network: str = "wifi", hidden: int = 64, seed: int = 0,
           compress: Optional[str] = "daq", strategy: str = "iep",
           exchange: str = "halo",
           sync_cost: float = simulation.DEFAULT_SYNC_COST) -> FographService:
    """Deprecated: use ``repro.api.Engine(...).compile(graph).session()``."""
    from repro.api.engine import Engine
    _deprecated("deploy", "repro.api.Engine(...).compile(graph).session()")
    engine = Engine((params, kind), cluster=cluster_spec, network=network,
                    placement=strategy,  # registry resolves legacy aliases
                    compressor="none" if compress is None else compress,
                    exchange=exchange, executor="sim", hidden=hidden,
                    seed=seed, sync_cost=sync_cost)
    return FographService(engine.compile(graph).session())


def serve_query(svc: FographService, *,
                distributed: bool = False) -> QueryResult:
    """Deprecated: use ``Session.query()`` (``executor="mesh-bsp"`` for the
    real-mesh path the old ``distributed=True`` flag selected)."""
    _deprecated("serve_query", "Session.query()")
    return svc.session.query(executor="mesh-bsp" if distributed else None)


def adapt(svc: FographService, *, lam: float = 1.3, theta: float = 0.5,
          seed: int = 0) -> str:
    """Deprecated: use ``Session.adapt()``."""
    _deprecated("adapt", "Session.adapt()")
    return svc.session.adapt(lam=lam, theta=theta, seed=seed)
