"""Fograph end-to-end serving pipeline (paper Fig. 5/6 workflow).

Glues every module along the paper's five steps:

  1. metadata registration  — profile fog nodes, register models (setup)
  2. execution planning      — IEP data placement
  3. compressed collection   — DAQ + lossless packing of device uploads
  4. distributed runtime     — BSP inference over the fog mesh axis
  5. adaptive scheduling     — dual-mode placement refinement across queries

Latency accounting comes from `core.simulation` (the container has no real
LAN); *numerical results* come from real JAX execution — the embeddings a
query returns are genuinely computed with the (de)quantized features, so
accuracy experiments measure true quantization effects.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core import compression, simulation
from repro.core.placement import FogSpec, Placement, iep_place
from repro.core.scheduler import SchedulerState, schedule_step
from repro.gnn.graph import Graph
from repro.gnn.layers import EdgeList
from repro.gnn.models import gnn_apply


@dataclasses.dataclass
class FographService:
    """A deployed Fograph service instance (one GNN model, one fog cluster)."""
    cluster: simulation.FogCluster
    fogs: List[FogSpec]
    params: list
    kind: str
    placement: Placement
    compress: Optional[str] = "daq"
    exchange: str = "halo"
    state: SchedulerState = None

    def __post_init__(self):
        if self.state is None:
            self.state = SchedulerState(placement=self.placement)


def deploy(graph: Graph, params, kind: str, *, cluster_spec: str = "1A+4B+1C",
           network: str = "wifi", hidden: int = 64, seed: int = 0,
           compress: Optional[str] = "daq", strategy: str = "iep",
           exchange: str = "halo",
           sync_cost: float = simulation.DEFAULT_SYNC_COST) -> FographService:
    """Setup phase: profile, register metadata, plan placement."""
    k_layers = len(params)
    cluster = simulation.make_cluster(cluster_spec, network, graph,
                                      hidden=hidden, k_layers=k_layers,
                                      seed=seed, sync_cost=sync_cost)
    fogs = cluster.fog_specs(seed=seed)
    placement = iep_place(graph, fogs, k_layers=k_layers,
                          sync_cost=sync_cost, seed=seed, strategy=strategy)
    return FographService(cluster=cluster, fogs=fogs, params=params,
                          kind=kind, placement=placement, compress=compress,
                          exchange=exchange)


@dataclasses.dataclass
class QueryResult:
    embeddings: np.ndarray
    latency: float
    throughput: float
    breakdown: Dict[str, float]
    wire_bytes: float


def serve_query(svc: FographService, *, distributed: bool = False) -> QueryResult:
    """Runtime phase for one inference query.

    The numerical path packs/unpacks features exactly as devices/fogs would
    (so quantization error is real); the distributed path additionally runs
    the BSP shard_map runtime when enough JAX devices exist, else the
    single-program equivalent (verified identical in tests).
    """
    g = svc.cluster.graph
    # --- step 3: compressed collection (real pack/unpack round-trip) ---
    if svc.compress == "daq":
        packed = compression.daq_pack(g.features.astype(np.float64), g.degrees)
        feats = compression.daq_unpack(packed).astype(np.float32)
    elif svc.compress == "uniform8":
        packed = compression.uniform_pack(g.features.astype(np.float64), 8)
        feats = compression.daq_unpack(packed).astype(np.float32)
    else:
        feats = g.features
    # --- step 4: distributed runtime (numerics) ---
    if distributed:
        from repro.runtime.bsp import bsp_infer
        g2 = dataclasses.replace(g, features=feats)
        emb = bsp_infer(svc.params, svc.kind, g2,
                        svc.state.placement.assignment, exchange=svc.exchange)
    else:
        emb = np.asarray(gnn_apply(svc.params, svc.kind, feats,
                                   EdgeList.from_graph(g)))
    # --- latency accounting (simulated cluster) ---
    res = simulation.simulate_multi_fog(svc.cluster, svc.state.placement,
                                        compress=svc.compress)
    return QueryResult(embeddings=emb, latency=res.total_latency,
                       throughput=res.throughput, breakdown=res.breakdown(),
                       wire_bytes=res.wire_bytes)


def adapt(svc: FographService, *, lam: float = 1.3, theta: float = 0.5,
          seed: int = 0) -> str:
    """Step 5: one adaptive-scheduler tick using current measured times."""
    t_real = simulation.measured_exec_times(svc.cluster, svc.state.placement)
    svc.state = schedule_step(svc.cluster.graph, svc.state, svc.fogs, t_real,
                              lam=lam, theta=theta,
                              sync_cost=svc.cluster.sync_cost, seed=seed)
    return svc.state.mode_history[-1]
