"""AdamW with decoupled weight decay + warmup-cosine schedule.

Moments live in the *params' own sharding* (the path-based rules in
models/sharding.py apply to the optimizer state pytree verbatim), so
optimizer memory is fully sharded. Giants can keep moments in bf16 via
``moment_dtype`` (HBM budget, see EXPERIMENTS.md §Dry-run notes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  final_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: Any = 3e-4          # float or schedule fn
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"

    def _mdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
            self.moment_dtype]

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self._mdtype())
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree_util.tree_map(zeros, params),
                          v=jax.tree_util.tree_map(zeros, params))

    def apply(self, params, grads, state: AdamWState):
        step = state.step + 1
        lr = (self.learning_rate(step)
              if callable(self.learning_rate) else self.learning_rate)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        mdt = self._mdtype()

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
            v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
            mh = m32 / bc1
            vh = v32 / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return newp, m32.astype(mdt), v32.astype(mdt)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v
               in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, m=new_m, v=new_v)
