"""Adaptive workload scheduler (paper §III-F, Alg. 2).

Dual-mode regulation:
  * load-balance indicator  mu_j = T_j_real / mean_k(T_k_real)   (Eq. 9)
  * slackness lambda (>1) tolerated imbalance; skew threshold theta (default .5)
  * if any mu_j > lambda:  n+/n <= theta -> lightweight *diffusion* vertex
    migration; otherwise -> global IEP re-plan.

Diffusion (Fig. 10): repeatedly pick the (highest, lowest) estimated-time
partitions and migrate boundary vertices that share the most neighbors with
the underloaded side, until the estimated balance satisfies lambda.
All moves are virtual (on the placement) and applied atomically, as in the
paper ("operated virtually ... executed physically when ... idle").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.api.registry import PLACEMENTS
from repro.core.placement import FogSpec, Placement
from repro.core.profiler import cardinality_of
from repro.gnn.graph import Graph


def load_indicators(t_real: np.ndarray) -> np.ndarray:
    """mu_j (Eq. 9)."""
    t_real = np.asarray(t_real, np.float64)
    return t_real / max(t_real.mean(), 1e-12)


def _estimated_exec(g: Graph, assignment: np.ndarray,
                    fogs: Sequence[FogSpec]) -> np.ndarray:
    out = np.zeros(len(fogs))
    for j, f in enumerate(fogs):
        mine = np.flatnonzero(assignment == j)
        if mine.size:
            out[j] = f.latency_model.predict(cardinality_of(g, mine))
    return out


def _boundary_candidates(g: Graph, assignment: np.ndarray, src: int,
                         dst: int) -> np.ndarray:
    """Vertices in src ranked by #neighbors already in dst (descending)."""
    in_src = assignment == src
    cross = in_src[g.receivers] & (assignment[g.senders] == dst)
    if not cross.any():
        return np.array([], np.int64)
    verts, counts = np.unique(g.receivers[cross], return_counts=True)
    return verts[np.argsort(-counts)]


def diffusion_adjust(g: Graph, assignment: np.ndarray,
                     fogs: Sequence[FogSpec], lam: float,
                     max_migrations: int = 256) -> np.ndarray:
    """Pairwise overloaded->underloaded vertex diffusion (paper Fig. 10).

    ``fogs`` latency models must carry the *updated* load factors (the
    online profiler's eta), so estimates reflect current background load.
    """
    assignment = assignment.copy()
    for _ in range(max_migrations):
        est = _estimated_exec(g, assignment, fogs)
        mu = load_indicators(est)
        if mu.max() <= lam:
            break
        src = int(np.argmax(est))
        dst = int(np.argmin(est))
        cands = _boundary_candidates(g, assignment, src, dst)
        if cands.size == 0:  # no shared boundary: take any src vertex
            cands = np.flatnonzero(assignment == src)
            if cands.size <= 1:
                break
        moved = False
        for v in cands[:8]:
            trial = assignment.copy()
            trial[v] = dst
            t_est = _estimated_exec(g, trial, fogs)
            if t_est.max() < est.max() - 1e-12:
                assignment = trial
                moved = True
                break
        if not moved:
            break
    return assignment


@dataclasses.dataclass
class SchedulerState:
    placement: Placement
    mode_history: list = dataclasses.field(default_factory=list)
    migrations: int = 0
    replans: int = 0


def schedule_step(g: Graph, state: SchedulerState, fogs: Sequence[FogSpec],
                  t_real: np.ndarray, *, lam: float = 1.3,
                  theta: float = 0.5, bytes_per_vertex: Optional[float] = None,
                  k_layers: int = 2, sync_cost: float = 5e-3,
                  seed: int = 0,
                  replan_strategy: str = "iep",
                  replan_partitioner=None) -> SchedulerState:
    """One Alg. 2 invocation: update timings -> skew check -> dual-mode.

    ``replan_strategy`` names a PLACEMENTS registry entry used for the
    global re-plan branch (the paper uses IEP; baselines are pluggable);
    ``replan_partitioner`` overrides the BGP solver the re-plan uses, so a
    plan compiled with a custom partitioner keeps it across re-plans.
    """
    t_real = np.asarray(t_real, np.float64)
    # Step 1: update performance estimates (online profiler eta per node).
    for j, f in enumerate(fogs):
        mine = np.flatnonzero(state.placement.assignment == j)
        if mine.size:
            f.latency_model.observe(cardinality_of(g, mine), float(t_real[j]))
    # Step 2: skew indicators.
    mu = load_indicators(t_real)
    if mu.max() <= lam:
        state.mode_history.append("none")
        return state
    n_over = int(np.sum(mu > lam))
    if n_over / len(fogs) <= theta:
        new_assign = diffusion_adjust(g, state.placement.assignment, fogs, lam)
        moved = int(np.sum(new_assign != state.placement.assignment))
        state.placement = dataclasses.replace(
            state.placement, assignment=new_assign)
        state.migrations += moved
        state.mode_history.append(f"diffusion({moved})")
    else:
        state.placement = PLACEMENTS.resolve(replan_strategy).place(
            g, fogs, bytes_per_vertex=bytes_per_vertex, k_layers=k_layers,
            sync_cost=sync_cost, seed=seed, partitioner=replan_partitioner)
        state.replans += 1
        state.mode_history.append("replan")
    return state
