"""Balanced Graph Partitioning (BGP) — the METIS stand-in of IEP step 1.

The paper (Alg. 1 line 2) delegates min-cut balanced partitioning to a
pluggable BGP solver ("Fograph allows for altering appropriate solvers") and
uses METIS in its implementation. METIS is not available offline, so we
implement the classic two-phase recipe METIS itself uses at a single level:

  1. *Region growing*: seed n partitions from spread high-degree vertices and
     grow them breadth-first under a capacity bound — yields connected,
     vertex-balanced partitions.
  2. *Fiduccia–Mattheyses-style refinement*: passes of single-vertex moves
     with positive cut gain, subject to a balance tolerance.

The output contract matches the paper: n partitions, balanced in |V| (the
*statistical* balance the paper notes is insufficient on its own — IEP step 2
then maps partitions to heterogeneous fogs).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.api.registry import PARTITIONERS
from repro.gnn.graph import Graph, edge_cut


def _adjacency(g: Graph):
    """CSR (indptr, indices) with row = vertex, cols = neighbors."""
    return g.indptr, g.indices


def _spread_seeds(g: Graph, n: int, rng: np.random.Generator) -> np.ndarray:
    """Pick n seeds: first = max degree, rest = BFS-farthest from chosen."""
    deg = g.degrees
    seeds = [int(np.argmax(deg))]
    indptr, indices = _adjacency(g)
    dist = np.full(g.num_vertices, np.iinfo(np.int32).max, np.int64)
    for _ in range(1, n):
        # Multi-source BFS from current seeds, take the farthest vertex.
        dist[:] = np.iinfo(np.int32).max
        frontier = np.array(seeds, dtype=np.int64)
        dist[frontier] = 0
        d = 0
        while frontier.size:
            d += 1
            nxt = []
            for v in frontier:
                nbrs = indices[indptr[v]:indptr[v + 1]]
                new = nbrs[dist[nbrs] > d]
                dist[new] = d
                nxt.append(new)
            frontier = np.unique(np.concatenate(nxt)) if nxt else np.array([], np.int64)
        unreached = dist == np.iinfo(np.int32).max
        if unreached.any():
            cand = np.flatnonzero(unreached)
            seeds.append(int(cand[np.argmax(deg[cand])]))
        else:
            seeds.append(int(np.argmax(np.where(np.isin(
                np.arange(g.num_vertices), seeds), -1, dist))))
    return np.array(seeds, dtype=np.int64)


def _region_grow(g: Graph, n: int, capacity: np.ndarray,
                 rng: np.random.Generator) -> np.ndarray:
    indptr, indices = _adjacency(g)
    assignment = -np.ones(g.num_vertices, dtype=np.int64)
    sizes = np.zeros(n, dtype=np.int64)
    seeds = _spread_seeds(g, n, rng)
    frontiers = []
    for p, s in enumerate(seeds):
        if assignment[s] == -1:
            assignment[s] = p
            sizes[p] = 1
        frontiers.append(list(indices[indptr[s]:indptr[s + 1]]))
    # Round-robin growth: smallest partition grows first.
    active = set(range(n))
    while active:
        p = min(active, key=lambda q: sizes[q])
        fr = frontiers[p]
        grown = False
        while fr:
            v = fr.pop()
            if assignment[v] == -1 and sizes[p] < capacity[p]:
                assignment[v] = p
                sizes[p] += 1
                fr.extend(int(u) for u in indices[indptr[v]:indptr[v + 1]]
                          if assignment[u] == -1)
                grown = True
                break
        if not grown or sizes[p] >= capacity[p]:
            active.discard(p)
    # Unassigned leftovers (disconnected components): fill smallest parts.
    for v in np.flatnonzero(assignment == -1):
        p = int(np.argmin(sizes / np.maximum(capacity, 1)))
        assignment[v] = p
        sizes[p] += 1
    return assignment


def _refine(g: Graph, assignment: np.ndarray, capacity: np.ndarray,
            passes: int = 4, tol: float = 0.05) -> np.ndarray:
    """FM-style boundary moves with positive gain under balance tolerance."""
    n = int(capacity.shape[0])
    indptr, indices = _adjacency(g)
    assignment = assignment.copy()
    sizes = np.bincount(assignment, minlength=n)
    hi = np.ceil(capacity * (1 + tol)).astype(np.int64)
    lo = np.floor(capacity * (1 - tol)).astype(np.int64)
    for _ in range(passes):
        boundary = np.unique(g.receivers[
            assignment[g.senders] != assignment[g.receivers]])
        moved = 0
        for v in boundary:
            pv = assignment[v]
            if sizes[pv] <= max(1, lo[pv]):
                continue
            nbrs = indices[indptr[v]:indptr[v + 1]]
            if nbrs.size == 0:
                continue
            counts = np.bincount(assignment[nbrs], minlength=n)
            internal = counts[pv]
            counts[pv] = -1
            best = int(np.argmax(counts))
            gain = counts[best] - internal
            if gain > 0 and sizes[best] < hi[best]:
                assignment[v] = best
                sizes[pv] -= 1
                sizes[best] += 1
                moved += 1
        if moved == 0:
            break
    return assignment


def bgp(g: Graph, n: int, weights: Optional[np.ndarray] = None,
        seed: int = 0, refine_passes: int = 4) -> np.ndarray:
    """Partition ``g`` into ``n`` parts; returns int64[|V|] assignment.

    ``weights`` (optional, len n, sums to ~1) sets per-partition capacity
    fractions — used by IEP re-planning when partitions should be sized to
    heterogeneous capability rather than uniformly.
    """
    if n <= 1:
        return np.zeros(g.num_vertices, dtype=np.int64)
    if n > g.num_vertices:
        raise ValueError(f"n={n} > |V|={g.num_vertices}")
    rng = np.random.default_rng(seed)
    if weights is None:
        weights = np.full(n, 1.0 / n)
    weights = np.asarray(weights, np.float64)
    weights = weights / weights.sum()
    capacity = np.maximum(1, np.ceil(weights * g.num_vertices)).astype(np.int64)
    assignment = _region_grow(g, n, capacity, rng)
    assignment = _refine(g, assignment, capacity, passes=refine_passes)
    return assignment


PARTITIONERS.register("bgp", bgp)


try:
    import pymetis as _pymetis
except ImportError:   # optional dependency; the registry entry is gated
    _pymetis = None


def metis(g: Graph, n: int, weights: Optional[np.ndarray] = None,
          seed: int = 0, **_ignored) -> np.ndarray:
    """Real METIS k-way partitioning via ``pymetis`` (optional dep).

    The paper's own implementation delegates BGP to METIS; this entry is
    registered only when ``pymetis`` is importable, so offline containers
    keep the pure-numpy ``bgp`` stand-in as the default.  ``weights``
    (heterogeneity-aware capacity fractions) are forwarded as METIS target
    partition weights when the installed pymetis supports ``tpwgts``;
    otherwise METIS balances uniformly and IEP's LBAP mapping still
    absorbs fog heterogeneity.  ``seed`` is accepted for signature parity
    but METIS's own randomization is not reseeded.
    """
    if _pymetis is None:
        raise ImportError("partitioner 'metis' needs the optional pymetis "
                          "package; pip install pymetis or use 'bgp'")
    if n <= 1:
        return np.zeros(g.num_vertices, dtype=np.int64)
    if n > g.num_vertices:
        raise ValueError(f"n={n} > |V|={g.num_vertices}")
    xadj = np.asarray(g.indptr, np.int64)
    adjncy = np.asarray(g.indices, np.int64)
    kw = {}
    if weights is not None:
        w = np.asarray(weights, np.float64)
        kw["tpwgts"] = list(w / w.sum())
    try:
        _, membership = _pymetis.part_graph(n, xadj=xadj, adjncy=adjncy,
                                            **kw)
    except TypeError:   # older pymetis without tpwgts support
        _, membership = _pymetis.part_graph(n, xadj=xadj, adjncy=adjncy)
    return np.asarray(membership, dtype=np.int64)


if _pymetis is not None:
    PARTITIONERS.register("metis", metis)


def partition_stats(g: Graph, assignment: np.ndarray) -> dict:
    n = int(assignment.max()) + 1
    sizes = np.bincount(assignment, minlength=n)
    return {
        "sizes": sizes,
        "edge_cut": edge_cut(g, assignment),
        "cut_fraction": edge_cut(g, assignment) / max(1, g.num_edges),
        "imbalance": float(sizes.max() / max(1.0, g.num_vertices / n)),
    }
