"""Fog/cloud serving latency simulation (paper §II-C methodology).

The container has no LAN/WAN or heterogeneous machines, so the measurement
study and all latency/throughput benchmarks run on an analytic simulator
whose constants are calibrated to reproduce the paper's *reported ratios*
(Fig. 3: 64/67/61% collection reduction fog vs cloud; ~1.65/1.73/1.40x
single-fog speedups; cloud execution <2% of its pipeline; straw-man
multi-fog exec ~= 67% of single-fog).

Node types A/B/C follow Table II (A is ~37.8% slower than B per §IV-A;
C is the most powerful). Network constants model effective *collection*
bandwidth; NSA 5G uplink is the weakest (hence the paper's largest fog
speedup on 5G), WiFi the strongest.

Everything downstream (IEP, scheduler, benchmarks) consumes this module via
``FogSpec`` latency models, and the *ground truth* execution cost uses the
same analytic workload formula with the true capability — so planner error
vs. reality stays representative.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import compression
from repro.core.placement import FogSpec, Placement
from repro.core.profiler import (LatencyModel, analytic_measurer,
                                 cardinality_of, profile_node_analytic)
from repro.gnn.graph import Graph

# ----------------------------------------------------------------------------
# Hardware / network constants (calibrated to paper ratios)
# ----------------------------------------------------------------------------

# Effective sustained GNN throughput (flop/s) per node type. Table II gives
# i7-6700 (A: 4GB, memory-bound; B: 8GB) and Xeon W-2145 (C).
NODE_CAPABILITY = {
    "A": 1.20e8,
    "B": 1.90e8,   # A is ~37% slower than B (paper §IV-A: 37.8%)
    "C": 3.20e8,
    "cloud": 5.0e10,  # Tesla V100 instance
}

# Effective aggregate data-collection bandwidth, bytes/s.
#   wan: devices -> remote cloud (Internet);  lan: devices -> local fogs.
NETWORKS = {
    "4g":   dict(wan=2.40e6, lan=5.00e6),   # fog collect ~36% of cloud
    "5g":   dict(wan=1.92e6, lan=4.36e6),   # ~33% (67% cut)
    "wifi": dict(wan=4.80e6, lan=9.23e6),   # ~39% (61% cut)
}

# Long-tail collection (paper SSVI "long-tail distribution of data
# collection time", SSII-C "GNN execution is obliged to wait until all
# correlated data points arrive"): the slowest of V device uploads grows
# ~ln(V); WAN tails are an order of magnitude heavier than LAN.
WAN_TAIL_S = 0.12
LAN_TAIL_S = 0.015

# Uncompressible per-vertex transport overhead (headers, ids, timestamps).
PROTOCOL_BYTES_PER_VERTEX = 24.0

DECOMPRESS_BYTES_PER_S = 200e6   # zlib inflate on fog CPU
QUANTIZE_OVERHEAD_S = 2e-3       # device-side packing (parallelized, §III-D)
DEFAULT_SYNC_COST = 0.10         # delta: one BSP synchronization (LAN round)
CLOUD_RTT = 0.05


# Per-fog allocated-bandwidth diversity (paper SSII: "their available
# bandwidth allocated for serving also vary"): weak gateways sit on slower
# uplinks than cloudlets. Factors are relative to the per-fog fair share.
BANDWIDTH_FACTOR = {"A": 0.6, "B": 1.0, "C": 1.5}


@dataclasses.dataclass
class SimNode:
    name: str
    node_type: str
    capability: float          # true flop/s (ground truth)
    background_load: float = 0.0   # >=0; effective = capability/(1+load)

    @property
    def effective_capability(self) -> float:
        return self.capability / (1.0 + self.background_load)

    @property
    def bandwidth_factor(self) -> float:
        return BANDWIDTH_FACTOR.get(self.node_type, 1.0)


def parse_cluster_spec(spec: str) -> List[str]:
    """"1A+4B+1C" -> ['A','B','B','B','B','C']."""
    out = []
    for term in spec.split("+"):
        term = term.strip()
        count, t = int(term[:-1]), term[-1].upper()
        out.extend([t] * count)
    return out


def multi_access_bandwidth(lan: float, n: int) -> float:
    """Per-fog collection bandwidth with n access points: more fogs widen
    total bandwidth sub-linearly (paper §II-C: 'widens the bandwidth and
    relieves the networking contention')."""
    total = lan * (1.0 + 0.25 * (n - 1))
    return total / n


def exec_flops(card, feature_dim: int, hidden: int, k_layers: int) -> float:
    """Workload model shared by profiler and ground truth: per layer,
    update matmuls ~ 2 V F H, aggregation ~ 8 |N_V| F."""
    v, nv = card
    return k_layers * (2.0 * v * feature_dim * hidden + 8.0 * nv * feature_dim)


@dataclasses.dataclass
class FogCluster:
    nodes: List[SimNode]
    network: str
    graph: Graph
    feature_dim: int
    hidden: int
    k_layers: int
    sync_cost: float = DEFAULT_SYNC_COST
    profile_noise: float = 0.03

    def lan_bandwidth_per_fog(self) -> float:
        return multi_access_bandwidth(NETWORKS[self.network]["lan"],
                                      len(self.nodes))

    def ground_truth_exec(self, node: SimNode, vertex_ids: np.ndarray) -> float:
        card = cardinality_of(self.graph, vertex_ids)
        return (exec_flops(card, self.feature_dim, self.hidden, self.k_layers)
                / node.effective_capability + 1e-4)

    def node_bandwidth(self, node: SimNode) -> float:
        """Per-fog allocated bandwidth (fair share x type diversity,
        renormalized so the cluster total is unchanged)."""
        base = self.lan_bandwidth_per_fog()
        mean_f = np.mean([n.bandwidth_factor for n in self.nodes])
        return base * node.bandwidth_factor / mean_f

    def fog_specs(self, seed: int = 0) -> List[FogSpec]:
        """Profile every node (offline phase) and register metadata."""
        specs = []
        for j, node in enumerate(self.nodes):
            rng = np.random.default_rng(seed + 1000 + j)

            def measure_c(c, _cap=node.capability, _rng=rng):
                t = (exec_flops(c, self.feature_dim, self.hidden,
                                self.k_layers) / _cap + 1e-4)
                if self.profile_noise:
                    t *= float(1.0 + _rng.normal(scale=self.profile_noise))
                return max(t, 1e-9)

            model = profile_node_analytic(self.graph, measure_c, seed=seed + j)
            specs.append(FogSpec(name=node.name,
                                 bandwidth_bytes_per_s=self.node_bandwidth(
                                     node),
                                 latency_model=model))
        return specs


def make_cluster(spec: str, network: str, graph: Graph, *, hidden: int = 64,
                 k_layers: int = 2, seed: int = 0,
                 sync_cost: float = DEFAULT_SYNC_COST) -> FogCluster:
    types = parse_cluster_spec(spec)
    nodes = [SimNode(name=f"fog{j}({t})", node_type=t,
                     capability=NODE_CAPABILITY[t])
             for j, t in enumerate(types)]
    return FogCluster(nodes=nodes, network=network, graph=graph,
                      feature_dim=graph.feature_dim, hidden=hidden,
                      k_layers=k_layers, sync_cost=sync_cost)


# ----------------------------------------------------------------------------
# Serving pipelines (latency + throughput accounting)
# ----------------------------------------------------------------------------

def _norm_compress(compress: Optional[str]) -> Optional[str]:
    """The registry's explicit "none" key means the same as None here."""
    return None if compress in (None, "none") else compress


def _partition_wire_bytes(g: Graph, vertex_ids: np.ndarray,
                          compress: Optional[str]) -> float:
    overhead = len(vertex_ids) * PROTOCOL_BYTES_PER_VERTEX
    raw = len(vertex_ids) * g.feature_dim * 8.0 + overhead
    if compress is None or len(vertex_ids) == 0:
        return raw
    feats = g.features[vertex_ids].astype(np.float64)
    degs = g.degrees[vertex_ids]
    if compress == "daq":
        return overhead + float(compression.daq_pack(feats, degs).nbytes(True))
    if compress == "daq_lz4":    # DAQ with the paper's LZ4 lossless stage
        return overhead + float(
            compression.daq_pack(feats, degs, codec="lz4").nbytes(True))
    if compress == "daq_noll":   # DAQ without the lossless stage
        return overhead + float(compression.daq_pack(feats, degs, lossless=False)
                                .nbytes(False))
    if compress == "uniform8":
        return overhead + float(compression.uniform_pack(feats, 8).nbytes(True))
    raise ValueError(compress)


@dataclasses.dataclass
class ServingResult:
    collect: np.ndarray      # per fog
    execute: np.ndarray      # per fog (incl. sync)
    unpack: np.ndarray       # per fog (pipelined; reported separately)
    total_latency: float
    throughput: float        # pipelined steady-state inferences/s
    wire_bytes: float

    def breakdown(self) -> Dict[str, float]:
        per_fog = self.collect + self.execute
        j = int(np.argmax(per_fog))
        return {"collect": float(self.collect[j]),
                "execute": float(self.execute[j]),
                "total": self.total_latency}


def simulate_cloud(cluster: FogCluster, *, compress: Optional[str] = None,
                   congestion: float = 1.0,
                   batch_size: int = 1) -> ServingResult:
    """De-facto cloud serving: full upload over WAN, fast datacenter GPU.

    ``batch_size`` > 1 prices a micro-batch of B coalesced queries: B full
    uploads share one WAN round-trip and one coalesced long-tail window
    (slowest of B*V uploads ~ ln(B*V)), and the GPU runs B inferences
    back-to-back with one launch overhead.
    """
    compress = _norm_compress(compress)
    b = int(batch_size)
    g = cluster.graph
    wan = NETWORKS[cluster.network]["wan"]
    all_v = np.arange(g.num_vertices)
    wire = _partition_wire_bytes(g, all_v, compress) * b
    tail = WAN_TAIL_S * np.log(max(b * g.num_vertices, 2))
    collect = wire / wan * congestion + CLOUD_RTT + tail
    cloud = SimNode("cloud", "cloud", NODE_CAPABILITY["cloud"])
    exec_t = (b * exec_flops((g.num_vertices, 0), cluster.feature_dim,
                             cluster.hidden, cluster.k_layers)
              / cloud.effective_capability + 5e-3)
    unpack = wire / DECOMPRESS_BYTES_PER_S if compress else 0.0
    total = collect + exec_t + unpack
    return ServingResult(np.array([collect]), np.array([exec_t]),
                         np.array([unpack]), total,
                         b / max(collect, exec_t + unpack), wire)


def simulate_single_fog(cluster: FogCluster, *,
                        compress: Optional[str] = None,
                        batch_size: int = 1) -> ServingResult:
    """Single most-powerful fog node executes everything (paper §II-C)."""
    compress = _norm_compress(compress)
    b = int(batch_size)
    g = cluster.graph
    lan = NETWORKS[cluster.network]["lan"]
    best = max(cluster.nodes, key=lambda nd: nd.effective_capability)
    all_v = np.arange(g.num_vertices)
    wire = _partition_wire_bytes(g, all_v, compress) * b
    collect = wire / lan + LAN_TAIL_S * np.log(max(b * g.num_vertices, 2))
    exec_t = b * cluster.ground_truth_exec(best, all_v)
    unpack = wire / DECOMPRESS_BYTES_PER_S if compress else 0.0
    total = collect + exec_t + unpack
    return ServingResult(np.array([collect]), np.array([exec_t]),
                         np.array([unpack]), total,
                         b / max(collect, exec_t + unpack), wire)


def simulate_multi_fog(cluster: FogCluster, placement: Placement, *,
                       compress: Optional[str] = None,
                       batch_size: int = 1,
                       sync_scale: float = 1.0) -> ServingResult:
    """Distributed BSP serving under a data placement (straw-man or IEP).

    Latency = max_j (collect_j + exec_j) + K*delta sync (Eq. 6/7); unpack is
    pipelined on a separate thread (§III-D) and overlaps execution, so only
    its non-overlapped remainder counts.

    ``batch_size`` > 1 prices a micro-batch of B coalesced queries (§III-D
    micro-batching): each fog collects B feature uploads in one window —
    paying the device-side packing overhead once and one coalesced
    long-tail (slowest of B*|V_j| uploads ~ ln(B*|V_j|)) — then runs one
    batched BSP superstep whose per-layer synchronizations carry all B
    feature sets, so the K*delta sync cost is paid once per batch instead
    of once per query.

    ``sync_scale`` scales the K*delta per-layer synchronization term: a
    stale-tolerant ``halo_async`` serve that replays recorded halo tables
    never stalls a superstep on the exchange, so it is priced at
    ``sync_scale=0.0`` (the whole point of the mode on WAN-separated
    sites); 1.0 is the synchronous exchange.
    """
    if not 0.0 <= sync_scale <= 1.0:
        raise ValueError(f"sync_scale must be in [0, 1], got {sync_scale}")
    compress = _norm_compress(compress)
    b = int(batch_size)
    g = cluster.graph
    n = len(cluster.nodes)
    collect = np.zeros(n)
    exec_t = np.zeros(n)
    unpack = np.zeros(n)
    wire_total = 0.0
    for j, node in enumerate(cluster.nodes):
        mine = np.flatnonzero(placement.assignment == j)
        if mine.size == 0:
            continue
        wire = _partition_wire_bytes(g, mine, compress) * b
        wire_total += wire
        bw = cluster.node_bandwidth(node)
        collect[j] = (wire / bw + (QUANTIZE_OVERHEAD_S if compress else 0.0)
                      + LAN_TAIL_S * np.log(max(b * len(mine), 2)))
        exec_t[j] = (b * cluster.ground_truth_exec(node, mine)
                     + sync_scale * cluster.k_layers * cluster.sync_cost)
        unpack[j] = wire / DECOMPRESS_BYTES_PER_S if compress else 0.0
        # Pipelined unpack: only the part not hidden by execution adds.
        exec_t[j] += max(0.0, unpack[j] - exec_t[j]) * 0.0
    per_fog = collect + exec_t
    total = float(per_fog.max())
    throughput = b / max(collect.max(), exec_t.max())
    return ServingResult(collect, exec_t, unpack, total, throughput,
                         wire_total)


def simulate(pipeline: str, cluster: FogCluster,
             placement: Optional[Placement] = None, *,
             compress: Optional[str] = None,
             batch_size: int = 1,
             sync_scale: float = 1.0) -> ServingResult:
    """Dispatch the latency accounting for one serving pipeline.

    ``pipeline``: "cloud", "single" (most powerful fog) or "multi"
    (distributed BSP under ``placement``). Executor backends resolve their
    accounting through this single entry point. ``batch_size`` prices a
    micro-batch of coalesced queries (B=1 is one query and reproduces the
    unbatched numbers exactly). ``sync_scale`` scales the multi-fog
    pipeline's K*delta sync term (0.0 for a stale ``halo_async`` serve —
    no superstep stalls on the exchange); the single/cloud pipelines have
    no BSP sync and ignore it.
    """
    if batch_size < 1:
        raise ValueError(f"batch_size must be >= 1, got {batch_size}")
    if pipeline == "cloud":
        return simulate_cloud(cluster, compress=compress,
                              batch_size=batch_size)
    if pipeline == "single":
        return simulate_single_fog(cluster, compress=compress,
                                   batch_size=batch_size)
    if pipeline == "multi":
        if placement is None:
            raise ValueError("pipeline 'multi' needs a placement")
        return simulate_multi_fog(cluster, placement, compress=compress,
                                  batch_size=batch_size,
                                  sync_scale=sync_scale)
    raise ValueError(f"unknown pipeline {pipeline!r}; "
                     "available: cloud, multi, single")


# ----------------------------------------------------------------------------
# Two-stage collect/execute pipeline (paper §III-D "parallelized
# data collection": query i+1's compressed collection overlaps query i's
# execution on the fogs)
# ----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchSchedule:
    """Timeline of one micro-batch through the two-stage pipeline.

    The collection stage (shared uplink + unpack threads) and the
    execution stage (fog CPUs + BSP syncs) are each serially reusable, so
    batch k's collection may overlap batch k-1's execution but two batches
    never collect (or execute) concurrently. ``overlap_saved`` is the time
    this batch's collection ran concurrently with the previous batch's
    execution — the §III-D pipelining win.
    """
    ready: float
    collect_start: float
    collect_end: float
    execute_start: float
    execute_end: float
    overlap_saved: float = 0.0

    @property
    def queue_delay(self) -> float:
        return self.collect_start - self.ready

    @property
    def span(self) -> float:
        return self.execute_end - self.collect_start


def pipeline_schedule(batches: Sequence[Tuple[float, float, float]],
                      *, pipelined: bool = True,
                      start: Tuple[float, float, float] = (0.0, 0.0, 0.0)
                      ) -> List[BatchSchedule]:
    """Schedule ``(ready, collect, execute)`` stage times through the
    two-stage pipeline; returns one :class:`BatchSchedule` per batch.

    ``pipelined=False`` reproduces the strictly serial loop (batch k's
    collection waits for batch k-1's execution to finish) — the
    ``Session.stream`` baseline the pipelined server is measured against.

    ``start`` is ``(collect_free, execute_free, prev_execute_start)``
    resource state, so callers (the ``Server``) can schedule batches
    incrementally in O(1) each: feed ``schedule_state(sched[-1])`` of one
    call as the ``start`` of the next.
    """
    out: List[BatchSchedule] = []
    collect_free, execute_free, prev_e_start = start
    for ready, c_t, e_t in batches:
        floor = collect_free if pipelined else max(collect_free, execute_free)
        c_start = max(ready, floor)
        c_end = c_start + c_t
        e_start = max(c_end, execute_free)
        e_end = e_start + e_t
        # Intersection of this collect window with the previous execute
        # window: the collection time hidden behind execution.
        overlap = max(0.0, min(c_end, execute_free) - max(c_start,
                                                          prev_e_start))
        out.append(BatchSchedule(ready, c_start, c_end, e_start, e_end,
                                 overlap))
        collect_free, execute_free, prev_e_start = c_end, e_end, e_start
    return out


def schedule_state(sched: BatchSchedule) -> Tuple[float, float, float]:
    """Resource state after ``sched``, for ``pipeline_schedule(start=...)``."""
    return (sched.collect_end, sched.execute_end, sched.execute_start)


# ----------------------------------------------------------------------------
# Dynamic-graph update pricing (the serving control plane's admission input)
# ----------------------------------------------------------------------------

# Fixed control overhead of one repair: delta folding, placement bookkeeping,
# and the repartitioner's greedy pass — independent of delta size.
UPDATE_BASE_S = 0.02
# Rebuild work per touched vertex/edge, in flop-equivalents priced against
# the cluster's mean capability: dirty-shard block-CSR re-packing reads each
# touched vertex's feature row and each touched edge's adjacency entry a
# small constant number of times.
UPDATE_VERTEX_FLOPS = 64.0
UPDATE_EDGE_FLOPS = 16.0


def simulate_update(cluster: FogCluster, delta) -> float:
    """Price one graph-delta repair on the simulated serving clock.

    ``delta`` is any object with the :class:`repro.api.updates.GraphDelta`
    shape accessors (``num_added_vertices``, ``remove_vertices``,
    ``add_edges``, ``remove_edges``, ``feature_ids``, ``is_structural``) —
    duck-typed so this core module stays import-free of ``repro.api``.

    The price mirrors the incremental-repair stages: (a) fixed control
    overhead, (b) uploading new/updated feature rows over the LAN,
    (c) dirty-shard rebuild compute on the cluster's mean-capability fog,
    and (d) one BSP synchronization round when the delta is structural
    (repartition + halo table swap must quiesce the superstep). Updates
    serialize with execution in the ``Server``'s pipeline, so this is the
    time the execution stage is blocked.

    ``cluster`` must be the cluster the repair actually runs on: after a
    node failover the caller threads the SURVIVING ``FogCluster``
    (``plan.cluster`` of the failover plan) through, so the
    mean-capability term reflects degraded capacity rather than the
    original fleet.
    """
    g = cluster.graph
    touched_v = (delta.num_added_vertices + delta.num_removed_vertices
                 + len(delta.feature_ids))
    touched_e = len(delta.add_edges) + len(delta.remove_edges)
    uploads = delta.num_added_vertices + len(delta.feature_ids)
    wire = uploads * (g.feature_dim * 8.0 + PROTOCOL_BYTES_PER_VERTEX)
    collect = wire / NETWORKS[cluster.network]["lan"]
    mean_cap = float(np.mean([n.effective_capability
                              for n in cluster.nodes]))
    rebuild = (UPDATE_VERTEX_FLOPS * touched_v * g.feature_dim
               + UPDATE_EDGE_FLOPS * touched_e) / mean_cap
    sync = cluster.sync_cost if delta.is_structural else 0.0
    return UPDATE_BASE_S + collect + rebuild + sync


# ----------------------------------------------------------------------------
# Fault-recovery pricing (the node-level fault-tolerance tiers)
# ----------------------------------------------------------------------------

# Tier 1 — transient halo-exchange loss: every failed sync round costs the
# wasted round itself (one delta) plus an exponentially growing backoff
# before the retry, truncated by the attempt budget and the hard timeout.
RETRY_BACKOFF_BASE_S = 0.02
RETRY_BACKOFF_MULT = 2.0
RETRY_MAX_ATTEMPTS = 4
RETRY_TIMEOUT_S = 1.0

# Tier 3 — shard failover: fixed control overhead of the replan (evict +
# greedy re-place + placement re-pricing) plus re-uploading each moved
# vertex's feature row to its new fog over the LAN, plus the rebuild on the
# SURVIVING cluster's mean capability (degraded-capacity pricing), plus one
# quiescing sync round while the layout swaps.
FAILOVER_BASE_S = 0.05


def simulate_retry(losses: int, *, sync_cost: float = DEFAULT_SYNC_COST,
                   base: float = RETRY_BACKOFF_BASE_S,
                   mult: float = RETRY_BACKOFF_MULT,
                   max_attempts: int = RETRY_MAX_ATTEMPTS,
                   timeout: float = RETRY_TIMEOUT_S
                   ) -> Tuple[float, int, bool]:
    """Price recovering ``losses`` consecutive transient exchange losses.

    Attempt ``k`` (0-based) fails, costing the wasted sync round plus a
    ``base * mult**k`` backoff; after ``losses`` failed attempts the next
    retry succeeds (its cost is the normal sync already in the serving
    account). Returns ``(recovery_seconds, attempts_made, succeeded)`` —
    ``succeeded`` is False when the attempt budget or the timeout would be
    exceeded first (the caller escalates to the next recovery tier, paying
    the time spent so far). Fully deterministic.
    """
    losses = int(losses)
    if losses < 0:
        raise ValueError(f"losses must be >= 0, got {losses}")
    t = 0.0
    for k in range(losses):
        if k >= max_attempts:
            return t, k, False
        step = sync_cost + base * mult ** k
        if t + step > timeout + 1e-12:
            return t, k, False
        t += step
    return t, losses, True


def simulate_failover(cluster: FogCluster, moved_vertices: int,
                      feature_dim: Optional[int] = None) -> float:
    """Price one shard failover on the simulated serving clock.

    ``cluster`` is the SURVIVING cluster (the failover plan's — degraded
    capacity prices the rebuild, same threading rule as
    :func:`simulate_update`); ``moved_vertices`` how many vertices the
    crashed node held (each re-uploads one feature row and re-packs its
    shard entries). Occupies the Server pipeline's execution stage, like
    an update repair.
    """
    if feature_dim is None:
        feature_dim = cluster.feature_dim
    wire = moved_vertices * (feature_dim * 8.0 + PROTOCOL_BYTES_PER_VERTEX)
    collect = wire / NETWORKS[cluster.network]["lan"]
    mean_cap = float(np.mean([n.effective_capability
                              for n in cluster.nodes]))
    rebuild = UPDATE_VERTEX_FLOPS * moved_vertices * feature_dim / mean_cap
    return FAILOVER_BASE_S + collect + rebuild + cluster.sync_cost


def apply_load_trace(cluster: FogCluster, loads: Sequence[float]) -> None:
    for node, load in zip(cluster.nodes, loads):
        node.background_load = float(load)


def measured_exec_times(cluster: FogCluster, placement: Placement) -> np.ndarray:
    """T_real per fog under current background loads (online profiler input)."""
    out = np.zeros(len(cluster.nodes))
    for j, node in enumerate(cluster.nodes):
        mine = np.flatnonzero(placement.assignment == j)
        if mine.size:
            out[j] = (cluster.ground_truth_exec(node, mine)
                      + cluster.k_layers * cluster.sync_cost)
    return out
