"""Incremental repartitioning for mutating graphs (ROADMAP "Dynamic graphs").

Full ``Engine.compile`` re-runs the whole setup phase — fog profiling, BGP
partitioning, IEP mapping, and (on the kernel path) pre-blocking every
shard's adjacency — for any topology change.  This module implements the
repair path instead:

  1. ``mutate_graph``        apply a :class:`~repro.api.updates.GraphDelta`
                             to a Graph, producing the mutated graph and an
                             old-id -> new-id map.
  2. ``repair_assignment``   greedy min-cut-aware placement of new vertices
                             into the *existing* partitions: each new vertex
                             joins the partition holding the plurality of
                             its already-placed neighbors, subject to a
                             per-partition capacity bound (survivors never
                             move, so clean shards stay bit-identical).
  3. ``dirty_partitions``    conservative dirty-shard tracking: which
                             partitions' local / halo block-CSR operands the
                             delta invalidated.  Everything cheap (padded
                             COO buffers, masks, boundary packing) is always
                             recomputed; only the expensive per-shard
                             pre-blocking consults these sets (see
                             ``bsp.build_partitioned(prev=...)``).
  4. ``plan_delta``          fold a sequence of deltas over (graph,
                             assignment), unioning dirty sets — the
                             coalescing primitive behind the Session's
                             deferred-update policy.
  5. ``refresh_placement``   re-price the repaired placement with the
                             plan's already-profiled fog latency models, so
                             simulation / scheduler see honest numbers
                             without re-profiling.

The decision to *not* repair — imbalance or edge-cut degradation beyond a
threshold — is taken by ``Engine.apply_delta``, which falls back to the
full compile pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.api.updates import GraphDelta
from repro.core.placement import FogSpec, Placement, _finish
from repro.gnn.graph import Graph, edge_cut, from_edge_list


# ----------------------------------------------------------------------------
# Graph mutation
# ----------------------------------------------------------------------------

def mutate_graph(g: Graph, delta: GraphDelta) -> Tuple[Graph, np.ndarray]:
    """Apply ``delta`` to ``g``; returns ``(new_graph, vmap)``.

    ``vmap`` has ``g.num_vertices + delta.num_added_vertices`` entries
    mapping old ids (and the ``V+i`` aliases of new vertices) to new ids;
    removed vertices map to ``-1``.  Survivors keep their relative order
    and new vertices are appended, so untouched partitions keep identical
    slot layouts — the property dirty-shard reuse rests on.
    """
    delta.validate(g.num_vertices, g.feature_dim)
    v_old, k = g.num_vertices, delta.num_added_vertices
    keep = np.ones(v_old, bool)
    keep[delta.remove_vertices] = False
    n_kept = int(keep.sum())

    vmap = -np.ones(v_old + k, np.int64)
    vmap[:v_old][keep] = np.arange(n_kept)
    vmap[v_old:] = n_kept + np.arange(k)
    v_new = n_kept + k

    # Old directed edges, minus removals. Keeping the original order (old
    # edges first, additions appended) keeps untouched shards' edge
    # subsequences — hence their block-CSR operands — bit-identical.
    s, r = g.senders.astype(np.int64), g.receivers.astype(np.int64)
    alive = keep[s] & keep[r]
    if len(delta.remove_edges):
        eid = s * v_old + r
        rem = delta.remove_edges
        rem_keys = np.concatenate([rem[:, 0] * v_old + rem[:, 1],
                                   rem[:, 1] * v_old + rem[:, 0]])
        alive &= ~np.isin(eid, rem_keys)
    edges = np.stack([vmap[s[alive]], vmap[r[alive]]], axis=1)
    if len(delta.add_edges):
        add = vmap[delta.add_edges]
        # Vertex removal wins over edge addition within one delta: an
        # added edge touching a removed vertex is dropped, like every
        # other edge incident to it.
        add = add[(add >= 0).all(axis=1)]
        add = np.concatenate([add, add[:, ::-1]], axis=0)  # both directions
        edges = np.concatenate([edges, add], axis=0)

    feats = g.features[keep]
    if k:
        feats = np.concatenate([feats, delta.add_features], axis=0)
    if len(delta.feature_ids):
        feats = feats.copy()
        feats[vmap[delta.feature_ids]] = delta.feature_values

    labels = positions = None
    if g.labels is not None:
        new_l = (np.zeros(k, g.labels.dtype) if delta.add_labels is None
                 else np.asarray(delta.add_labels, g.labels.dtype))
        labels = np.concatenate([g.labels[keep], new_l])
    if g.positions is not None:
        new_p = (np.zeros((k,) + g.positions.shape[1:], g.positions.dtype)
                 if delta.add_positions is None
                 else np.asarray(delta.add_positions, g.positions.dtype))
        positions = np.concatenate([g.positions[keep], new_p], axis=0)

    # from_edge_list dedups with first-occurrence order and drops self
    # loops; both directions are already present, so undirected=False.
    g_new = from_edge_list(v_new, edges, feats, labels, positions,
                           undirected=False)
    return g_new, vmap


# ----------------------------------------------------------------------------
# Localized partition repair
# ----------------------------------------------------------------------------

def repair_assignment(g_new: Graph, assignment: np.ndarray, n: int, *,
                      capacity: Optional[np.ndarray] = None,
                      tol: float = 0.10) -> np.ndarray:
    """Greedy min-cut-aware placement of unassigned vertices.

    ``assignment`` is int64[|V_new|] with ``-1`` marking new vertices;
    survivors keep their partition.  Each new vertex (in id order — new
    vertices may neighbor each other) joins the partition that already
    holds most of its neighbors, provided that partition is below
    ``capacity * (1 + tol)``; vertices with no placed neighbors, or whose
    plurality partition is full, go to the least-loaded partition relative
    to capacity.  ``capacity`` defaults to the current partition sizes
    scaled to the new vertex count (preserving IEP's heterogeneity-aware
    sizing), with a uniform floor for empty partitions.
    """
    assignment = np.asarray(assignment, np.int64).copy()
    new_ids = np.flatnonzero(assignment < 0)
    if new_ids.size == 0:
        return assignment
    sizes = np.bincount(assignment[assignment >= 0], minlength=n).astype(
        np.float64)
    if capacity is None:
        frac = (sizes + 1.0) / (sizes + 1.0).sum()
        capacity = frac * g_new.num_vertices
    cap_hi = np.maximum(np.asarray(capacity, np.float64) * (1.0 + tol), 1.0)
    indptr, indices = g_new.indptr, g_new.indices
    for v in new_ids:
        nbr_parts = assignment[indices[indptr[v]:indptr[v + 1]]]
        nbr_parts = nbr_parts[nbr_parts >= 0]
        p = -1
        if nbr_parts.size:
            counts = np.bincount(nbr_parts, minlength=n).astype(np.float64)
            counts[sizes >= cap_hi] = -1.0   # full partitions ineligible
            if counts.max() > 0:
                p = int(np.argmax(counts))
        if p < 0:
            p = int(np.argmin(sizes / np.maximum(cap_hi, 1e-12)))
        assignment[v] = p
        sizes[p] += 1
    return assignment


def imbalance_of(assignment: np.ndarray, n: int) -> float:
    """max partition size over the uniform share (1.0 = perfectly even)."""
    sizes = np.bincount(assignment, minlength=n)
    return float(sizes.max() / max(1.0, len(assignment) / n))


def evacuate_assignment(assignment: np.ndarray, keep: Sequence[int],
                        n_old: int) -> np.ndarray:
    """Renumber an assignment onto the surviving partitions.

    ``keep`` lists the surviving old partition indices in their new
    order; every vertex on a surviving partition maps to that
    partition's new index (``0 .. len(keep)-1``), every vertex on an
    evicted partition becomes ``-1`` — exactly the shape
    :func:`repair_assignment` re-places. This is the shard-failover
    front half: evacuate, then repair onto the survivors.
    """
    keep = np.asarray(list(keep), np.int64)
    if keep.size == 0:
        raise ValueError("evacuate_assignment needs >= 1 survivor")
    if keep.size != np.unique(keep).size:
        raise ValueError(f"duplicate survivor indices in {keep.tolist()}")
    if keep.min() < 0 or keep.max() >= n_old:
        raise ValueError(f"survivor indices {keep.tolist()} out of range "
                         f"for {n_old} partitions")
    newidx = -np.ones(n_old, np.int64)
    newidx[keep] = np.arange(keep.size)
    assignment = np.asarray(assignment, np.int64)
    if assignment.size and (assignment.min() < 0
                            or assignment.max() >= n_old):
        raise ValueError("assignment references partitions outside "
                         f"[0, {n_old})")
    return newidx[assignment]


# ----------------------------------------------------------------------------
# Dirty-shard tracking
# ----------------------------------------------------------------------------

def dirty_partitions(g_old: Graph, a_old: np.ndarray, g_new: Graph,
                     a_new: np.ndarray, vmap: np.ndarray,
                     delta: GraphDelta, n: int
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Which partitions' (local, halo) block-CSR operands ``delta`` dirtied.

    Conservative (a clean verdict is a guarantee, a dirty one merely
    skips reuse):

      * partitions that gained/lost members are dirty on both operands and
        boundary-suspect (their slot layout shifted);
      * an added/removed edge (including edges that died with a removed
        vertex) dirties the *receiver's* partition — its local operand for
        intra-partition edges, its halo operand for cross edges; the
        sender's partition only becomes boundary-suspect (its own operands
        don't list the edge, but its boundary row set may change);
      * every boundary-suspect partition dirties the halo operand of every
        partition that still reads rows from it — the gathered halo
        table's row positions shifted for those readers.
    """
    member_dirty = set(int(p) for p in np.unique(a_old[delta.remove_vertices])
                       ) if len(delta.remove_vertices) else set()
    if delta.num_added_vertices:
        member_dirty |= set(
            int(p) for p in np.unique(a_new[vmap[g_old.num_vertices:]]))

    dirty_local = set(member_dirty)
    dirty_halo = set(member_dirty)
    boundary_suspect = set(member_dirty)

    def touch_edges(sp: np.ndarray, rp: np.ndarray) -> None:
        same = sp == rp
        dirty_local.update(int(p) for p in np.unique(rp[same]))
        dirty_halo.update(int(p) for p in np.unique(rp[~same]))
        boundary_suspect.update(int(p) for p in np.unique(sp[~same]))

    # Edges that died with removed vertices (both stored directions of an
    # undirected edge appear, so each surviving endpoint is seen as the
    # receiver of one of them).
    if len(delta.remove_vertices):
        gone = np.zeros(g_old.num_vertices, bool)
        gone[delta.remove_vertices] = True
        hit = gone[g_old.senders] | gone[g_old.receivers]
        touch_edges(a_old[g_old.senders[hit]], a_old[g_old.receivers[hit]])
    # Explicit edge removals (old-id space) and additions (mapped); both
    # directions of each undirected pair.
    if len(delta.remove_edges):
        u, v = delta.remove_edges[:, 0], delta.remove_edges[:, 1]
        touch_edges(a_old[np.concatenate([u, v])],
                    a_old[np.concatenate([v, u])])
    if len(delta.add_edges):
        add = vmap[delta.add_edges]
        add = add[(add >= 0).all(axis=1)]   # removal wins (see mutate_graph)
        if len(add):
            u, v = add[:, 0], add[:, 1]
            touch_edges(a_new[np.concatenate([u, v])],
                        a_new[np.concatenate([v, u])])

    # Halo propagation: readers of any boundary-suspect partition.
    cross = a_new[g_new.senders] != a_new[g_new.receivers]
    pairs = np.unique(
        a_new[g_new.senders[cross]] * n + a_new[g_new.receivers[cross]])
    for key in pairs:
        q, p = int(key // n), int(key % n)
        if q in boundary_suspect:
            dirty_halo.add(p)
    return (np.array(sorted(dirty_local), np.int64),
            np.array(sorted(dirty_halo), np.int64))


# ----------------------------------------------------------------------------
# Folding deltas + re-pricing
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class DeltaPlan:
    """Everything ``Engine.apply_delta`` needs to decide and rebuild."""
    graph: Graph
    assignment: np.ndarray
    dirty_local: np.ndarray
    dirty_halo: np.ndarray
    structural: bool
    counts: dict
    cut_fraction_before: float
    cut_fraction_after: float
    imbalance_before: float
    imbalance: float


def plan_delta(graph: Graph, assignment: np.ndarray,
               deltas: Sequence[GraphDelta], n: int, *,
               repair_tol: float = 0.10) -> DeltaPlan:
    """Fold ``deltas`` in order over (graph, assignment).

    Each delta addresses the graph produced by the previous one (the
    deferred-update contract).  Dirty sets are unioned, so one rebuild at
    the end covers the whole burst — the coalescing win of deferred mode.
    """
    assignment = np.asarray(assignment, np.int64)
    e0 = max(1, graph.num_edges)
    cut_before = edge_cut(graph, assignment) / e0
    g_cur, a_cur = graph, assignment
    dirty_l: set = set()
    dirty_h: set = set()
    counts = dict(added_vertices=0, removed_vertices=0, added_edges=0,
                  removed_edges=0, feature_upserts=0)
    structural = False
    for delta in deltas:
        if delta.is_empty:
            continue
        g_next, vmap = mutate_graph(g_cur, delta)
        if g_next.num_vertices < n:
            raise ValueError(
                f"delta leaves {g_next.num_vertices} vertices for {n} fog "
                f"partitions — cannot repair or recompile")
        mapped = -np.ones(g_next.num_vertices, np.int64)
        alive = vmap[:g_cur.num_vertices] >= 0
        mapped[vmap[:g_cur.num_vertices][alive]] = a_cur[alive]
        a_next = repair_assignment(g_next, mapped, n, tol=repair_tol)
        if delta.is_structural:
            structural = True
            dl, dh = dirty_partitions(g_cur, a_cur, g_next, a_next, vmap,
                                      delta, n)
            dirty_l |= set(int(p) for p in dl)
            dirty_h |= set(int(p) for p in dh)
        d = delta.describe()
        for key in counts:
            counts[key] += d[key]
        g_cur, a_cur = g_next, a_next
    cut_after = edge_cut(g_cur, a_cur) / max(1, g_cur.num_edges)
    return DeltaPlan(graph=g_cur, assignment=a_cur,
                     dirty_local=np.array(sorted(dirty_l), np.int64),
                     dirty_halo=np.array(sorted(dirty_h), np.int64),
                     structural=structural, counts=counts,
                     cut_fraction_before=float(cut_before),
                     cut_fraction_after=float(cut_after),
                     imbalance_before=imbalance_of(assignment, n),
                     imbalance=imbalance_of(a_cur, n))


def refresh_placement(g: Graph, assignment: np.ndarray,
                      mapping: np.ndarray, fogs: Sequence[FogSpec], *,
                      bytes_per_vertex: Optional[float] = None,
                      k_layers: int = 2, sync_cost: float = 5e-3
                      ) -> Placement:
    """Re-price a repaired assignment with already-profiled fog models.

    Rebuilds the ``Placement`` diagnostics (est_collect / est_exec per fog,
    Eq. 5/6) for the new graph without re-running BGP or LBAP — the
    partition -> fog ``mapping`` is inherited from the plan being repaired,
    so the simulator and the adaptive scheduler see costs that match the
    mutated topology.
    """
    if bytes_per_vertex is None:
        bytes_per_vertex = g.feature_dim * 8.0  # matches iep_place default
    mapping = np.asarray(mapping, np.int64)
    inv = np.zeros(len(mapping), np.int64)
    inv[mapping] = np.arange(len(mapping))
    partition_of = inv[assignment]
    parts = [np.flatnonzero(partition_of == k) for k in range(len(mapping))]
    return _finish(g, parts, mapping, fogs, bytes_per_vertex, k_layers,
                   sync_cost, partition_of)
