"""Communication optimizer (paper §III-D): degree-aware quantization (DAQ)
plus lossless sparsity elimination.

DAQ: vertices are binned by degree into four intervals <D1, D2, D3> and
their feature vectors linearly quantized to <q0, q1, q2, q3> bits
(default <64, 32, 16, 8>): high-degree vertices tolerate aggressive
quantization because aggregation smooths their error. Thm 2's closed-form
compression ratio is implemented and tested against measured bits.

Lossless stage: the paper uses LZ4 + bit shuffling. When the optional
``lz4`` package is importable, the ``"lz4"`` codec (and the ``daq_lz4``
COMPRESSORS entry) uses real LZ4 frames after the byte-shuffle filter;
otherwise requesting it falls back to the stdlib zlib codec with a
warning. The default stays zlib so wire-byte accounting is stable across
environments. The shuffle transposes the byte planes of fixed-width
elements, which groups the mostly-zero high bytes of sparse/quantized
features and greatly improves either entropy coder's ratio.
"""
from __future__ import annotations

import dataclasses
import warnings
import zlib
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

try:
    import lz4.frame as _lz4frame
except ImportError:   # optional dependency (paper's lossless stage)
    _lz4frame = None

DEFAULT_BITS = (64, 32, 16, 8)

#: Lossless codecs for the post-quantization payload. "auto" resolves to
#: lz4 when importable, else zlib.
LOSSLESS_CODECS = ("zlib", "lz4", "auto")


def resolve_lossless_codec(codec: str) -> str:
    """Resolve a LOSSLESS_CODECS name to an available concrete codec."""
    if codec not in LOSSLESS_CODECS:
        raise ValueError(f"unknown lossless codec {codec!r}; available: "
                         f"{', '.join(LOSSLESS_CODECS)}")
    if codec == "auto":
        return "lz4" if _lz4frame is not None else "zlib"
    if codec == "lz4" and _lz4frame is None:
        warnings.warn("lz4 requested for the lossless stage but the lz4 "
                      "package is not importable; falling back to zlib",
                      RuntimeWarning, stacklevel=3)
        return "zlib"
    return codec


def lossless_compress(payload: bytes, codec: str = "zlib"
                      ) -> Tuple[bytes, str]:
    """Compress the shuffled payload; returns (blob, concrete codec)."""
    codec = resolve_lossless_codec(codec)
    if codec == "lz4":
        return _lz4frame.compress(payload), "lz4"
    return zlib.compress(payload, level=6), "zlib"


# ----------------------------------------------------------------------------
# Degree binning
# ----------------------------------------------------------------------------

def equal_length_thresholds(degrees: np.ndarray) -> Tuple[int, int, int]:
    """Four equal-length intervals over [0, D_max]. On heavy-tailed degree
    distributions this puts nearly every vertex in the first (widest-bit)
    bin, so it compresses poorly; kept for completeness."""
    dmax = max(int(degrees.max()), 4)
    return (dmax // 4, dmax // 2, 3 * dmax // 4)


def quantile_thresholds(degrees: np.ndarray) -> Tuple[int, int, int]:
    """Quartile thresholds of the empirical degree distribution — our
    default reading of the paper's 'four equal-length intervals based on
    the input graph's degree distribution': equal *mass* per interval,
    which is the only reading that yields meaningful compression on the
    heavy-tailed graphs of Table III."""
    qs = np.quantile(degrees, [0.25, 0.5, 0.75]).astype(np.int64)
    d1 = max(1, int(qs[0]))
    d2 = max(d1, int(qs[1]))
    d3 = max(d2, int(qs[2]))
    return (d1, d2, d3)


def assign_bits(degrees: np.ndarray,
                thresholds: Optional[Tuple[int, int, int]] = None,
                bits: Sequence[int] = DEFAULT_BITS) -> np.ndarray:
    """Per-vertex target bitwidth by degree interval (Fig. 9)."""
    if thresholds is None:
        thresholds = quantile_thresholds(degrees)
    d1, d2, d3 = thresholds
    assert d1 <= d2 <= d3, thresholds
    out = np.full(degrees.shape, bits[0], dtype=np.int64)
    out[degrees >= d1] = bits[1]
    out[degrees >= d2] = bits[2]
    out[degrees >= d3] = bits[3]
    return out


def theorem2_ratio(degree_cdf: Callable[[np.ndarray], np.ndarray],
                   thresholds: Tuple[int, int, int],
                   bits: Sequence[int] = DEFAULT_BITS,
                   q_input: int = 64) -> float:
    """Thm 2: ratio = q3/Q - (1/Q) sum_i F_D(D_i) (q_i - q_{i-1}).

    NOTE on the interval convention: the closed form holds when F_D(D_i) is
    the fraction of vertices in bins 0..i-1, i.e. P(D < D_i). For integer
    degrees that's CDF(D_i - 1), matching ``assign_bits``'s half-open
    intervals [D_{i}, D_{i+1}).
    """
    q0, q1, q2, q3 = bits
    d = np.asarray(thresholds, np.int64)
    f = np.asarray(degree_cdf(d - 1), np.float64)
    total = q3 - (f[0] * (q1 - q0) + f[1] * (q2 - q1) + f[2] * (q3 - q2))
    return float(total) / q_input


# ----------------------------------------------------------------------------
# Linear quantization per vertex
# ----------------------------------------------------------------------------

# sub-byte widths store in uint8 (levels = 2^b - 1 still apply; a real wire
# format would bit-pack them — nbytes() accounts for the logical bits)
_STORE_DTYPE = {2: np.uint8, 4: np.uint8, 8: np.uint8, 16: np.uint16,
                32: np.uint32, 64: np.uint64}


def _quantize_rows(x: np.ndarray, nbits: int):
    """Row-wise linear quantization to ``nbits``. Returns (q, mins, scales)."""
    mins = x.min(axis=1, keepdims=True)
    maxs = x.max(axis=1, keepdims=True)
    levels = float(2 ** min(nbits, 62) - 1)
    scales = np.maximum(maxs - mins, 1e-12) / levels
    q = np.clip(np.rint((x - mins) / scales), 0, levels)
    return q.astype(_STORE_DTYPE[nbits]), mins.squeeze(1), scales.squeeze(1)


def _dequantize_rows(q: np.ndarray, mins: np.ndarray, scales: np.ndarray):
    return (q.astype(np.float64) * scales[:, None] + mins[:, None])


@dataclasses.dataclass
class PackedFeatures:
    """DAQ output: vertices grouped by bitwidth + optional lossless payload."""
    num_vertices: int
    feature_dim: int
    bits_per_vertex: np.ndarray            # int64[|V|]
    groups: dict                           # nbits -> (vertex_ids, q, mins, scales)
    lossless_payload: Optional[bytes] = None
    lossless_codec: Optional[str] = None   # concrete codec of the payload

    @property
    def quant_bits(self) -> int:
        """Total feature payload bits after DAQ (before lossless)."""
        return int(self.bits_per_vertex.sum()) * self.feature_dim

    @property
    def raw_bits(self) -> int:
        return self.num_vertices * self.feature_dim * 64

    def nbytes(self, lossless: bool = True) -> int:
        if lossless and self.lossless_payload is not None:
            return len(self.lossless_payload)
        return self.quant_bits // 8

    @property
    def measured_ratio(self) -> float:
        return self.quant_bits / self.raw_bits


def byte_shuffle(a: np.ndarray) -> bytes:
    """HDF5-style shuffle filter: transpose byte planes of the elements."""
    b = np.ascontiguousarray(a).view(np.uint8).reshape(a.size, a.dtype.itemsize)
    return b.T.tobytes()


def daq_pack(features: np.ndarray, degrees: np.ndarray,
             thresholds: Optional[Tuple[int, int, int]] = None,
             bits: Sequence[int] = DEFAULT_BITS,
             lossless: bool = True,
             codec: str = "zlib") -> PackedFeatures:
    """Quantize features degree-aware, then shuffle + losslessly compress.

    The input is treated as Q=64-bit (the paper's raw feature width); the
    64-bit bin stores float64 verbatim (no quantization error). ``codec``
    selects the lossless stage ("zlib" | "lz4" | "auto"); "lz4" (the
    paper's choice) degrades to zlib with a warning when the lz4 package
    is not importable.
    """
    x = np.asarray(features, np.float64)
    degrees = np.asarray(degrees)
    bpv = assign_bits(degrees, thresholds, bits)
    groups = {}
    payload_parts = []
    for nbits in sorted(set(int(b) for b in bits), reverse=True):
        ids = np.flatnonzero(bpv == nbits)
        if ids.size == 0:
            continue
        rows = x[ids]
        if nbits >= 64:
            q, mins, scales = rows.view(np.uint64), None, None
        else:
            q, mins, scales = _quantize_rows(rows, nbits)
        groups[nbits] = (ids, q, mins, scales)
        payload_parts.append(byte_shuffle(q))
    payload = used_codec = None
    if lossless:
        payload, used_codec = lossless_compress(b"".join(payload_parts),
                                                codec)
    return PackedFeatures(num_vertices=x.shape[0], feature_dim=x.shape[1],
                          bits_per_vertex=bpv, groups=groups,
                          lossless_payload=payload,
                          lossless_codec=used_codec)


def daq_unpack(packed: PackedFeatures) -> np.ndarray:
    """Dequantize back to the original bitwidth (float64) in vertex order —
    the fog-side unpacking step; the 64-bit bin is exactly lossless."""
    out = np.zeros((packed.num_vertices, packed.feature_dim), np.float64)
    for nbits, (ids, q, mins, scales) in packed.groups.items():
        if nbits >= 64:
            out[ids] = q.view(np.float64)
        else:
            out[ids] = _dequantize_rows(q, mins, scales)
    return out


def uniform_pack(features: np.ndarray, nbits: int = 8,
                 lossless: bool = True) -> PackedFeatures:
    """Uniform quantization baseline (paper Table V 'Uni. 8-bit')."""
    degrees = np.zeros(features.shape[0], np.int64)
    return daq_pack(features, degrees, thresholds=(1, 1, 1),
                    bits=(nbits,) * 4, lossless=lossless)


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A COMPRESSORS registry entry: one device-upload codec.

    ``roundtrip`` packs and unpacks features exactly as devices/fogs would,
    so downstream numerics carry the true quantization error. ``sim_key``
    is the wire-byte accounting key understood by
    ``simulation._partition_wire_bytes`` (None = raw upload).
    """
    name: str
    sim_key: Optional[str]
    pack: Optional[Callable[[np.ndarray, np.ndarray], PackedFeatures]]

    def roundtrip(self, features: np.ndarray,
                  degrees: np.ndarray) -> np.ndarray:
        if self.pack is None:
            return np.asarray(features, np.float32)
        packed = self.pack(np.asarray(features, np.float64), degrees)
        return daq_unpack(packed).astype(np.float32)


def _register_compressors():
    from repro.api.registry import COMPRESSORS
    COMPRESSORS.register("none", Compressor("none", None, None))
    COMPRESSORS.register("daq", Compressor(
        "daq", "daq", lambda x, d: daq_pack(x, d)))
    COMPRESSORS.register("daq_noll", Compressor(
        "daq_noll", "daq_noll", lambda x, d: daq_pack(x, d, lossless=False)))
    # The paper's LZ4 lossless stage (optional lz4 dep; zlib fallback with
    # a warning). Numerics are identical to "daq" — only the lossless
    # payload (and hence the wire bytes) differs.
    COMPRESSORS.register("daq_lz4", Compressor(
        "daq_lz4", "daq_lz4", lambda x, d: daq_pack(x, d, codec="lz4")))
    COMPRESSORS.register("uniform8", Compressor(
        "uniform8", "uniform8", lambda x, d: uniform_pack(x, 8)))


_register_compressors()


def end_to_end_sizes(features: np.ndarray, degrees: np.ndarray,
                     **kw) -> dict:
    """Raw vs DAQ vs DAQ+lossless byte sizes (for communication accounting)."""
    packed = daq_pack(features, degrees, **kw)
    raw = features.shape[0] * features.shape[1] * 8
    return {
        "raw_bytes": raw,
        "daq_bytes": packed.quant_bits // 8,
        "wire_bytes": packed.nbytes(lossless=True),
        "daq_ratio": packed.measured_ratio,
        "wire_ratio": packed.nbytes(True) / raw,
    }
