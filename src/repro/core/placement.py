"""Inference Execution Planner (IEP) — paper §III-C, Alg. 1.

Two-step heuristic for the NP-hard min-max placement problem P (Eq. 7):

  step 1  BGP min-cut partitioning (repro.core.partition, METIS stand-in)
  step 2  partition->fog mapping as a Linear Bottleneck Assignment Problem
          (LBAP), solved exactly by threshold search + perfect-matching
          checks; binary search over the O(n^2) candidate thresholds gives
          the paper's O(n^3 log n).

Also implements the paper's comparison baselines: METIS+Random and
METIS+Greedy (§III-C "Discussion"), and the straw-man fog placement
(DistDGL-style: partitions mapped stochastically, §IV-A).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.api.registry import PLACEMENTS
from repro.core.partition import bgp
from repro.core.profiler import LatencyModel, cardinality_of
from repro.gnn.graph import Graph


# ----------------------------------------------------------------------------
# Assignment solvers
# ----------------------------------------------------------------------------

def hungarian(cost: np.ndarray) -> np.ndarray:
    """Exact min-sum assignment (Munkres / Jonker-Volgenant shortest
    augmenting path, O(n^3)). Returns col[j] assigned to each row j... i.e.
    result[i] = column assigned to row i."""
    cost = np.asarray(cost, np.float64)
    n, m = cost.shape
    assert n == m, "square cost matrix required"
    INF = float("inf")
    u = np.zeros(n + 1)
    v = np.zeros(n + 1)
    p = np.zeros(n + 1, dtype=np.int64)   # p[j] = row matched to column j
    way = np.zeros(n + 1, dtype=np.int64)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = np.full(n + 1, INF)
        used = np.zeros(n + 1, dtype=bool)
        while True:
            used[j0] = True
            i0 = p[j0]
            delta = INF
            j1 = -1
            for j in range(1, n + 1):
                if not used[j]:
                    cur = cost[i0 - 1, j - 1] - u[i0] - v[j]
                    if cur < minv[j]:
                        minv[j] = cur
                        way[j] = j0
                    if minv[j] < delta:
                        delta = minv[j]
                        j1 = j
            for j in range(n + 1):
                if used[j]:
                    u[p[j]] += delta
                    v[j] -= delta
                else:
                    minv[j] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    result = np.zeros(n, dtype=np.int64)
    for j in range(1, n + 1):
        result[p[j] - 1] = j - 1
    return result


def _kuhn_perfect_matching(adj: List[np.ndarray], n: int) -> Optional[np.ndarray]:
    """Kuhn's augmenting-path bipartite matching. adj[i] = candidate columns
    for row i. Returns match row->col or None if no perfect matching."""
    match_col = -np.ones(n, dtype=np.int64)

    def try_row(i: int, seen: np.ndarray) -> bool:
        for j in adj[i]:
            if not seen[j]:
                seen[j] = True
                if match_col[j] < 0 or try_row(int(match_col[j]), seen):
                    match_col[j] = i
                    return True
        return False

    for i in range(n):
        if not try_row(i, np.zeros(n, dtype=bool)):
            return None
    result = -np.ones(n, dtype=np.int64)
    for j in range(n):
        result[match_col[j]] = j
    return result


def lbap(cost: np.ndarray) -> np.ndarray:
    """Linear Bottleneck Assignment: minimize max_{i} cost[i, sigma(i)].

    Binary search over sorted unique costs for the smallest threshold tau
    admitting a perfect matching among edges with cost <= tau (paper's
    binary-search acceleration of Alg. 1 lines 7-16).
    """
    cost = np.asarray(cost, np.float64)
    n = cost.shape[0]
    thresholds = np.unique(cost)
    lo, hi = 0, len(thresholds) - 1
    best = None
    while lo <= hi:
        mid = (lo + hi) // 2
        tau = thresholds[mid]
        adj = [np.flatnonzero(cost[i] <= tau) for i in range(n)]
        m = _kuhn_perfect_matching(adj, n)
        if m is not None:
            best = m
            hi = mid - 1
        else:
            lo = mid + 1
    assert best is not None, "complete bipartite graph always matches"
    return best


def lbap_threshold_descending(cost: np.ndarray) -> np.ndarray:
    """Literal Alg. 1 (priority queue of descending thresholds + Hungarian
    feasibility) — kept for fidelity tests against the binary-search path."""
    cost = np.asarray(cost, np.float64)
    n = cost.shape[0]
    thresholds = np.unique(cost)[::-1]  # descending
    best = None
    for tau in thresholds:
        adj = [np.flatnonzero(cost[i] <= tau) for i in range(n)]
        m = _kuhn_perfect_matching(adj, n)
        if m is None:
            break
        best = m
    assert best is not None
    return best


# ----------------------------------------------------------------------------
# IEP
# ----------------------------------------------------------------------------

@dataclasses.dataclass
class FogSpec:
    """Static per-fog serving configuration (metadata registration)."""
    name: str
    bandwidth_bytes_per_s: float          # b_j, allocated collection bandwidth
    latency_model: LatencyModel           # omega_j


@dataclasses.dataclass
class Placement:
    """pi: vertex -> fog assignment plus planning diagnostics."""
    assignment: np.ndarray                # int64[|V|] fog index per vertex
    partition_of: np.ndarray              # int64[|V|] partition index (pre-map)
    mapping: np.ndarray                   # partition k -> fog mapping[k]
    est_collect: np.ndarray               # t_colle per fog (Eq. 5)
    est_exec: np.ndarray                  # t_exec per fog (Eq. 6)

    @property
    def est_total(self) -> np.ndarray:
        return self.est_collect + self.est_exec

    @property
    def est_makespan(self) -> float:
        return float(self.est_total.max())


def pair_cost(g: Graph, part_vertices: np.ndarray, fog: FogSpec,
              bytes_per_vertex: float, k_layers: int,
              sync_cost: float) -> float:
    """Eq. (8): <P_k, f_j> = |P_k| phi / b_j + omega_j(P_k) + K delta."""
    t_colle = len(part_vertices) * bytes_per_vertex / fog.bandwidth_bytes_per_s
    card = cardinality_of(g, part_vertices)
    return t_colle + fog.latency_model.predict(card) + k_layers * sync_cost


def _build_cost_matrix(g: Graph, parts: List[np.ndarray],
                       fogs: Sequence[FogSpec], bytes_per_vertex: float,
                       k_layers: int, sync_cost: float) -> np.ndarray:
    n = len(fogs)
    cost = np.zeros((n, n))
    cards = [cardinality_of(g, p) for p in parts]
    for k in range(n):
        for j, fog in enumerate(fogs):
            t_colle = (len(parts[k]) * bytes_per_vertex
                       / fog.bandwidth_bytes_per_s)
            cost[k, j] = (t_colle + fog.latency_model.predict(cards[k])
                          + k_layers * sync_cost)
    return cost


def _finish(g: Graph, parts: List[np.ndarray], mapping: np.ndarray,
            fogs: Sequence[FogSpec], bytes_per_vertex: float,
            k_layers: int, sync_cost: float,
            partition_assignment: np.ndarray) -> Placement:
    n = len(fogs)
    assignment = np.zeros(g.num_vertices, dtype=np.int64)
    est_collect = np.zeros(n)
    est_exec = np.zeros(n)
    for k, part in enumerate(parts):
        j = int(mapping[k])
        assignment[part] = j
        est_collect[j] = (len(part) * bytes_per_vertex
                          / fogs[j].bandwidth_bytes_per_s)
        est_exec[j] = (fogs[j].latency_model.predict(cardinality_of(g, part))
                       + k_layers * sync_cost)
    return Placement(assignment=assignment,
                     partition_of=partition_assignment,
                     mapping=np.asarray(mapping, np.int64),
                     est_collect=est_collect, est_exec=est_exec)


def match_bottleneck(cost: np.ndarray, seed: int = 0) -> np.ndarray:
    """IEP's partition->fog matcher: exact LBAP bottleneck assignment."""
    return lbap(cost)


def match_greedy(cost: np.ndarray, seed: int = 0) -> np.ndarray:
    """METIS+Greedy baseline: rows pick their cheapest unused fog in order."""
    n = cost.shape[0]
    mapping = -np.ones(n, dtype=np.int64)
    used = np.zeros(n, dtype=bool)
    for k in range(n):
        order = np.argsort(cost[k])
        j = next(int(jj) for jj in order if not used[jj])
        mapping[k] = j
        used[j] = True
    return mapping


def match_random(cost: np.ndarray, seed: int = 0) -> np.ndarray:
    """METIS+Random / straw-man: stochastic partition->fog mapping."""
    return np.random.default_rng(seed).permutation(cost.shape[0])


# canonical registry key -> (matcher, heterogeneity-aware partition sizing)
_STRATEGIES = {
    "iep": (match_bottleneck, True),
    "metis+greedy": (match_greedy, False),
    "random": (match_random, False),
}


def iep_place(g: Graph, fogs: Sequence[FogSpec], *,
              bytes_per_vertex: Optional[float] = None,
              k_layers: int = 2, sync_cost: float = 5e-3,
              seed: int = 0, strategy: str = "iep",
              capacity_weights: Optional[np.ndarray] = None,
              partitioner: Optional[Callable] = None) -> Placement:
    """Full IEP data placement (Alg. 1) and its baselines.

    strategy:
      "iep"           BGP + LBAP bottleneck mapping    (the paper's algorithm)
      "metis+greedy"  BGP + greedy min-cost mapping    (METIS+Greedy baseline;
                      "greedy" is accepted as an alias)
      "random"        BGP + stochastic mapping         (METIS+Random/straw-man)

    ``partitioner`` overrides the BGP solver (same signature as
    ``partition.bgp``); any ``PARTITIONERS`` registry entry qualifies.
    """
    n = len(fogs)
    strategy = PLACEMENTS.canonical(strategy)  # aliases live in the registry
    if strategy not in _STRATEGIES:
        raise ValueError(f"unknown strategy {strategy!r}; "
                         f"available: {', '.join(sorted(_STRATEGIES))}")
    matcher, het_sizing = _STRATEGIES[strategy]
    if partitioner is None:
        partitioner = bgp
    if bytes_per_vertex is None:
        bytes_per_vertex = g.feature_dim * 8.0  # float64 features, Q=64
    if capacity_weights is None and het_sizing:
        # Heterogeneity-aware partition sizing (paper Fig. 13b: the type-C
        # fog holds the most vertices): equal-size partitions cannot
        # balance a heterogeneous cluster no matter how they are mapped,
        # so IEP sizes partitions by profiled total per-vertex cost. The
        # baselines (METIS+Random / METIS+Greedy) keep straw-man sizing.
        capacity_weights = capability_weights(fogs, g, bytes_per_vertex)
    part_assign = partitioner(g, n, weights=capacity_weights, seed=seed)
    parts = [np.flatnonzero(part_assign == k) for k in range(n)]
    cost = _build_cost_matrix(g, parts, fogs, bytes_per_vertex,
                              k_layers, sync_cost)
    mapping = matcher(cost, seed=seed)
    return _finish(g, parts, mapping, fogs, bytes_per_vertex, k_layers,
                   sync_cost, part_assign)


@dataclasses.dataclass(frozen=True)
class PlacementStrategy:
    """A PLACEMENTS registry entry: one partition->fog mapping policy.

    ``place`` runs the full vertex placement (step 2 of the paper's
    workflow); ``match`` exposes the bare cost-matrix matcher so non-graph
    substrates (e.g. the transformer pod scheduler in ``launch.serve``)
    reuse the same policy on their own cost models.
    """
    name: str
    matcher: Callable[..., np.ndarray]

    def place(self, g: Graph, fogs: Sequence[FogSpec], **kw) -> Placement:
        return iep_place(g, fogs, strategy=self.name, **kw)

    def match(self, cost: np.ndarray, seed: int = 0) -> np.ndarray:
        return self.matcher(np.asarray(cost, np.float64), seed=seed)


for _name, (_matcher, _) in _STRATEGIES.items():
    PLACEMENTS.register(_name, PlacementStrategy(_name, _matcher))


def capability_weights(fogs: Sequence[FogSpec], g: Graph,
                       bytes_per_vertex: float = 0.0) -> np.ndarray:
    """Capacity fractions inversely proportional to each fog's *total*
    per-vertex serving cost (collection + execution, Eq. 8's two terms).

    This sizes partitions so that collect_j + exec_j equalizes across the
    heterogeneous cluster (paper Fig. 13b shows the type-C fog holding the
    most vertices). Sizing by compute speed alone would overload a fast
    fog's uplink when collection is not compressed."""
    n = len(fogs)
    probe_v = max(2, g.num_vertices // n)
    probe = (probe_v, max(2, g.num_edges // n))
    cost = []
    for f in fogs:
        exec_pv = f.latency_model.predict(probe) / probe_v
        coll_pv = bytes_per_vertex / f.bandwidth_bytes_per_s
        cost.append(exec_pv + coll_pv)
    speed = 1.0 / np.maximum(np.asarray(cost), 1e-12)
    return speed / speed.sum()
