"""Proxy-guided GNN latency profiler (paper §III-B).

Offline: sample a calibration set of subgraphs with varying *cardinality*
⟨|V|, |N_V|⟩ (20 samples per cardinality axis, preserving the degree
distribution), measure per-fog execution latency, and fit the linear
regression of Eq. (3):   latency = beta . <|V|, |N_V|> + eps.

Online: two-step estimation — measure T_real for the local cardinality c,
compute the load factor eta = T_real / omega(c), and predict any other
cardinality c' as eta * omega(c').

Measurement sources are pluggable: real wall-clock timing of the jitted GNN
on this host (``time_gnn_measurer``) or the fog-cluster capability simulator
(``repro.core.simulation``) for heterogeneous-node experiments.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Sequence, Tuple

import numpy as np

from repro.gnn.graph import Graph, neighbor_count, subgraph

Cardinality = Tuple[int, int]  # (|V|, |N_V|)


def sample_calibration_set(g: Graph, num_sizes: int = 6,
                           samples_per_size: int = 20,
                           seed: int = 0) -> List[np.ndarray]:
    """Uniformly sample vertex subsets of varying cardinality.

    Per the paper, for each cardinality axis we draw a group of samples so
    the natural degree distribution is preserved.
    """
    rng = np.random.default_rng(seed)
    sizes = np.unique(np.linspace(
        max(1, g.num_vertices // (num_sizes * 4)),
        max(2, int(g.num_vertices * 0.9)),
        num_sizes).astype(np.int64))
    out = []
    for s in sizes:
        for _ in range(samples_per_size):
            out.append(rng.choice(g.num_vertices, size=int(s), replace=False))
    return out


def cardinality_of(g: Graph, vertex_ids: np.ndarray) -> Cardinality:
    return (int(len(vertex_ids)), neighbor_count(g, vertex_ids))


@dataclasses.dataclass
class LatencyModel:
    """omega(<c>) = beta . <|V|, |N_V|> + eps (Eq. 3), per fog node."""
    beta: np.ndarray   # float64[2]
    eps: float
    load_factor: float = 1.0  # eta, updated online

    def predict(self, c: Cardinality) -> float:
        base = float(self.beta @ np.asarray(c, np.float64) + self.eps)
        return self.load_factor * max(base, 1e-9)

    def observe(self, c: Cardinality, t_real: float) -> float:
        """Online two-step estimation: update eta from one real measurement."""
        base = float(self.beta @ np.asarray(c, np.float64) + self.eps)
        self.load_factor = t_real / max(base, 1e-9)
        return self.load_factor


def fit_latency_model(cards: Sequence[Cardinality],
                      latencies: Sequence[float]) -> LatencyModel:
    """Least-squares fit of Eq. (3). Guards against degenerate designs."""
    x = np.asarray(cards, np.float64)
    y = np.asarray(latencies, np.float64)
    design = np.concatenate([x, np.ones((len(x), 1))], axis=1)
    coef, *_ = np.linalg.lstsq(design, y, rcond=None)
    beta, eps = coef[:2], float(coef[2])
    # Latency must be non-decreasing in workload: clamp negative slopes that
    # arise from noisy tiny calibration sets.
    beta = np.maximum(beta, 0.0)
    return LatencyModel(beta=beta, eps=max(eps, 0.0))


def profile_node(g: Graph, measure: Callable[[np.ndarray], float],
                 num_sizes: int = 6, samples_per_size: int = 20,
                 seed: int = 0) -> LatencyModel:
    """Offline profiling of one fog node.

    ``measure(vertex_ids) -> seconds`` abstracts the node: real timing or
    simulated capability.
    """
    cal = sample_calibration_set(g, num_sizes, samples_per_size, seed)
    cards = [cardinality_of(g, ids) for ids in cal]
    lats = [measure(ids) for ids in cal]
    # Average within identical |V| groups as the paper does per-cardinality.
    return fit_latency_model(cards, lats)


def time_gnn_measurer(g: Graph, kind: str, params,
                      repeats: int = 3) -> Callable[[np.ndarray], float]:
    """Wall-clock measurer: times the jitted GNN forward on this host."""
    import jax
    import jax.numpy as jnp
    from repro.gnn.layers import EdgeList
    from repro.gnn.models import gnn_apply

    def measure(vertex_ids: np.ndarray) -> float:
        sg = subgraph(g, vertex_ids)
        edges = EdgeList.from_graph(sg)
        h = jnp.asarray(sg.features)
        fn = jax.jit(lambda hh: gnn_apply(params, kind, hh, edges))
        fn(h).block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(repeats):
            fn(h).block_until_ready()
        return (time.perf_counter() - t0) / repeats

    return measure


def analytic_measurer(capability_flops: float, feature_dim: int,
                      hidden: int, noise: float = 0.0, seed: int = 0,
                      overhead: float = 1e-4) -> Callable[[np.ndarray], float]:
    """Closed-form workload model for simulated heterogeneous nodes.

    GNN layer cost ~ |V|·F·H (update matmuls) + |N_V|·F (aggregation reads);
    capability_flops scales node speed (types A/B/C in Table II).
    """
    rng = np.random.default_rng(seed)

    def measure_cardinality(c: Cardinality) -> float:
        v, nv = c
        flops = 2.0 * v * feature_dim * hidden + 8.0 * nv * feature_dim
        t = flops / capability_flops + overhead
        if noise:
            t *= float(1.0 + rng.normal(scale=noise))
        return max(t, 1e-9)

    return measure_cardinality


def profile_node_analytic(g: Graph, measure_c: Callable[[Cardinality], float],
                          num_sizes: int = 6, samples_per_size: int = 20,
                          seed: int = 0) -> LatencyModel:
    """Like profile_node but for measurers taking cardinalities directly."""
    cal = sample_calibration_set(g, num_sizes, samples_per_size, seed)
    cards = [cardinality_of(g, ids) for ids in cal]
    lats = [measure_c(c) for c in cards]
    return fit_latency_model(cards, lats)
