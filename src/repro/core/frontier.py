"""k-hop dirty frontiers and per-layer activation caching (ROADMAP
"Dynamic graphs", incremental *queries*).

``Engine.apply_delta`` repairs the plan incrementally, but until this
module a query after an update still recomputed all V vertices. The
observation: a K-layer GNN output row u changes only when some input
within K hops of u changed. This module computes that reach exactly:

  1. ``fold_delta_frontier``  replay a ``GraphDelta`` sequence through
                              ``mutate_graph`` and extract the *seed*
                              set (touched vertices / edge endpoints in
                              the post-mutation id space), the composed
                              old->new vertex map, and the union-
                              adjacency extras — removed edges between
                              survivors, which no longer exist in the
                              new graph but still propagate dirt (the
                              endpoints lost a neighbor).
  2. ``expand_frontier``      per-layer dirty sets: D_l = all vertices
                              within l hops of a seed over the union of
                              pre- and post-mutation adjacency.
  3. ``ActivationCache``      retains the last full pass's per-layer
                              [V, F_l] activations plus the collected
                              h^0 it was computed from; remaps rows
                              through the order-preserving compaction
                              on update; decides per query whether the
                              frontier path applies (and is cheap
                              enough) or a full recompute must run.

Feature changes are caught *by value*: at query time the freshly
collected h^0 is compared bitwise against the cached h^0 and every
differing row joins the seeds. This subsumes feature upserts, per-query
feature overrides, and the DAQ codec's global degree-quantile coupling
(a structural delta can shift quantization thresholds and thereby
change h^0 rows whose raw features never moved).

Everything here is host-side numpy; the executors own the jitted
gather / sub-aggregate / scatter-merge programs.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.api.updates import GraphDelta
from repro.core.incremental import mutate_graph
from repro.gnn.graph import Graph

__all__ = ["FrontierUpdate", "QueryFrontier", "FrontierPlan",
           "ActivationCache", "fold_delta_frontier", "expand_frontier",
           "frontier_plan"]


_EMPTY_IDS = np.empty(0, np.int64)
_EMPTY_EDGES = np.empty((0, 2), np.int64)


@dataclasses.dataclass(frozen=True)
class FrontierUpdate:
    """What one (folded) delta sequence means for cached activations."""
    graph: Graph              # post-mutation graph (replayed)
    vmap: np.ndarray          # int64[v_old] old id -> new id, -1 if removed
    seeds: np.ndarray         # int64, sorted unique, new-id space
    extra_edges: np.ndarray   # int64[m, 2], new-id space, both directions
    removed_vertices: bool    # any vertex removal anywhere in the sequence
    structural: bool          # any vertex/edge add or remove (vs feature-only)


@dataclasses.dataclass(frozen=True)
class QueryFrontier:
    """Per-layer dirty rows for one incremental query."""
    seeds: np.ndarray         # int64, sorted unique
    rows: List[np.ndarray]    # one int64 array per layer, D_1 .. D_K
    fraction: float           # |D_K| / V


@dataclasses.dataclass(frozen=True)
class FrontierPlan:
    """Frontier snapshot for the ``plan.frontier`` analysis checks."""
    seeds: np.ndarray
    rows: List[np.ndarray]    # D_1 .. D_K
    extra_edges: np.ndarray
    num_vertices: int
    num_layers: int
    revision: str             # adjacency fingerprint the frontier was cut at


def _unique(ids) -> np.ndarray:
    if len(ids) == 0:
        return _EMPTY_IDS
    return np.unique(np.asarray(ids, np.int64))


def _delta_seeds(g: Graph, delta: GraphDelta, vmap: np.ndarray):
    """(seeds, extra_edges) of one delta, in the post-mutation id space."""
    v_old = g.num_vertices
    seeds: List[np.ndarray] = []
    extras: List[np.ndarray] = []
    # Added vertices (appended after the survivors).
    if delta.num_added_vertices:
        seeds.append(vmap[v_old:])
    # Added edges touch both (surviving) endpoints.
    if len(delta.add_edges):
        add = vmap[np.asarray(delta.add_edges, np.int64)]
        seeds.append(add[add >= 0])
    # Removed edges: both former endpoints lose a neighbor. Pairs whose
    # endpoints both survive also enter the union adjacency — the edge is
    # gone from the new graph but dirt still propagates across it.
    if len(delta.remove_edges):
        rem = vmap[np.asarray(delta.remove_edges, np.int64)]
        seeds.append(rem[rem >= 0])
        both = rem[(rem >= 0).all(axis=1)]
        if len(both):
            extras.append(np.concatenate([both, both[:, ::-1]], axis=0))
    # Removed vertices dirty every surviving former neighbor (the removed
    # row itself no longer exists; propagation *through* it is covered by
    # seeding its whole former neighborhood).
    if len(delta.remove_vertices):
        nbrs = []
        for x in np.asarray(delta.remove_vertices, np.int64):
            nbrs.append(g.indices[g.indptr[x]:g.indptr[x + 1]])
        if nbrs:
            nb = vmap[np.concatenate(nbrs).astype(np.int64)]
            seeds.append(nb[nb >= 0])
    # Feature upserts touch their target rows. (The h^0 value diff at
    # query time would catch them too; seeding keeps the frontier exact
    # even for callers that skip the diff.)
    if len(delta.feature_ids):
        upd = vmap[np.asarray(delta.feature_ids, np.int64)]
        seeds.append(upd[upd >= 0])
    seed_ids = (_unique(np.concatenate(seeds)) if seeds else _EMPTY_IDS)
    extra = (np.concatenate(extras, axis=0) if extras else _EMPTY_EDGES)
    return seed_ids, extra


def fold_delta_frontier(g: Graph,
                        deltas: Sequence[GraphDelta]) -> FrontierUpdate:
    """Replay ``deltas`` over ``g`` and fold their frontier bookkeeping.

    The replay is the same deterministic ``mutate_graph`` chain
    ``core.incremental.plan_delta`` runs, so the returned graph is
    bit-identical to the plan the Engine rebased onto (callers may
    assert via ``kernels.ops.graph_fingerprint``). Seeds and extras
    from earlier deltas are carried through each later delta's vertex
    map; an extra edge losing an endpoint drops out (its invalidation
    then flows through the vertex-removal seeding of that delta).
    """
    if isinstance(deltas, GraphDelta):
        deltas = [deltas]
    v0 = g.num_vertices
    vmap_total = np.arange(v0, dtype=np.int64)
    seeds = _EMPTY_IDS
    extras = _EMPTY_EDGES
    removed_any = False
    structural_any = False
    cur = g
    for delta in deltas:
        prev = cur
        cur, vmap = mutate_graph(cur, delta)
        removed_any = removed_any or len(delta.remove_vertices) > 0
        structural_any = structural_any or bool(
            delta.num_added_vertices or len(delta.remove_vertices)
            or len(delta.add_edges) or len(delta.remove_edges))
        # Carry earlier bookkeeping into the new id space.
        if len(seeds):
            seeds = seeds[vmap[seeds] >= 0]
            seeds = vmap[seeds] if len(seeds) else _EMPTY_IDS
        if len(extras):
            m = vmap[extras]
            extras = m[(m >= 0).all(axis=1)]
        d_seeds, d_extras = _delta_seeds(prev, delta, vmap)
        seeds = _unique(np.concatenate([seeds, d_seeds]))
        if len(d_extras):
            extras = np.concatenate([extras, d_extras], axis=0)
        # Compose the total old->new map.
        alive = vmap_total >= 0
        nxt = np.full(v0, -1, np.int64)
        nxt[alive] = vmap[vmap_total[alive]]
        vmap_total = nxt
    if len(extras):
        extras = np.unique(extras, axis=0)
    return FrontierUpdate(graph=cur, vmap=vmap_total, seeds=seeds,
                          extra_edges=extras, removed_vertices=removed_any,
                          structural=structural_any)


def expand_frontier(graph: Graph, seeds: np.ndarray,
                    extra_edges: np.ndarray,
                    num_layers: int) -> List[np.ndarray]:
    """Per-layer dirty sets ``[D_1, ..., D_K]``: D_l is the l-hop ball of
    ``seeds`` over the union adjacency (the graph's own edges — both
    directions are stored — plus ``extra_edges``, the removed-but-
    invalidating pairs)."""
    v = graph.num_vertices
    send = np.asarray(graph.senders, np.int64)
    recv = np.asarray(graph.receivers, np.int64)
    if len(extra_edges):
        send = np.concatenate([send, np.asarray(extra_edges[:, 0], np.int64)])
        recv = np.concatenate([recv, np.asarray(extra_edges[:, 1], np.int64)])
    dirty = np.zeros(v, bool)
    seeds = np.asarray(seeds, np.int64)
    dirty[seeds] = True
    out: List[np.ndarray] = []
    for _ in range(int(num_layers)):
        nxt = dirty.copy()
        nxt[recv[dirty[send]]] = True
        dirty = nxt
        out.append(np.flatnonzero(dirty).astype(np.int64))
    return out


def frontier_plan(graph: Graph, seeds: np.ndarray, extra_edges: np.ndarray,
                  num_layers: int, revision: str) -> FrontierPlan:
    """Bundle an expanded frontier for the ``plan.frontier`` checks."""
    rows = expand_frontier(graph, seeds, extra_edges, num_layers)
    return FrontierPlan(seeds=np.asarray(seeds, np.int64), rows=rows,
                        extra_edges=np.asarray(extra_edges, np.int64),
                        num_vertices=graph.num_vertices,
                        num_layers=int(num_layers), revision=revision)


class ActivationCache:
    """Per-layer activations of the last full pass, plus the pending dirt.

    Lifecycle (driven by ``api.session.Session``):

      * ``populate`` after a full pass: store the collected h^0 and every
        layer output, tagged with the (aggregation mode, executor family)
        that produced them and the graph's adjacency fingerprint.
      * ``apply_update`` at flush time: remap all rows through the
        delta's order-preserving compaction (survivors keep their values,
        new rows zero), accumulate seeds / union-adjacency extras, and
        note structural changes — block regrouping makes the Pallas
        path's accumulation order layout-sensitive, so ``pallas_ok``
        gates it off until the next full pass rebases the cache
        (feature-only streams keep it armed).
      * ``plan_query`` per query: revision/tag agreement, the bitwise
        h^0 diff, frontier expansion, and the ``max_fraction`` budget.
      * ``merge`` after an incremental query: the scatter-merged layer
        tables become the new cache state and the pending dirt clears.

    Numerics contract: a value served from (or merged into) the cache is
    bit-identical to what a from-scratch pass under the same (mode,
    family) would produce — callers must re-populate, not merge, when
    either tag changes.
    """

    def __init__(self, max_fraction: float = 0.25):
        if not 0.0 < float(max_fraction) <= 1.0:
            raise ValueError("frontier_max_fraction must be in (0, 1], "
                             f"got {max_fraction}")
        self.max_fraction = float(max_fraction)
        self.h0: Optional[np.ndarray] = None
        self.layers: Optional[List[np.ndarray]] = None
        self.revision: Optional[str] = None
        self.mode: Optional[str] = None
        self.family: Optional[str] = None
        self.seeds = _EMPTY_IDS
        self.extra_edges = _EMPTY_EDGES
        self.pallas_ok = True

    # -- state ---------------------------------------------------------------

    @property
    def primed(self) -> bool:
        return self.layers is not None

    def clear(self) -> None:
        self.h0 = None
        self.layers = None
        self.revision = None
        self.mode = None
        self.family = None
        self.seeds = _EMPTY_IDS
        self.extra_edges = _EMPTY_EDGES
        self.pallas_ok = True

    def matches(self, revision: str, mode: str, family: str) -> bool:
        return (self.primed and self.revision == revision
                and self.mode == mode and self.family == family)

    # -- lifecycle -----------------------------------------------------------

    def populate(self, h0: np.ndarray, layers: Sequence[np.ndarray],
                 revision: str, mode: str, family: str) -> None:
        self.h0 = np.asarray(h0, np.float32)
        self.layers = [np.asarray(a, np.float32) for a in layers]
        self.revision = revision
        self.mode = mode
        self.family = family
        self.seeds = _EMPTY_IDS
        self.extra_edges = _EMPTY_EDGES
        self.pallas_ok = True

    def apply_update(self, fu: FrontierUpdate, revision: str) -> None:
        """Rebase cached rows onto the mutated graph's id space."""
        if not self.primed:
            return
        v_new = fu.graph.num_vertices
        # src[new_id] = old row feeding it, -1 for brand-new vertices.
        src = np.full(v_new, -1, np.int64)
        alive = np.flatnonzero(fu.vmap >= 0)
        src[fu.vmap[alive]] = alive

        def remap(arr: np.ndarray) -> np.ndarray:
            out = np.zeros((v_new,) + arr.shape[1:], arr.dtype)
            m = src >= 0
            out[m] = arr[src[m]]
            return out

        self.h0 = remap(self.h0)
        self.layers = [remap(a) for a in self.layers]
        # Pending dirt from an earlier un-queried flush rides along.
        if len(self.seeds):
            s = self.seeds[fu.vmap[self.seeds] >= 0]
            self.seeds = fu.vmap[s] if len(s) else _EMPTY_IDS
        if len(self.extra_edges):
            m = fu.vmap[self.extra_edges]
            self.extra_edges = m[(m >= 0).all(axis=1)]
        self.seeds = _unique(np.concatenate([self.seeds, fu.seeds]))
        if len(fu.extra_edges):
            self.extra_edges = np.unique(np.concatenate(
                [self.extra_edges, fu.extra_edges], axis=0), axis=0)
        # Structural deltas poison the kernel path until the next full
        # pass: removals renumber ids (tiles regroup), mesh halo layout
        # is globally coupled, and even a pure edge add can insert an
        # all-zero tile into a clean row-block's accumulation, where
        # IEEE ``-0.0 + 0.0 == +0.0`` flips bits. Feature-only deltas
        # (the common sensor-refresh stream) keep it armed.
        self.pallas_ok = self.pallas_ok and not fu.structural
        self.revision = revision

    def plan_query(self, feats, graph: Graph,
                   num_layers: int) -> Optional[QueryFrontier]:
        """Frontier for one query whose collected input is ``feats``
        ([V, F] or a stacked [B, V, F] micro-batch — the batch unions its
        members' h^0 diffs into one stacked frontier). ``None`` means the
        frontier path does not apply (unprimed cache, shape drift, or a
        frontier above the ``max_fraction`` budget) and the caller must
        run a full pass."""
        if not self.primed:
            return None
        feats = np.asarray(feats, np.float32)
        stacked = feats.ndim == 3
        if feats.shape[-2:] != self.h0.shape:
            return None
        # Bitwise diff: NaN != NaN is True, so NaN rows always recompute.
        diff = feats != self.h0
        changed = np.flatnonzero(
            diff.any(axis=(0, 2)) if stacked else diff.any(axis=1))
        seeds = _unique(np.concatenate([self.seeds, changed]))
        if len(seeds) == 0:
            return QueryFrontier(seeds=_EMPTY_IDS, rows=[], fraction=0.0)
        rows = expand_frontier(graph, seeds, self.extra_edges, num_layers)
        fraction = len(rows[-1]) / max(graph.num_vertices, 1)
        if fraction > self.max_fraction:
            return None
        return QueryFrontier(seeds=seeds, rows=rows, fraction=fraction)

    def merge(self, h0: np.ndarray,
              layers: Sequence[np.ndarray]) -> None:
        """Adopt the scatter-merged tables of an incremental query."""
        self.h0 = np.asarray(h0, np.float32)
        self.layers = [np.asarray(a, np.float32) for a in layers]
        self.seeds = _EMPTY_IDS
        self.extra_edges = _EMPTY_EDGES
        self.pallas_ok = True

    def frontier_plan(self, graph: Graph,
                      num_layers: int) -> Optional[FrontierPlan]:
        """Snapshot the *pending* frontier for the analysis checks."""
        if not self.primed or self.revision is None:
            return None
        return frontier_plan(graph, self.seeds, self.extra_edges,
                             num_layers, self.revision)
