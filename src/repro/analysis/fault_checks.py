"""Node-failure recovery checks (the "fault" analyzer family).

Audits the node-level fault-tolerance machinery (``repro.api.faults``) —
pass a :class:`~repro.api.faults.FailoverAudit` as ``ctx.failover``.
Three invariants mirror what the recovery tiers rely on:

  fault.failover.coverage   a failover plan really evicted the crashed
                            nodes (they appear in no cluster/fog/
                            assignment slot), the surviving shards still
                            cover every vertex, and — the pricing bugfix
                            invariant — a failover plan carries
                            ``cluster_spec=None`` so later recompiles
                            and ``simulate_update`` pricing never
                            resurrect the crashed node
  fault.halo.consistency    the serving session's stale halo store
                            agrees with the graph it serves: recorded
                            tables from before a failover (partitioned
                            for the dead layout) must have been
                            invalidated, never replayed
  fault.retry.budget        the plan's exchange retry knobs can actually
                            recover something (at least one backoff
                            attempt fits the timeout), and the replayed
                            FaultSchedule is well-formed (time-sorted,
                            no double-crash without a recover between)

Checks require ``ctx.failover`` and are skipped — not failed — on
contexts without one, so plain plan sweeps are unaffected.
"""
from __future__ import annotations

from typing import Iterable

import numpy as np

from repro.analysis.diagnostics import (AnalysisContext, Diagnostic, error,
                                        info, register_check)
from repro.api.registry import EXCHANGES


@register_check(
    "fault.failover.coverage", family="fault", layer="plan",
    requires=("failover",),
    description="failover plan evicts the crashed nodes, survivors cover "
                "every vertex, and cluster_spec is None")
def check_failover_coverage(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    """The degraded plan must be a complete serving plan on exactly the
    survivors — anything still referencing the crashed node would price
    or route work to a dead machine."""
    audit = ctx.failover
    plan = audit.plan
    node_names = [n.name for n in plan.cluster.nodes]
    fog_names = [f.name for f in plan.fogs]
    crashed = set(audit.crashed)
    leaked = sorted(crashed & (set(node_names) | set(fog_names)))
    if leaked:
        yield error(
            "fault.failover.coverage",
            f"crashed node(s) {leaked} still appear in the failover "
            "plan's cluster/fog roster — work would be priced or routed "
            "to a dead machine",
            layer="plan", subject="cluster.nodes",
            fix_hint="derive the plan via Engine.fail_nodes, which "
                     "rebuilds the cluster from the survivors only")
        return
    a = np.asarray(plan.placement.assignment)
    n = len(plan.fogs)
    if a.shape[0] != plan.graph.num_vertices:
        yield error(
            "fault.failover.coverage",
            f"assignment covers {a.shape[0]} vertices but the graph has "
            f"{plan.graph.num_vertices} — the evicted shard was dropped, "
            "not re-placed",
            layer="plan", subject="placement.assignment",
            fix_hint="repair_assignment must re-place every evicted "
                     "vertex (evacuate_assignment marks them -1)")
        return
    if a.size and (a.min() < 0 or a.max() >= n):
        yield error(
            "fault.failover.coverage",
            f"assignment references partitions outside [0, {n}) "
            f"(min {int(a.min())}, max {int(a.max())}) — an evicted "
            "vertex was never re-placed",
            layer="plan", subject="placement.assignment",
            fix_hint="run repair_assignment on the evacuated assignment")
        return
    sizes = np.bincount(a, minlength=n)
    empty = [fog_names[j] for j in range(n) if sizes[j] == 0]
    if empty:
        yield error(
            "fault.failover.coverage",
            f"surviving fog(s) {empty} own zero vertices after failover "
            "— the re-placement collapsed a shard",
            layer="plan", subject="placement.assignment",
            fix_hint="repair_assignment with capacity balancing keeps "
                     "every survivor populated")
        return
    if plan.provenance == "failover" and plan.config.cluster_spec is not None:
        yield error(
            "fault.failover.coverage",
            f"failover plan still carries cluster_spec="
            f"{plan.config.cluster_spec!r} — Engine.from_plan prefers the "
            "spec over the surviving cluster, so a later recompile or "
            "update pricing would resurrect the crashed node",
            layer="plan", subject="config.cluster_spec",
            fix_hint="failover plans must set cluster_spec=None "
                     "(Engine.fail_nodes does)")
        return
    base = audit.base_plan
    if base is not None:
        if base.graph.num_vertices != plan.graph.num_vertices:
            yield error(
                "fault.failover.coverage",
                f"failover plan serves {plan.graph.num_vertices} vertices "
                f"but its base plan served {base.graph.num_vertices} — a "
                "failover must not change the graph",
                layer="plan", subject="graph",
                fix_hint="fail over first, then apply graph deltas")
            return
        expect = len(base.fogs) - len(crashed)
        if crashed and len(plan.fogs) != expect:
            yield error(
                "fault.failover.coverage",
                f"{len(crashed)} node(s) crashed off a {len(base.fogs)}-"
                f"fog base plan but the failover plan has "
                f"{len(plan.fogs)} fogs (expected {expect})",
                layer="plan", subject="fogs",
                fix_hint="every crashed node evicts exactly one fog")
            return
    yield info(
        "fault.failover.coverage",
        f"{len(crashed) or 'no'} crashed node(s) evicted; "
        f"{plan.graph.num_vertices} vertices covered by "
        f"{n} surviving shards (largest {int(sizes.max())})",
        layer="plan", subject="placement.assignment")


@register_check(
    "fault.halo.consistency", family="fault", layer="plan",
    requires=("failover",),
    description="no stale halo table recorded for a pre-failover layout "
                "survives onto the degraded plan")
def check_halo_consistency(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    """Recorded halo tables are partitioned for one specific layout; a
    failover changes the layout, so tables recorded before it must have
    been invalidated (Session.rebind does) — replaying them would ship
    features to the wrong shards."""
    audit = ctx.failover
    server = audit.server
    sess = None
    if server is not None:
        sess = getattr(server, "session", None)
    if sess is None:
        yield info("fault.halo.consistency",
                   "no live server in the audit — nothing recorded to "
                   "check", layer="plan", subject="session")
        return
    store = getattr(sess, "_halo", None)
    if store is None or store.tables is None:
        yield info("fault.halo.consistency",
                   "halo store empty/absent — nothing stale to replay",
                   layer="plan", subject="session._halo")
        return
    from repro.kernels import ops
    current = ops.graph_fingerprint(sess.plan.graph)
    if store.revision != current:
        yield error(
            "fault.halo.consistency",
            f"recorded halo tables carry revision "
            f"{str(store.revision)[:12]}… but the session serves "
            f"{current[:12]}… — a stale ride-through would replay tables "
            "partitioned for a dead layout",
            layer="plan", subject="session._halo",
            fix_hint="Session.rebind/failover must invalidate the halo "
                     "store; call session._halo.invalidate()")
        return
    yield info(
        "fault.halo.consistency",
        f"halo store revision matches the serving graph (age "
        f"{store.age}/{store.bound})",
        layer="plan", subject="session._halo")


@register_check(
    "fault.retry.budget", family="fault", layer="plan",
    requires=("failover",),
    description="exchange retry knobs admit at least one backoff attempt "
                "and the fault schedule is well-formed")
def check_retry_budget(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    """Tier 1 must be reachable: a retryable exchange whose first backoff
    attempt already blows the timeout silently degrades every transient
    loss to tier 2/3. The schedule (when supplied) must be replayable:
    time-sorted with no node crashing twice without a recover between."""
    audit = ctx.failover
    plan = audit.plan
    exch = EXCHANGES.resolve(plan.config.exchange)
    if getattr(exch, "retryable", False):
        knobs = [("max_retries", exch.max_retries, exch.max_retries >= 1),
                 ("backoff_base_s", exch.backoff_base_s,
                  exch.backoff_base_s > 0),
                 ("backoff_mult", exch.backoff_mult,
                  exch.backoff_mult >= 1.0),
                 ("retry_timeout_s", exch.retry_timeout_s,
                  exch.retry_timeout_s > 0)]
        bad = [(k, v) for k, v, ok in knobs if not ok]
        if bad:
            yield error(
                "fault.retry.budget",
                f"exchange {exch.name!r} retry knobs out of range: "
                + ", ".join(f"{k}={v}" for k, v in bad),
                layer="plan", subject=f"EXCHANGES[{exch.name!r}]",
                fix_hint="max_retries >= 1, backoff_base_s > 0, "
                         "backoff_mult >= 1, retry_timeout_s > 0")
            return
        _, _, ok = exch.recovery_cost(1, plan.cluster.sync_cost)
        if not ok:
            yield error(
                "fault.retry.budget",
                f"exchange {exch.name!r} cannot recover even a single "
                f"lost round within retry_timeout_s="
                f"{exch.retry_timeout_s} at sync_cost="
                f"{plan.cluster.sync_cost} — tier-1 retry is unreachable "
                "and every transient loss degrades straight to stale/"
                "failover",
                layer="plan", subject=f"EXCHANGES[{exch.name!r}]",
                fix_hint="raise retry_timeout_s or lower backoff_base_s "
                         "so attempt 0 fits the budget")
            return
    sched = audit.schedule
    if sched is not None:
        times = [f.time for f in sched]
        if times != sorted(times):
            yield error(
                "fault.retry.budget",
                "fault schedule is not time-sorted — the injector fires "
                "events in list order and would replay the past",
                layer="plan", subject="schedule",
                fix_hint="construct via FaultSchedule(...), which sorts")
            return
        down: set = set()
        for f in sched:
            if f.kind == "crash":
                if f.node in down:
                    yield error(
                        "fault.retry.budget",
                        f"node {f.node!r} crashes twice (t={f.time}) "
                        "without a recover between — the second event "
                        "can never fire",
                        layer="plan", subject="schedule",
                        fix_hint="pair every crash with a recover (see "
                                 "FaultSchedule.random)")
                    return
                down.add(f.node)
            elif f.kind == "recover":
                down.discard(f.node)
    n_ev = 0 if sched is None else len(sched)
    yield info(
        "fault.retry.budget",
        f"exchange {exch.name!r} "
        + ("retry budget admits recovery"
           if getattr(exch, "retryable", False)
           else "is not retryable (tier 1 skipped by design)")
        + (f"; schedule of {n_ev} events well-formed" if sched is not None
           else ""),
        layer="plan", subject=f"EXCHANGES[{exch.name!r}]")
