"""Command line front end: ``python -m repro.analysis``.

Modes:

  * ``python -m repro.analysis plan.pkl`` — verify a pickled Plan.
  * ``python -m repro.analysis --demo`` — compile a demo plan for every
    partitioner x compressor x executor registry combination and verify
    each (plus one structural ``apply_delta`` scenario and one lowered-HLO
    module); this is the CI smoke sweep behind ``scripts/ci.sh``.
  * ``python -m repro.analysis --list`` — print the check catalogue.

``--strict`` also fails (exit 1) on warnings; default fails on errors
only.  ``--families plan,cache`` restricts the run.
"""
from __future__ import annotations

import argparse
import pickle
import sys
from typing import List, Optional, Sequence

from repro.analysis.diagnostics import (AnalysisContext, CHECKS, Report,
                                        checks_for, run_checks)

#: demo graph scale: ~180 vertices — big enough for multi-shard layouts,
#: small enough that the full registry sweep stays in CI budget.
DEMO_SCALE = 0.03


def _demo_plans():
    """(label, plan) for every partitioner x compressor x executor combo."""
    import jax

    from repro.api.engine import Engine
    from repro.api.registry import COMPRESSORS, EXECUTORS, PARTITIONERS
    from repro.gnn import datasets, models

    g = datasets.load("siot", scale=DEMO_SCALE, seed=0)
    params = models.gnn_init(jax.random.PRNGKey(0), "gcn",
                             [g.feature_dim, 16, 8])
    for partitioner in PARTITIONERS.keys():
        for compressor in COMPRESSORS.keys():
            for executor in EXECUTORS.keys():
                label = f"{partitioner}+{compressor}+{executor}"
                engine = Engine((params, "gcn"), "1A+3B",
                                partitioner=partitioner,
                                compressor=compressor,
                                executor=executor, exchange="halo",
                                aggregation="auto")
                yield label, engine, engine.compile(g)


def _demo_update_plan():
    """One structural apply_delta (the PR-4 ``n=`` repair path)."""
    import jax

    from repro.api.engine import Engine
    from repro.api.updates import GraphDelta
    from repro.gnn import datasets, models

    g = datasets.load("siot", scale=DEMO_SCALE, seed=1)
    params = models.gnn_init(jax.random.PRNGKey(1), "gcn",
                             [g.feature_dim, 16, 8])
    engine = Engine((params, "gcn"), "1A+3B", executor="mesh-bsp",
                    aggregation="pallas")
    plan = engine.compile(g)
    import numpy as np
    v = g.num_vertices
    delta = GraphDelta(
        add_features=np.ones((2, g.feature_dim), np.float32),
        add_edges=[(v, 0), (v + 1, 1)],
        remove_edges=[(int(g.senders[0]), int(g.receivers[0]))])
    return engine, engine.apply_delta(plan, delta, force="incremental")


def _demo_frontier():
    """One frontier-bearing session: query, flush a delta, snapshot.

    Exercises the ``frontier`` family against live incremental state —
    a cache-enabled session whose pending dirty frontier spans a real
    flushed delta (feature upsert + edge add).
    """
    import jax
    import numpy as np

    from repro.api.engine import Engine
    from repro.api.updates import GraphDelta
    from repro.gnn import datasets, models

    g = datasets.load("siot", scale=DEMO_SCALE, seed=2)
    params = models.gnn_init(jax.random.PRNGKey(2), "gcn",
                             [g.feature_dim, 16, 8])
    engine = Engine((params, "gcn"), "1A+3B", executor="sim",
                    aggregation="segment_sum")
    sess = engine.compile(g).session(activation_cache=True)
    sess.query()                                  # populate the cache
    v = g.num_vertices
    sess.update(GraphDelta(
        add_edges=[(0, v // 2), (v // 2, 0)],
        feature_ids=[1],
        feature_values=np.ones((1, g.feature_dim), np.float32)))
    return sess


def _demo_failover():
    """One post-failover plan + live fault-aware server for the fault
    family: compile on the full cluster, crash one node mid-trace via a
    chaos schedule, audit the degraded state the server is left in."""
    import jax

    from repro.api.engine import Engine
    from repro.api.faults import FailoverAudit, Fault, FaultSchedule
    from repro.api.server import Request
    from repro.gnn import datasets, models

    g = datasets.load("siot", scale=DEMO_SCALE, seed=3)
    params = models.gnn_init(jax.random.PRNGKey(3), "gcn",
                             [g.feature_dim, 16, 8])
    engine = Engine((params, "gcn"), "1A+3B", executor="sim",
                    exchange="halo_async", staleness_bound=2)
    plan = engine.compile(g)
    crashed = plan.cluster.nodes[-1].name
    sched = FaultSchedule([Fault(time=0.05, kind="crash", node=crashed)])
    server = plan.server(max_batch=4, faults=sched)
    for i in range(8):
        server.submit(Request(arrival_time=0.02 * i))
    server.drain()
    return FailoverAudit(plan=server.session.plan, base_plan=plan,
                         crashed=(crashed,), server=server, schedule=sched)


def _demo_hlo() -> str:
    """Lowered HLO text of a small jitted layer stack."""
    import jax
    import jax.numpy as jnp

    def stack(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=3)
        return h

    x = jnp.zeros((8, 16), jnp.float32)
    w = jnp.zeros((16, 16), jnp.float32)
    return jax.jit(stack).lower(x, w).compile().as_text()


def _families(arg: Optional[str]) -> Optional[Sequence[str]]:
    return None if not arg else tuple(s.strip() for s in arg.split(",")
                                      if s.strip())


def _print_catalogue() -> None:
    for fn in checks_for(None):
        print(f"{fn.check_id:32s} [{fn.family}/{fn.layer}] "
              f"{fn.description}")


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static plan/kernel/cache verifier (docs/analysis.md)")
    p.add_argument("plan", nargs="?", help="pickled Plan to verify")
    p.add_argument("--demo", action="store_true",
                   help="verify plans for every partitioner x compressor "
                        "x executor registry combination")
    p.add_argument("--strict", action="store_true",
                   help="exit nonzero on warnings too")
    p.add_argument("--families",
                   help="comma-separated analyzer families to run "
                        "(plan,frontier,fleet,fault,kernel,cache,hlo; "
                        "default all applicable)")
    p.add_argument("--list", action="store_true", dest="list_checks",
                   help="print the check catalogue and exit")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="also print info-level diagnostics")
    args = p.parse_args(argv)

    if args.list_checks:
        _print_catalogue()
        return 0
    if not args.demo and not args.plan:
        p.error("give a pickled plan path or --demo")

    families = _families(args.families)
    total = Report()
    failed = False

    def run(label: str, ctx: AnalysisContext, fams) -> None:
        nonlocal failed
        report = run_checks(ctx, families=fams)
        total.extend(report)
        bad = report.errors + (report.warnings if args.strict else [])
        status = "FAIL" if bad else "ok"
        if bad:
            failed = True
        print(f"[{status:4s}] {label}: {len(report.ran)} checks, "
              f"{len(report.errors)} errors, {len(report.warnings)} "
              f"warnings")
        for d in report.diagnostics:
            if d.severity != "info" or args.verbose:
                print("    " + d.format().replace("\n", "\n    "))

    if args.plan:
        with open(args.plan, "rb") as fh:
            plan = pickle.load(fh)
        run(args.plan, AnalysisContext(plan=plan),
            families or ("plan", "kernel", "cache"))
    if args.demo:
        for label, _engine, plan in _demo_plans():
            run(label, AnalysisContext(plan=plan),
                families or ("plan", "kernel", "cache"))
        if families is None or "plan" in families:
            _engine, updated = _demo_update_plan()
            run("apply_delta[structural]", AnalysisContext(plan=updated),
                families or ("plan", "kernel", "cache"))
        if families is None or "frontier" in families:
            sess = _demo_frontier()
            run("frontier[pending-delta]",
                AnalysisContext(plan=sess.plan,
                                frontier=sess.frontier_state()),
                families or ("plan", "frontier", "kernel", "cache"))
        if families is None or "fault" in families:
            audit = _demo_failover()
            run("fault[post-failover]",
                AnalysisContext(plan=audit.plan, failover=audit),
                families or ("plan", "fault", "kernel", "cache"))
        if families is None or "hlo" in families:
            run("hlo[scan-stack]", AnalysisContext(hlo=_demo_hlo()),
                ("hlo",))

    n_checks = len(list(CHECKS))
    print(f"{n_checks} registered checks; {len(total.ran)} runs, "
          f"{len(total.errors)} errors, {len(total.warnings)} warnings"
          + (" — FAIL" if failed else " — OK"))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
