"""Cache-key and closure audit (the "cache" analyzer family).

Two process-wide caches key compiled state by *some* of the serving knobs:
``bsp._PROGRAM_CACHE`` (compiled shard_map programs) and
``ops._BLOCK_CSR_CACHE`` (prepared whole-graph block-CSR operands).  A knob
that affects lowering but is missing from the key serves a stale program; a
closure that captures a retired graph's buffers pins its memory until LRU
eviction (the leak class the batched-execution PR fixed by hand).  These
checks audit both failure modes statically:

  * **knob coverage** — every ``EngineConfig`` field must be classified in
    :data:`KNOB_COVERAGE`: either it reaches the program key (directly or
    via a derived field like ``use_kernels``), or it is explicitly declared
    key-irrelevant (pricing/planning/diagnostics).  Adding a knob without
    classifying it is an error — the author must decide.
  * **key arity/shape** — every live cache key must have exactly the
    registered fields (``bsp.PROGRAM_KEY_FIELDS`` /
    ``ops.BLOCK_CSR_KEY_FIELDS``) with the expected types.
  * **closure pins** — walk every cached program's closure chain; any cell
    holding a large ndarray or a Graph/PartitionedGraph/BlockShardCsr/
    BlockCsr is a retired-buffer pin.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Tuple

import numpy as np

from repro.analysis.diagnostics import (AnalysisContext, Diagnostic, error,
                                        info, register_check)
from repro.api.plan import EngineConfig

#: How each EngineConfig knob relates to the compiled-program cache key.
#: ``via`` names the _program_key fields that carry the knob's effect;
#: an empty ``via`` with kind "key-irrelevant:*" declares the knob cannot
#: change lowering.  check_program_key_fields errors on any EngineConfig
#: field missing here: new knobs must be classified deliberately.
KNOB_COVERAGE = {
    # Change the partition layout -> captured by the key's geometry tuple.
    "partitioner": {"kind": "geometry", "via": ("geometry",)},
    "placement": {"kind": "geometry", "via": ("geometry",)},
    # DAQ compressors flip the fused-dequant halo wire.
    "compressor": {"kind": "lowering", "via": ("halo_quant",)},
    "exchange": {"kind": "lowering", "via": ("exchange",)},
    # Selects WHICH runtime entry point runs (tag/mesh), not how one
    # program lowers; the mesh program key carries tag + mesh_key.
    "executor": {"kind": "dispatch", "via": ("tag", "mesh_key")},
    # Selects WHETHER a serve runs the fresh sync program or the stale
    # replay program ("stale"/"stale_many" tags); each lowers under its
    # own tag, so the bound itself never changes a cached program.
    "staleness_bound": {"kind": "dispatch", "via": ("tag",)},
    # Resolves to the use_kernels flag baked into the program.
    "aggregation": {"kind": "lowering", "via": ("use_kernels",)},
    # Pricing/planning inputs: consumed before any program is traced.
    "network": {"kind": "key-irrelevant:pricing", "via": ()},
    "cluster_spec": {"kind": "key-irrelevant:pricing", "via": ()},
    "hidden": {"kind": "key-irrelevant:pricing", "via": ()},
    "seed": {"kind": "key-irrelevant:pricing", "via": ()},
    "sync_cost": {"kind": "key-irrelevant:pricing", "via": ()},
    "bytes_per_vertex": {"kind": "key-irrelevant:pricing", "via": ()},
    "update_max_imbalance": {"kind": "key-irrelevant:planning", "via": ()},
    "update_max_cut_growth": {"kind": "key-irrelevant:planning", "via": ()},
    # Diagnostics only: validation never changes what is compiled.
    "validate": {"kind": "key-irrelevant:diagnostics", "via": ()},
}

#: expected python type(s) of each _program_key field, by position.
_PROGRAM_KEY_TYPES = {
    "tag": str, "kind": str, "axis": str, "exchange": str,
    "use_kernels": bool, "halo_quant": bool, "interpret": bool,
    "geometry": tuple, "mesh_key": tuple,
}

#: ndarray cells above this many elements count as pinned buffers.
_PIN_ELEMENT_THRESHOLD = 1024


@register_check(
    "cache.program.key_fields", family="cache", layer="cache",
    requires=(),
    description="every lowering-relevant knob reaches the compiled-program "
                "cache key; live keys carry all registered fields")
def check_program_key_fields(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    from repro.runtime import bsp
    out = []
    cid = "cache.program.key_fields"
    fields = {f.name for f in dataclasses.fields(EngineConfig)}
    covered = set(KNOB_COVERAGE)
    for missing in sorted(fields - covered):
        out.append(error(
            cid, f"EngineConfig.{missing} is not classified in "
                 f"cache_audit.KNOB_COVERAGE — if it affects lowering it "
                 f"MUST join bsp._program_key, else declare it "
                 f"key-irrelevant", layer="cache",
            subject=f"EngineConfig.{missing}",
            fix_hint="add the field to KNOB_COVERAGE with its key mapping "
                     "(and to _program_key if it changes lowering)"))
    for stale in sorted(covered - fields):
        out.append(error(
            cid, f"KNOB_COVERAGE classifies {stale!r} which is no longer "
                 f"an EngineConfig field", layer="cache",
            subject=f"KNOB_COVERAGE[{stale!r}]",
            fix_hint="drop the stale classification"))
    key_fields = bsp.PROGRAM_KEY_FIELDS
    for knob, spec in KNOB_COVERAGE.items():
        for via in spec["via"]:
            if via not in key_fields:
                out.append(error(
                    cid, f"knob {knob!r} claims to reach the program key "
                         f"via {via!r}, but PROGRAM_KEY_FIELDS has no such "
                         f"field", layer="cache", subject=f"via[{via!r}]",
                    fix_hint="KNOB_COVERAGE and bsp.PROGRAM_KEY_FIELDS "
                             "drifted apart"))
    cache = ctx.resolved_program_cache()
    for key in cache:
        if not isinstance(key, tuple) or len(key) != len(key_fields):
            got = len(key) if isinstance(key, tuple) else type(key).__name__
            out.append(error(
                cid, f"cached-program key {key!r} has {got} fields, "
                     f"registered key has {len(key_fields)} "
                     f"({', '.join(key_fields)}) — a knob was stripped "
                     f"from the key and distinct programs now collide",
                layer="cache", subject="_PROGRAM_CACHE",
                fix_hint="key every program with bsp._program_key"))
            continue
        for name, value in zip(key_fields, key):
            want = _PROGRAM_KEY_TYPES[name]
            if not isinstance(value, want):
                out.append(error(
                    cid, f"cached-program key field {name!r} is "
                         f"{type(value).__name__}, expected {want.__name__}"
                         f" (key {key!r})", layer="cache",
                    subject=f"key.{name}",
                    fix_hint="key every program with bsp._program_key"))
    if not out:
        out.append(info(cid, f"{len(cache)} cached programs keyed on "
                             f"{len(key_fields)} fields; all "
                             f"{len(fields)} knobs classified",
                        layer="cache", subject="_PROGRAM_CACHE"))
    return out


@register_check(
    "cache.blockcsr.key_fields", family="cache", layer="cache",
    requires=(),
    description="BlockCsr cache keys carry fingerprint + normalize + block")
def check_blockcsr_key_fields(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    from repro.kernels import ops
    out = []
    cid = "cache.blockcsr.key_fields"
    key_fields = ops.BLOCK_CSR_KEY_FIELDS
    cache = ctx.resolved_block_csr_cache()
    for key in cache:
        if not isinstance(key, tuple) or len(key) != len(key_fields):
            got = len(key) if isinstance(key, tuple) else type(key).__name__
            out.append(error(
                cid, f"BlockCsr cache key {key!r} has {got} fields, "
                     f"registered key has {len(key_fields)} "
                     f"({', '.join(key_fields)}) — operands for different "
                     f"adjacencies/normalizations would collide",
                layer="cache", subject="_BLOCK_CSR_CACHE",
                fix_hint="key entries as (graph_fingerprint(g), normalize, "
                         "block)"))
            continue
        fp, normalize, block = key
        if not (isinstance(fp, str) and len(fp) == 32):
            out.append(error(
                cid, f"BlockCsr key fingerprint {fp!r} is not a 32-hex "
                     f"adjacency digest — content keying is broken and a "
                     f"mutated graph can alias a stale operand",
                layer="cache", subject="key.fingerprint",
                fix_hint="use ops.graph_fingerprint(g)"))
        if normalize not in (None, "mean"):
            out.append(error(
                cid, f"BlockCsr key normalize={normalize!r} is not a known "
                     f"normalization", layer="cache",
                subject="key.normalize", fix_hint="use None or 'mean'"))
        if not isinstance(block, int) or block <= 0:
            out.append(error(
                cid, f"BlockCsr key block={block!r} is not a positive "
                     f"tile edge", layer="cache", subject="key.block",
                fix_hint="use the BLOCK tile size"))
    if not out:
        out.append(info(cid, f"{len(cache)} cached BlockCsr operands, keys "
                             f"well-formed", layer="cache",
                        subject="_BLOCK_CSR_CACHE"))
    return out


def _closure_cells(fn, depth: int = 0, seen=None) -> List[Tuple[str, object]]:
    """(path, value) for every closure cell reachable from ``fn`` through
    __wrapped__ chains and nested function cells (bounded depth)."""
    if seen is None:
        seen = set()
    if depth > 6 or id(fn) in seen:
        return []
    seen.add(id(fn))
    out: List[Tuple[str, object]] = []
    wrapped = getattr(fn, "__wrapped__", None)
    if wrapped is not None:
        out.extend(_closure_cells(wrapped, depth + 1, seen))
    closure = getattr(fn, "__closure__", None)
    names = getattr(getattr(fn, "__code__", None), "co_freevars", ())
    if closure:
        for name, cell in zip(names, closure):
            try:
                value = cell.cell_contents
            except ValueError:     # empty cell
                continue
            path = f"{getattr(fn, '__name__', '<fn>')}.{name}"
            out.append((path, value))
            if callable(value):
                out.extend(_closure_cells(value, depth + 1, seen))
    return out


def _pin_description(value) -> str:
    """Non-empty description when ``value`` pins retired graph state."""
    type_names = ("Graph", "PartitionedGraph", "BlockShardCsr", "BlockCsr")
    if type(value).__name__ in type_names:
        return f"a {type(value).__name__} instance"
    size = getattr(value, "size", None)
    if (size is not None and getattr(value, "dtype", None) is not None
            and size > _PIN_ELEMENT_THRESHOLD):
        return (f"a {getattr(value, 'shape', '?')} {value.dtype} buffer "
                f"({int(size)} elements)")
    return ""


@register_check(
    "cache.program.closure_pins", family="cache", layer="cache",
    requires=(),
    description="no cached program's closure pins retired graph buffers")
def check_closure_pins(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    out = []
    cid = "cache.program.closure_pins"
    cache = ctx.resolved_program_cache()
    for key, fn in cache.items():
        for path, value in _closure_cells(fn):
            desc = _pin_description(value)
            if desc:
                out.append(error(
                    cid, f"cached program {key!r} closes over {desc} at "
                         f"{path} — the buffer stays pinned for the "
                         f"cache's whole LRU lifetime even after the graph "
                         f"retires", layer="cache", subject=path,
                    fix_hint="bind layout statics to locals before "
                             "defining shard_fn; pass every buffer as a "
                             "traced operand (see bsp.bsp_apply)"))
    if not out:
        out.append(info(cid, f"{len(cache)} cached programs hold only "
                             f"scalar/static closures", layer="cache",
                        subject="_PROGRAM_CACHE"))
    return out


def _audit_numpy_guard(x) -> bool:
    """True when ``x`` is an ndarray-like with real storage (helper for
    tests constructing synthetic pins)."""
    return isinstance(x, np.ndarray) and x.size > _PIN_ELEMENT_THRESHOLD
