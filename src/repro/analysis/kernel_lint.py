"""Pallas launch-geometry lint (the "kernel" analyzer family).

Derives every kernel launch a plan implies — the mesh executor's per-shard
local/halo SpMMs (single + batched, DAQ-fused where the plan quantizes the
halo wire) and the single-program executors' whole-graph SpMM — and lints
them *abstractly*: ``jax.eval_shape`` traces the real jitted wrappers
(``block_spmm`` / ``dequant_spmm`` + batched variants) with
``ShapeDtypeStruct`` operands, so grid/operand divisibility and shape
contracts are checked by the kernels' own assertions without allocating or
executing anything.  On top of tracing: scalar-prefetch table bounds (the
kernels index the source table with NO bounds check), dtype agreement on
the quantized wire against the executor's declared wire format, and a
VMEM/SMEM footprint estimate against the TPU budgets.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.diagnostics import (AnalysisContext, Diagnostic, error,
                                        register_check, warning)
from repro.api.registry import EXECUTORS
from repro.kernels.daq_dequant import dequant_spmm, dequant_spmm_batched
from repro.kernels.gather_aggregate import (block_spmm, block_spmm_batched,
                                            padded_feature_dim)
from repro.runtime.bsp import KERNEL_KINDS

#: ~16 MB of VMEM per TPU core (see the Pallas guide's memory-space table);
#: one grid step's resident operands must fit with headroom to spare.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024
#: SMEM is "small" (scalar memory); the scalar-prefetched [VB, M] column
#: table must stay tiny.  Heuristic budget — the exact size is per-chip.
SMEM_BUDGET_BYTES = 64 * 1024

_KERNELS = {
    "block_spmm": block_spmm,
    "block_spmm_batched": block_spmm_batched,
    "dequant_spmm": dequant_spmm,
    "dequant_spmm_batched": dequant_spmm_batched,
}


@dataclasses.dataclass
class LaunchSpec:
    """One kernel launch the plan implies, reduced to what lint needs."""
    label: str               # e.g. "mesh/halo/batched"
    kernel: str              # key into _KERNELS
    tile_shape: Tuple[int, int, int, int]   # per-shard [VB, M, B, B]
    cols: np.ndarray         # FULL stacked column table (all shards)
    src_rows: int            # padded source-table rows
    out_rows: int            # VB * B
    f: int                   # padded feature width of this launch
    batch: Optional[int] = None       # micro-batch size (None = single)
    wire_dtype: np.dtype = np.dtype(np.float32)   # source-table dtype
    quant: bool = False      # True = dequant-fused (codes + scale/min rows)

    @property
    def block(self) -> int:
        return self.tile_shape[-1]

    def abstract_operands(self):
        """ShapeDtypeStructs matching the kernel wrapper's signature."""
        vb, m, b, _ = self.tile_shape
        S = jax.ShapeDtypeStruct
        blocks = S((vb, m, b, b), jnp.float32)
        cols = S((vb, m), jnp.int32)
        mask = S((vb, m), jnp.float32)
        if self.batch is None:
            table = S((self.src_rows, self.f), self.wire_dtype)
            rows = S((self.src_rows,), jnp.float32)
        else:
            table = S((self.batch, self.src_rows, self.f), self.wire_dtype)
            rows = S((self.batch, self.src_rows), jnp.float32)
        if self.quant:
            return (blocks, cols, mask, table, rows, rows)
        return (blocks, cols, mask, table)

    def expected_out_shape(self) -> Tuple[int, ...]:
        if self.batch is None:
            return (self.out_rows, self.f)
        return (self.batch, self.out_rows, self.f)


def _panel_widths(plan) -> List[int]:
    """Padded feature widths the layer stack feeds the aggregation kernels:
    each layer's input width (the first dim of its 2-D weight leaves)."""
    widths = []
    for p in plan.model.params:
        mats = [a for a in jax.tree_util.tree_leaves(p)
                if getattr(a, "ndim", 0) == 2]
        if mats:
            widths.append(int(mats[0].shape[0]))
    if not widths:
        widths = [plan.graph.feature_dim]
    return sorted({padded_feature_dim(w) for w in widths})


def plan_quantizes_halo(plan) -> bool:
    """Mirror of the mesh executor's DAQ-fusion rule: the halo wire is
    quantized when the kernel path is active and the plan compresses
    uploads with DAQ (see ``_MeshBsp._halo_quant``)."""
    return (plan.partitioned.halo_csr is not None
            and plan.model.kind in KERNEL_KINDS
            and plan.config.compressor.startswith("daq"))


def launches_for_plan(plan, batch_probe: int = 8) -> List[LaunchSpec]:
    """Every distinct kernel launch this plan's serving paths can issue."""
    specs: List[LaunchSpec] = []
    pg = plan.partitioned
    widths = _panel_widths(plan)
    if pg.local_csr is not None and pg.halo_csr is not None:
        quant = plan_quantizes_halo(plan)
        for name, csr in (("local", pg.local_csr), ("halo", pg.halo_csr)):
            is_quant = quant and name == "halo"
            wire = np.dtype(np.uint8) if is_quant else np.dtype(np.float32)
            kern = "dequant_spmm" if is_quant else "block_spmm"
            for f in widths:
                for batch in (None, batch_probe):
                    specs.append(LaunchSpec(
                        label=(f"mesh/{name}/"
                               f"{'batched' if batch else 'single'}/f{f}"),
                        kernel=kern + ("_batched" if batch else ""),
                        tile_shape=csr.blocks.shape[1:],
                        cols=np.asarray(csr.cols),
                        src_rows=csr.src_rows, out_rows=csr.out_rows,
                        f=f, batch=batch, wire_dtype=wire, quant=is_quant))
    backend = EXECUTORS.resolve(plan.config.executor)
    if (not getattr(backend, "needs_block_shards", False)
            and plan.model.kind in KERNEL_KINDS
            and plan.config.aggregation in ("pallas", "auto")):
        from repro.kernels import ops
        csr = ops.block_csr_for(plan.graph)
        for f in widths:
            for batch in (None, batch_probe):
                specs.append(LaunchSpec(
                    label=(f"single/graph/"
                           f"{'batched' if batch else 'single'}/f{f}"),
                    kernel="block_spmm" + ("_batched" if batch else ""),
                    tile_shape=tuple(csr.blocks.shape),
                    cols=np.asarray(csr.cols), src_rows=csr.padded_v,
                    out_rows=csr.padded_v, f=f, batch=batch))
    return specs


@register_check(
    "kernel.grid.divisibility", family="kernel", layer="kernel",
    description="abstract-trace every implied launch through the real "
                "kernel wrappers")
def check_grid_divisibility(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    out = []
    cid = "kernel.grid.divisibility"
    for spec in launches_for_plan(ctx.plan, ctx.batch_probe):
        fn = functools.partial(_KERNELS[spec.kernel], interpret=True)
        try:
            res = jax.eval_shape(fn, *spec.abstract_operands())
        except Exception as e:  # the wrappers assert their grid contract
            out.append(error(
                cid, f"{spec.label}: {spec.kernel} rejects the launch "
                     f"geometry ({type(e).__name__}: {e})", layer="kernel",
                subject=spec.label,
                fix_hint="operand shapes do not divide the kernel grid — "
                         "pad src rows to the 128 tile edge and features "
                         "via padded_feature_dim"))
            continue
        if tuple(res.shape) != spec.expected_out_shape():
            out.append(error(
                cid, f"{spec.label}: traced output {tuple(res.shape)} != "
                     f"expected {spec.expected_out_shape()}",
                layer="kernel", subject=spec.label,
                fix_hint="the block-CSR out_rows disagree with the kernel "
                         "grid — rebuild the shards"))
    return out


@register_check(
    "kernel.prefetch.bounds", family="kernel", layer="kernel",
    description="scalar-prefetched column tables stay inside the padded "
                "source table")
def check_prefetch_bounds(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    out = []
    cid = "kernel.prefetch.bounds"
    seen = set()
    for spec in launches_for_plan(ctx.plan, ctx.batch_probe):
        key = (id(spec.cols), spec.src_rows)
        if key in seen:
            continue
        seen.add(key)
        limit = spec.src_rows // spec.block
        cols = spec.cols
        if cols.size == 0:
            continue
        lo, hi = int(cols.min()), int(cols.max())
        if lo < 0 or hi >= limit:
            out.append(error(
                cid, f"{spec.label}: block_cols span [{lo}, {hi}] but the "
                     f"padded source table has only {limit} column blocks "
                     f"({spec.src_rows} rows / {spec.block}) — the kernel "
                     f"indexes with NO bounds check and would read out of "
                     f"the table", layer="kernel", subject=spec.label,
                fix_hint="rebuild the block-CSR shards; a dirty-shard "
                         "reuse kept tiles whose source space shrank"))
    return out


@register_check(
    "kernel.wire.dtype", family="kernel", layer="kernel",
    description="the quantized halo wire's dtypes match the kernel "
                "contract and the declared wire format")
def check_wire_dtype(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    plan = ctx.plan
    pg = plan.partitioned
    out = []
    cid = "kernel.wire.dtype"
    if pg.halo_csr is None or plan.config.executor != "mesh-bsp":
        return out
    from repro.runtime import bsp
    backend = EXECUTORS.resolve(plan.config.executor)
    try:
        declared = backend.wire_format(plan, plan.config.exchange,
                                       plan.config.aggregation)
    except Exception:
        declared = None
    f = padded_feature_dim(plan.graph.feature_dim)
    payload = jax.ShapeDtypeStruct((pg.boundary_slots, f), jnp.float32)
    codes, scales, mins = jax.eval_shape(bsp._wire_quantize, payload)
    if plan_quantizes_halo(plan):
        if not jnp.issubdtype(codes.dtype, jnp.unsignedinteger):
            out.append(error(
                cid, f"the quantized halo wire carries {codes.dtype} codes "
                     f"— dequant_spmm expects unsigned integer codes and "
                     f"silently mis-decodes anything else", layer="kernel",
                subject="_wire_quantize",
                fix_hint="quantize to uint8 (or another unsigned width) "
                         "before the all_gather"))
        for name, spec in (("scales", scales), ("mins", mins)):
            if spec.dtype != jnp.float32:
                out.append(error(
                    cid, f"halo wire {name} are {spec.dtype}, kernel "
                         f"contract is float32", layer="kernel",
                    subject="_wire_quantize",
                    fix_hint="keep the per-row (scale, min) pair f32"))
        actual = (codes.dtype.itemsize,
                  scales.dtype.itemsize + mins.dtype.itemsize)
        if declared is not None and declared != actual:
            out.append(error(
                cid, f"executor declares wire format {declared} "
                     f"(bytes/feature, bytes/row) but the quantized path "
                     f"ships {actual} — the exchange-bytes accounting and "
                     f"the roofline are lying", layer="kernel",
                subject="wire_format",
                fix_hint="keep _MeshBsp.wire_format in sync with "
                         "bsp._wire_quantize"))
    elif declared is not None and declared != (4, 0):
        out.append(error(
            cid, f"float halo wire declared as {declared}, expected (4, 0)",
            layer="kernel", subject="wire_format",
            fix_hint="non-DAQ plans ship raw float32 boundary rows"))
    return out


@register_check(
    "kernel.vmem.budget", family="kernel", layer="kernel",
    description="per-grid-step VMEM (and SMEM prefetch-table) footprint "
                "fits the TPU budgets")
def check_vmem_budget(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    out = []
    cid = "kernel.vmem.budget"
    for spec in launches_for_plan(ctx.plan, ctx.batch_probe):
        vb, m, b, _ = spec.tile_shape
        f_tile = min(128, spec.f)
        tiles = m * b * b * 4
        panel = spec.src_rows * f_tile * spec.wire_dtype.itemsize
        acc = b * f_tile * 4
        vmem = tiles + panel + acc
        if spec.quant:
            vmem += 2 * spec.src_rows * 4     # scale + min rows
        if vmem > VMEM_BUDGET_BYTES:
            out.append(warning(
                cid, f"{spec.label}: one grid step holds ~{vmem / 2**20:.1f}"
                     f" MiB in VMEM (tiles {tiles / 2**20:.1f} + source "
                     f"panel {panel / 2**20:.1f} + acc) against the "
                     f"~{VMEM_BUDGET_BYTES // 2**20} MiB/core budget — the "
                     f"launch will spill or fail to lower on hardware",
                layer="kernel", subject=spec.label,
                fix_hint="shard the graph further (smaller per-partition "
                         "source tables) or tile the source panel"))
        if spec.batch is not None:
            smem = vb * m * 4   # scalar-prefetched [VB, M] i32 column table
            if smem > SMEM_BUDGET_BYTES:
                out.append(warning(
                    cid, f"{spec.label}: the scalar-prefetched column "
                         f"table is {smem / 1024:.0f} KiB against a "
                         f"~{SMEM_BUDGET_BYTES // 1024} KiB SMEM budget",
                    layer="kernel", subject=spec.label,
                    fix_hint="the ELL width M is blowing up — repartition "
                             "or densify the shard"))
    return out
