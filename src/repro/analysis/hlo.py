"""Post-optimization HLO analyzer for the roofline terms (the "hlo"
analyzer family; formerly ``repro.launch.hlo_analysis``, which remains as
a re-export shim).

``compiled.cost_analysis()`` counts every while-loop body ONCE, but our
layer stacks (lax.scan), microbatch accumulation, and attention q-chunk
loops are all while loops — so its FLOPs/bytes understate real work by the
trip counts. This module re-derives the terms from ``compiled.as_text()``:

  * builds a symbol table (op name -> shape) per module,
  * builds the computation call graph (fusion `calls=`, while `body=` /
    `condition=`, `to_apply=`) with while trip counts taken from
    ``backend_config={"known_trip_count":{"n":...}}``,
  * multiplies each computation's cost by the product of trip counts along
    its call chain,
  * FLOPs: 2 * result_elements * contracted_size for every `dot`
    (+ convolution via window accounting),
  * bytes: operand + result bytes of every *fusion-boundary* op (fusions,
    dots, copies, slices, collectives, ...) — register-level ops inside
    fused computations are free,
  * collectives: result bytes of all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute (per-device shapes post-SPMD).

All sums are per-device (post-SPMD shapes are per-partition).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.analysis.diagnostics import (error, info, register_check,
                                        warning)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128|token)\[([0-9,]*)\]")

_OP_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^)]*\))|(?:[\w\[\]{},:\s/*]*?))\s*"
    r"([a-z][a-z0-9\-]*)\((.*)$")

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# Ops whose operands/results are materialized buffers (fusion boundaries).
_BOUNDARY_OPS = {
    "fusion", "dot", "convolution", "copy", "copy-start", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "reduce", "broadcast",
    "transpose", "reshape", "concatenate", "slice", "pad", "select",
    "iota", "rng", "sort", "select-and-scatter", "reduce-window", "map",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}

_SKIP_OPS = {"get-tuple-element", "tuple", "parameter", "constant",
             "bitcast", "while", "conditional", "call", "after-all",
             "partition-id", "replica-id", "custom-call",
             "get-dimension-size", "domain", "all-gather-done",
             "all-reduce-done", "copy-done", "collective-permute-done"}


def _shape_elems_bytes(text: str) -> Tuple[int, int]:
    """(elements, bytes) summed over every shape literal in ``text``."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class Op:
    name: str
    shape_text: str
    opcode: str
    rest: str       # operands + attributes tail of the line


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op] = dataclasses.field(default_factory=list)


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Dict[str, str],
                                    str]:
    """-> (computations, symbol table name->shape_text, entry name)."""
    comps: Dict[str, Computation] = {}
    shapes: Dict[str, str] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if line and not line[0].isspace():
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
            continue
        m = _OP_RE.match(line)
        if not m or cur is None:
            continue
        name, shape_text, opcode, rest = m.groups()
        cur.ops.append(Op(name, shape_text, opcode, rest))
        shapes[name] = shape_text
    return comps, shapes, entry


def _call_edges(op: Op) -> List[Tuple[str, bool]]:
    """[(callee, is_loop_body)] for one op."""
    out = []
    for key in ("calls", "to_apply", "body", "condition", "true_computation",
                "false_computation"):
        for m in re.finditer(rf"{key}=%?([\w.\-]+)", op.rest):
            out.append((m.group(1), key in ("body", "condition")))
    return out


def _trip_count(op: Op) -> int:
    m = re.search(r'known_trip_count[":{\s]*["n:\s]*"?(\d+)', op.rest)
    return int(m.group(1)) if m else 1


def computation_multipliers(comps: Dict[str, Computation],
                            entry: str) -> Dict[str, float]:
    """Execution count of each computation (product of trips on call chain).

    Iterative propagation from the entry (the call graph is a DAG)."""
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    if entry not in comps:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    # Topo-ish: repeat until fixpoint (graph is small).
    for _ in range(len(comps) + 2):
        changed = False
        acc: Dict[str, float] = {name: 0.0 for name in comps}
        acc[entry] = 1.0
        for cname, comp in comps.items():
            if mult.get(cname, 0.0) <= 0:
                continue
            for op in comp.ops:
                edges = _call_edges(op)
                if not edges:
                    continue
                trips = _trip_count(op) if op.opcode == "while" else 1
                for callee, is_loop in edges:
                    if callee in acc:
                        acc[callee] += mult[cname] * (trips if is_loop else 1)
        for name in comps:
            if name != entry and abs(acc[name] - mult[name]) > 1e-9:
                mult[name] = acc[name]
                changed = True
        if not changed:
            break
    return {k: max(v, 0.0) for k, v in mult.items()}


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    result_elems, _ = _shape_elems_bytes(op.shape_text)
    # lhs operand: first %name inside parens. Operands may be printed bare
    # ("dot(%a, %b)") or typed ("dot(f32[32,64]{1,0} %a, ...)"), so search
    # for the first reference rather than anchoring at the paren.
    mo = re.search(r"%([\w.\-]+)", op.rest)
    if not mo:
        return 0.0
    lhs_shape = shapes.get(mo.group(1), "")
    mdim = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    if not mdim or not lhs_shape:
        return 2.0 * result_elems  # degenerate
    sm = _SHAPE_RE.search(lhs_shape)
    if not sm:
        return 2.0 * result_elems
    dims = [int(d) for d in sm.group(2).split(",") if d]
    contracted = 1
    for ax in mdim.group(1).split(","):
        if ax:
            ax = int(ax)
            if ax < len(dims):
                contracted *= dims[ax]
    return 2.0 * result_elems * contracted


def _operand_bytes(op: Op, shapes: Dict[str, str]) -> int:
    total = 0
    # operands = %names before any ", attr=" — just scan all %refs in the
    # call parens segment (attrs reference computations with %, filter by
    # presence in symbol table).
    paren = op.rest.split("),")[0]
    for m in re.finditer(r"%([\w.\-]+)", paren):
        st = shapes.get(m.group(1))
        if st:
            total += _shape_elems_bytes(st)[1]
    return total


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    dot_count: int = 0
    unscaled_flops: float = 0.0

    @property
    def total_collective(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(hlo: str) -> HloCost:
    comps, shapes, entry = parse_module(hlo)
    mult = computation_multipliers(comps, entry)
    # Computations reached only through fusion `calls=` are register-level:
    # find the set of fused computations.
    fused = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                for callee, _ in _call_edges(op):
                    fused.add(callee)
            elif op.opcode in ("reduce", "scatter", "sort", "map",
                               "reduce-window", "select-and-scatter",
                               "all-reduce", "reduce-scatter",
                               "all-reduce-start"):
                for callee, _ in _call_edges(op):
                    fused.add(callee)  # tiny apply fns
    cost = HloCost(collective_bytes={c: 0.0 for c in COLLECTIVES})
    for cname, comp in comps.items():
        m = mult.get(cname, 1.0)
        if m <= 0:
            continue
        in_fused = cname in fused
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                f = _dot_flops(op, shapes)
                cost.flops += m * f
                cost.unscaled_flops += f
                cost.dot_count += 1
            elif oc == "convolution":
                # window flops ~ 2 * result * (kernel spatial * in_ch/feat)
                result_elems, _ = _shape_elems_bytes(op.shape_text)
                cost.flops += m * 2.0 * result_elems  # lower bound
            base = oc.replace("-start", "")
            if base in COLLECTIVES:
                _, b = _shape_elems_bytes(op.shape_text)
                # XLA:CPU promotes bf16 all-reduce accumulation to f32
                # (`to_apply=%add..._promoted`); TPU reduces natively in
                # bf16, so count the wire payload at half width.
                if "_promoted" in op.rest:
                    b //= 2
                cost.collective_bytes[base] += m * b
            if not in_fused and oc in _BOUNDARY_OPS:
                cost.bytes_accessed += _op_bytes_scaled(op, shapes, m)
    return cost


def _op_bytes_scaled(op: Op, shapes: Dict[str, str], m: float) -> float:
    """Traffic of one op executed ``m`` times.

    Operands much larger than the result inside a loop are slice-accessed
    stacked buffers (scan-stacked layer weights, chunked activations): the
    loop touches each element ~once over all iterations, so they count
    once, not x m.
    """
    _, rb = _shape_elems_bytes(op.shape_text)
    name = op.name
    if "dynamic-update-slice" in name or op.opcode == "dynamic-update-slice":
        small = 0
        paren = op.rest.split("),")[0]
        for mm in re.finditer(r"%([\w.\-]+)", paren):
            st = shapes.get(mm.group(1))
            if st:
                b = _shape_elems_bytes(st)[1]
                if b < rb:
                    small += b
        return m * 2.0 * small
    if "dynamic-slice" in name or op.opcode in ("dynamic-slice", "slice",
                                                "gather"):
        return m * 2.0 * rb  # read slice + write result
    total = m * rb
    paren = op.rest.split("),")[0]
    for mm in re.finditer(r"%([\w.\-]+)", paren):
        st = shapes.get(mm.group(1))
        if not st:
            continue
        b = _shape_elems_bytes(st)[1]
        if m > 1 and b > 8 * max(rb, 1):
            total += b          # stacked buffer: read once across the loop
        else:
            total += m * b
    return total


def _op_bytes(op: Op, shapes: Dict[str, str]) -> float:
    """Materialized traffic of one fusion-boundary op.

    Dynamic-slice reads only the slice; dynamic-update-slice writes only the
    update (the big buffer is aliased in place). XLA embeds the root opcode
    in fusion names, so `..._dynamic-update-slice_fusion` is handled the
    same way — without this, loop-carried buffers accessed via slices get
    counted in full every iteration (~100x overcount).
    """
    _, rb = _shape_elems_bytes(op.shape_text)
    name = op.name
    if "dynamic-update-slice" in name or op.opcode == "dynamic-update-slice":
        # count small operands (the update + indices) twice (read+write)
        small = 0
        paren = op.rest.split("),")[0]
        for mm in re.finditer(r"%([\w.\-]+)", paren):
            st = shapes.get(mm.group(1))
            if st:
                b = _shape_elems_bytes(st)[1]
                if b < rb:
                    small += b
        return 2.0 * small
    if "dynamic-slice" in name or op.opcode in ("dynamic-slice", "slice",
                                                "gather"):
        return 2.0 * rb  # read slice + write result
    return rb + _operand_bytes(op, shapes)


@register_check(
    "hlo.module.structure", family="hlo", layer="hlo", requires=("hlo",),
    description="HLO text parses, has an ENTRY computation, and yields "
                "finite roofline terms")
def check_hlo_module(ctx) -> list:
    """Structural sanity of a lowered module before roofline extraction."""
    out = []
    cid = "hlo.module.structure"
    comps, shapes, entry = parse_module(ctx.hlo)
    if not comps:
        out.append(error(
            cid, "no computations parsed from HLO text — not a "
                 "post-optimization module dump", layer="hlo",
            subject="module",
            fix_hint="pass compiled.as_text() (jax .lower(...).compile())"))
        return out
    if not entry:
        out.append(error(
            cid, f"module has {len(comps)} computations but no ENTRY — "
                 f"call-graph multipliers cannot anchor", layer="hlo",
            subject="module",
            fix_hint="dump the whole module, not a single computation"))
        return out
    dangling = []
    for comp in comps.values():
        for op in comp.ops:
            for callee, _ in _call_edges(op):
                if callee not in comps:
                    dangling.append(f"{comp.name}->{callee}")
    if dangling:
        out.append(warning(
            cid, f"{len(dangling)} call edges target computations missing "
                 f"from the module (e.g. {dangling[0]}) — costs below them "
                 f"are not counted", layer="hlo", subject="call-graph",
            fix_hint="the dump is truncated; re-dump the full module"))
    cost = analyze(ctx.hlo)
    if not (cost.flops >= 0 and cost.bytes_accessed >= 0):
        out.append(error(
            cid, f"roofline terms are not finite/non-negative "
                 f"(flops={cost.flops}, bytes={cost.bytes_accessed})",
            layer="hlo", subject="analyze()",
            fix_hint="trip-count or shape parsing regressed"))
    if not out:
        out.append(info(
            cid, f"{len(comps)} computations, entry {entry!r}: "
                 f"{cost.flops:.3g} flops, {cost.bytes_accessed:.3g} bytes, "
                 f"{cost.total_collective:.3g} collective bytes, "
                 f"{cost.dot_count} dots", layer="hlo", subject=entry))
    return out
