"""Frontier invariant checks (the "frontier" analyzer family).

Audits a :class:`repro.core.frontier.FrontierPlan` — the pending
dirty-frontier snapshot a cache-enabled :class:`~repro.api.session.Session`
exposes via ``frontier_state()`` — against the plan it claims to describe.
The two invariants mirror what the incremental executor path relies on:

  plan.frontier.closure    the per-layer dirty sets really are the k-hop
                           balls of the seeds over the *union* adjacency
                           (graph edges plus the removed-edge survivor
                           pairs), monotone in depth and within bounds
  plan.frontier.revision   the snapshot was cut at the adjacency the plan
                           is currently serving (a cache/plan revision
                           split is exactly the staleness bug the cache
                           tag exists to prevent)

Checks require both ``ctx.plan`` and ``ctx.frontier`` and are skipped —
not failed — on contexts without a frontier, so plain plan sweeps are
unaffected.
"""
from __future__ import annotations

from typing import Iterable

import numpy as np

from repro.analysis.diagnostics import (AnalysisContext, Diagnostic, error,
                                        info, register_check)
from repro.core.frontier import expand_frontier
from repro.kernels import ops


@register_check(
    "plan.frontier.closure", family="frontier", layer="plan",
    requires=("plan", "frontier"),
    description="per-layer dirty rows are the exact k-hop closure of the "
                "seeds over the union adjacency")
def check_frontier_closure(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    """Re-expand the frontier from its own seeds and demand agreement."""
    fp = ctx.frontier
    g = ctx.plan.graph
    v = g.num_vertices
    k = len(fp.rows)
    if fp.num_layers != k:
        yield error("plan.frontier.closure",
                    f"frontier claims {fp.num_layers} layers but carries "
                    f"{k} row sets", layer="plan", subject="rows",
                    fix_hint="rebuild the snapshot via "
                             "Session.frontier_state()")
        return
    for name, ids in [("seeds", fp.seeds)] + [
            (f"rows[{i}]", r) for i, r in enumerate(fp.rows)]:
        ids = np.asarray(ids)
        if len(ids) and (ids.min() < 0 or ids.max() >= v):
            yield error("plan.frontier.closure",
                        f"{name} contains out-of-range vertex ids "
                        f"(graph has {v} vertices)",
                        layer="plan", subject=name,
                        fix_hint="the cache was not remapped through the "
                                 "last delta's vertex map; clear it")
            return
    if len(fp.extra_edges):
        ee = np.asarray(fp.extra_edges)
        if ee.min() < 0 or ee.max() >= v:
            yield error("plan.frontier.closure",
                        "extra_edges reference out-of-range vertex ids",
                        layer="plan", subject="extra_edges",
                        fix_hint="remap or drop stale removed-edge pairs")
            return
    truth = expand_frontier(g, np.asarray(fp.seeds, np.int64),
                            np.asarray(fp.extra_edges, np.int64),
                            k)
    prev = np.asarray(fp.seeds, np.int64)
    for i, (got, want) in enumerate(zip(fp.rows, truth)):
        got = np.asarray(got, np.int64)
        missing = np.setdiff1d(want, got)
        if len(missing):
            yield error(
                "plan.frontier.closure",
                f"layer {i + 1} dirty set misses {len(missing)} vertices "
                f"of its {i + 1}-hop ball (e.g. {missing[:3].tolist()}) — "
                "an incremental pass would serve stale activations there",
                layer="plan", subject=f"rows[{i}]",
                fix_hint="expand_frontier must run over the union "
                         "adjacency (graph edges + extra_edges)")
            return
        if len(np.setdiff1d(prev, got)):
            yield error(
                "plan.frontier.closure",
                f"layer {i + 1} dirty set is not a superset of layer {i}'s "
                "— frontier depth must be monotone",
                layer="plan", subject=f"rows[{i}]",
                fix_hint="each BFS step must union, not replace, the "
                         "previous dirty set")
            return
        prev = got
    yield info("plan.frontier.closure",
               f"{k}-layer frontier of {len(fp.seeds)} seeds closed "
               f"correctly (|D_K| = {len(fp.rows[-1]) if k else 0} of {v})",
               layer="plan", subject="rows")


@register_check(
    "plan.frontier.revision", family="frontier", layer="plan",
    requires=("plan", "frontier"),
    description="frontier snapshot was cut at the adjacency the plan "
                "currently serves")
def check_frontier_revision(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    """Cache-revision agreement: the snapshot's fingerprint must match."""
    fp = ctx.frontier
    g = ctx.plan.graph
    if fp.num_vertices != g.num_vertices:
        yield error(
            "plan.frontier.revision",
            f"frontier was cut over {fp.num_vertices} vertices but the "
            f"plan serves {g.num_vertices}",
            layer="plan", subject="num_vertices",
            fix_hint="apply_update must remap the cache through every "
                     "flushed delta before the next query")
        return
    rev = ops.graph_fingerprint(g)
    if fp.revision != rev:
        yield error(
            "plan.frontier.revision",
            "frontier revision disagrees with the plan's adjacency "
            f"fingerprint ({fp.revision[:12]}… vs {rev[:12]}…) — cached "
            "activations would be served against a different graph",
            layer="plan", subject="revision",
            fix_hint="clear the activation cache or rebase it with a "
                     "full capturing pass")
        return
    yield info("plan.frontier.revision",
               "frontier revision matches the serving adjacency",
               layer="plan", subject="revision")
