"""repro.analysis — static plan/kernel/cache verifier.

Audits compiled :class:`~repro.api.plan.Plan` objects, the Pallas launch
geometry they imply, and the process-wide program/operand caches *without
executing anything*.  Six analyzer families (see ``docs/analysis.md`` for
the invariant catalogue):

  plan      partition coverage/disjointness, halo consistency, ELL padding,
            capacity skew, post-update layout agreement
  frontier  dirty-frontier closure soundness + cache-revision agreement of
            a session's pending incremental state
  fleet     geo-fleet router coverage, cross-tier graph-revision agreement,
            staleness_bound consistency of the stale-tolerant exchange
  fault     node-failure recovery: failover-plan eviction/coverage (and
            the cluster_spec=None pricing invariant), stale-halo layout
            agreement, retry-budget reachability + schedule well-formedness
  kernel    jax.eval_shape lint of block_spmm / dequant_spmm launches:
            grid divisibility, prefetch-table bounds, wire dtype, VMEM/SMEM
  cache     program/BlockCsr cache-key completeness + closure-pin detection
  hlo       post-lowering roofline-term extraction (ex launch.hlo_analysis)

Entry points::

    from repro.analysis import run_checks, verify_plan
    report = run_checks(plan)                  # plan+kernel+cache families
    verify_plan(plan, mode="strict")           # what EngineConfig.validate
                                               # plumbs into Engine.compile
    python -m repro.analysis --demo --strict   # CI sweep over registry
                                               # combination plans
"""
from repro.analysis.diagnostics import (AnalysisContext, CHECKS, Diagnostic,
                                        PlanInvariantWarning,
                                        PlanValidationError, Report,
                                        SEVERITIES, VALIDATE_MODES,
                                        checks_for, register_check,
                                        run_checks, verify_plan)

# Importing the check modules registers every check in CHECKS.
from repro.analysis import cache_audit    # noqa: E402,F401
from repro.analysis import fault_checks   # noqa: E402,F401
from repro.analysis import fleet_checks   # noqa: E402,F401
from repro.analysis import frontier_checks  # noqa: E402,F401
from repro.analysis import hlo            # noqa: E402,F401
from repro.analysis import kernel_lint    # noqa: E402,F401
from repro.analysis import plan_checks    # noqa: E402,F401

__all__ = [
    "AnalysisContext", "CHECKS", "Diagnostic", "PlanInvariantWarning",
    "PlanValidationError", "Report", "SEVERITIES", "VALIDATE_MODES",
    "cache_audit", "checks_for", "fault_checks", "fleet_checks",
    "frontier_checks", "hlo", "kernel_lint",
    "plan_checks", "register_check", "run_checks", "verify_plan",
]
