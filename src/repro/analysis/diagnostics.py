"""Diagnostics framework for the static plan/kernel/cache verifier.

Everything the analyzers emit is a :class:`Diagnostic` — one finding with a
stable check id (``"plan.halo.consistency"``), a severity, the layer it
lives in and a fix hint.  Checks are plain functions registered in the
string-keyed :data:`CHECKS` registry (the same :class:`~repro.api.registry.
Registry` class behind the five pipeline registries), take an
:class:`AnalysisContext` and yield diagnostics; :func:`run_checks` collects
them into a :class:`Report`.

The catalogue lives in ``docs/analysis.md``; the four analyzer families are

  plan    invariants of a compiled :class:`~repro.api.plan.Plan`
          (``repro.analysis.plan_checks``)
  kernel  Pallas launch-geometry lint over the plan's implied kernel
          launches (``repro.analysis.kernel_lint``)
  cache   audit of the process-wide compiled-program / BlockCsr caches
          (``repro.analysis.cache_audit``)
  hlo     post-lowering roofline-term extraction
          (``repro.analysis.hlo``, the former ``launch.hlo_analysis``)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, List, Optional, Sequence, Tuple

from repro.api.registry import Registry

#: legal Diagnostic severities, in decreasing order of gravity.
SEVERITIES = ("error", "warning", "info")

#: legal values of the ``EngineConfig.validate`` knob.
VALIDATE_MODES = ("off", "warn", "strict")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of a static check.

    ``check_id`` is the stable dotted id of the check that produced it
    (``family.subject.property``); ``layer`` names the stack layer the
    invariant lives in ("plan", "kernel", "cache", "hlo"); ``subject``
    pinpoints the object ("halo_csr[2]", "key[3]"); ``fix_hint`` tells the
    operator what to do about it.
    """
    check_id: str
    severity: str
    message: str
    layer: str = ""
    subject: str = ""
    fix_hint: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}; "
                             f"available: {', '.join(SEVERITIES)}")

    def format(self) -> str:
        loc = f" [{self.subject}]" if self.subject else ""
        hint = f"\n      fix: {self.fix_hint}" if self.fix_hint else ""
        return (f"{self.severity.upper():7s} {self.check_id}{loc}: "
                f"{self.message}{hint}")


def error(check_id: str, message: str, **kw) -> Diagnostic:
    return Diagnostic(check_id, "error", message, **kw)


def warning(check_id: str, message: str, **kw) -> Diagnostic:
    return Diagnostic(check_id, "warning", message, **kw)


def info(check_id: str, message: str, **kw) -> Diagnostic:
    return Diagnostic(check_id, "info", message, **kw)


@dataclasses.dataclass
class AnalysisContext:
    """Everything a check may inspect.  ``plan`` feeds the plan/kernel
    families, ``hlo`` (post-optimization HLO text) the hlo family; the two
    cache handles default to the live process-wide caches and exist so
    tests can audit synthetic cache states."""
    plan: Optional[object] = None
    hlo: Optional[str] = None
    program_cache: Optional[dict] = None
    block_csr_cache: Optional[dict] = None
    #: pending dirty-frontier snapshot (:class:`repro.core.frontier.
    #: FrontierPlan`, from ``Session.frontier_state()``) for the frontier
    #: family; None on sessions without an activation cache.
    frontier: Optional[object] = None
    #: geo-distributed fleet state for the fleet family: a
    #: :class:`repro.api.fleet.FleetServer` (router + live per-site
    #: sessions; the full audit) or a bare ``Fleet`` (compiled plans
    #: only — the revision check still runs, the router/serving checks
    #: report what a bare fleet cannot violate).
    fleet: Optional[object] = None
    #: node-failure recovery state for the fault family: a
    #: :class:`repro.api.faults.FailoverAudit` bundling a post-failover
    #: plan with the full-cluster plan it degraded from, the crashed node
    #: names, and optionally the live Server / the replayed FaultSchedule.
    failover: Optional[object] = None
    #: representative micro-batch size for lint of the batched kernels.
    batch_probe: int = 8

    def resolved_program_cache(self) -> dict:
        if self.program_cache is None:
            from repro.runtime import bsp
            return bsp._PROGRAM_CACHE
        return self.program_cache

    def resolved_block_csr_cache(self) -> dict:
        if self.block_csr_cache is None:
            from repro.kernels import ops
            return ops._BLOCK_CSR_CACHE
        return self.block_csr_cache


#: check-id -> check function; one entry per invariant in docs/analysis.md.
CHECKS = Registry("analysis check")


def register_check(check_id: str, *, family: str, layer: str,
                   requires: Tuple[str, ...] = ("plan",),
                   description: str = ""):
    """Decorator: register ``fn(ctx) -> Iterable[Diagnostic]`` under
    ``check_id``.  ``requires`` names the AnalysisContext attributes the
    check needs (it is skipped, not failed, when one is None)."""
    def wrap(fn: Callable[[AnalysisContext], Iterable[Diagnostic]]):
        fn.check_id = check_id
        fn.family = family
        fn.layer = layer
        fn.requires = tuple(requires)
        fn.description = description or (fn.__doc__ or "").strip().split(
            "\n")[0]
        CHECKS.register(check_id, fn)
        return fn
    return wrap


def checks_for(families: Optional[Sequence[str]] = None) -> List[Callable]:
    """Registered checks, optionally filtered to the given families."""
    out = []
    for cid in CHECKS:
        fn = CHECKS.resolve(cid)
        if families is None or fn.family in families:
            out.append(fn)
    return out


@dataclasses.dataclass
class Report:
    """The outcome of one verifier run: which checks ran, what they found."""
    diagnostics: List[Diagnostic] = dataclasses.field(default_factory=list)
    ran: Tuple[str, ...] = ()
    skipped: Tuple[str, ...] = ()

    @property
    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def by_check(self, check_id: str) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.check_id == check_id]

    def check_ids(self) -> set:
        return {d.check_id for d in self.diagnostics}

    def format(self, verbose: bool = False) -> str:
        lines = []
        for d in self.diagnostics:
            if d.severity == "info" and not verbose:
                continue
            lines.append(d.format())
        tally = (f"{len(self.ran)} checks ran, {len(self.errors)} errors, "
                 f"{len(self.warnings)} warnings")
        if self.skipped:
            tally += f" ({len(self.skipped)} skipped: missing inputs)"
        lines.append(tally)
        return "\n".join(lines)

    def raise_if_errors(self) -> "Report":
        if self.errors:
            raise PlanValidationError(self)
        return self

    def extend(self, other: "Report") -> "Report":
        self.diagnostics.extend(other.diagnostics)
        self.ran = tuple(self.ran) + tuple(other.ran)
        self.skipped = tuple(self.skipped) + tuple(other.skipped)
        return self


class PlanValidationError(RuntimeError):
    """Raised by strict validation when any check reports an error."""

    def __init__(self, report: Report):
        self.report = report
        super().__init__(
            f"{len(report.errors)} invariant violation(s):\n"
            + "\n".join(d.format() for d in report.errors))


class PlanInvariantWarning(UserWarning):
    """Category used by warn-mode validation (targetable by filters)."""


def run_checks(ctx_or_plan, families: Optional[Sequence[str]] = None,
               checks: Optional[Sequence[str]] = None) -> Report:
    """Run registered checks against a plan or a full AnalysisContext.

    ``families`` filters by analyzer family ("plan", "kernel", "cache",
    "hlo"); ``checks`` filters by exact check id.  A check whose required
    context attributes are missing is recorded as skipped.  A check that
    *crashes* is reported as an error on its own id — a broken verifier
    must never pass silently.
    """
    ctx = (ctx_or_plan if isinstance(ctx_or_plan, AnalysisContext)
           else AnalysisContext(plan=ctx_or_plan))
    fns = checks_for(families)
    if checks is not None:
        wanted = set(checks)
        for cid in wanted:
            CHECKS.resolve(cid)   # fail fast on unknown ids
        fns = [f for f in fns if f.check_id in wanted]
    report = Report()
    ran, skipped = [], []
    for fn in fns:
        if any(getattr(ctx, r, None) is None for r in fn.requires):
            skipped.append(fn.check_id)
            continue
        try:
            report.diagnostics.extend(fn(ctx))
        except Exception as e:  # noqa: BLE001 — verifier crash = finding
            report.diagnostics.append(error(
                fn.check_id, f"check crashed: {type(e).__name__}: {e}",
                layer=fn.layer, subject="(verifier)",
                fix_hint="fix the check in repro.analysis — a crashing "
                         "verifier must not pass silently"))
        ran.append(fn.check_id)
    report.ran = tuple(ran)
    report.skipped = tuple(skipped)
    return report


def verify_plan(plan, mode: str = "strict",
                families: Sequence[str] = ("plan",)) -> Report:
    """Engine-facing entry point: run the plan invariant checks.

    ``mode="strict"`` raises :class:`PlanValidationError` on any error;
    ``mode="warn"`` emits a :class:`PlanInvariantWarning` per error/warning
    and returns; ``mode="off"`` is a no-op.  This is what
    ``EngineConfig.validate`` plumbs into ``Engine.compile`` /
    ``Engine.apply_delta``.
    """
    if mode not in VALIDATE_MODES:
        raise ValueError(f"unknown validate mode {mode!r}; available: "
                         f"{', '.join(VALIDATE_MODES)}")
    if mode == "off":
        return Report()
    report = run_checks(plan, families=families)
    if mode == "strict":
        report.raise_if_errors()
    else:
        import warnings as _warnings
        for d in report.diagnostics:
            if d.severity in ("error", "warning"):
                _warnings.warn(d.format(), PlanInvariantWarning,
                               stacklevel=3)
    return report
