"""Fleet invariant checks (the "fleet" analyzer family).

Audits the geo-distributed serving layer (``repro.api.fleet``) — pass a
:class:`~repro.api.fleet.FleetServer` (or a bare ``Fleet``) as
``ctx.fleet``. Three invariants mirror what the router and the
stale-tolerant exchange rely on:

  fleet.router.coverage       the routing table covers EVERY fleet site
                              with its true centroid — a site missing
                              from the table silently never receives
                              traffic (worse than being marked down,
                              which reroutes visibly)
  fleet.revision.agreement    every tier (each site plan + the cloud)
                              serves the same graph revision; after an
                              update fan-out a diverging tier would
                              answer queries against a different graph
  fleet.staleness.consistency the FleetServer's ``staleness_bound``
                              agrees with each site session's halo-store
                              bound, every bound > 0 rides a
                              stale-tolerant exchange entry, and the
                              cloud tier always serves fresh

Checks require ``ctx.fleet`` and are skipped — not failed — on contexts
without one, so plain plan sweeps are unaffected.
"""
from __future__ import annotations

from typing import Iterable, Optional, Tuple

from repro.analysis.diagnostics import (AnalysisContext, Diagnostic, error,
                                        info, register_check)
from repro.api.registry import EXCHANGES
from repro.kernels import ops


def _unpack(obj) -> Tuple[object, Optional[object]]:
    """``ctx.fleet`` -> (Fleet, FleetServer-or-None)."""
    if hasattr(obj, "router"):          # FleetServer
        return obj.fleet, obj
    return obj, None                    # bare Fleet


def _tier_revision(g) -> str:
    """Full serving revision of one tier's graph: adjacency fingerprint
    extended with the feature table. ``ops.graph_fingerprint`` hashes
    adjacency only (all the operand caches need), but a feature-only
    delta applied to one tier still makes it answer differently — tier
    agreement must see it."""
    import hashlib

    import numpy as np
    d = hashlib.blake2b(digest_size=16)
    d.update(ops.graph_fingerprint(g).encode())
    d.update(np.ascontiguousarray(g.features, np.float32).tobytes())
    return d.hexdigest()


@register_check(
    "fleet.router.coverage", family="fleet", layer="fleet",
    requires=("fleet",),
    description="routing table covers every fleet site at its true "
                "centroid")
def check_router_coverage(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    """Every site must be routable: table keys == fleet sites, centroids
    agree. (Down sites stay IN the table — the route policy skips them
    visibly; a missing entry is invisible starvation.)"""
    fleet, fs = _unpack(ctx.fleet)
    if fs is None:
        yield info("fleet.router.coverage",
                   "bare Fleet carries no router — nothing to cover yet",
                   layer="fleet", subject="router")
        return
    table = fs.router.table
    names = set(fleet.site_names)
    missing = sorted(names - set(table))
    if missing:
        yield error(
            "fleet.router.coverage",
            f"routing table misses site(s) {missing} — requests can "
            "never be routed there (silent starvation)",
            layer="fleet", subject="router.table",
            fix_hint="rebuild the Router from the Fleet; the table must "
                     "enumerate every Site, down or not")
        return
    extra = sorted(set(table) - names)
    if extra:
        yield error(
            "fleet.router.coverage",
            f"routing table lists unknown site(s) {extra} — requests "
            "routed there have no server",
            layer="fleet", subject="router.table",
            fix_hint="rebuild the Router from the Fleet")
        return
    for site in fleet.sites:
        if tuple(table[site.name]) != tuple(site.location):
            yield error(
                "fleet.router.coverage",
                f"site {site.name!r} centroid drifted: table says "
                f"{tuple(table[site.name])}, fleet says "
                f"{tuple(site.location)} — nearest-site ranking is wrong",
                layer="fleet", subject=f"table[{site.name!r}]",
                fix_hint="the table entry must be the Site.location")
            return
    yield info("fleet.router.coverage",
               f"routing table covers all {len(names)} sites "
               f"({len(fs.router.down_sites)} currently down)",
               layer="fleet", subject="router.table")


@register_check(
    "fleet.revision.agreement", family="fleet", layer="fleet",
    requires=("fleet",),
    description="every tier (sites + cloud) serves one graph revision")
def check_revision_agreement(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    """After an update fan-out all tiers must fingerprint identically; a
    diverging tier answers queries against a different graph."""
    fleet, fs = _unpack(ctx.fleet)
    if fs is not None:
        graphs = [(name, fs.servers[name].session.plan.graph)
                  for name in fs.tier_names]
    else:
        graphs = [(s.name, s.plan.graph) for s in fleet.sites]
        graphs.append(("cloud", fleet.cloud_plan.graph))
    revs = {name: _tier_revision(g) for name, g in graphs}
    distinct = sorted(set(revs.values()))
    if len(distinct) > 1:
        by_rev = {r: sorted(n for n, v in revs.items() if v == r)
                  for r in distinct}
        yield error(
            "fleet.revision.agreement",
            f"{len(distinct)} graph revisions across tiers: "
            + "; ".join(f"{r[:12]}… -> {ns}" for r, ns in by_rev.items())
            + " — an update fan-out missed at least one tier",
            layer="fleet", subject="graph",
            fix_hint="apply every GraphDelta through FleetServer.update "
                     "so sites and cloud move together")
        return
    yield info("fleet.revision.agreement",
               f"all {len(revs)} tiers on revision {distinct[0][:12]}…",
               layer="fleet", subject="graph")


@register_check(
    "fleet.staleness.consistency", family="fleet", layer="fleet",
    requires=("fleet",),
    description="staleness_bound agrees between FleetServer config, "
                "per-site halo stores and the exchange entry")
def check_staleness_consistency(ctx: AnalysisContext
                                ) -> Iterable[Diagnostic]:
    """The bound the facade reports must be the bound the sessions
    enforce, and any bound > 0 must ride a stale-tolerant exchange."""
    fleet, fs = _unpack(ctx.fleet)
    if fs is None:
        bounds = {s.name: s.plan.config.staleness_bound
                  for s in fleet.sites}
        for name, bound in bounds.items():
            exch = EXCHANGES.resolve(
                fleet.site(name).plan.config.exchange)
            if bound > 0 and not getattr(exch, "stale_tolerant", False):
                yield error(
                    "fleet.staleness.consistency",
                    f"site {name!r} plan has staleness_bound={bound} on "
                    f"exchange {exch.name!r}, which is not stale-tolerant",
                    layer="fleet", subject=f"{name}.config",
                    fix_hint="compile with exchange='halo_async' or "
                             "staleness_bound=0")
                return
        yield info("fleet.staleness.consistency",
                   f"site plan bounds {sorted(set(bounds.values()))} all "
                   "ride stale-tolerant exchanges (or are 0)",
                   layer="fleet", subject="config")
        return
    declared = int(fs.staleness_bound)
    site_bounds = {}
    for name in fleet.site_names:
        sess = fs.servers[name].session
        store = getattr(sess, "_halo", None)
        site_bounds[name] = 0 if store is None else int(store.bound)
        exch = EXCHANGES.resolve(sess.plan.config.exchange)
        if site_bounds[name] > 0 and not getattr(exch, "stale_tolerant",
                                                 False):
            yield error(
                "fleet.staleness.consistency",
                f"site {name!r} serves with bound {site_bounds[name]} on "
                f"exchange {exch.name!r}, which is not stale-tolerant — "
                "its halo replay has no contract",
                layer="fleet", subject=f"{name}.session",
                fix_hint="only 'halo_async' (ExchangeSpec.stale_tolerant) "
                         "may serve stale halo tables")
            return
    effective = max(site_bounds.values()) if site_bounds else 0
    if declared != effective:
        yield error(
            "fleet.staleness.consistency",
            f"FleetServer declares staleness_bound={declared} but its "
            f"site sessions enforce {site_bounds} (effective {effective}) "
            "— reported response staleness would not match the contract",
            layer="fleet", subject="staleness_bound",
            fix_hint="thread one bound through FleetServer(staleness_"
                     "bound=...) instead of mutating sessions directly")
        return
    cloud_store = getattr(fs.servers["cloud"].session, "_halo", None)
    if cloud_store is not None:
        yield error(
            "fleet.staleness.consistency",
            "the cloud tier carries a halo store — the last-resort tier "
            "must always serve fresh (it holds the whole graph; there is "
            "no exchange to skip)",
            layer="fleet", subject="cloud.session",
            fix_hint="compile the cloud plan with staleness_bound=0")
        return
    yield info("fleet.staleness.consistency",
               f"bound {declared} consistent across facade, "
               f"{len(site_bounds)} site sessions and exchange entries "
               "(cloud fresh)",
               layer="fleet", subject="staleness_bound")
