"""Plan invariant checks (the "plan" analyzer family).

Every check re-derives ground truth from the plan's own ``Graph`` +
assignment and compares it against the frozen serving buffers — partition
coverage, halo layout, ELL-block-CSR padding, capacity balance, and the
cross-field agreement that ``Engine.apply_delta`` must preserve.  Nothing
here executes a query: a corrupted plan is caught before it serves.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterable, List

import numpy as np

from repro.analysis.diagnostics import (AnalysisContext, Diagnostic,
                                        VALIDATE_MODES, error, info,
                                        register_check, warning)
from repro.api.registry import ALL_REGISTRIES

#: a predicted makespan this far above the mean per-fog total means the
#: profiled fog model expects one fog to dominate the BSP superstep.
CAPACITY_SKEW_THRESHOLD = 2.5


def _binary(arr: np.ndarray) -> bool:
    return bool(np.isin(arr, (0.0, 1.0)).all())


def _expected_layout(g, part_of: np.ndarray, n: int, b_pad: int):
    """Re-derive the halo layout of ``build_partitioned`` from scratch:
    per-partition boundary sets and each vertex's halo slot."""
    recv_part = part_of[g.receivers]
    boundary_ids: List[np.ndarray] = []
    for p in range(n):
        cross = (part_of[g.senders] == p) & (recv_part != p)
        boundary_ids.append(np.unique(g.senders[cross]))
    halo_slot = np.zeros(g.num_vertices, np.int64)
    for bs in boundary_ids:
        halo_slot[bs] = np.arange(len(bs))
    return recv_part, boundary_ids, halo_slot


def _decode_shard(csr, p: int, block: int) -> Counter:
    """Real (src_row, dst_row) -> multiplicity of one stacked shard."""
    edges: Counter = Counter()
    vb, m = csr.cols.shape[1:3]
    for i in range(vb):
        for k in range(m):
            if csr.mask[p, i, k] == 0.0:
                continue
            rr, cc = np.nonzero(csr.blocks[p, i, k])
            base_src = int(csr.cols[p, i, k]) * block
            for r, c, w in zip(rr, cc, csr.blocks[p, i, k][rr, cc]):
                edges[(base_src + int(c), i * block + int(r))] += int(
                    round(float(w)))
    return edges


@register_check(
    "plan.partition.coverage", family="plan", layer="plan",
    description="every vertex occupies exactly one live (partition, slot)")
def check_partition_coverage(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    plan = ctx.plan
    g, pg = plan.graph, plan.partitioned
    out = []
    cid = "plan.partition.coverage"
    hint = ("rebuild the layout with bsp.build_partitioned — a partial "
            "apply_delta left the inverse permutation stale")
    if len(pg.part_of) != g.num_vertices or len(pg.slot_of) != g.num_vertices:
        out.append(error(cid, f"inverse permutation covers "
                              f"{len(pg.part_of)} vertices, graph has "
                              f"{g.num_vertices}", layer="plan",
                         subject="part_of/slot_of", fix_hint=hint))
        return out
    if g.num_vertices == 0:
        return out
    if pg.part_of.min() < 0 or pg.part_of.max() >= pg.n:
        out.append(error(cid, f"part_of values outside [0, {pg.n})",
                         layer="plan", subject="part_of", fix_hint=hint))
        return out
    if pg.slot_of.min() < 0 or pg.slot_of.max() >= pg.slots:
        out.append(error(cid, f"slot_of values outside [0, {pg.slots})",
                         layer="plan", subject="slot_of", fix_hint=hint))
        return out
    occupied = pg.vertex_mask[pg.part_of, pg.slot_of]
    if not np.all(occupied == 1.0):
        bad = int(np.sum(occupied != 1.0))
        out.append(error(cid, f"{bad} vertices map to slots whose "
                              f"vertex_mask is 0 (dead slots)",
                         layer="plan", subject="vertex_mask", fix_hint=hint))
    live = int(pg.vertex_mask.sum())
    if live != g.num_vertices:
        out.append(error(cid, f"vertex_mask marks {live} live slots for "
                              f"{g.num_vertices} vertices", layer="plan",
                         subject="vertex_mask", fix_hint=hint))
    return out


@register_check(
    "plan.partition.disjoint", family="plan", layer="plan",
    description="the vertex -> (partition, slot) map is injective")
def check_partition_disjoint(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    pg = ctx.plan.partitioned
    flat = pg.part_of * pg.slots + pg.slot_of
    dup = len(flat) - len(np.unique(flat))
    if dup:
        return [error(
            "plan.partition.disjoint",
            f"{dup} vertex pairs share a (partition, slot) — their "
            f"embeddings would overwrite each other", layer="plan",
            subject="part_of/slot_of",
            fix_hint="rebuild the layout; two vertices were assigned the "
                     "same slot (corrupt repair_assignment output)")]
    return []


@register_check(
    "plan.layout.masks", family="plan", layer="plan",
    description="masks are binary, padded rows zeroed, indices in range")
def check_layout_masks(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    plan = ctx.plan
    g, pg = plan.graph, plan.partitioned
    out = []
    cid = "plan.layout.masks"
    for name in ("vertex_mask", "edge_mask", "boundary_mask"):
        if not _binary(getattr(pg, name)):
            out.append(error(cid, f"{name} contains values outside "
                                  "{0, 1}; masked multiply-accumulate "
                                  "would scale real data", layer="plan",
                             subject=name,
                             fix_hint="masks must be exactly 0.0/1.0"))
    live_edges = int(pg.edge_mask.sum())
    if live_edges != g.num_edges:
        out.append(error(cid, f"edge_mask marks {live_edges} live edges, "
                              f"graph has {g.num_edges}", layer="plan",
                         subject="edge_mask",
                         fix_hint="rebuild the layout — the per-partition "
                                  "edge split lost or duplicated edges"))
    padded = pg.feats * (1.0 - pg.vertex_mask[..., None])
    if padded.any():
        out.append(error(cid, "padded feature rows are non-zero; kernels "
                              "blindly multiply-accumulate padding",
                         layer="plan", subject="feats",
                         fix_hint="zero rows where vertex_mask == 0"))
    bounds = ((pg.senders_global, pg.n * pg.slots, "senders_global"),
              (pg.senders_halo, pg.slots + pg.n * pg.boundary_slots,
               "senders_halo"),
              (pg.receivers_local, pg.slots, "receivers_local"),
              (pg.boundary_rows, pg.slots, "boundary_rows"))
    for arr, limit, name in bounds:
        if arr.size and (arr.min() < 0 or arr.max() >= limit):
            out.append(error(cid, f"{name} indexes outside [0, {limit})",
                             layer="plan", subject=name,
                             fix_hint="gather would read out of the padded "
                                      "table — rebuild the layout"))
    return out


@register_check(
    "plan.halo.consistency", family="plan", layer="plan",
    description="halo tables/tiles carry exactly the cross-partition edges")
def check_halo_consistency(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    plan = ctx.plan
    g, pg = plan.graph, plan.partitioned
    out = []
    cid = "plan.halo.consistency"
    n, b_pad, slots = pg.n, pg.boundary_slots, pg.slots
    part_of, slot_of = pg.part_of, pg.slot_of
    recv_part, boundary_ids, halo_slot = _expected_layout(
        g, part_of, n, b_pad)
    # 1) Boundary table: partition p must export exactly its boundary set.
    for p in range(n):
        bs = boundary_ids[p]
        if len(bs) > b_pad:
            out.append(error(
                cid, f"partition {p} has {len(bs)} boundary vertices but "
                     f"only {b_pad} boundary slots", layer="plan",
                subject=f"boundary_rows[{p}]",
                fix_hint="boundary capacity under-sized — rebuild layout"))
            continue
        want_rows = slot_of[bs]
        got_rows = pg.boundary_rows[p, :len(bs)]
        got_live = int(pg.boundary_mask[p].sum())
        if got_live != len(bs) or not np.array_equal(got_rows, want_rows):
            out.append(error(
                cid, f"partition {p} exports {got_live} boundary rows, "
                     f"expected {len(bs)} (the vertices foreign partitions "
                     f"actually read)", layer="plan",
                subject=f"boundary_rows[{p}]",
                fix_hint="a halo row was dropped/added without rebuilding "
                         "the exchange map — run a dirty-shard rebuild "
                         "covering this partition"))
    # 2) COO halo senders: every cross-partition edge must address the
    #    combined [local slots | n*b_pad halo] table correctly.
    for p in range(n):
        eids = np.flatnonzero(recv_part == p)
        s, r = g.senders[eids], g.receivers[eids]
        local = part_of[s] == p
        want = np.where(local, slot_of[s],
                        slots + part_of[s] * b_pad + halo_slot[s])
        got = pg.senders_halo[p, :len(eids)]
        if not np.array_equal(got, want):
            bad = int(np.sum(got != want))
            out.append(error(
                cid, f"partition {p}: {bad} edges address the wrong row of "
                     f"the combined halo table", layer="plan",
                subject=f"senders_halo[{p}]",
                fix_hint="halo slot assignment drifted from the boundary "
                         "sets — rebuild the exchange map"))
        want_recv = slot_of[r]
        if not np.array_equal(pg.receivers_local[p, :len(eids)], want_recv):
            out.append(error(
                cid, f"partition {p}: receiver slots disagree with the "
                     f"graph's edges", layer="plan",
                subject=f"receivers_local[{p}]",
                fix_hint="rebuild the layout"))
    # 3) Block-CSR shards (kernel path): decoded tiles must equal the
    #    local/remote edge multisets — every halo column a real remote
    #    neighbor, and nothing else.
    if pg.halo_csr is not None:
        block = pg.halo_csr.blocks.shape[-1]
        for p in range(n):
            eids = np.flatnonzero(recv_part == p)
            s, r = g.senders[eids], g.receivers[eids]
            remote = part_of[s] != p
            want = Counter(zip(
                (part_of[s[remote]] * b_pad + halo_slot[s[remote]]).tolist(),
                slot_of[r[remote]].tolist()))
            got = _decode_shard(pg.halo_csr, p, block)
            if got != want:
                missing = sum((want - got).values())
                extra = sum((got - want).values())
                out.append(error(
                    cid, f"partition {p}: halo block-CSR disagrees with the "
                         f"graph's cross-partition edges ({missing} "
                         f"missing, {extra} spurious)", layer="plan",
                    subject=f"halo_csr[{p}]",
                    fix_hint="a stale/corrupt tile survived a dirty-shard "
                             "rebuild — invalidate and re-block this shard"))
    if pg.local_csr is not None:
        block = pg.local_csr.blocks.shape[-1]
        for p in range(n):
            eids = np.flatnonzero(recv_part == p)
            s, r = g.senders[eids], g.receivers[eids]
            local = part_of[s] == p
            want = Counter(zip(slot_of[s[local]].tolist(),
                               slot_of[r[local]].tolist()))
            got = _decode_shard(pg.local_csr, p, block)
            if got != want:
                missing = sum((want - got).values())
                extra = sum((got - want).values())
                out.append(error(
                    cid, f"partition {p}: local block-CSR disagrees with "
                         f"the shard's own edges ({missing} missing, "
                         f"{extra} spurious)", layer="plan",
                    subject=f"local_csr[{p}]",
                    fix_hint="re-block this shard"))
    return out


@register_check(
    "plan.blocks.ell", family="plan", layer="plan",
    description="ELL padding discipline and block-CSR geometry")
def check_blocks_ell(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    pg = ctx.plan.partitioned
    out = []
    cid = "plan.blocks.ell"
    for name, csr in (("local_csr", pg.local_csr),
                      ("halo_csr", pg.halo_csr)):
        if csr is None:
            continue
        n, vb, m, b, b2 = csr.blocks.shape
        if b != b2:
            out.append(error(cid, f"{name}: tiles are {b}x{b2}, expected "
                                  "square MXU tiles", layer="plan",
                             subject=name, fix_hint="rebuild the shards"))
        if csr.blocks.dtype != np.float32 or csr.mask.dtype != np.float32:
            out.append(error(cid, f"{name}: tiles/mask must be float32, got "
                                  f"{csr.blocks.dtype}/{csr.mask.dtype}",
                             layer="plan", subject=name,
                             fix_hint="the kernels accumulate in f32"))
        if not np.issubdtype(csr.cols.dtype, np.integer):
            out.append(error(cid, f"{name}: cols must be integer, got "
                                  f"{csr.cols.dtype}", layer="plan",
                             subject=name,
                             fix_hint="scalar-prefetch tables are i32"))
        if csr.cols.shape != (n, vb, m) or csr.mask.shape != (n, vb, m):
            out.append(error(cid, f"{name}: cols/mask shapes "
                                  f"{csr.cols.shape}/{csr.mask.shape} do "
                                  f"not match tiles {(n, vb, m)}",
                             layer="plan", subject=name,
                             fix_hint="rebuild the shards"))
            continue
        if not _binary(csr.mask):
            out.append(error(cid, f"{name}: block_mask values outside "
                                  "{0, 1}", layer="plan", subject=name,
                             fix_hint="ELL tile masks are exactly 0/1"))
        if csr.out_rows != vb * b:
            out.append(error(cid, f"{name}: out_rows {csr.out_rows} != "
                                  f"VB*B = {vb * b}", layer="plan",
                             subject=name, fix_hint="rebuild the shards"))
        if csr.out_rows < pg.slots:
            out.append(error(cid, f"{name}: out_rows {csr.out_rows} cannot "
                                  f"cover the {pg.slots} partition slots",
                             layer="plan", subject=name,
                             fix_hint="rebuild the shards"))
        if csr.src_rows % b != 0:
            out.append(error(cid, f"{name}: src_rows {csr.src_rows} is not "
                                  f"a multiple of the {b} tile edge",
                             layer="plan", subject=name,
                             fix_hint="pad the source table to the tile "
                                      "grid"))
        src_tables = {"local_csr": pg.slots,
                      "halo_csr": pg.n * pg.boundary_slots}
        want_src = int(-(-src_tables[name] // b) * b)
        if csr.src_rows != want_src:
            out.append(error(cid, f"{name}: src_rows {csr.src_rows} != "
                                  f"{want_src} (padded source-table rows)",
                             layer="plan", subject=name,
                             fix_hint="the kernels pad the source table to "
                                      "src_rows at launch; a mismatch "
                                      "reads garbage rows"))
        pad = csr.mask == 0.0
        if np.any(csr.cols[pad] != 0):
            out.append(error(cid, f"{name}: ELL padding tiles must point "
                                  f"at source block 0 (got non-zero cols "
                                  f"under mask==0)", layer="plan",
                             subject=name,
                             fix_hint="padding tiles index block 0 so the "
                                      "masked matmul stays in bounds"))
        if np.any(csr.blocks[pad] != 0.0):
            out.append(error(cid, f"{name}: ELL padding tiles carry "
                                  f"non-zero weights", layer="plan",
                             subject=name,
                             fix_hint="zero the padding tiles — the mask "
                                      "multiplies the matmul result, not "
                                      "the operand load"))
    return out


@register_check(
    "plan.capacity.imbalance", family="plan", layer="plan",
    description="profiled fog model predicts a balanced BSP superstep")
def check_capacity_imbalance(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    plan = ctx.plan
    pl = plan.placement
    out = []
    cid = "plan.capacity.imbalance"
    tot = np.asarray(pl.est_total, float)
    if len(tot) > 1 and tot.mean() > 0:
        skew = float(tot.max() / tot.mean())
        if skew > CAPACITY_SKEW_THRESHOLD:
            worst = int(tot.argmax())
            out.append(warning(
                cid, f"fog {plan.fogs[worst].name!r} is predicted to take "
                     f"{skew:.1f}x the mean per-fog total "
                     f"({tot.max():.4f}s vs {tot.mean():.4f}s mean) — the "
                     f"BSP superstep stalls on it every layer",
                layer="plan", subject=f"est_total[{worst}]",
                fix_hint="repartition (apply_delta crossed a capacity "
                         "cliff) or re-run placement against fresh fog "
                         "profiles"))
        mk = float(pl.est_makespan)
        if not np.isclose(mk, tot.max(), rtol=1e-9, atol=1e-12):
            out.append(error(
                cid, f"est_makespan {mk:.6f} disagrees with "
                     f"max(est_total) {tot.max():.6f}", layer="plan",
                subject="placement",
                fix_hint="the placement estimates were mutated "
                         "inconsistently — re-price via "
                         "incremental.refresh_placement"))
    return out


@register_check(
    "plan.update.consistency", family="plan", layer="plan",
    description="assignment, layout, cluster and features agree post-update")
def check_update_consistency(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    plan = ctx.plan
    g, pg, pl = plan.graph, plan.partitioned, plan.placement
    out = []
    cid = "plan.update.consistency"
    hint = ("Engine.apply_delta must hand every derived structure the same "
            "graph revision — recompile the plan")
    if pg.n != plan.num_fogs:
        out.append(error(cid, f"layout has {pg.n} partitions for "
                              f"{plan.num_fogs} fogs", layer="plan",
                         subject="partitioned.n", fix_hint=hint))
    if len(pl.assignment) != g.num_vertices:
        out.append(error(cid, f"assignment covers {len(pl.assignment)} "
                              f"vertices, graph has {g.num_vertices}",
                         layer="plan", subject="placement.assignment",
                         fix_hint=hint))
    elif not np.array_equal(pg.part_of, pl.assignment):
        moved = int(np.sum(pg.part_of != pl.assignment))
        out.append(error(cid, f"{moved} vertices live in a different "
                              f"partition than the placement assigns — "
                              f"the layout was built for another "
                              f"assignment", layer="plan",
                         subject="part_of vs assignment", fix_hint=hint))
    mapping = np.asarray(pl.mapping)
    if sorted(mapping.tolist()) != list(range(plan.num_fogs)):
        out.append(error(cid, "partition -> fog mapping is not a "
                              "permutation", layer="plan",
                         subject="placement.mapping", fix_hint=hint))
    if plan.cluster.graph is not None:
        cg = plan.cluster.graph
        if (cg.num_vertices != g.num_vertices
                or cg.num_edges != g.num_edges):
            out.append(error(
                cid, f"cluster was profiled against a "
                     f"{cg.num_vertices}v/{cg.num_edges}e graph; the plan "
                     f"serves {g.num_vertices}v/{g.num_edges}e", layer="plan",
                subject="cluster.graph", fix_hint=hint))
    if plan.cluster.feature_dim != g.feature_dim:
        out.append(error(cid, f"cluster prices {plan.cluster.feature_dim}-d "
                              f"features, graph has {g.feature_dim}-d",
                         layer="plan", subject="cluster.feature_dim",
                         fix_hint=hint))
    if plan.cluster.k_layers != plan.model.num_layers:
        out.append(error(cid, f"cluster prices {plan.cluster.k_layers} "
                              f"layers, model has {plan.model.num_layers}",
                         layer="plan", subject="cluster.k_layers",
                         fix_hint=hint))
    if (len(pg.part_of) == g.num_vertices and g.num_vertices
            and pg.part_of.max() < pg.n and pg.slot_of.max() < pg.slots):
        frozen = pg.feats[pg.part_of, pg.slot_of]
        if not np.array_equal(frozen, g.features.astype(np.float32)):
            stale = int(np.sum(np.any(
                frozen != g.features.astype(np.float32), axis=-1)))
            out.append(error(
                cid, f"{stale} vertices' frozen feature rows disagree with "
                     f"the plan's graph — the partition table is serving a "
                     f"retired revision", layer="plan", subject="feats",
                fix_hint="refresh via PartitionedGraph.with_features or "
                         "rebuild the layout"))
    return out


@register_check(
    "plan.config.keys", family="plan", layer="plan",
    description="every pipeline knob resolves in its registry")
def check_config_keys(ctx: AnalysisContext) -> Iterable[Diagnostic]:
    from repro.runtime.bsp import AGGREGATIONS
    cfg = ctx.plan.config
    out = []
    cid = "plan.config.keys"
    for field, registry in (("partitioner", "partitioner"),
                            ("placement", "placement"),
                            ("compressor", "compressor"),
                            ("exchange", "exchange"),
                            ("executor", "executor")):
        key = getattr(cfg, field)
        if key not in ALL_REGISTRIES[registry]:
            out.append(error(
                cid, f"config.{field} = {key!r} does not resolve "
                     f"(available: {', '.join(ALL_REGISTRIES[registry])})",
                layer="plan", subject=f"config.{field}",
                fix_hint="the plan was built against a registry state that "
                         "no longer exists — recompile"))
    if cfg.aggregation not in AGGREGATIONS:
        out.append(error(cid, f"config.aggregation = {cfg.aggregation!r} "
                              f"not in {AGGREGATIONS}", layer="plan",
                         subject="config.aggregation",
                         fix_hint="use segment_sum | pallas | auto"))
    validate = getattr(cfg, "validate", "off")
    if validate not in VALIDATE_MODES:
        out.append(error(cid, f"config.validate = {validate!r} not in "
                              f"{VALIDATE_MODES}", layer="plan",
                         subject="config.validate",
                         fix_hint="use off | warn | strict"))
    if not out:
        out.append(info(cid, "all pipeline knobs resolve", layer="plan",
                        subject="config"))
    return out
