"""Model-serving driver with Fograph-style request placement.

The paper's technique generalized to the transformer substrate (DESIGN.md
§4): incoming generation requests are *data points*, serving pods are
*fog nodes*. The same machinery drives placement:

  * each pod is profiled with the paper's proxy-guided profiler (latency
    ~ beta . <batch, total_cache_tokens> + eps — the transformer analogue
    of omega(<|V|, |N_V|>)),
  * request batches are matched to heterogeneous pods through the same
    PLACEMENTS registry the GNN fog path uses — "iep" resolves to the LBAP
    bottleneck solver (min-max completion = Eq. 7); "metis+greedy" and
    "random" give the paper's baselines via ``--placement``,
  * the dual-mode load indicators decide when to re-plan.

Runs a REAL decode loop (reduced config on CPU; full config on a TPU mesh)
with continuous batching per pod.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 24 --tokens 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.placement import PLACEMENTS  # import registers strategies
from repro.configs import registry
from repro.core.profiler import LatencyModel, fit_latency_model
from repro.models import transformer as tf


@dataclass
class Pod:
    """A serving pod: capability factor models heterogeneous hardware
    generations (the paper's type A/B/C fogs)."""
    name: str
    speed: float                     # relative decode throughput
    queue: List[int] = field(default_factory=list)
    model: LatencyModel = None


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    done: List[int] = field(default_factory=list)


def profile_pods(pods: List[Pod], base_step_s: float):
    """Offline profiling: fit omega(<batch, cache_tokens>) per pod."""
    cards, all_lat = [], {p.name: [] for p in pods}
    for b in (1, 2, 4, 8):
        for t in (64, 256, 1024):
            cards.append((b, t))
            for p in pods:
                lat = base_step_s * (0.5 + 0.05 * b + t / 4096) / p.speed
                all_lat[p.name].append(lat)
    for p in pods:
        p.model = fit_latency_model(cards, all_lat[p.name])


def place_batches(batches, pods, placement: str = "iep", seed: int = 0):
    """Batch->pod matching via a PLACEMENTS registry strategy (Eq. 7/8).

    The default "iep" resolves to the exact LBAP bottleneck solver; any
    registered strategy key works (thin adapter over the fog pipeline).
    """
    n = max(len(batches), len(pods))
    cost = np.zeros((n, n))
    for k in range(n):
        for j in range(n):
            if k >= len(batches) or j >= len(pods):
                cost[k, j] = 0.0
            else:
                b = batches[k]
                cache = sum(len(r.prompt) + r.max_new for r in b)
                cost[k, j] = pods[j].model.predict((len(b), cache))
    return PLACEMENTS.resolve(placement).match(cost, seed=seed)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU mesh); default reduced for CPU")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--pods", default="1.0,1.6,2.4",
                    help="comma-separated pod speed factors")
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--placement", default="iep",
                    help="PLACEMENTS registry key for batch->pod matching "
                         f"(available: {', '.join(PLACEMENTS.keys())})")
    args = ap.parse_args(argv)

    cfg = registry.get(args.arch)
    if not args.full:
        cfg = registry.reduced(cfg)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # Requests with mixed prompt lengths.
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(4, 17))).astype(
        np.int32), args.tokens) for i in range(args.requests)]

    pods = [Pod(f"pod{i}({s})", float(s))
            for i, s in enumerate(args.pods.split(","))]
    profile_pods(pods, base_step_s=0.02)

    # Greedy batching, then heterogeneity-aware placement rounds.
    batches = [reqs[i:i + args.batch_size]
               for i in range(0, len(reqs), args.batch_size)]
    print(f"serving {len(reqs)} requests in {len(batches)} batches over "
          f"{len(pods)} heterogeneous pods ({cfg.name})")

    prefill = jax.jit(lambda p, toks: tf.prefill(
        p, cfg, toks, cache_len=toks.shape[1] + args.tokens))
    decode = jax.jit(lambda p, c, tok, pos: tf.decode_step(
        p, cfg, c, tok, pos))

    t0 = time.time()
    round_idx = 0
    sim_pod_busy = np.zeros(len(pods))
    while batches:
        take = batches[:len(pods)]
        mapping = place_batches(take, pods, placement=args.placement,
                                seed=round_idx)
        for k, batch in enumerate(take):
            j = int(mapping[k]) if int(mapping[k]) < len(pods) else 0
            pod = pods[j]
            # real decode (numerics) — pad prompts to a common length
            plen = max(len(r.prompt) for r in batch)
            toks = np.zeros((len(batch), plen), np.int32)
            for bi, r in enumerate(batch):
                toks[bi, plen - len(r.prompt):] = r.prompt
            logits, caches = prefill(params, jnp.asarray(toks))
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            for r, t in zip(batch, np.asarray(tok)[:, 0]):
                r.done.append(int(t))
            for step in range(args.tokens - 1):
                pos = jnp.asarray(plen + step)
                logits, caches = decode(params, caches, tok, pos)
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
                for r, t in zip(batch, np.asarray(tok)[:, 0]):
                    r.done.append(int(t))
            # simulated pod wall-time accounting (heterogeneity)
            cache = sum(len(r.prompt) + r.max_new for r in batch)
            sim_pod_busy[j] += args.tokens * pod.model.predict(
                (len(batch), cache))
        batches = batches[len(pods):]
        round_idx += 1

    wall = time.time() - t0
    done = sum(len(r.done) for r in reqs)
    print(f"generated {done} tokens in {wall:.1f}s wall "
          f"({done / wall:.1f} tok/s real decode)")
    print("simulated pod busy-seconds (balance):",
          np.round(sim_pod_busy, 3))
    print(f"bottleneck/mean ratio: "
          f"{sim_pod_busy.max() / max(sim_pod_busy.mean(), 1e-9):.3f} "
          f"(1.0 = perfectly balanced)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
