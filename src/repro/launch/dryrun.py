import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST precede every other import (jax locks the
# device count at first init). Hence no module docstring above them.

# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production meshes, and extract the roofline terms from the compiled
# artifact. MUST be a separate process from tests/benchmarks (the first two
# lines force 512 host devices before jax initializes).
#
# Per combo this prints/records:
#   * compiled.memory_analysis()  — bytes/device (proves the sharding fits)
#   * compiled.cost_analysis()    — HLO FLOPs + bytes accessed
#   * collective bytes parsed from the optimized HLO
#   * the three roofline terms (seconds) + dominant bottleneck
#   * MODEL_FLOPS = 6 N D (dense; N_active for MoE) vs HLO FLOPs ratio
#
# Usage:
#   python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k
#   python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
import argparse
import dataclasses
import functools
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.data.pipeline import input_specs
from repro.launch import hlo_analysis
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import sharding as shd
from repro.models import transformer as tf
from repro.models.config import INPUT_SHAPES, ArchConfig
from repro.optim.adamw import AdamW

# Microbatch table: activation-memory control for train_4k (tokens/device
# per microbatch <= ~16k for giants).
def microbatches_for(cfg: ArchConfig, data_shards: int,
                     global_batch: int) -> int:
    per_dev = max(1, global_batch // max(data_shards, 1))
    if cfg.d_model >= 6144:
        want = 8
    elif cfg.d_model >= 3072:
        want = 4
    else:
        want = 2
    while per_dev % want:
        want //= 2
    return max(1, want)


def serve_window(cfg: ArchConfig, shape_name: str) -> int:
    """long_500k uses the sliding-window serve variant for attention archs
    (SSM/hybrid run natively; their attention window is already bounded)."""
    if shape_name == "long_500k" and cfg.family not in ("ssm",):
        return cfg.sliding_window or 0
    return 0


def _maybe(fn, *a, **k):
    try:
        return fn(*a, **k)
    except Exception as e:  # noqa: BLE001 — diagnostics only
        return f"<unavailable: {type(e).__name__}>"


@dataclasses.dataclass
class DryRunResult:
    arch: str
    shape: str
    mesh: str
    mode: str
    ok: bool
    error: str = ""
    lower_s: float = 0.0
    compile_s: float = 0.0
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    collective: dict = dataclasses.field(default_factory=dict)
    memory: dict = dataclasses.field(default_factory=dict)
    model_flops: float = 0.0
    roofline: dict = dataclasses.field(default_factory=dict)
    cost_analysis_raw: dict = dataclasses.field(default_factory=dict)
    opts: list = dataclasses.field(default_factory=list)


def _memory_dict(compiled) -> dict:
    ma = _maybe(compiled.memory_analysis)
    out = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[f] = getattr(ma, f, None) if not isinstance(ma, str) else ma
    if not isinstance(ma, str):
        try:
            args = ma.argument_size_in_bytes - ma.alias_size_in_bytes
            out["peak_bytes_per_device"] = (args + ma.output_size_in_bytes
                                            + ma.temp_size_in_bytes)
        except Exception:  # noqa: BLE001
            pass
    return out


def model_flops_estimate(cfg: ArchConfig, shape, mode: str) -> float:
    """6 N_active D (train) / 2 N_active D (inference) token-FLOPs."""
    n = cfg.active_param_count()
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens


def build_lowerable(cfg: ArchConfig, shape_name: str, mesh, opts=()):
    """Returns (fn, args, in_shardings, mode) ready for jit/lower.

    ``opts`` — SSPerf variants: "serve_attn_dh" (head_dim-sharded attention
    projections for kv-indivisible serving), "quant_cache" (int8 KV cache),
    "expert_grid" (experts over the full data x model grid).
    """
    opts = set(opts)
    shape = INPUT_SHAPES[shape_name]
    # Pin residual-stream batch sharding (see models/sharding.py).
    data_ax = shd.data_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in data_ax]))
    seq_par = "seq_parallel" in opts and shape.kind != "decode"
    if shape.global_batch % max(total, 1) == 0 and shape.global_batch >= total:
        shd.enable_activation_constraints(data_ax, seq_parallel=seq_par)
    elif shape.global_batch % mesh.shape.get("data", 1) == 0 \
            and shape.global_batch >= mesh.shape.get("data", 1):
        shd.enable_activation_constraints(("data",), seq_parallel=seq_par)
    else:
        shd.enable_activation_constraints(None)
    if shape.kind != "train":
        # Serving runs bf16 weights (f32 weights of a 67B model would not
        # fit 16-way TP on 16 GB chips; bf16 serving is standard practice).
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    params_abs = tf.abstract_params(cfg)
    fsdp = shd.needs_fsdp(cfg, mesh, train=shape.kind == "train")
    if "expert_grid" in opts and cfg.num_experts:
        fsdp_dense = fsdp  # dense weights may still need FSDP
        p_shard = shd.param_shardings(cfg, params_abs, mesh, fsdp=fsdp_dense,
                                      serve_attn_dh="serve_attn_dh" in opts,
                                      expert_grid=True)
    else:
        p_shard = shd.param_shardings(cfg, params_abs, mesh, fsdp=fsdp,
                                      serve_attn_dh="serve_attn_dh" in opts)
    window = serve_window(cfg, shape_name)

    if shape.kind == "train":
        moment_dtype = ("bfloat16" if cfg.param_count() > 1.5e11
                        else "float32")
        opt = AdamW(learning_rate=3e-4, moment_dtype=moment_dtype)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        from repro.optim.adamw import AdamWState
        o_shard = AdamWState(
            step=jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()),
            m=p_shard, v=p_shard)
        # ^ moments mirror params exactly; the scalar step replicates
        data_shards = int(np.prod([mesh.shape[a]
                                   for a in shd.data_axes(mesh)]))
        mb = microbatches_for(cfg, data_shards, shape.global_batch)
        batch_abs = input_specs(cfg, shape)
        b_shard = shd.batch_shardings(mesh, batch_abs)
        step = tf.make_train_step(cfg, opt, microbatches=mb, remat=True)
        return (step, (params_abs, opt_abs, batch_abs),
                (p_shard, o_shard, b_shard), "train", {"microbatches": mb})

    if shape.kind == "prefill":
        batch_abs = input_specs(cfg, shape)
        b_shard = shd.batch_shardings(mesh, batch_abs)

        def prefill_fn(params, batch):
            logits, caches = tf.prefill(params, cfg, batch["inputs"],
                                        window=window)
            return logits, caches

        return (prefill_fn, (params_abs, batch_abs), (p_shard, b_shard),
                "prefill", {})

    # decode
    batch_abs = input_specs(cfg, shape)
    cache_window = window
    cache_abs = jax.eval_shape(
        functools.partial(tf.init_cache, cfg, shape.global_batch,
                          shape.seq_len, window=cache_window,
                          quantized="quant_cache" in opts))
    c_shard = shd.cache_shardings(cfg, cache_abs, mesh, shape.global_batch)
    b_shard = shd.batch_shardings(mesh, batch_abs)

    def decode_fn(params, caches, batch):
        return tf.decode_step(params, cfg, caches, batch["tokens"],
                              batch["pos"], window=window)

    return (decode_fn, (params_abs, cache_abs, batch_abs),
            (p_shard, c_shard, b_shard), "decode", {})


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            verbose: bool = True, hlo_out: str = "",
            opts=()) -> DryRunResult:
    cfg = registry.get(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_chips = int(np.prod(list(mesh.shape.values())))
    res = DryRunResult(arch=arch, shape=shape_name, mesh=mesh_name, mode="",
                       ok=False)
    res.opts = list(opts)
    try:
        fn, args, in_shardings, mode, extra = build_lowerable(
            cfg, shape_name, mesh, opts=opts)
        res.mode = mode
        t0 = time.time()
        with mesh:
            jitted = jax.jit(fn, in_shardings=in_shardings)
            lowered = jitted.lower(*args)
            res.lower_s = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            res.compile_s = time.time() - t0
        ca = _maybe(compiled.cost_analysis)
        if isinstance(ca, dict):
            # raw XLA numbers (while bodies counted ONCE — see hlo_analysis)
            res.cost_analysis_raw = {
                "flops": float(ca.get("flops", 0.0)),
                "bytes": float(ca.get("bytes accessed", 0.0))}
        res.memory = _memory_dict(compiled)
        hlo = _maybe(compiled.as_text)
        if isinstance(hlo, str) and not hlo.startswith("<unavailable"):
            cost = hlo_analysis.analyze(hlo)
            res.flops_per_device = cost.flops
            res.bytes_per_device = cost.bytes_accessed
            res.collective = dict(cost.collective_bytes,
                                  total=cost.total_collective)
            if hlo_out:
                with open(hlo_out, "w") as f:
                    f.write(hlo)
        shape = INPUT_SHAPES[shape_name]
        res.model_flops = model_flops_estimate(cfg, shape, mode)
        # Roofline terms (seconds). cost_analysis flops/bytes are per-device
        # for the SPMD partitioned module.
        comp = res.flops_per_device / PEAK_FLOPS_BF16
        memt = res.bytes_per_device / HBM_BW
        coll = res.collective.get("total", 0) / ICI_BW
        dom = max(("compute", comp), ("memory", memt),
                  ("collective", coll), key=lambda kv: kv[1])[0]
        res.roofline = {
            "compute_s": comp, "memory_s": memt, "collective_s": coll,
            "dominant": dom,
            "model_flops_ratio": (res.model_flops
                                  / max(res.flops_per_device * n_chips, 1.0)),
        }
        res.ok = True
        if verbose:
            print(f"[OK] {arch} x {shape_name} x {mesh_name} ({mode}"
                  f"{', mb=' + str(extra['microbatches']) if extra.get('microbatches') else ''}) "
                  f"lower {res.lower_s:.1f}s compile {res.compile_s:.1f}s")
            print(f"     flops/dev={res.flops_per_device:.3e} "
                  f"bytes/dev={res.bytes_per_device:.3e} "
                  f"coll/dev={res.collective.get('total', 0):.3e}")
            print(f"     roofline: compute={comp * 1e3:.2f}ms "
                  f"memory={memt * 1e3:.2f}ms collective={coll * 1e3:.2f}ms "
                  f"-> {dom}-bound; useful-flop ratio="
                  f"{res.roofline['model_flops_ratio']:.3f}")
            print(f"     memory/device: {res.memory}")
    except Exception as e:  # noqa: BLE001
        res.error = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[FAIL] {arch} x {shape_name} x {mesh_name}: {res.error}")
            traceback.print_exc(limit=4)
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--hlo-dir", default="")
    ap.add_argument("--opts", default="",
                    help="comma list: serve_attn_dh,quant_cache,expert_grid")
    args = ap.parse_args(argv)
    opts = tuple(o for o in args.opts.split(",") if o)

    archs = registry.list_archs() if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                hlo_out = ""
                if args.hlo_dir:
                    os.makedirs(args.hlo_dir, exist_ok=True)
                    hlo_out = os.path.join(
                        args.hlo_dir,
                        f"{registry.canonical(arch)}_{shape}_"
                        f"{'mp' if mp else 'sp'}.hlo")
                res = run_one(arch, shape, multi_pod=mp, hlo_out=hlo_out,
                              opts=opts)
                failures += 0 if res.ok else 1
                suffix = ("_" + "_".join(opts)) if opts else ""
                fname = (f"{registry.canonical(arch)}_{shape}_"
                         f"{'2x16x16' if mp else '16x16'}{suffix}.json")
                with open(os.path.join(args.out, fname), "w") as f:
                    json.dump(dataclasses.asdict(res), f, indent=2,
                              default=str)
    print(f"\ndry-run complete: {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
