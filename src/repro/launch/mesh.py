"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single-pod: 16 x 16 = 256 chips (v5e pod),
axes (data, model). Multi-pod: 2 x 16 x 16 = 512 chips, axes
(pod, data, model) — the leading ``pod`` axis carries pod-level data
parallelism over the DCN/ICI seam.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Debug mesh over whatever devices exist on this host."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants for the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
