"""End-to-end training driver.

Runs a real training loop (synthetic corpus -> sharded train_step ->
checkpoints) for any --arch at either the reduced scale (CPU-runnable,
default) or full scale (TPU mesh). Demonstrates the complete substrate:
data pipeline, optimizer, remat/microbatching, checkpoint/resume.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
      --steps 200 --seq 256 --batch 8 --size 100m
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import io as ckpt
from repro.configs import registry
from repro.data.pipeline import SyntheticCorpus
from repro.models import transformer as tf
from repro.models.config import InputShape
from repro.optim.adamw import AdamW, warmup_cosine


def size_config(cfg, size: str):
    """Derive a ~25m / ~100m parameter variant of the same family."""
    presets = {
        "reduced": {},
        "25m": dict(num_layers=4, d_model=512, num_heads=8, num_kv_heads=4,
                    head_dim=64, d_ff=1536, vocab_size=8192),
        "100m": dict(num_layers=8, d_model=768, num_heads=12,
                     num_kv_heads=4, head_dim=64, d_ff=3072,
                     vocab_size=16384),
    }
    base = registry.reduced(cfg)
    if size == "reduced":
        return base
    kw = dict(presets[size])
    if cfg.num_heads == 0:  # SSM: no heads
        kw.update(num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0)
    if cfg.hybrid_pattern:
        kw["num_layers"] = max(len(cfg.hybrid_pattern),
                               kw["num_layers"] // len(cfg.hybrid_pattern)
                               * len(cfg.hybrid_pattern))
    if cfg.num_experts:
        kw.update(moe_d_ff=kw.get("d_ff", 1536) // 2)
    return dataclasses.replace(base, name=f"{cfg.name}-{size}", **kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--size", default="25m",
                    choices=["reduced", "25m", "100m"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = size_config(registry.get(args.arch), args.size)
    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")
    shape = InputShape("train", seq_len=args.seq, global_batch=args.batch,
                       kind="train")
    corpus = SyntheticCorpus(cfg, shape, seed=0)
    opt = AdamW(learning_rate=warmup_cosine(args.lr, warmup=20,
                                            total=args.steps))
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    start = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        start = ckpt.latest_step(args.ckpt_dir)
        restored = ckpt.restore(args.ckpt_dir,
                                {"params": params, "opt": state})
        params, state = restored["params"], restored["opt"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(tf.make_train_step(cfg, opt,
                                         microbatches=args.microbatches))
    t0 = time.time()
    tokens = 0
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in corpus.batch(i).items()}
        params, state, metrics = step_fn(params, state, batch)
        tokens += args.batch * args.seq
        if (i + 1) % args.log_every == 0 or i == start:
            dt = time.time() - t0
            print(f"step {i + 1:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"tok/s {tokens / max(dt, 1e-9):,.0f}")
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i + 1,
                      {"params": params, "opt": state})
    print(f"done: {args.steps - start} steps in {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
