"""Back-compat shim: the HLO analyzer moved to :mod:`repro.analysis.hlo`
so all static tooling lives under one roof.  Import from there."""
from repro.analysis.hlo import (  # noqa: F401
    COLLECTIVES,
    Computation,
    HloCost,
    Op,
    _call_edges,
    _dot_flops,
    _op_bytes,
    _op_bytes_scaled,
    _operand_bytes,
    _shape_elems_bytes,
    _trip_count,
    analyze,
    computation_multipliers,
    parse_module,
)
