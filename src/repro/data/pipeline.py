"""Synthetic-corpus data pipeline: deterministic, shardable, shaped exactly
like the dry-run's ``input_specs``.

No tokenizer / corpus ships offline, so the pipeline generates a mixture of
Zipfian token streams with Markov locality (so a real model can actually
reduce loss on it) plus per-arch input adapters:
  * tokens archs   -> {"inputs": int32 [B,S], "targets": int32 [B,S]}
  * embeddings archs (VLM stub) -> {"inputs": f32 [B,S,D], "targets": ...}

Batches come from an index-seeded PRNG: batch i is reproducible from (seed,
i) alone, so the pipeline is stateless, resumable from a checkpointed step,
and identical across hosts without any cross-host coordination.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.models.config import ArchConfig, InputShape


@dataclasses.dataclass
class SyntheticCorpus:
    cfg: ArchConfig
    shape: InputShape
    seed: int = 0
    zipf_a: float = 1.3
    markov_jump: float = 0.15

    def _rng(self, batch_idx: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, batch_idx))

    def _token_batch(self, rng, b: int, s: int) -> np.ndarray:
        v = self.cfg.vocab_size
        # Zipf marginals, re-mapped into vocab range.
        base = rng.zipf(self.zipf_a, size=(b, s + 1)).astype(np.int64)
        base = base % v
        # Markov locality: with prob 1-jump, next token = prev + small delta
        # (gives learnable bigram structure).
        stay = rng.random((b, s + 1)) > self.markov_jump
        delta = rng.integers(1, 17, size=(b, s + 1))
        toks = base.copy()
        for t in range(1, s + 1):
            toks[:, t] = np.where(stay[:, t],
                                  (toks[:, t - 1] + delta[:, t]) % v,
                                  base[:, t])
        return toks

    def batch(self, batch_idx: int) -> Dict[str, np.ndarray]:
        rng = self._rng(batch_idx)
        b, s = self.shape.global_batch, self.shape.seq_len
        toks = self._token_batch(rng, b, s)
        if self.cfg.input_mode == "embeddings":
            # VLM/audio stub frontend: project token stream to embeddings
            # deterministically (stands in for ViT patches / codec frames).
            d = self.cfg.d_model
            proj_rng = np.random.default_rng((self.seed, 2 ** 31))
            proj = proj_rng.normal(size=(64, d)).astype(np.float32) * 0.02
            inputs = proj[toks[:, :-1] % 64]
            return {"inputs": inputs,
                    "targets": toks[:, 1:].astype(np.int32)}
        return {"inputs": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def input_specs(cfg: ArchConfig, shape: InputShape, *, batch_override=None):
    """ShapeDtypeStruct stand-ins for every model input of (cfg, shape) —
    the dry-run's only data source (no allocation).

    train  -> {"inputs", "targets"}
    prefill-> {"inputs"}
    decode -> {"tokens" [B,1] (or embeddings), "pos" scalar} (+ caches are
              built by the launcher via eval_shape on init_cache).
    """
    import jax
    import jax.numpy as jnp

    b = batch_override or shape.global_batch
    s = shape.seq_len
    if cfg.input_mode == "embeddings" and shape.kind != "decode":
        inp = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.float32)
    else:
        inp = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        return {"inputs": inp,
                "targets": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if shape.kind == "prefill":
        return {"inputs": inp}
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
