"""Dataset builders mirroring the paper's Table III.

The container has no network access, so we synthesize structurally faithful
stand-ins for the three real-world datasets (SIoT, Yelp, PeMS) and implement
the RMAT series exactly as the paper describes (Appendix D): R-MAT topology
at SIoT's density (0.11%), Node2Vec-like 32-d features (we use spectral-ish
random projections of the adjacency), community-derived 8-class labels.

Every builder accepts ``scale`` to shrink |V| proportionally for CI-speed
tests while preserving degree-distribution shape.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.gnn.graph import Graph, from_edge_list

# Paper Table III statistics.
TABLE_III = {
    "siot": dict(vertices=16216, edges=146117, feature=52, labels=2, duration=1),
    "yelp": dict(vertices=10000, edges=15683, feature=100, labels=2, duration=1),
    "pems": dict(vertices=307, edges=340, feature=3, labels=0, duration=12),
    "rmat-20k": dict(vertices=20_000, edges=199_000, feature=32, labels=8, duration=1),
    "rmat-40k": dict(vertices=40_000, edges=799_000, feature=32, labels=8, duration=1),
    "rmat-60k": dict(vertices=60_000, edges=1_790_000, feature=32, labels=8, duration=1),
    "rmat-80k": dict(vertices=80_000, edges=3_190_000, feature=32, labels=8, duration=1),
    "rmat-100k": dict(vertices=100_000, edges=4_990_000, feature=32, labels=8, duration=1),
}


def rmat_edges(num_vertices: int, num_edges: int, rng: np.random.Generator,
               a: float = 0.57, b: float = 0.19, c: float = 0.19) -> np.ndarray:
    """R-MAT recursive generator [Chakrabarti et al., SDM'04]."""
    scale = int(np.ceil(np.log2(max(2, num_vertices))))
    n = num_edges
    # Vectorized: for each of `scale` levels draw a quadrant per edge.
    probs = np.array([a, b, c, 1.0 - a - b - c])
    rows = np.zeros(n, dtype=np.int64)
    cols = np.zeros(n, dtype=np.int64)
    for level in range(scale):
        q = rng.choice(4, size=n, p=probs)
        half = 1 << (scale - level - 1)
        rows += np.where((q == 2) | (q == 3), half, 0)
        cols += np.where((q == 1) | (q == 3), half, 0)
    keep = (rows < num_vertices) & (cols < num_vertices) & (rows != cols)
    return np.stack([rows[keep], cols[keep]], axis=1)


def _community_labels(num_vertices: int, edges: np.ndarray, num_classes: int,
                      rng: np.random.Generator, iters: int = 8) -> np.ndarray:
    """Cheap label propagation to derive community-structured labels."""
    labels = rng.integers(0, num_classes, size=num_vertices)
    if edges.shape[0] == 0 or num_classes <= 1:
        return labels.astype(np.int32)
    s, r = edges[:, 0], edges[:, 1]
    for _ in range(iters):
        votes = np.zeros((num_vertices, num_classes), dtype=np.int64)
        np.add.at(votes, r, np.eye(num_classes, dtype=np.int64)[labels[s]])
        np.add.at(votes, s, np.eye(num_classes, dtype=np.int64)[labels[r]])
        # Keep own vote to stabilise.
        votes[np.arange(num_vertices), labels] += 1
        labels = votes.argmax(axis=1)
    return labels.astype(np.int32)


def _structural_features(num_vertices: int, edges: np.ndarray, dim: int,
                         rng: np.random.Generator, sparse_onehot: bool,
                         labels: Optional[np.ndarray] = None) -> np.ndarray:
    """Features with real signal: a few propagation rounds of random
    projections (Node2Vec stand-in) or sparse one-hot attribute blocks
    (SIoT-style: device type/brand/mobility one-hots)."""
    if sparse_onehot:
        # SIoT: categorical one-hot blocks -> very sparse, highly compressible.
        blocks = max(2, dim // 13)
        feats = np.zeros((num_vertices, dim), dtype=np.float32)
        base = 0
        per = dim // blocks
        cat = None
        for b in range(blocks):
            width = per if b < blocks - 1 else dim - base
            if labels is not None and b == 0:
                # First block correlates with the label so GNNs can learn.
                cat = (labels * width // max(1, labels.max() + 1)) % width
                noise = rng.integers(0, width, size=num_vertices)
                flip = rng.random(num_vertices) < 0.15
                cat = np.where(flip, noise, cat)
            else:
                cat = rng.integers(0, width, size=num_vertices)
            feats[np.arange(num_vertices), base + cat] = 1.0
            base += width
        return feats
    # Dense embedding-ish features (Yelp word2vec / RMAT node2vec stand-in):
    x = rng.normal(size=(num_vertices, dim)).astype(np.float32)
    if labels is not None:
        centers = rng.normal(size=(int(labels.max()) + 1, dim)).astype(np.float32)
        x = 0.7 * centers[labels] + 0.5 * x
    if edges.shape[0]:
        s, r = edges[:, 0], edges[:, 1]
        deg = np.bincount(r, minlength=num_vertices) + 1.0
        for _ in range(2):  # smooth over the graph -> structure-aware
            agg = np.zeros_like(x)
            np.add.at(agg, r, x[s])
            x = (x + agg / deg[:, None]).astype(np.float32) * 0.5
    return x


def _build(name: str, stats: dict, scale: float, seed: int,
           sparse_onehot: bool) -> Graph:
    rng = np.random.default_rng(seed)
    n = max(8, int(stats["vertices"] * scale))
    e = max(n, int(stats["edges"] * scale))
    edges = rmat_edges(n, int(e * 1.35), rng)[:e]
    nc = max(1, stats["labels"])
    labels = _community_labels(n, edges, nc, rng) if stats["labels"] else None
    feats = _structural_features(n, edges, stats["feature"], rng,
                                 sparse_onehot, labels)
    positions = rng.uniform(0, 100, size=(n, 2)).astype(np.float32)
    return from_edge_list(n, edges, feats, labels, positions)


def load(name: str, scale: float = 1.0, seed: int = 0) -> Graph:
    """Load a dataset by Table III name; ``scale`` shrinks it for tests."""
    name = name.lower()
    if name not in TABLE_III:
        raise KeyError(f"unknown dataset {name!r}; options: {sorted(TABLE_III)}")
    stats = TABLE_III[name]
    sparse_onehot = name == "siot"
    return _build(name, stats, scale, seed, sparse_onehot)


@dataclasses.dataclass
class TemporalGraph:
    """PeMS-style spatial-temporal data: a static sensor graph plus a
    [T_in, |V|, F] window of recent measurements and a [T_out, |V|] target
    (flow forecasting for the next hour at 5-min steps, §IV-C)."""
    graph: Graph
    history: np.ndarray  # [T_in, V, F]
    target: np.ndarray   # [T_out, V]


def load_pems_window(scale: float = 1.0, seed: int = 0, t_in: int = 12,
                     t_out: int = 12) -> TemporalGraph:
    g = load("pems", scale=scale, seed=seed)
    rng = np.random.default_rng(seed + 1)
    n = g.num_vertices
    t = np.arange(t_in + t_out)[:, None]
    phase = rng.uniform(0, 2 * np.pi, size=(1, n))
    daily = 60 + 40 * np.sin(2 * np.pi * t / 24 + phase)
    noise = rng.normal(scale=4.0, size=(t_in + t_out, n))
    flow = (daily + noise).astype(np.float32)           # total flow
    speed = (65 - 0.2 * flow + rng.normal(scale=2, size=flow.shape)).astype(np.float32)
    occ = (flow / 120.0).astype(np.float32)             # occupancy
    hist = np.stack([flow[:t_in], speed[:t_in], occ[:t_in]], axis=-1)
    return TemporalGraph(graph=g, history=hist, target=flow[t_in:])
