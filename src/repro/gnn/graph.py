"""Graph containers and structural utilities.

Host-side representation is numpy (partitioning, placement, compression all
operate on the host, as in the paper's metadata server); device-side compute
uses padded COO edge lists + ``jax.ops.segment_sum`` so every kernel is
jit-able with static shapes.

Terminology follows the paper: *vertex* = graph vertex, *node* = fog server.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Graph:
    """An undirected graph stored as COO + CSR, with per-vertex features.

    Attributes:
      num_vertices: |V|.
      senders / receivers: int32[E] directed edge endpoints. For undirected
        graphs both (u,v) and (v,u) appear, so E = 2 * |undirected edges|.
      indptr / indices: CSR over the same directed edges (row = receiver,
        columns = its in-neighbors), used by the Pallas aggregation kernel
        and by host-side partitioning.
      features: float32[|V|, F] vertex features (h^(0)).
      labels: optional int32[|V|] class labels.
      positions: optional float32[|V|, 2] spatial coordinates (PeMS case study).
    """

    num_vertices: int
    senders: np.ndarray
    receivers: np.ndarray
    indptr: np.ndarray
    indices: np.ndarray
    features: np.ndarray
    labels: Optional[np.ndarray] = None
    positions: Optional[np.ndarray] = None

    @property
    def num_edges(self) -> int:
        return int(self.senders.shape[0])

    @property
    def feature_dim(self) -> int:
        return int(self.features.shape[-1])

    @property
    def degrees(self) -> np.ndarray:
        """In-degree per vertex (== out-degree for undirected graphs)."""
        return np.diff(self.indptr).astype(np.int32)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]

    def validate(self) -> None:
        assert self.senders.shape == self.receivers.shape
        assert self.indptr.shape == (self.num_vertices + 1,)
        assert self.indptr[-1] == self.num_edges
        assert self.features.shape[0] == self.num_vertices
        if self.num_edges:
            assert int(self.senders.max()) < self.num_vertices
            assert int(self.receivers.max()) < self.num_vertices


def from_edge_list(num_vertices: int,
                   edges: np.ndarray,
                   features: np.ndarray,
                   labels: Optional[np.ndarray] = None,
                   positions: Optional[np.ndarray] = None,
                   undirected: bool = True) -> Graph:
    """Build a Graph from an [E0, 2] array of (u, v) pairs.

    Self loops and duplicate edges are removed; if ``undirected`` both
    directions are materialized.
    """
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    # Drop self loops.
    edges = edges[edges[:, 0] != edges[:, 1]]
    if undirected:
        edges = np.concatenate([edges, edges[:, ::-1]], axis=0)
    # Dedup.
    if edges.shape[0]:
        key = edges[:, 0] * num_vertices + edges[:, 1]
        _, uniq = np.unique(key, return_index=True)
        edges = edges[np.sort(uniq)]
    senders = edges[:, 0].astype(np.int32)
    receivers = edges[:, 1].astype(np.int32)
    indptr, indices = _coo_to_csr(num_vertices, receivers, senders)
    g = Graph(
        num_vertices=num_vertices,
        senders=senders,
        receivers=receivers,
        indptr=indptr,
        indices=indices,
        features=np.asarray(features, dtype=np.float32),
        labels=None if labels is None else np.asarray(labels, dtype=np.int32),
        positions=positions,
    )
    g.validate()
    return g


def _coo_to_csr(num_vertices: int, rows: np.ndarray, cols: np.ndarray):
    """CSR where row r lists the senders of edges received by r (in-neighbors)."""
    order = np.argsort(rows, kind="stable")
    sorted_rows = rows[order]
    sorted_cols = cols[order].astype(np.int32)
    counts = np.bincount(sorted_rows, minlength=num_vertices)
    indptr = np.zeros(num_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return indptr, sorted_cols


def subgraph(g: Graph, vertex_ids: np.ndarray) -> Graph:
    """Induced subgraph on ``vertex_ids`` (relabeled 0..len-1)."""
    vertex_ids = np.asarray(vertex_ids, dtype=np.int64)
    remap = -np.ones(g.num_vertices, dtype=np.int64)
    remap[vertex_ids] = np.arange(len(vertex_ids))
    keep = (remap[g.senders] >= 0) & (remap[g.receivers] >= 0)
    edges = np.stack(
        [remap[g.senders[keep]], remap[g.receivers[keep]]], axis=1)
    return from_edge_list(
        len(vertex_ids), edges,
        g.features[vertex_ids],
        None if g.labels is None else g.labels[vertex_ids],
        None if g.positions is None else g.positions[vertex_ids],
        undirected=False)  # both directions already present


def neighbor_count(g: Graph, vertex_ids: np.ndarray) -> int:
    """|N_V|: number of distinct one-hop neighbors of a vertex set (the
    cardinality's second axis in the paper's profiler, §III-B)."""
    vertex_ids = np.asarray(vertex_ids)
    in_set = np.zeros(g.num_vertices, dtype=bool)
    in_set[vertex_ids] = True
    touching = in_set[g.receivers]  # edges arriving at the set
    nbrs = np.unique(g.senders[touching])
    return int(np.sum(~in_set[nbrs]))


def edge_cut(g: Graph, assignment: np.ndarray) -> int:
    """Number of directed edges crossing partitions under ``assignment``."""
    return int(np.sum(assignment[g.senders] != assignment[g.receivers]))


def partition_boundary(g: Graph, assignment: np.ndarray, part: int) -> np.ndarray:
    """Vertices in ``part`` that have at least one neighbor outside it."""
    mine = assignment == part
    cross = mine[g.receivers] & ~mine[g.senders]
    return np.unique(g.receivers[cross])


def halo_vertices(g: Graph, assignment: np.ndarray, part: int) -> np.ndarray:
    """Remote vertices whose features ``part`` must pull each BSP layer."""
    mine = assignment == part
    incoming = mine[g.receivers] & ~mine[g.senders]
    return np.unique(g.senders[incoming])


def degree_histogram(g: Graph) -> np.ndarray:
    return np.bincount(g.degrees)


def degree_cdf(g: Graph):
    """Empirical CDF F_D(d) of the degree distribution (Thm 2)."""
    hist = degree_histogram(g).astype(np.float64)
    cdf = np.cumsum(hist) / max(1.0, hist.sum())

    def F(d):
        d = np.asarray(d, dtype=np.int64)
        return np.where(d < 0, 0.0,
                        cdf[np.minimum(d, len(cdf) - 1)])

    return F


def pad_edges(senders: np.ndarray, receivers: np.ndarray, target: int,
              pad_vertex: int):
    """Pad COO edge lists to ``target`` edges pointing at a sink vertex.

    Padding edges use sender==receiver==pad_vertex with mask 0 so that
    segment-sum aggregation ignores them (pad_vertex row is discarded).
    """
    e = senders.shape[0]
    assert e <= target, (e, target)
    pad = target - e
    mask = np.concatenate([np.ones(e, np.float32), np.zeros(pad, np.float32)])
    s = np.concatenate([senders, np.full(pad, pad_vertex, senders.dtype)])
    r = np.concatenate([receivers, np.full(pad, pad_vertex, receivers.dtype)])
    return s, r, mask
