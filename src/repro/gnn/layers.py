"""GNN layers in pure JAX, matching the paper's Table I inference functions.

All layers consume COO edge lists (senders, receivers) plus an optional edge
mask (for padded/static-shape distributed execution) and use
``jax.ops.segment_sum`` for aggregation, so they jit with static shapes and
compose with shard_map. Aggregation can optionally be routed through the
Pallas CSR kernel (see repro.kernels.ops) by the model wrapper.

  GCN       a_v = sum_{u in N(v)} h_u
            h_v = sigma(W . (a_v + h_v) / (|N(v)| + 1))
  GAT       a_v = sum_{u in N(v) u {v}} alpha_vu W h_u ;  h_v = sigma(a_v)
  GraphSAGE a_v = mean_{u in N(v)} h_u ; h_v = sigma(W . [a_v, h_v])
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _glorot(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[-2], shape[-1]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(key, shape, dtype, -lim, lim)


class EdgeList(NamedTuple):
    """Static-shape COO connectivity for jit'd layers."""
    senders: jnp.ndarray    # int32[E]
    receivers: jnp.ndarray  # int32[E]
    mask: jnp.ndarray       # float32[E] — 0 for padding edges
    num_vertices: int       # static

    @classmethod
    def from_graph(cls, g, pad_to: Optional[int] = None) -> "EdgeList":
        s, r = g.senders, g.receivers
        mask = np.ones(len(s), np.float32)
        if pad_to is not None and pad_to > len(s):
            pad = pad_to - len(s)
            sink = g.num_vertices - 1
            s = np.concatenate([s, np.full(pad, sink, s.dtype)])
            r = np.concatenate([r, np.full(pad, sink, r.dtype)])
            mask = np.concatenate([mask, np.zeros(pad, np.float32)])
        return cls(jnp.asarray(s), jnp.asarray(r), jnp.asarray(mask),
                   g.num_vertices)


def masked_degree(edges: EdgeList) -> jnp.ndarray:
    """float32[V] in-degree under the edge mask."""
    return jax.ops.segment_sum(edges.mask, edges.receivers,
                               num_segments=edges.num_vertices)


def aggregate_sum(h: jnp.ndarray, edges: EdgeList,
                  h_src: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """a_v = sum_{u in N(v)} h_u via gather + segment_sum.

    ``h_src`` (defaults to ``h``) is the array senders index into — in
    distributed BSP execution it is the halo-gathered feature table while
    ``h`` stays the local partition's features.
    """
    src = h if h_src is None else h_src
    msgs = src[edges.senders] * edges.mask[:, None]
    return jax.ops.segment_sum(msgs, edges.receivers,
                               num_segments=edges.num_vertices)


def aggregate_mean(h: jnp.ndarray, edges: EdgeList,
                   h_src: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    deg = masked_degree(edges)
    return aggregate_sum(h, edges, h_src) / jnp.maximum(deg, 1.0)[:, None]


# ----------------------------------------------------------------------------
# GCN
# ----------------------------------------------------------------------------

def gcn_init(key, in_dim: int, out_dim: int):
    wk, bk = jax.random.split(key)
    return {"w": _glorot(wk, (in_dim, out_dim)),
            "b": jnp.zeros((out_dim,), jnp.float32)}


def gcn_layer(params, h, edges: EdgeList, *, activation=jax.nn.relu,
              aggregate=aggregate_sum, h_src=None):
    """Paper Table I GCN row (sum aggregate, mean-with-self update)."""
    a = aggregate(h, edges, h_src)
    deg = masked_degree(edges)
    z = (a + h) / (deg + 1.0)[:, None]
    out = z @ params["w"] + params["b"]
    return activation(out) if activation is not None else out


# ----------------------------------------------------------------------------
# GAT (single head per layer; attention params learned, used directly at
# inference per the paper)
# ----------------------------------------------------------------------------

def gat_init(key, in_dim: int, out_dim: int):
    wk, ak1, ak2 = jax.random.split(key, 3)
    return {"w": _glorot(wk, (in_dim, out_dim)),
            "att_src": _glorot(ak1, (1, out_dim)),
            "att_dst": _glorot(ak2, (1, out_dim))}


def gat_layer(params, h, edges: EdgeList, *, activation=jax.nn.elu,
              h_src=None):
    wh = h @ params["w"]                                # [P, D] (local)
    wh_src = wh if h_src is None else h_src @ params["w"]
    alpha_src = (wh_src * params["att_src"]).sum(-1)    # [M]
    alpha_dst = (wh * params["att_dst"]).sum(-1)        # [P]
    # Self loops: include v in its own neighborhood (Table I: N_v u {v}).
    # In distributed mode the caller passes explicit self-edges instead
    # (senders index a different table), so only add them when h_src is h.
    if h_src is None:
        v_ids = jnp.arange(edges.num_vertices, dtype=edges.senders.dtype)
        s = jnp.concatenate([edges.senders, v_ids])
        r = jnp.concatenate([edges.receivers, v_ids])
        m = jnp.concatenate([edges.mask, jnp.ones_like(v_ids, jnp.float32)])
    else:
        s, r, m = edges.senders, edges.receivers, edges.mask
    logits = jax.nn.leaky_relu(alpha_src[s] + alpha_dst[r], 0.2)
    logits = jnp.where(m > 0, logits, -jnp.inf)
    # Segment softmax over each receiver's incoming edges.
    seg_max = jax.ops.segment_max(logits, r, num_segments=edges.num_vertices)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.where(m > 0, jnp.exp(logits - seg_max[r]), 0.0)
    denom = jax.ops.segment_sum(ex, r, num_segments=edges.num_vertices)
    coef = ex / jnp.maximum(denom[r], 1e-16)
    msgs = wh_src[s] * coef[:, None]
    a = jax.ops.segment_sum(msgs, r, num_segments=edges.num_vertices)
    return activation(a) if activation is not None else a


# ----------------------------------------------------------------------------
# GraphSAGE (mean aggregate version, Table I)
# ----------------------------------------------------------------------------

def sage_init(key, in_dim: int, out_dim: int):
    wk, bk = jax.random.split(key)
    return {"w": _glorot(wk, (2 * in_dim, out_dim)),
            "b": jnp.zeros((out_dim,), jnp.float32)}


def sage_layer(params, h, edges: EdgeList, *, activation=jax.nn.relu,
               aggregate=aggregate_mean, h_src=None):
    a = aggregate(h, edges, h_src)
    # The [a | h] @ W update, written as two explicit matmuls: XLA's
    # dot(concat) rewrite fires differently for batched vs unbatched
    # operands, which would break the batched==serial bit-identity the
    # executor run_many contract relies on. Splitting pins one reduction
    # order for both lowerings.
    f = h.shape[-1]
    out = a @ params["w"][:f] + h @ params["w"][f:] + params["b"]
    if activation is not None:
        out = activation(out)
    # L2 normalize as in GraphSAGE inference.
    return out / jnp.maximum(jnp.linalg.norm(out, axis=-1, keepdims=True), 1e-12)


LAYER_FNS = {"gcn": (gcn_init, gcn_layer),
             "gat": (gat_init, gat_layer),
             "sage": (sage_init, sage_layer)}


def apply_layer_with_sum(kind: str, p, h, edges: EdgeList, a_sum, *,
                         last: bool):
    """Apply one GCN/SAGE layer given its precomputed neighbor SUM.

    The shared tail of every fused-kernel execution path (single-program
    and mesh shards alike): the expensive neighbor sum ``a_sum`` has
    already been computed — by one fused (possibly batch-grid) SpMM
    dispatch — and only the cheap dense update remains. ``h``/``a_sum``
    are one [V, F] table or a stacked [B, V, F] micro-batch; the stacked
    case runs the update per-example under ``jax.vmap``, which preserves
    the per-example op sequence exactly (broadcasting the dense algebra
    over [B, V, F] does not: XLA lowers some batched contractions
    differently in the last float bits), keeping batched==serial
    bit-identity. SAGE's mean normalization is applied here, from the
    same masked degree the plain path uses.
    """
    _, layer_fn = LAYER_FNS[kind]
    kwargs = {"activation": None} if last else {}

    def apply_one(hh, aa):
        if kind == "sage":               # SAGE aggregates the mean
            def hook(h_, edges_, h_src_=None, _aa=aa):
                deg = masked_degree(edges_)
                return _aa / jnp.maximum(deg, 1.0)[:, None]
        else:
            def hook(h_, edges_, h_src_=None, _aa=aa):
                return _aa
        return layer_fn(p, hh, edges, aggregate=hook, **kwargs)

    if h.ndim == 3:
        return jax.vmap(apply_one)(h, a_sum)
    return apply_one(h, a_sum)
