"""Full GNN models: K-layer stacks of Table-I layers + ASTGCN-lite.

Includes a tiny full-batch trainer so accuracy experiments (paper Tables IV/V)
run against *trained* models rather than random weights.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.gnn.layers import (EdgeList, LAYER_FNS, aggregate_sum,
                              aggregate_mean, masked_degree)


def gnn_init(key, kind: str, dims: Sequence[int]) -> List[dict]:
    """dims = [in, hidden..., out]; returns per-layer param list."""
    init_fn, _ = LAYER_FNS[kind]
    keys = jax.random.split(key, len(dims) - 1)
    return [init_fn(k, dims[i], dims[i + 1]) for i, k in enumerate(keys)]


def gnn_apply_layers(params: List[dict], kind: str, h: jnp.ndarray,
                     edges: EdgeList, *, aggregate=None) -> List[jnp.ndarray]:
    """K-layer forward returning every layer's output, h^1 .. h^K.

    The per-layer op sequence is the single source of truth for
    ``gnn_apply`` (which returns only h^K), so capturing intermediates —
    what the activation-cache path does to seed incremental recompute —
    traces the exact same program modulo dead-code elimination and stays
    bit-identical to the plain forward.
    """
    _, layer_fn = LAYER_FNS[kind]
    n = len(params)
    outs = []
    for i, p in enumerate(params):
        kwargs = {}
        if aggregate is not None and kind in ("gcn", "sage"):
            kwargs["aggregate"] = aggregate
        if i == n - 1:
            h = layer_fn(p, h, edges, activation=None, **kwargs)
        else:
            h = layer_fn(p, h, edges, **kwargs)
        outs.append(h)
    return outs


def gnn_apply(params: List[dict], kind: str, h: jnp.ndarray, edges: EdgeList,
              *, aggregate=None) -> jnp.ndarray:
    """K-layer forward; last layer has no activation (logits)."""
    return gnn_apply_layers(params, kind, h, edges, aggregate=aggregate)[-1]


def num_layers(params) -> int:
    return len(params)


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(logits, labels):
    return jnp.mean((logits.argmax(-1) == labels).astype(jnp.float32))


def train_node_classifier(key, kind: str, graph, hidden: int = 64,
                          steps: int = 120, lr: float = 5e-3,
                          num_layers_: int = 2):
    """Full-batch training of a K-layer GNN node classifier. Small graphs
    only (used to produce trained weights for the accuracy benchmarks)."""
    assert graph.labels is not None
    nc = int(graph.labels.max()) + 1
    dims = [graph.feature_dim] + [hidden] * (num_layers_ - 1) + [nc]
    params = gnn_init(key, kind, dims)
    edges = EdgeList.from_graph(graph)
    h0 = jnp.asarray(graph.features)
    y = jnp.asarray(graph.labels)

    def loss_fn(p):
        return cross_entropy(gnn_apply(p, kind, h0, edges), y)

    @jax.jit
    def step(p):
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p = jax.tree_util.tree_map(lambda w, g: w - lr * g, p, grads)
        return p, loss

    loss = None
    for _ in range(steps):
        params, loss = step(params)
    return params, float(loss)


# ----------------------------------------------------------------------------
# ASTGCN-lite: spatial-temporal forecasting model (case study §IV-C).
#
# Faithful skeleton of Guo et al. AAAI'19: temporal attention + spatial
# attention + graph convolution + temporal convolution, predicting
# T_out=12 future flow values per sensor. Chebyshev convolution is
# approximated by the first-order GCN aggregation (K=1), which is the
# standard simplification (Kipf & Welling).
# ----------------------------------------------------------------------------

def astgcn_init(key, num_features: int, t_in: int, t_out: int,
                hidden: int = 32):
    ks = jax.random.split(key, 8)
    glorot = lambda k, s: jax.random.normal(k, s) * (2.0 / sum(s[-2:])) ** 0.5
    return {
        # temporal attention over the T_in axis
        "ta_q": glorot(ks[0], (num_features, hidden)),
        "ta_k": glorot(ks[1], (num_features, hidden)),
        # spatial gcn
        "gc_w": glorot(ks[2], (num_features, hidden)),
        "gc_b": jnp.zeros((hidden,)),
        # temporal conv (kernel 3, same padding) over time
        "tc_w": glorot(ks[3], (3 * hidden, hidden)),
        "tc_b": jnp.zeros((hidden,)),
        # output head: all T_in x hidden -> t_out
        "out_w": glorot(ks[4], (t_in * hidden, t_out)),
        "out_b": jnp.zeros((t_out,)),
    }


def astgcn_apply(params, history: jnp.ndarray, edges: EdgeList) -> jnp.ndarray:
    """history: [T_in, V, F] -> forecast [T_out, V]."""
    t_in, v, f = history.shape
    x = history
    # Temporal attention: weight timesteps per vertex.
    q = jnp.einsum("tvf,fh->tvh", x, params["ta_q"])
    k = jnp.einsum("tvf,fh->tvh", x, params["ta_k"])
    att = jnp.einsum("tvh,svh->vts", q, k) / jnp.sqrt(q.shape[-1])
    att = jax.nn.softmax(att, axis=-1)                    # [V, T, T]
    x = jnp.einsum("vts,svf->tvf", att, x)
    # Spatial graph convolution per timestep.
    def spatial(h):  # [V, F]
        a = aggregate_sum(h, edges)
        deg = masked_degree(edges)
        z = (a + h) / (deg + 1.0)[:, None]
        return jax.nn.relu(z @ params["gc_w"] + params["gc_b"])
    x = jax.vmap(spatial)(x)                              # [T, V, H]
    # Temporal convolution (kernel=3, same) via unfold.
    xp = jnp.pad(x, ((1, 1), (0, 0), (0, 0)))
    stacked = jnp.concatenate([xp[:-2], xp[1:-1], xp[2:]], axis=-1)  # [T,V,3H]
    x = jax.nn.relu(stacked @ params["tc_w"] + params["tc_b"])       # [T,V,H]
    # Head: flatten time, predict T_out flows.
    flat = x.transpose(1, 0, 2).reshape(v, -1)            # [V, T*H]
    out = flat @ params["out_w"] + params["out_b"]        # [V, T_out]
    return out.T                                          # [T_out, V]


def train_astgcn(key, tg, steps: int = 200, lr: float = 1e-3, hidden: int = 32):
    """Train ASTGCN-lite on a PeMS-style window (z-scored targets)."""
    g = tg.graph
    edges = EdgeList.from_graph(g)
    hist = jnp.asarray(tg.history)
    mu, sd = float(tg.target.mean()), float(tg.target.std() + 1e-6)
    y = jnp.asarray((tg.target - mu) / sd)
    params = astgcn_init(key, hist.shape[-1], hist.shape[0], y.shape[0], hidden)

    def loss_fn(p, h):
        pred = astgcn_apply(p, h, edges)
        return jnp.mean((pred - y) ** 2)

    @jax.jit
    def step(p, h):
        loss, grads = jax.value_and_grad(loss_fn)(p, h)
        p = jax.tree_util.tree_map(lambda w, g_: w - lr * g_, p, grads)
        return p, loss

    loss = None
    for _ in range(steps):
        params, loss = step(params, hist)
    return params, (mu, sd), float(loss)


def forecast_errors(pred: np.ndarray, target: np.ndarray) -> Dict[str, float]:
    """MAE / RMSE / MAPE as in paper Table V."""
    pred = np.asarray(pred, np.float64)
    target = np.asarray(target, np.float64)
    err = pred - target
    mae = float(np.abs(err).mean())
    rmse = float(np.sqrt((err ** 2).mean()))
    mape = float((np.abs(err) / np.maximum(np.abs(target), 1e-6)).mean() * 100)
    return {"mae": mae, "rmse": rmse, "mape": mape}
