"""``fograph-demo`` console entry point: the quickstart, end to end.

Trains a small GCN on the SIoT-style graph, compiles a serving plan on a
heterogeneous simulated fog cluster, serves queries, then overloads the
busiest fog and shows the adaptive scheduler reacting — the full Fig. 5/6
workflow on the Engine/Plan/Session API.
"""
from __future__ import annotations

import argparse

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="siot")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--kind", default="gcn", choices=["gcn", "gat", "sage"])
    ap.add_argument("--cluster", default="1A+4B+1C")
    ap.add_argument("--network", default="wifi")
    ap.add_argument("--compressor", default="daq")
    ap.add_argument("--placement", default="iep")
    ap.add_argument("--executor", default="sim")
    ap.add_argument("--queries", type=int, default=3)
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args(argv)

    import jax

    from repro.api import Engine
    from repro.gnn import datasets, models

    graph = datasets.load(args.dataset, scale=args.scale, seed=0)
    params, loss = models.train_node_classifier(
        jax.random.PRNGKey(0), args.kind, graph, steps=args.steps)
    print(f"trained {args.kind} on |V|={graph.num_vertices} "
          f"|E|={graph.num_edges} (loss {loss:.3f})")

    engine = Engine((params, args.kind), cluster=args.cluster,
                    network=args.network, compressor=args.compressor,
                    placement=args.placement, executor=args.executor)
    plan = engine.compile(graph)
    print("placement (vertices per fog):", plan.vertices_per_fog())
    print(f"estimated makespan: {plan.est_makespan:.3f}s")

    session = plan.session(accuracy_fn=lambda emb: float(
        models.accuracy(emb, graph.labels)))
    for i, r in enumerate(session.stream(args.queries)):
        print(f"query {i}: latency {r.latency:.3f}s  "
              f"throughput {r.throughput:.2f}/s  "
              f"wire {r.wire_bytes / 1e3:.1f} KB  "
              f"accuracy {r.accuracy:.4f}  [{r.backend}]")

    from repro.core import simulation
    t = simulation.measured_exec_times(plan.cluster, session.placement)
    plan.cluster.nodes[int(np.argmax(t))].background_load = 2.5
    print("scheduler action after overload:", session.adapt(lam=1.2))
    print(f"latency after adaptation: {session.query().latency:.3f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
