"""``fograph-demo`` console entry point: the quickstart, end to end.

Trains a small GCN on the SIoT-style graph, compiles a serving plan on a
heterogeneous simulated fog cluster, serves a Poisson arrival trace
through the micro-batching ``Server`` front-end (vs. the cloud baseline),
then overloads the busiest fog and shows the adaptive scheduler reacting
— the full Fig. 5/6 workflow on the Engine/Plan/Session/Server API.
"""
from __future__ import annotations

import argparse

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="siot")
    ap.add_argument("--scale", type=float, default=0.1)
    ap.add_argument("--kind", default="gcn", choices=["gcn", "gat", "sage"])
    ap.add_argument("--cluster", default="1A+4B+1C")
    ap.add_argument("--network", default="wifi")
    ap.add_argument("--compressor", default="daq")
    ap.add_argument("--placement", default="iep")
    ap.add_argument("--executor", default="sim")
    ap.add_argument("--aggregation", default="auto",
                    choices=["segment_sum", "pallas", "auto"],
                    help="shard-local aggregation path (pallas = the "
                         "block-CSR kernels; auto = kernels on TPU)")
    ap.add_argument("--queries", type=int, default=16)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate (req/s) for the trace")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=80)
    args = ap.parse_args(argv)

    import jax

    from repro.api import Engine, traces
    from repro.gnn import datasets, models

    graph = datasets.load(args.dataset, scale=args.scale, seed=0)
    params, loss = models.train_node_classifier(
        jax.random.PRNGKey(0), args.kind, graph, steps=args.steps)
    print(f"trained {args.kind} on |V|={graph.num_vertices} "
          f"|E|={graph.num_edges} (loss {loss:.3f})")

    engine = Engine((params, args.kind), cluster=args.cluster,
                    network=args.network, compressor=args.compressor,
                    placement=args.placement, executor=args.executor,
                    aggregation=args.aggregation)
    plan = engine.compile(graph)
    print("placement (vertices per fog):", plan.vertices_per_fog())
    print(f"estimated makespan: {plan.est_makespan:.3f}s")

    acc_fn = lambda emb: float(models.accuracy(emb, graph.labels))  # noqa: E731
    server = plan.server(max_batch=args.max_batch, max_wait=0.05,
                         accuracy_fn=acc_fn)
    trace = traces.poisson(args.queries, args.rate, seed=1)
    responses = server.replay(trace)
    for r in responses[:3]:
        print(f"request {r.request_id}: latency {r.latency:.3f}s "
              f"(queue {r.queue_delay:.3f}s, batch of {r.batch_size})  "
              f"wire {r.wire_bytes / 1e3:.1f} KB  "
              f"accuracy {r.accuracy:.4f}  [{r.backend}]")
    s = server.summarize(responses)
    print(f"trace of {s['requests']}: makespan {s['makespan_s']:.2f}s  "
          f"throughput {s['throughput_rps']:.2f}/s  "
          f"p95 latency {s['latency_p95_s']:.3f}s  "
          f"mean batch {s['mean_batch']:.2f}  "
          f"overlap saved {s['overlap_saved_s']:.2f}s")

    session = server.session
    cloud = session.query(executor="cloud")
    # Pin the fog side of the Fig. 3 comparison to a fog backend even when
    # the demo itself was pointed at the cloud executor.
    fog_exec = "sim" if args.executor == "cloud" else args.executor
    fog = session.query(executor=fog_exec)
    print(f"cloud-vs-fog (Fig. 3): cloud {cloud.latency:.3f}s vs "
          f"fog {fog.latency:.3f}s [{fog_exec}] "
          f"({cloud.latency / fog.latency:.2f}x speedup)")

    from repro.core import simulation
    t = simulation.measured_exec_times(plan.cluster, session.placement)
    plan.cluster.nodes[int(np.argmax(t))].background_load = 2.5
    print("scheduler action after overload:", session.adapt(lam=1.2))
    print(f"latency after adaptation: {session.query().latency:.3f}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
