"""SLO-aware serving control plane: deadlines, priorities, degradation.

The paper's real-time story (5.39x latency, 6.84x throughput) only holds
if latency targets survive load; a ``Server`` that admits everything and
batches with a constant ``max_batch`` simply grows its queue under
overload. This module is the production half of the serving stack: the
*policy* layer the request-level ``Server`` consults before it spends
simulated-clock time on a request.

Four pieces, wired through ``Server(slo=..., adaptive_batch=...)``:

  * **Deadlines + priority classes** — ``Request``/``UpdateRequest`` carry
    ``deadline`` (a latency budget in simulated seconds from arrival) and
    ``priority`` (higher = more important). Every ``repro.api.traces``
    generator annotates them; under overload the Server serves pending
    queries highest-priority-first (never reordering across a graph
    update, so mutation visibility stays FIFO-consistent).
  * **Admission control** — before serving a micro-batch the Server
    estimates its finish time on the simulated clock (current pipeline
    state + ``Session.account(batch_size=B)``). If a member's deadline
    would be blown it walks the :data:`degradation ladder
    <default_ladder>`; if even the last rung misses, the request is
    rejected (a :class:`Rejection`, not silently-late work) — or served
    late when ``reject_hopeless=False``.
  * **Degradation ladder** — an ordered tuple of
    :class:`DegradationLevel` rungs, each a *complete* knob set
    (``aggregation`` / ``compressor`` / ``num_layers``) built cumulatively:
    strict-Pallas → ``segment_sum``, ``daq`` → ``uniform8``, then
    progressively fewer GNN layers. Each rung is served by a cached
    ``Session`` over ``plan.with_overrides(...)``, so a degraded response
    is **bit-identical** to a session configured with those knobs
    directly; ``Response.degradation`` records the rung.
  * **Adaptive batch sizing** — :class:`AdaptiveBatchController` closes
    the loop on the measured batched-latency curve: seeded from
    ``BENCH_serving.json`` (the PR 5 dispatch-amortization sweep), refined
    online from per-batch service observations, and queried per drain for
    the batch size that maximizes efficiency ``B / service(B)`` subject to
    the head-of-line deadline slack.

Updates are not free control-plane work anymore: with the control plane
active, a ``GraphDelta``'s repair is priced by
``core.simulation.simulate_update`` and occupies the execution stage of
the pipeline (an update whose repair cannot meet its deadline is
rejected *before* mutating the graph).
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

# ----------------------------------------------------------------------------
# Degradation ladder
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DegradationLevel:
    """One rung of the degradation ladder: a complete serving-knob set.

    ``None`` fields inherit the base session's knob. Rungs are complete
    (not diffs): rung k carries every override of rungs 1..k, so the
    Server can jump straight to any rung.
    """
    name: str
    aggregation: Optional[str] = None
    compressor: Optional[str] = None
    num_layers: Optional[int] = None

    def knobs(self) -> Dict[str, object]:
        return {k: v for k, v in (("aggregation", self.aggregation),
                                  ("compressor", self.compressor),
                                  ("num_layers", self.num_layers))
                if v is not None}


def default_ladder(session) -> Tuple[DegradationLevel, ...]:
    """Build the default ladder for a session's base configuration.

    Cumulative, cheapest-sacrifice first:

      1. ``aggregation="segment_sum"`` — only when the base session
         resolves to the strict Pallas path (frees the kernel lane; no
         effect on the analytic clock, real effect on hardware).
      2. ``compressor="uniform8"`` — only for DAQ-family plans (drops the
         degree-aware allocation + lossless stage; cheaper device-side
         packing at some wire-byte cost).
      3. ``num_layers=K-1 .. 1`` — truncate the GNN's layer stack, the
         big lever: per-layer matmuls, aggregation AND one K*delta sync
         round each disappear from the critical path.

    Rungs that would be no-ops for the base config are skipped.

    On a failover plan (``plan.provenance == "failover"`` — the session
    serves a degraded-capacity surviving cluster) the non-depth
    sacrifices collapse into one leading **"survivor-degraded"** rung:
    lost capacity means the cheapest headroom (kernel lane + wire bytes)
    is taken in a single step before admission starts trading model
    depth.
    """
    from repro.runtime import bsp   # lazy: keep module import light
    plan = session.plan
    kind = plan.model.kind
    rungs = []
    agg = None
    try:
        exchange = (session._exchange.name
                    if getattr(session._executor, "needs_block_shards",
                               False) else None)
        resolved = bsp.resolve_aggregation(session._aggregation, kind,
                                           exchange=exchange)
    except ValueError:
        resolved = "segment_sum"
    if resolved == "pallas":
        agg = "segment_sum"
        rungs.append(DegradationLevel("segment_sum", aggregation=agg))
    comp = None
    if plan.config.compressor.startswith("daq"):
        comp = "uniform8"
        rungs.append(DegradationLevel("uniform8", aggregation=agg,
                                      compressor=comp))
    if getattr(plan, "provenance", "") == "failover" and rungs:
        # Survivor-degraded: on a degraded-capacity failover plan the
        # non-depth sacrifices are one rung, walked first.
        rungs = [DegradationLevel("survivor-degraded", aggregation=agg,
                                  compressor=comp)]
    for layers in range(plan.model.num_layers - 1, 0, -1):
        rungs.append(DegradationLevel(f"layers{layers}", aggregation=agg,
                                      compressor=comp, num_layers=layers))
    return tuple(rungs)


# ----------------------------------------------------------------------------
# Policy + decisions
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Admission policy the ``Server`` consults per micro-batch.

    Attributes:
      default_deadline: budget (simulated seconds from arrival) applied to
        requests that carry none; ``None`` leaves them deadline-free
        (never degraded for their own sake, never rejected).
      degrade: walk the ladder before giving up. ``False`` = admit/reject
        only.
      reject_hopeless: reject requests that would miss their deadline even
        at the last rung. ``False`` serves them late (at the last rung)
        and lets ``Response.deadline_met`` record the miss.
      ladder: explicit ladder; ``None`` builds :func:`default_ladder`
        from the server's base session.
      update_deadline: default deadline for ``UpdateRequest`` entries that
        carry none (updates are priced, never degraded).
    """
    default_deadline: Optional[float] = None
    degrade: bool = True
    reject_hopeless: bool = True
    ladder: Optional[Tuple[DegradationLevel, ...]] = None
    update_deadline: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class Rejection:
    """An admission-controller reject: the request was never served.

    Takes the place of a ``Response`` in ``Server.drain`` output (service
    order preserved); ``estimated_latency`` is the finish-minus-arrival
    the controller predicted at the best (most degraded) rung it
    considered. A rejected update never mutated the graph.
    """
    request_id: int
    arrival_time: float
    priority: int = 0
    deadline: Optional[float] = None
    estimated_latency: float = 0.0
    kind: str = "query"          # "query" | "update"
    reason: str = "deadline"


# ----------------------------------------------------------------------------
# Adaptive batch sizing
# ----------------------------------------------------------------------------


class AdaptiveBatchController:
    """Pick the micro-batch size from the measured batched-latency curve.

    The controller maintains an EMA of observed per-batch service time
    ``s(B)`` (collect + execute on the serving clock), optionally seeded
    from a benchmark curve (``BENCH_serving.json``'s ``batched_s`` per
    batch). Seed points are treated as a *shape prior*: once online
    observations exist, the seed curve is rescaled onto them (wall-clock
    benchmark seconds and simulated serving seconds differ in scale but
    share the amortization shape), and an online point always wins over a
    seed point at the same B.

    ``pick(backlog, slack=...)`` returns the B in ``[1, min(max_batch,
    backlog)]`` maximizing efficiency ``B / s(B)`` among sizes whose
    estimated service fits the head-of-line deadline slack; if nothing
    fits, 1 (serve the fastest thing we can); with no observations at
    all, the full backlog (optimistic: amortize everything queued).
    """

    def __init__(self, max_batch: int = 32, *,
                 seed_curve: Optional[Dict[int, float]] = None,
                 alpha: float = 0.4):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.max_batch = int(max_batch)
        self.alpha = float(alpha)
        self._seed = {int(b): float(s) for b, s in (seed_curve or {}).items()
                      if int(b) >= 1 and float(s) > 0.0}
        self._seed_scale = 1.0
        self._obs: Dict[int, float] = {}

    # -- learning ---------------------------------------------------------

    def observe(self, batch_size: int, service_s: float) -> None:
        """Fold one measured per-batch service time into the curve."""
        b, s = int(batch_size), float(service_s)
        if b < 1 or s <= 0.0 or not np.isfinite(s):
            return
        prev = self._obs.get(b)
        self._obs[b] = s if prev is None else (
            (1.0 - self.alpha) * prev + self.alpha * s)
        if self._seed:
            # Re-anchor the seed curve's scale on the online points.
            ratios = [self._obs[k] / self._raw_seed_estimate(k)
                      for k in self._obs]
            self._seed_scale = float(np.median(ratios))

    def _raw_seed_estimate(self, b: int) -> float:
        xs = sorted(self._seed)
        ys = [self._seed[x] for x in xs]
        return float(np.interp(b, xs, ys)) if len(xs) > 1 else ys[0]

    def _points(self) -> Dict[int, float]:
        pts = {b: s * self._seed_scale for b, s in self._seed.items()}
        pts.update(self._obs)
        return pts

    def estimate(self, batch_size: int) -> Optional[float]:
        """Estimated per-batch service seconds at ``batch_size``.

        Exact (EMA/seed) where observed; linear interpolation between
        observed sizes; affine extrapolation beyond them. ``None`` with no
        data at all.
        """
        pts = self._points()
        if not pts:
            return None
        b = int(batch_size)
        if b in pts:
            return pts[b]
        xs = np.array(sorted(pts), float)
        ys = np.array([pts[int(x)] for x in xs])
        if len(xs) == 1:
            return float(ys[0])
        if xs[0] <= b <= xs[-1]:
            return float(np.interp(b, xs, ys))
        slope, icept = np.polyfit(xs, ys, 1)
        return float(max(slope * b + icept, 1e-9))

    # -- decision ---------------------------------------------------------

    def pick(self, backlog: int, *, slack: Optional[float] = None) -> int:
        """Batch size for the next drain given ``backlog`` queued requests
        and the head-of-line request's deadline ``slack`` (seconds left
        before its collection must start finishing; None = unconstrained).
        """
        cap = max(1, min(self.max_batch, int(backlog)))
        if not self._points():
            return cap
        best_b, best_eff = None, -1.0
        for b in range(1, cap + 1):
            s = self.estimate(b)
            if slack is not None and s > slack:
                continue
            eff = b / max(s, 1e-12)
            if eff > best_eff:
                best_b, best_eff = b, eff
        return 1 if best_b is None else best_b

    def __repr__(self) -> str:
        return (f"AdaptiveBatchController(max_batch={self.max_batch}, "
                f"observed={sorted(self._obs)}, "
                f"seeded={sorted(self._seed)})")


def load_bench_curve(path: Optional[str] = None, *, executor: str = "sim",
                     aggregation: str = "segment_sum") -> Dict[int, float]:
    """Seed curve for :class:`AdaptiveBatchController` from a
    ``BENCH_serving.json`` sweep: batch size -> whole-batch seconds
    (``batched_s``), averaged over matching rows. Returns ``{}`` when the
    file is missing or malformed — the controller then starts cold.

    When the file has rows but none match the requested (executor,
    aggregation) pair, a :class:`RuntimeWarning` is emitted and the
    closest available pair is used instead — same executor first, then
    same aggregation, then any — so a controller asked for an unswept
    combination is seeded with a related curve rather than silently
    starting cold.
    """
    if path is None:
        here = os.path.abspath(__file__)
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(here))))
        path = os.path.join(root, "BENCH_serving.json")
    try:
        with open(path) as f:
            payload = json.load(f)
        rows = payload["rows"]
    except (OSError, ValueError, KeyError, TypeError):
        return {}
    by_pair: Dict[Tuple[str, str], Dict[int, list]] = {}
    for row in rows:
        try:
            pair = (str(row["executor"]), str(row["aggregation"]))
            by_pair.setdefault(pair, {}).setdefault(
                int(row["batch"]), []).append(float(row["batched_s"]))
        except (ValueError, KeyError, TypeError):
            continue
    if not by_pair:
        return {}
    want = (executor, aggregation)
    if want not in by_pair:
        fallback = (
            [p for p in sorted(by_pair) if p[0] == executor]
            or [p for p in sorted(by_pair) if p[1] == aggregation]
            or sorted(by_pair))[0]
        warnings.warn(
            f"load_bench_curve: no rows for executor={executor!r} "
            f"aggregation={aggregation!r} in {path}; falling back to "
            f"executor={fallback[0]!r} aggregation={fallback[1]!r}",
            RuntimeWarning, stacklevel=2)
        want = fallback
    curve = by_pair[want]
    return {b: float(np.mean(v)) for b, v in curve.items() if v}


# ----------------------------------------------------------------------------
# Trace annotation helpers
# ----------------------------------------------------------------------------


def slo_classes(classes: Sequence[Tuple[float, int, Optional[float]]]):
    """Build a ``slo_fn`` for the ``repro.api.traces`` generators from a
    mixed-criticality class spec: ``[(weight, priority, deadline), ...]``
    (weights need not sum to 1; deadline None = best-effort). Each request
    draws one class — e.g. 30% critical anomaly-detection traffic under a
    tight deadline over 70% background analytics::

        slo_fn = slo.slo_classes([(0.3, 2, 0.5), (0.7, 0, None)])
        trace = traces.poisson(256, rate=8.0, slo_fn=slo_fn)
    """
    if not classes:
        raise ValueError("classes must be non-empty")
    weights = np.array([c[0] for c in classes], float)
    if (weights <= 0).any():
        raise ValueError("class weights must be > 0")
    probs = weights / weights.sum()

    def slo_fn(i: int, rng: np.random.Generator):
        _, priority, deadline = classes[int(rng.choice(len(probs), p=probs))]
        return deadline, int(priority)

    return slo_fn


# ----------------------------------------------------------------------------
# Per-site policies (the fleet front-end, repro.api.fleet)
# ----------------------------------------------------------------------------


def per_site(default: Optional[SLOPolicy] = None,
             **overrides: Optional[SLOPolicy]) -> Dict[str, object]:
    """Build a per-site SLO policy table for ``FleetServer(slo=...)``.

    Keyword arguments map site names (including the ``"cloud"`` tier) to
    their :class:`SLOPolicy`; every other site serves under ``default``
    (None = that site runs without a control plane). Typical shape: a
    tight edge-side deadline with a laxer cloud fallback::

        slo.per_site(SLOPolicy(default_deadline=0.5),
                     cloud=SLOPolicy(default_deadline=2.0))

    The FleetServer validates the names against its site table at
    construction, so a typo'd site fails fast instead of silently serving
    policy-free.
    """
    for name, pol in overrides.items():
        if pol is not None and not isinstance(pol, SLOPolicy):
            raise TypeError(f"per-site policy {name!r} must be an SLOPolicy "
                            f"or None, got {type(pol).__name__}")
    if default is not None and not isinstance(default, SLOPolicy):
        raise TypeError(f"default must be an SLOPolicy or None, got "
                        f"{type(default).__name__}")
    table: Dict[str, object] = {"default": default}
    table.update(overrides)
    return table
