"""Compiled execution plans (the frozen output of ``Engine.compile``).

A ``Plan`` is the paper's "execution plan" artifact: profiling + IEP
placement have already run, the per-partition static-shape buffers are
frozen, and every pipeline component is resolved to a registry entry. Plans
are immutable — serving state (adaptive-scheduler migrations, query
counters) lives in ``Session`` objects spawned from the plan, so one plan
can back many concurrent sessions without interference.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.placement import FogSpec, Placement
from repro.core.simulation import FogCluster
from repro.gnn.graph import Graph
from repro.gnn.layers import LAYER_FNS
from repro.runtime.bsp import PartitionedGraph


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """The GNN being served: per-layer params + layer kind (Table I)."""
    params: tuple
    kind: str

    def __post_init__(self):
        if self.kind not in LAYER_FNS:
            raise ValueError(f"unknown GNN kind {self.kind!r}; "
                             f"available: {', '.join(sorted(LAYER_FNS))}")

    @property
    def num_layers(self) -> int:
        return len(self.params)


def as_model(model) -> ModelSpec:
    """Coerce ``(params, kind)`` / ``(kind, params)`` / ModelSpec."""
    if isinstance(model, ModelSpec):
        return model
    if isinstance(model, (tuple, list)) and len(model) == 2:
        a, b = model
        if isinstance(a, str):
            return ModelSpec(params=tuple(b), kind=a)
        return ModelSpec(params=tuple(a), kind=b)
    raise TypeError("model must be a ModelSpec or a (params, kind) pair, "
                    f"got {type(model).__name__}")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Resolved registry keys + knobs an Engine compiled with."""
    partitioner: str
    placement: str
    compressor: str
    exchange: str
    executor: str
    network: str
    cluster_spec: Optional[str]
    hidden: int
    seed: int
    sync_cost: float
    bytes_per_vertex: Optional[float] = None
    # Shard-local aggregation path: "segment_sum" | "pallas" | "auto"
    # (auto = the Pallas block-CSR kernels wherever supported on TPU,
    # segment_sum elsewhere). See runtime.bsp.resolve_aggregation.
    aggregation: str = "auto"
    # Stale-tolerant serving bound for the "halo_async" exchange: a serve
    # may replay recorded halo tables up to this many versions old before
    # the next fresh synchronous exchange is forced. 0 (the default) means
    # every serve syncs — bit-identical to exchange="halo". Only legal with
    # a stale-tolerant exchange entry (Engine validates eagerly).
    staleness_bound: int = 0
    # Dynamic-update repair thresholds (Engine.apply_delta): fall back to a
    # full recompile when the repaired partitioning's imbalance (max size /
    # uniform share) exceeds update_max_imbalance x the pre-update
    # imbalance (floored at 1.0, so heterogeneity-sized plans aren't
    # penalized for their intended skew), or its cut fraction exceeds
    # update_max_cut_growth x the pre-update cut fraction.
    update_max_imbalance: float = 2.0
    update_max_cut_growth: float = 1.5
    # Static plan verification (repro.analysis): "off" | "warn" | "strict".
    # strict runs the plan invariant checks at Engine.compile / apply_delta
    # exit and raises PlanValidationError on any violation; warn emits
    # PlanInvariantWarning instead. Never changes what is compiled.
    validate: str = "off"

    def with_overrides(self, **kw) -> "EngineConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class Plan:
    """An immutable compiled serving plan: Engine.compile(graph) -> Plan.

    ``provenance`` records how the plan was produced: "compile" (the full
    setup phase), "incremental" (``Engine.apply_delta`` repaired an
    existing plan), "recompile" (a delta tripped a repair threshold and
    the full pipeline re-ran) or "failover" (``Engine.fail_nodes``
    re-placed a crashed node's shards onto the surviving,
    degraded-capacity cluster — such plans carry ``cluster_spec=None``
    so later recompiles/pricing never resurrect the crashed node);
    ``update_report`` is the
    :class:`~repro.api.updates.UpdateReport` of the delta that produced an
    updated plan (None for fresh compiles).
    """
    model: ModelSpec
    graph: Graph
    cluster: FogCluster
    fogs: Tuple[FogSpec, ...]
    placement: Placement
    partitioned: PartitionedGraph
    config: EngineConfig
    provenance: str = "compile"
    update_report: Optional[object] = None

    @property
    def num_fogs(self) -> int:
        return len(self.fogs)

    @property
    def est_makespan(self) -> float:
        return self.placement.est_makespan

    def vertices_per_fog(self) -> np.ndarray:
        return np.bincount(self.placement.assignment,
                           minlength=self.num_fogs)

    def with_overrides(self, *, compressor: Optional[str] = None,
                       num_layers: Optional[int] = None) -> "Plan":
        """Derive a plan with degraded serving knobs, sharing every frozen
        buffer of this one.

        ``compressor`` swaps the upload codec (the derived config is what
        executors' wire-format decisions and the latency accounting read,
        so e.g. ``"uniform8"`` consistently disables the DAQ-fused halo
        wire); ``num_layers`` truncates the GNN to its first ``L`` layers
        (the truncated last layer serves logits, matching a model trained
        at that depth's op sequence) and re-prices the cluster's per-query
        workload at ``L`` layers. The graph, placement and partitioned
        buffers are shared — this is a cheap view, not a recompile. It is
        the mechanism behind the SLO control plane's degradation ladder
        (``repro.api.slo``) and the ``Session(compressor=, num_layers=)``
        overrides.
        """
        changes = {}
        if compressor is not None:
            from repro.api.registry import COMPRESSORS
            COMPRESSORS.resolve(compressor)   # fail fast on bad keys
            key = COMPRESSORS.canonical(compressor)
            if key != self.config.compressor:
                changes["config"] = self.config.with_overrides(
                    compressor=key)
        if num_layers is not None:
            k = self.model.num_layers
            if not 1 <= num_layers <= k:
                raise ValueError(f"num_layers must be in [1, {k}], "
                                 f"got {num_layers}")
            if num_layers < k:
                changes["model"] = ModelSpec(
                    params=self.model.params[:num_layers],
                    kind=self.model.kind)
                changes["cluster"] = dataclasses.replace(
                    self.cluster, k_layers=num_layers)
        return dataclasses.replace(self, **changes) if changes else self

    def session(self, **kw) -> "Session":
        """Open a serving session (owns all mutable runtime state)."""
        from repro.api.session import Session
        return Session(self, **kw)

    def server(self, *, max_batch: int = 8, max_wait: float = 0.0,
               pipelined: bool = True, slo=None, adaptive_batch=None,
               faults=None, **session_kw) -> "Server":
        """Open a request-level server (micro-batching + pipelined
        collect/execute) over a fresh session; ``slo``/``adaptive_batch``
        activate the SLO control plane (``repro.api.slo``); ``faults``
        installs a chaos schedule (``repro.api.faults``); extra kwargs
        go to ``session()``."""
        from repro.api.server import Server
        return Server(self.session(**session_kw), max_batch=max_batch,
                      max_wait=max_wait, pipelined=pipelined, slo=slo,
                      adaptive_batch=adaptive_batch, faults=faults)

    def describe(self) -> dict:
        """Plain-dict summary (for logs / dashboards)."""
        return {
            "model": {"kind": self.model.kind,
                      "layers": self.model.num_layers},
            "graph": {"vertices": self.graph.num_vertices,
                      "edges": self.graph.num_edges,
                      "feature_dim": self.graph.feature_dim},
            "fogs": [f.name for f in self.fogs],
            "vertices_per_fog": self.vertices_per_fog().tolist(),
            "est_makespan": self.est_makespan,
            "pipeline": dataclasses.asdict(self.config),
            "provenance": self.provenance,
        }
