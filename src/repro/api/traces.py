"""Arrival-trace generators for the request-level serving front-end.

Each generator returns a list of ``Request`` objects with nondecreasing
``arrival_time`` on the simulated clock — the input shape
``Server.replay`` consumes. Rates are requests/second.

  poisson(n, rate)            memoryless arrivals (exp inter-arrivals) —
                              the standard open-loop serving workload.
  constant(n, rate)           deterministic 1/rate spacing.
  bursty(n, rate, ...)        batched sensor wake-ups: bursts of
                              near-simultaneous queries separated by
                              idle gaps, at the same long-run rate.

``features_fn(i, rng)`` optionally attaches fresh per-request feature
uploads (e.g. noisy sensor readings); by default requests re-serve the
graph's stored features (``features=None``).
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.api.server import Request

FeaturesFn = Callable[[int, np.random.Generator], Optional[np.ndarray]]


def _build(arrivals: np.ndarray, features_fn: Optional[FeaturesFn],
           rng: np.random.Generator, executor: Optional[str]) -> List[Request]:
    out = []
    for i, t in enumerate(np.asarray(arrivals, float)):
        feats = None if features_fn is None else features_fn(i, rng)
        # request_id stays None: the Server assigns ids at submit() in
        # submission order, so they stay unique even when one server
        # replays several traces back to back.
        out.append(Request(features=feats, arrival_time=float(t),
                           executor=executor))
    return out


def poisson(n: int, rate: float, *, seed: int = 0,
            features_fn: Optional[FeaturesFn] = None,
            executor: Optional[str] = None,
            start: float = 0.0) -> List[Request]:
    """``n`` Poisson arrivals at ``rate`` req/s (exponential gaps)."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return _build(start + np.cumsum(gaps), features_fn, rng, executor)


def constant(n: int, rate: float, *, seed: int = 0,
             features_fn: Optional[FeaturesFn] = None,
             executor: Optional[str] = None,
             start: float = 0.0) -> List[Request]:
    """``n`` deterministic arrivals spaced exactly ``1/rate`` apart."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    return _build(start + np.arange(1, n + 1) / rate, features_fn, rng,
                  executor)


def bursty(n: int, rate: float, *, burst: int = 4, jitter: float = 0.01,
           seed: int = 0, features_fn: Optional[FeaturesFn] = None,
           executor: Optional[str] = None,
           start: float = 0.0) -> List[Request]:
    """``n`` arrivals in bursts of ~``burst`` near-simultaneous requests.

    Bursts fire every ``burst/rate`` seconds (so the long-run rate is
    ``rate``); within a burst, requests are spread by exponential jitter
    with mean ``jitter`` seconds — the correlated wake-up pattern of
    co-located IoT sensors.
    """
    if rate <= 0 or burst < 1:
        raise ValueError(f"need rate > 0 and burst >= 1, "
                         f"got rate={rate}, burst={burst}")
    rng = np.random.default_rng(seed)
    base = start + (np.arange(n) // burst + 1) * (burst / rate)
    arrivals = np.sort(base + rng.exponential(jitter, size=n))
    return _build(arrivals, features_fn, rng, executor)
