"""Arrival-trace generators for the request-level serving front-end.

Each generator returns a list of ``Request`` objects with nondecreasing
``arrival_time`` on the simulated clock — the input shape
``Server.replay`` consumes. Rates are requests/second.

  poisson(n, rate)            memoryless arrivals (exp inter-arrivals) —
                              the standard open-loop serving workload.
  constant(n, rate)           deterministic 1/rate spacing.
  bursty(n, rate, ...)        batched sensor wake-ups: bursts of
                              near-simultaneous queries separated by
                              idle gaps, at the same long-run rate.
  mixed(n, rate, ...)         interleaved update/query stream for mutating
                              IoT graphs: each Poisson arrival is a graph
                              update (``UpdateRequest``) with probability
                              ``update_fraction``, else a query.

``features_fn(i, rng)`` optionally attaches fresh per-request feature
uploads (e.g. noisy sensor readings); by default requests re-serve the
graph's stored features (``features=None``).

SLO annotations (read by the Server's control plane, ``repro.api.slo``):
every generator takes ``deadline=`` / ``priority=`` to stamp the whole
trace with one latency budget and class rank, or ``slo_fn(i, rng) ->
(deadline, priority)`` for per-request annotations — e.g. the output of
``repro.api.slo.slo_classes`` for a weighted mix of service classes.
``slo_fn`` wins over the scalar kwargs; in ``mixed`` traces it annotates
updates too.

Geo annotations (read by the fleet router, ``repro.api.fleet``): every
generator takes ``origin_fn(i) -> (lat, lon)`` to stamp per-request geo
coordinates — :func:`geo_origins` builds one from site centroids with a
zipfian site-popularity mixer. ``origin_fn`` owns its own RNG stream, so
the default (None) keeps every existing trace byte-identical: the shared
generator's feature/SLO draws are never perturbed.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.api.server import Request
from repro.api.updates import GraphDelta, UpdateRequest

FeaturesFn = Callable[[int, np.random.Generator], Optional[np.ndarray]]
DeltaFn = Callable[[int, np.random.Generator], GraphDelta]
#: (index, rng) -> (deadline seconds or None, priority)
SloFn = Callable[[int, np.random.Generator], Tuple[Optional[float], int]]
#: index -> (lat, lon); owns its own RNG stream (see geo_origins) so the
#: generators' shared feature/SLO draws stay untouched.
OriginFn = Callable[[int], Tuple[float, float]]


def geo_origins(centroids: Sequence[Tuple[float, float]], *,
                spread: float = 0.3, zipf_s: float = 1.0,
                seed: int = 0) -> OriginFn:
    """Build an ``origin_fn`` sampling request coordinates around site
    centroids with zipfian site popularity.

    ``centroids`` is a sequence of ``(lat, lon)`` site centers (e.g. the
    fleet's site locations, in listed order). Each request first draws a
    site with probability proportional to ``1 / rank^zipf_s`` (rank =
    1-based centroid position, so earlier sites are more popular;
    ``zipf_s=0`` is uniform), then scatters around that centroid with
    isotropic gaussian noise of ``spread`` degrees — the geo-skewed
    arrival mix a fleet router sees from real IoT deployments.

    The returned function owns a private RNG seeded from ``seed``:
    attaching origins to a trace never changes its arrivals, features or
    SLO annotations.
    """
    cents = [(float(lat), float(lon)) for lat, lon in centroids]
    if not cents:
        raise ValueError("centroids must be non-empty")
    if spread < 0:
        raise ValueError(f"spread must be >= 0, got {spread}")
    ranks = np.arange(1, len(cents) + 1, dtype=float)
    weights = ranks ** -float(zipf_s)
    probs = weights / weights.sum()
    rng = np.random.default_rng(seed)

    def origin_fn(i: int) -> Tuple[float, float]:
        j = int(rng.choice(len(cents), p=probs))
        lat, lon = cents[j]
        dlat, dlon = rng.normal(0.0, spread, size=2)
        return (lat + dlat, lon + dlon)

    return origin_fn


def _slo_of(i: int, rng: np.random.Generator, slo_fn: Optional[SloFn],
            deadline: Optional[float], priority: int
            ) -> Tuple[Optional[float], int]:
    if slo_fn is None:
        return deadline, priority
    d, p = slo_fn(i, rng)
    return (None if d is None else float(d)), int(p)


def _build(arrivals: np.ndarray, features_fn: Optional[FeaturesFn],
           rng: np.random.Generator, executor: Optional[str],
           deadline: Optional[float] = None, priority: int = 0,
           slo_fn: Optional[SloFn] = None,
           origin_fn: Optional[OriginFn] = None) -> List[Request]:
    out = []
    for i, t in enumerate(np.asarray(arrivals, float)):
        feats = None if features_fn is None else features_fn(i, rng)
        d, p = _slo_of(i, rng, slo_fn, deadline, priority)
        # origin_fn draws from its OWN rng (geo_origins), never from the
        # shared one: a trace with origins attached is the byte-identical
        # trace plus coordinates.
        origin = None if origin_fn is None else tuple(origin_fn(i))
        # request_id stays None: the Server assigns ids at submit() in
        # submission order, so they stay unique even when one server
        # replays several traces back to back.
        out.append(Request(features=feats, arrival_time=float(t),
                           executor=executor, deadline=d, priority=p,
                           origin=origin))
    return out


def poisson(n: int, rate: float, *, seed: int = 0,
            features_fn: Optional[FeaturesFn] = None,
            executor: Optional[str] = None,
            deadline: Optional[float] = None, priority: int = 0,
            slo_fn: Optional[SloFn] = None,
            origin_fn: Optional[OriginFn] = None,
            start: float = 0.0) -> List[Request]:
    """``n`` Poisson arrivals at ``rate`` req/s (exponential gaps)."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    return _build(start + np.cumsum(gaps), features_fn, rng, executor,
                  deadline, priority, slo_fn, origin_fn)


def constant(n: int, rate: float, *, seed: int = 0,
             features_fn: Optional[FeaturesFn] = None,
             executor: Optional[str] = None,
             deadline: Optional[float] = None, priority: int = 0,
             slo_fn: Optional[SloFn] = None,
             origin_fn: Optional[OriginFn] = None,
             start: float = 0.0) -> List[Request]:
    """``n`` deterministic arrivals spaced exactly ``1/rate`` apart."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    return _build(start + np.arange(1, n + 1) / rate, features_fn, rng,
                  executor, deadline, priority, slo_fn, origin_fn)


def bursty(n: int, rate: float, *, burst: int = 4, jitter: float = 0.01,
           seed: int = 0, features_fn: Optional[FeaturesFn] = None,
           executor: Optional[str] = None,
           deadline: Optional[float] = None, priority: int = 0,
           slo_fn: Optional[SloFn] = None,
           origin_fn: Optional[OriginFn] = None,
           start: float = 0.0) -> List[Request]:
    """``n`` arrivals in bursts of ~``burst`` near-simultaneous requests.

    Bursts fire every ``burst/rate`` seconds (so the long-run rate is
    ``rate``); within a burst, requests are spread by exponential jitter
    with mean ``jitter`` seconds — the correlated wake-up pattern of
    co-located IoT sensors.
    """
    if rate <= 0 or burst < 1:
        raise ValueError(f"need rate > 0 and burst >= 1, "
                         f"got rate={rate}, burst={burst}")
    rng = np.random.default_rng(seed)
    base = start + (np.arange(n) // burst + 1) * (burst / rate)
    arrivals = np.sort(base + rng.exponential(jitter, size=n))
    return _build(arrivals, features_fn, rng, executor, deadline, priority,
                  slo_fn, origin_fn)


def mixed(n: int, rate: float, *, delta_fn: DeltaFn,
          update_fraction: float = 0.2, seed: int = 0,
          features_fn: Optional[FeaturesFn] = None,
          executor: Optional[str] = None,
          deadline: Optional[float] = None, priority: int = 0,
          slo_fn: Optional[SloFn] = None,
          origin_fn: Optional[OriginFn] = None,
          start: float = 0.0) -> List[Union[Request, UpdateRequest]]:
    """``n`` Poisson arrivals; each is a graph update with probability
    ``update_fraction`` (its ``GraphDelta`` built by ``delta_fn(i, rng)``),
    else an inference query — the mutating-IoT-graph serving workload.

    Updates are applied in arrival order, so ``delta_fn`` must produce
    deltas valid against the *sequentially updated* graph (deltas that
    only touch edges/features of stable vertex ids are the easy case).
    SLO annotations land on updates too: the control plane prices an
    update's repair and can reject one whose deadline is unmeetable.
    """
    if not 0.0 <= update_fraction <= 1.0:
        raise ValueError(f"update_fraction must be in [0, 1], "
                         f"got {update_fraction}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    arrivals = start + np.cumsum(rng.exponential(1.0 / rate, size=n))
    is_update = rng.random(n) < update_fraction
    out: List[Union[Request, UpdateRequest]] = []
    for i, t in enumerate(arrivals):
        d, p = _slo_of(i, rng, slo_fn, deadline, priority)
        if is_update[i]:
            out.append(UpdateRequest(delta=delta_fn(i, rng),
                                     arrival_time=float(t),
                                     deadline=d, priority=p))
        else:
            feats = None if features_fn is None else features_fn(i, rng)
            origin = None if origin_fn is None else tuple(origin_fn(i))
            out.append(Request(features=feats, arrival_time=float(t),
                               executor=executor, deadline=d, priority=p,
                               origin=origin))
    return out
