"""Executor backends: where a query's numerics actually run.

All backends produce *real* JAX-computed embeddings (quantization error and
exchange semantics are genuine); they differ in how the computation is laid
out and which simulated pipeline prices its latency:

  "sim"       single-program numerics, multi-fog BSP latency accounting —
              the default for laptops/CI (verified numerically identical
              to the mesh path in tests).
  "single"    single-program numerics, single-most-powerful-fog accounting
              (the paper's single-fog baseline).
  "mesh-bsp"  shard_map over a real JAX device mesh, one device per fog
              partition, halo/allgather collectives per layer (§III-E);
              multi-fog accounting.
  "cloud"     single-program numerics, de-facto cloud accounting (full
              WAN upload to a datacenter GPU) — the paper's Fig. 3
              cloud-vs-fog baseline.

Every backend honours the Engine/Session ``aggregation`` knob ("segment_sum"
| "pallas" | "auto"): the single-program backends swap the model's
neighborhood aggregation for the whole-graph block-CSR Pallas kernel, the
mesh backend routes each shard's aggregation through the pre-blocked
local+halo SpMM (and, with a DAQ compressor, ships the halo quantized and
dequantizes inside the fused kernel). ``resolve_aggregation`` in
``runtime.bsp`` defines the fallback/strictness rules.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import EXECUTORS
from repro.gnn.layers import EdgeList, masked_degree
from repro.gnn.models import gnn_apply
from repro.kernels import ops
from repro.runtime import bsp


@dataclasses.dataclass(frozen=True)
class ExecutorBackend:
    """Base entry for the EXECUTORS registry.

    ``pipeline`` names the ``simulation.simulate`` accounting pipeline
    ("multi", "single" or "cloud"); ``run`` returns [V, D] embeddings in
    original vertex order. ``aggregation`` is the resolved Engine/Session
    knob (see ``bsp.resolve_aggregation``).
    """
    name: str
    pipeline: str

    #: True for backends whose kernel path reads the per-shard block-CSR
    #: operands of the PartitionedGraph (built on demand).
    needs_block_shards = False

    def check(self, plan) -> None:
        """Fail fast (helpful error) if this backend cannot run the plan."""

    def wire_format(self, plan, exchange: str, aggregation: str):
        """(dtype_bytes, row_overhead_bytes) of the per-sync halo payload."""
        return (4, 0)

    def run(self, plan, feats: np.ndarray, assignment: np.ndarray,
            pg: bsp.PartitionedGraph, exchange: str,
            aggregation: str = "segment_sum") -> np.ndarray:
        raise NotImplementedError

    def run_many(self, plan, feats_list: Sequence[np.ndarray],
                 assignment: np.ndarray, pg: bsp.PartitionedGraph,
                 exchange: str,
                 aggregation: str = "segment_sum") -> List[np.ndarray]:
        """One executor run over a micro-batch of feature sets.

        The default serves each set through ``run`` back-to-back, which
        keeps batched numerics bit-identical to serial queries (the
        batching win is priced by ``simulation.simulate(batch_size=B)``);
        backends with a natively batched layout may override.
        """
        return [self.run(plan, f, assignment, pg, exchange,
                         aggregation=aggregation)
                for f in feats_list]


def _graph_block_csr(graph) -> ops.BlockCsr:
    """Whole-graph block-CSR for the single-program kernel path.

    Cached on the (mutable) ``Graph`` instance — the adjacency is
    feature-independent, so one prepared operand serves every query and
    every session over that graph.
    """
    csr = getattr(graph, "_block_csr_cache", None)
    if csr is None:
        csr = ops.BlockCsr(graph)
        graph._block_csr_cache = csr
    return csr


def _kernel_aggregate(csr: ops.BlockCsr, kind: str):
    """The model's ``aggregate=`` hook backed by the Pallas SpMM."""

    def agg_sum(h, edges, h_src=None):
        src = h if h_src is None else h_src
        return csr.aggregate_traced(src)

    if kind != "sage":
        return agg_sum

    def agg_mean(h, edges, h_src=None):
        deg = masked_degree(edges)
        return agg_sum(h, edges, h_src) / jnp.maximum(deg, 1.0)[:, None]

    return agg_mean


@functools.partial(jax.jit, static_argnames=("kind", "num_vertices"))
def _batched_gnn_apply(params, kind, stacked, senders, receivers, mask,
                       num_vertices):
    """vmap of the K-layer forward over a [B, V, F] feature stack.

    One traced call per (graph, batch-size) instead of B dispatches; the
    per-example computation is the same op sequence as ``gnn_apply``, so
    results are bit-identical to the serial loop (asserted in
    tests/test_updates.py and by test_server's batched==serial suite).
    ``num_vertices`` is static (segment_sum needs a concrete count).
    """
    edges = EdgeList(senders, receivers, mask, num_vertices)
    return jax.vmap(lambda h: gnn_apply(params, kind, h, edges))(stacked)


class _SingleProgram(ExecutorBackend):
    def run(self, plan, feats, assignment, pg, exchange,
            aggregation="segment_sum"):
        # Single-program layout: no cross-fog exchange is involved, so the
        # kernel path only depends on the model kind.
        mode = bsp.resolve_aggregation(aggregation, plan.model.kind)
        aggregate = None
        if mode == "pallas":
            aggregate = _kernel_aggregate(_graph_block_csr(plan.graph),
                                          plan.model.kind)
        return np.asarray(gnn_apply(list(plan.model.params), plan.model.kind,
                                    feats, EdgeList.from_graph(plan.graph),
                                    aggregate=aggregate))

    def run_many(self, plan, feats_list, assignment, pg, exchange,
                 aggregation="segment_sum"):
        """Batched fast path: stack the micro-batch and run one traced
        call (``vmap`` over the batch axis) instead of B dispatches.

        Falls back to the serial base loop for singleton batches, for the
        Pallas kernel path (the whole-graph block-CSR kernel has no
        batching rule), and for GAT — its attention softmax fuses
        differently under jit and loses the batched==serial bit-identity
        contract that GCN/SAGE's linear aggregation keeps.
        """
        mode = bsp.resolve_aggregation(aggregation, plan.model.kind)
        if (len(feats_list) <= 1 or mode == "pallas"
                or plan.model.kind not in ("gcn", "sage")):
            return super().run_many(plan, feats_list, assignment, pg,
                                    exchange, aggregation=aggregation)
        stacked = jnp.asarray(np.stack(
            [np.asarray(f, np.float32) for f in feats_list]))
        edges = EdgeList.from_graph(plan.graph)
        out = _batched_gnn_apply(list(plan.model.params), plan.model.kind,
                                 stacked, edges.senders, edges.receivers,
                                 edges.mask, edges.num_vertices)
        return [np.asarray(o) for o in out]


class _MeshBsp(ExecutorBackend):
    #: this backend aggregates over PartitionedGraph.local_csr/halo_csr
    #: when the kernel path is active (Engine/Session build them lazily).
    needs_block_shards = True

    def check(self, plan) -> None:
        n = plan.num_fogs
        have = len(jax.devices())
        if have < n:
            raise RuntimeError(
                f"executor 'mesh-bsp' needs {n} JAX devices (one per fog "
                f"partition), have {have} — run under XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n}, or switch "
                f"the engine's executor knob to 'sim'")

    @staticmethod
    def _halo_quant(plan, exchange: str, aggregation: str) -> bool:
        """DAQ plans fuse wire dequantization into the halo SpMM (kernel
        path only): boundary rows cross the collective quantized."""
        return (bsp.resolve_aggregation(aggregation, plan.model.kind,
                                        exchange=exchange) == "pallas"
                and plan.config.compressor.startswith("daq"))

    def wire_format(self, plan, exchange, aggregation):
        if self._halo_quant(plan, exchange, aggregation):
            return (1, 8)   # uint8 codes + f32 (scale, min) per row
        return (4, 0)

    def run(self, plan, feats, assignment, pg, exchange,
            aggregation="segment_sum"):
        g = dataclasses.replace(plan.graph, features=feats)
        return bsp.bsp_infer(
            list(plan.model.params), plan.model.kind, g, assignment,
            exchange=exchange, aggregation=aggregation,
            halo_quant=self._halo_quant(plan, exchange, aggregation), pg=pg)


EXECUTORS.register("sim", _SingleProgram("sim", "multi"))
EXECUTORS.register("single", _SingleProgram("single", "single"))
EXECUTORS.register("mesh-bsp", _MeshBsp("mesh-bsp", "multi"))
EXECUTORS.register("cloud", _SingleProgram("cloud", "cloud"))
