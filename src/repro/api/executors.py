"""Executor backends: where a query's numerics actually run.

All backends produce *real* JAX-computed embeddings (quantization error and
exchange semantics are genuine); they differ in how the computation is laid
out and which simulated pipeline prices its latency:

  "sim"       single-program numerics, multi-fog BSP latency accounting —
              the default for laptops/CI (verified numerically identical
              to the mesh path in tests).
  "single"    single-program numerics, single-most-powerful-fog accounting
              (the paper's single-fog baseline).
  "mesh-bsp"  shard_map over a real JAX device mesh, one device per fog
              partition, halo/allgather collectives per layer (§III-E);
              multi-fog accounting.
  "cloud"     single-program numerics, de-facto cloud accounting (full
              WAN upload to a datacenter GPU) — the paper's Fig. 3
              cloud-vs-fog baseline.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import jax
import numpy as np

from repro.api.registry import EXECUTORS
from repro.gnn.layers import EdgeList
from repro.gnn.models import gnn_apply
from repro.runtime import bsp


@dataclasses.dataclass(frozen=True)
class ExecutorBackend:
    """Base entry for the EXECUTORS registry.

    ``pipeline`` names the ``simulation.simulate`` accounting pipeline
    ("multi", "single" or "cloud"); ``run`` returns [V, D] embeddings in
    original vertex order.
    """
    name: str
    pipeline: str

    def check(self, plan) -> None:
        """Fail fast (helpful error) if this backend cannot run the plan."""

    def run(self, plan, feats: np.ndarray, assignment: np.ndarray,
            pg: bsp.PartitionedGraph, exchange: str) -> np.ndarray:
        raise NotImplementedError

    def run_many(self, plan, feats_list: Sequence[np.ndarray],
                 assignment: np.ndarray, pg: bsp.PartitionedGraph,
                 exchange: str) -> List[np.ndarray]:
        """One executor run over a micro-batch of feature sets.

        The default serves each set through ``run`` back-to-back, which
        keeps batched numerics bit-identical to serial queries (the
        batching win is priced by ``simulation.simulate(batch_size=B)``);
        backends with a natively batched layout may override.
        """
        return [self.run(plan, f, assignment, pg, exchange)
                for f in feats_list]


class _SingleProgram(ExecutorBackend):
    def run(self, plan, feats, assignment, pg, exchange):
        return np.asarray(gnn_apply(list(plan.model.params), plan.model.kind,
                                    feats, EdgeList.from_graph(plan.graph)))


class _MeshBsp(ExecutorBackend):
    def check(self, plan) -> None:
        n = plan.num_fogs
        have = len(jax.devices())
        if have < n:
            raise RuntimeError(
                f"executor 'mesh-bsp' needs {n} JAX devices (one per fog "
                f"partition), have {have} — run under XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n}, or switch "
                f"the engine's executor knob to 'sim'")

    def run(self, plan, feats, assignment, pg, exchange):
        g = dataclasses.replace(plan.graph, features=feats)
        return bsp.bsp_infer(list(plan.model.params), plan.model.kind, g,
                             assignment, exchange=exchange)


EXECUTORS.register("sim", _SingleProgram("sim", "multi"))
EXECUTORS.register("single", _SingleProgram("single", "single"))
EXECUTORS.register("mesh-bsp", _MeshBsp("mesh-bsp", "multi"))
EXECUTORS.register("cloud", _SingleProgram("cloud", "cloud"))
