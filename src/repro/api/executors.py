"""Executor backends: where a query's numerics actually run.

All backends produce *real* JAX-computed embeddings (quantization error and
exchange semantics are genuine); they differ in how the computation is laid
out and which simulated pipeline prices its latency:

  "sim"       single-program numerics, multi-fog BSP latency accounting —
              the default for laptops/CI (verified numerically identical
              to the mesh path in tests).
  "single"    single-program numerics, single-most-powerful-fog accounting
              (the paper's single-fog baseline).
  "mesh-bsp"  shard_map over a real JAX device mesh, one device per fog
              partition, halo/allgather collectives per layer (§III-E);
              multi-fog accounting.
  "cloud"     single-program numerics, de-facto cloud accounting (full
              WAN upload to a datacenter GPU) — the paper's Fig. 3
              cloud-vs-fog baseline.

Every backend honours the Engine/Session ``aggregation`` knob ("segment_sum"
| "pallas" | "auto"): the single-program backends swap the model's
neighborhood aggregation for the whole-graph block-CSR Pallas kernel, the
mesh backend routes each shard's aggregation through the pre-blocked
local+halo SpMM (and, with a DAQ compressor, ships the halo quantized and
dequantizes inside the fused kernel). ``resolve_aggregation`` in
``runtime.bsp`` defines the fallback/strictness rules.

Micro-batch execution (``run_many``) is natively batched on every backend:
the Server's stacked [B, V, F] feature batch runs in ONE traced call — the
kernel path through the batch-axis grid kernels (``block_spmm_batched`` /
``dequant_spmm_batched``, one fused dispatch for the whole batch), the
segment-sum and GAT paths through one ``jax.vmap`` program, and the mesh
backend through ``bsp.bsp_infer_many`` (one shard_map launch, one
collective per layer for the whole batch). Batched responses are
bit-identical to the serial per-request loop: serial execution runs the
same jitted per-example functions, and vmap / the batched kernels preserve
the per-example op sequence exactly (asserted per executor x model in
tests/test_batched_exec.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.registry import EXECUTORS
from repro.gnn.layers import EdgeList, aggregate_sum, apply_layer_with_sum
from repro.gnn.models import gnn_apply, gnn_apply_layers
from repro.kernels import ops
from repro.kernels.gather_aggregate import (block_spmm, block_spmm_batched,
                                            padded_feature_dim)
from repro.runtime import bsp

#: model kinds the incremental frontier path supports: their per-layer
#: aggregation is a static SUM over fixed adjacency, so a row subset can
#: be recomputed from sub-edges (GAT re-weights edges per layer from all
#: rows' values, so a dirty-row restriction is unsound).
FRONTIER_KINDS = ("gcn", "sage")


def _as_stack(feats: Union[np.ndarray, Sequence[np.ndarray]]) -> np.ndarray:
    """Coerce a micro-batch (list of [V, F] arrays or an already stacked
    [B, V, F] array) to one stacked float32 array."""
    if isinstance(feats, np.ndarray) and feats.ndim == 3:
        return np.asarray(feats, np.float32)
    return np.stack([np.asarray(f, np.float32) for f in feats])


@dataclasses.dataclass(frozen=True)
class ExecutorBackend:
    """Base entry for the EXECUTORS registry.

    ``pipeline`` names the ``simulation.simulate`` accounting pipeline
    ("multi", "single" or "cloud"); ``run`` returns [V, D] embeddings in
    original vertex order. ``aggregation`` is the resolved Engine/Session
    knob (see ``bsp.resolve_aggregation``).
    """
    name: str
    pipeline: str

    #: True for backends whose kernel path reads the per-shard block-CSR
    #: operands of the PartitionedGraph (built on demand).
    needs_block_shards = False

    def check(self, plan) -> None:
        """Fail fast (helpful error) if this backend cannot run the plan."""

    def wire_format(self, plan, exchange: str, aggregation: str):
        """(dtype_bytes, row_overhead_bytes) of the per-sync halo payload."""
        return (4, 0)

    def run(self, plan, feats: np.ndarray, assignment: np.ndarray,
            pg: bsp.PartitionedGraph, exchange: str,
            aggregation: str = "segment_sum") -> np.ndarray:
        raise NotImplementedError

    def run_many(self, plan,
                 feats: Union[np.ndarray, Sequence[np.ndarray]],
                 assignment: np.ndarray, pg: bsp.PartitionedGraph,
                 exchange: str,
                 aggregation: str = "segment_sum") -> List[np.ndarray]:
        """One executor run over a micro-batch of feature sets.

        ``feats`` is either a stacked [B, V, F] array (what the Server's
        micro-batcher hands over) or a sequence of [V, F] arrays. The
        base implementation serves each set through ``run`` back-to-back;
        every registered backend overrides it with a natively batched
        single-dispatch path whose per-request results are bit-identical
        to this serial loop (the batching win is additionally priced by
        ``simulation.simulate(batch_size=B)``).
        """
        return [self.run(plan, f, assignment, pg, exchange,
                         aggregation=aggregation)
                for f in _as_stack(feats)]

    # -- incremental (frontier) execution ------------------------------------

    #: numerics family tag for the activation cache: values cached under
    #: one family must not be merged into another's recompute ("single"
    #: covers sim/single/cloud, which share one jitted program).
    frontier_family = "single"

    def supports_frontier(self, plan, aggregation: str) -> bool:
        """Whether ``run_frontier``/``run_layers`` exist for this plan."""
        return False

    def run_layers(self, plan, feats, assignment, pg, exchange,
                   aggregation: str = "segment_sum") -> List[np.ndarray]:
        """Full pass that also returns every layer's activations.

        ``feats`` is [V, F] (returns K arrays [V, F_l]) or a stacked
        [B, V, F] micro-batch (returns K arrays [B, V, F_l]); the last
        entry is the plain ``run``/``run_many`` output, bit for bit.
        """
        raise NotImplementedError

    def run_frontier(self, plan, feats, assignment, pg, exchange,
                     aggregation, rows_per_layer, cached_layers):
        """Incremental pass: recompute only ``rows_per_layer[l]`` per
        layer and scatter-merge into ``cached_layers``. Returns
        ``(embeddings, merged_layers)`` where embeddings is [V, D] (or a
        list of [V, D] for a stacked ``feats``) bit-identical to a full
        recompute, and merged_layers is the new cache state.
        """
        raise NotImplementedError

    # -- stale-tolerant halo serving (exchange="halo_async") -----------------

    def supports_stale_halo(self, plan, aggregation: str) -> bool:
        """Whether this backend can replay recorded halo tables
        (``run_stale``/``run_stale_many``). Only the mesh backend has a
        real exchange to skip; single-program backends serve stale
        requests through their ordinary path (the Session still does the
        version/staleness accounting)."""
        return False

    def run_stale(self, plan, feats, assignment, pg,
                  halo_tables, aggregation: str = "segment_sum"):
        """Serve one query replaying ``halo_tables`` (the per-layer
        boundary-row tables of an earlier fresh pass) instead of running
        the per-layer exchange. Local rows use the CURRENT ``feats``;
        only cross-partition reads are stale."""
        raise NotImplementedError

    def run_stale_many(self, plan, feats, assignment, pg,
                       halo_tables, aggregation: str = "segment_sum"):
        raise NotImplementedError


@functools.partial(jax.jit, static_argnames=("kind",))
def _jit_gnn_apply(params, kind, h, senders, receivers, mask):
    """Jitted per-example K-layer forward (segment-sum aggregation).

    The serial ``run`` path uses this (rather than tracing ``gnn_apply``
    eagerly) so serial and batched execution share one compiled op
    sequence: jit-vs-eager differs in the last float bits for some layer
    stacks (GAT's attention softmax), while ``jax.vmap`` of a jitted
    function is bit-identical per example.
    """
    edges = EdgeList(senders, receivers, mask, h.shape[-2])
    return gnn_apply(params, kind, h, edges)


@functools.partial(jax.jit, static_argnames=("kind",))
def _batched_gnn_apply(params, kind, stacked, senders, receivers, mask):
    """vmap of the K-layer forward over a [B, V, F] feature stack.

    One traced call per (graph, batch-size) instead of B dispatches; the
    per-example computation is the same op sequence as
    ``_jit_gnn_apply``, so results are bit-identical to the serial loop
    for every kind — including GAT, whose per-layer attention re-weighting
    rides this vmapped edge-weighted path (asserted in
    tests/test_batched_exec.py and tests/test_updates.py).
    """
    edges = EdgeList(senders, receivers, mask, stacked.shape[-2])
    return jax.vmap(lambda h: gnn_apply(params, kind, h, edges))(stacked)


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def _kernel_gnn_apply(params, kind, h, senders, receivers, mask,
                      blocks, cols, cmask, *, interpret):
    """K-layer forward with block-CSR Pallas aggregation, single or stacked.

    ``h`` is one [V, F] feature table or a stacked [B, V, F] micro-batch.
    Per layer, the neighbor sum runs as ONE fused SpMM dispatch —
    ``block_spmm`` for a single example, ``block_spmm_batched`` (batch
    grid axis + scalar-prefetched column table) for a stack — and the
    dense layer update then runs per-example (under ``jax.vmap`` for the
    stacked case), which keeps batched results bit-identical to serial
    ones: the batched kernel preserves the per-(row-block, feature-tile)
    arithmetic of the unbatched kernel, and vmap preserves the dense op
    sequence. GCN/SAGE only (GAT re-weights edges per layer and cannot be
    pre-blocked; ``resolve_aggregation`` rejects it upstream).
    """
    v = h.shape[-2]
    edges = EdgeList(senders, receivers, mask, v)
    padded_v = blocks.shape[0] * blocks.shape[-1]

    def spmm(src):
        f = src.shape[-1]
        pad = ((0, padded_v - v), (0, padded_feature_dim(f) - f))
        if src.ndim == 3:
            out = block_spmm_batched(
                blocks, cols, cmask,
                jnp.pad(src.astype(jnp.float32), ((0, 0),) + pad),
                interpret=interpret)
            return out[:, :v, :f]
        out = block_spmm(blocks, cols, cmask,
                         jnp.pad(src.astype(jnp.float32), pad),
                         interpret=interpret)
        return out[:v, :f]

    n = len(params)
    for i, p in enumerate(params):
        # Fused (batched) SpMM dispatch, then the shared dense tail.
        h = apply_layer_with_sum(kind, p, h, edges, spmm(h), last=i == n - 1)
    return h


@functools.partial(jax.jit, static_argnames=("kind",))
def _jit_gnn_capture(params, kind, h, senders, receivers, mask):
    """``_jit_gnn_apply`` returning every layer (same traced program
    modulo dead-code elimination — see ``gnn_apply_layers``)."""
    edges = EdgeList(senders, receivers, mask, h.shape[-2])
    return gnn_apply_layers(params, kind, h, edges)


@functools.partial(jax.jit, static_argnames=("kind",))
def _batched_gnn_capture(params, kind, stacked, senders, receivers, mask):
    """``_batched_gnn_apply`` returning every layer ([B, V, F_l] each)."""
    edges = EdgeList(senders, receivers, mask, stacked.shape[-2])
    return jax.vmap(lambda h: gnn_apply_layers(params, kind, h, edges))(
        stacked)


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def _kernel_gnn_capture(params, kind, h, senders, receivers, mask,
                        blocks, cols, cmask, *, interpret):
    """``_kernel_gnn_apply`` returning every layer, single or stacked."""
    v = h.shape[-2]
    edges = EdgeList(senders, receivers, mask, v)
    padded_v = blocks.shape[0] * blocks.shape[-1]

    def spmm(src):
        f = src.shape[-1]
        pad = ((0, padded_v - v), (0, padded_feature_dim(f) - f))
        if src.ndim == 3:
            out = block_spmm_batched(
                blocks, cols, cmask,
                jnp.pad(src.astype(jnp.float32), ((0, 0),) + pad),
                interpret=interpret)
            return out[:, :v, :f]
        out = block_spmm(blocks, cols, cmask,
                         jnp.pad(src.astype(jnp.float32), pad),
                         interpret=interpret)
        return out[:v, :f]

    n = len(params)
    outs = []
    for i, p in enumerate(params):
        h = apply_layer_with_sum(kind, p, h, edges, spmm(h), last=i == n - 1)
        outs.append(h)
    return outs


def _bucket(n: int, lo: int = 8) -> int:
    """Next power of two >= max(n, lo): bounds the jit shape churn of the
    per-layer frontier programs to O(log V) specializations."""
    b = lo
    while b < n:
        b *= 2
    return b


def _segment_frontier_operands(graph, rows: np.ndarray):
    """Static-shape operands for one layer's sub-edge recompute.

    ``rows`` are the layer's dirty vertices. The row list is padded to a
    bucket with ``V`` — an out-of-bounds id the scatter-merge drops — and
    the edges *into* dirty rows are extracted in original edge order
    (the bit-identity of the per-row segment sums rests on that), with
    receivers compacted to row positions. Padding edges carry mask 0 and
    point at the last row slot, which the ``len(rows) + 1`` bucket floor
    guarantees is a padding slot, so their +0.0 never touches a real row.
    """
    v = graph.num_vertices
    rows = np.asarray(rows, np.int64)
    r_pad = _bucket(len(rows) + 1)
    rows_p = np.full(r_pad, v, np.int64)
    rows_p[:len(rows)] = rows
    comp = np.zeros(v, np.int64)
    comp[rows] = np.arange(len(rows))
    dirty = np.zeros(v, bool)
    dirty[rows] = True
    send = np.asarray(graph.senders, np.int64)
    recv = np.asarray(graph.receivers, np.int64)
    sel = np.flatnonzero(dirty[recv])
    e_pad = _bucket(len(sel))
    sub_s = np.zeros(e_pad, np.int32)
    sub_r = np.full(e_pad, r_pad - 1, np.int32)
    sub_m = np.zeros(e_pad, np.float32)
    sub_s[:len(sel)] = send[sel]
    sub_r[:len(sel)] = comp[recv[sel]]
    sub_m[:len(sel)] = 1.0
    return (jnp.asarray(rows_p), jnp.asarray(sub_s), jnp.asarray(sub_r),
            jnp.asarray(sub_m))


def _kernel_frontier_operands(graph, rows: np.ndarray, block: int):
    """Row-block-granular operands for the Pallas frontier path.

    The dirty rows are widened to whole 128-row blocks (the kernel's
    launch unit); every row of a selected block is recomputed and merged
    — bit-safe, since a clean row in a dirty block sees exactly its full
    operands. The block list is padded to a bucket with block 0; padding
    slots' row ids are set to ``V`` so their (duplicate) outputs drop at
    the scatter. Degrees and the dense tail then ride the same sub-edge
    machinery as the segment path, keyed by the widened row set.
    """
    v = graph.num_vertices
    rows = np.asarray(rows, np.int64)
    sel = np.unique(rows // block)
    s_pad = _bucket(len(sel) + 1, lo=1)
    sel_p = np.zeros(s_pad, np.int64)
    sel_p[:len(sel)] = sel
    rows_k = (sel_p[:, None] * block + np.arange(block)).reshape(-1)
    rows_k[len(sel) * block:] = v          # padding blocks: all dropped
    real = rows_k[rows_k < v]              # in-graph rows of real blocks
    r_pad = rows_k.shape[0]
    comp = np.zeros(v, np.int64)
    comp[real] = np.flatnonzero(rows_k < v)
    dirty = np.zeros(v, bool)
    dirty[real] = True
    send = np.asarray(graph.senders, np.int64)
    recv = np.asarray(graph.receivers, np.int64)
    e_sel = np.flatnonzero(dirty[recv])
    e_pad = _bucket(len(e_sel))
    sub_s = np.zeros(e_pad, np.int32)
    sub_r = np.full(e_pad, r_pad - 1, np.int32)
    sub_m = np.zeros(e_pad, np.float32)
    sub_s[:len(e_sel)] = send[e_sel]
    sub_r[:len(e_sel)] = comp[recv[e_sel]]
    sub_m[:len(e_sel)] = 1.0
    # rows_k[-1] is always a padding slot (s_pad >= len(sel) + 1), so the
    # padded sub-edges above never land on a real row.
    return (jnp.asarray(rows_k), jnp.asarray(sub_s), jnp.asarray(sub_r),
            jnp.asarray(sub_m), jnp.asarray(sel_p))


def _segment_frontier_tail(p, kind, h_full, cached_out, rows, sub_s, sub_r,
                           sub_m, last):
    """One incremental layer: sub-edge segment aggregation over the dirty
    rows, the shared dense tail on the gathered rows, scatter-merge into
    the cached table. Out-of-range row ids (padding) clamp on gather and
    drop on scatter."""
    edges = EdgeList(sub_s, sub_r, sub_m, rows.shape[0])
    a = aggregate_sum(h_full, edges)
    out = apply_layer_with_sum(kind, p, h_full[rows], edges, a, last=last)
    return cached_out.at[rows].set(out, mode="drop")


@functools.partial(jax.jit, static_argnames=("kind", "last"))
def _segment_frontier_layer(p, kind, h_full, cached_out, rows, sub_s,
                            sub_r, sub_m, *, last):
    return _segment_frontier_tail(p, kind, h_full, cached_out, rows,
                                  sub_s, sub_r, sub_m, last)


@functools.partial(jax.jit, static_argnames=("kind", "last"))
def _segment_frontier_layer_many(p, kind, h_stack, cached_out, rows, sub_s,
                                 sub_r, sub_m, *, last):
    """vmap of the incremental layer over a stacked micro-batch sharing
    one (unioned) frontier; the cached table broadcasts."""
    return jax.vmap(lambda hf: _segment_frontier_tail(
        p, kind, hf, cached_out, rows, sub_s, sub_r, sub_m, last))(h_stack)


def _kernel_frontier_sum(h_full, sel, blocks, cols, cmask, interpret):
    """Neighbor sums for the selected row blocks: ``block_spmm`` over the
    gathered tile subset — bit-identical to the corresponding row slice
    of the full launch (same per-(row-block, f-tile) accumulation)."""
    v, f = h_full.shape[-2:]
    block = blocks.shape[-1]
    padded_v = blocks.shape[0] * block
    pad = ((0, padded_v - v), (0, padded_feature_dim(f) - f))
    sub = (blocks[sel], cols[sel], cmask[sel])
    if h_full.ndim == 3:
        out = block_spmm_batched(
            *sub, jnp.pad(h_full.astype(jnp.float32), ((0, 0),) + pad),
            interpret=interpret)
        return out[..., :f]
    out = block_spmm(*sub, jnp.pad(h_full.astype(jnp.float32), pad),
                     interpret=interpret)
    return out[:, :f]


@functools.partial(jax.jit, static_argnames=("kind", "last", "interpret"))
def _kernel_frontier_layer(p, kind, h_full, cached_out, rows, sub_s, sub_r,
                           sub_m, sel, blocks, cols, cmask, *, last,
                           interpret):
    a = _kernel_frontier_sum(h_full, sel, blocks, cols, cmask, interpret)
    edges = EdgeList(sub_s, sub_r, sub_m, rows.shape[0])
    out = apply_layer_with_sum(kind, p, h_full[rows], edges, a, last=last)
    return cached_out.at[rows].set(out, mode="drop")


@functools.partial(jax.jit, static_argnames=("kind", "last", "interpret"))
def _kernel_frontier_layer_many(p, kind, h_stack, cached_out, rows, sub_s,
                                sub_r, sub_m, sel, blocks, cols, cmask, *,
                                last, interpret):
    a = _kernel_frontier_sum(h_stack, sel, blocks, cols, cmask, interpret)
    edges = EdgeList(sub_s, sub_r, sub_m, rows.shape[0])
    out = apply_layer_with_sum(kind, p, h_stack[:, rows], edges, a,
                               last=last)
    return jax.vmap(lambda o: cached_out.at[rows].set(o, mode="drop"))(out)


class _SingleProgram(ExecutorBackend):
    def _apply(self, plan, h: jnp.ndarray,
               aggregation: str) -> jnp.ndarray:
        """Dispatch one traced call for ``h`` = [V, F] or [B, V, F]."""
        # Single-program layout: no cross-fog exchange is involved, so the
        # kernel path only depends on the model kind.
        mode = bsp.resolve_aggregation(aggregation, plan.model.kind)
        params = list(plan.model.params)
        edges = EdgeList.from_graph(plan.graph)
        if mode == "pallas":
            csr = ops.block_csr_for(plan.graph)
            return _kernel_gnn_apply(
                params, plan.model.kind, h, edges.senders, edges.receivers,
                edges.mask, csr.blocks, csr.cols, csr.mask,
                interpret=jax.default_backend() != "tpu")
        if h.ndim == 3:
            return _batched_gnn_apply(params, plan.model.kind, h,
                                      edges.senders, edges.receivers,
                                      edges.mask)
        return _jit_gnn_apply(params, plan.model.kind, h, edges.senders,
                              edges.receivers, edges.mask)

    def run(self, plan, feats, assignment, pg, exchange,
            aggregation="segment_sum"):
        return np.asarray(self._apply(plan, jnp.asarray(feats, jnp.float32),
                                      aggregation))

    def run_many(self, plan, feats, assignment, pg, exchange,
                 aggregation="segment_sum"):
        """Batched fast path: one traced call over the stacked micro-batch
        instead of B dispatches — the batch-axis Pallas kernels for the
        GCN/SAGE kernel path, ``jax.vmap`` for segment-sum and GAT.
        Singleton batches take the serial path (B=1 reproduces the
        single-query numbers and timings exactly).
        """
        stacked = _as_stack(feats)
        if stacked.shape[0] <= 1:
            return super().run_many(plan, stacked, assignment, pg,
                                    exchange, aggregation=aggregation)
        out = self._apply(plan, jnp.asarray(stacked), aggregation)
        return [np.asarray(o) for o in out]

    def supports_frontier(self, plan, aggregation):
        return plan.model.kind in FRONTIER_KINDS

    def run_layers(self, plan, feats, assignment, pg, exchange,
                   aggregation="segment_sum"):
        h = jnp.asarray(feats, jnp.float32)
        mode = bsp.resolve_aggregation(aggregation, plan.model.kind)
        params = list(plan.model.params)
        edges = EdgeList.from_graph(plan.graph)
        if mode == "pallas":
            csr = ops.block_csr_for(plan.graph)
            outs = _kernel_gnn_capture(
                params, plan.model.kind, h, edges.senders, edges.receivers,
                edges.mask, csr.blocks, csr.cols, csr.mask,
                interpret=jax.default_backend() != "tpu")
        elif h.ndim == 3:
            outs = _batched_gnn_capture(params, plan.model.kind, h,
                                        edges.senders, edges.receivers,
                                        edges.mask)
        else:
            outs = _jit_gnn_capture(params, plan.model.kind, h,
                                    edges.senders, edges.receivers,
                                    edges.mask)
        return [np.asarray(o) for o in outs]

    def run_frontier(self, plan, feats, assignment, pg, exchange,
                     aggregation, rows_per_layer, cached_layers):
        mode = bsp.resolve_aggregation(aggregation, plan.model.kind)
        kind = plan.model.kind
        params = list(plan.model.params)
        g = plan.graph
        h = jnp.asarray(feats, jnp.float32)
        stacked = h.ndim == 3
        csr = ops.block_csr_for(g) if mode == "pallas" else None
        interp = jax.default_backend() != "tpu"
        n = len(params)
        merged = []
        for i, p in enumerate(params):
            cached = jnp.asarray(cached_layers[i], jnp.float32)
            last = i == n - 1
            if mode == "pallas":
                rows, sub_s, sub_r, sub_m, sel = _kernel_frontier_operands(
                    g, rows_per_layer[i], int(csr.blocks.shape[-1]))
                fl = (_kernel_frontier_layer_many if stacked
                      else _kernel_frontier_layer)
                h = fl(p, kind, h, cached, rows, sub_s, sub_r, sub_m, sel,
                       csr.blocks, csr.cols, csr.mask, last=last,
                       interpret=interp)
            else:
                rows, sub_s, sub_r, sub_m = _segment_frontier_operands(
                    g, rows_per_layer[i])
                fl = (_segment_frontier_layer_many if stacked
                      else _segment_frontier_layer)
                h = fl(p, kind, h, cached, rows, sub_s, sub_r, sub_m,
                       last=last)
            merged.append(np.asarray(h))
        emb = merged[-1]
        if stacked:
            return [e for e in emb], merged
        return emb, merged


class _MeshBsp(ExecutorBackend):
    #: this backend aggregates over PartitionedGraph.local_csr/halo_csr
    #: when the kernel path is active (Engine/Session build them lazily).
    needs_block_shards = True

    def check(self, plan) -> None:
        n = plan.num_fogs
        have = len(jax.devices())
        if have < n:
            raise RuntimeError(
                f"executor 'mesh-bsp' needs {n} JAX devices (one per fog "
                f"partition), have {have} — run under XLA_FLAGS="
                f"--xla_force_host_platform_device_count={n}, or switch "
                f"the engine's executor knob to 'sim'")

    @staticmethod
    def _halo_quant(plan, exchange: str, aggregation: str) -> bool:
        """DAQ plans fuse wire dequantization into the halo SpMM (kernel
        path only): boundary rows cross the collective quantized."""
        return (bsp.resolve_aggregation(aggregation, plan.model.kind,
                                        exchange=exchange) == "pallas"
                and plan.config.compressor.startswith("daq"))

    def wire_format(self, plan, exchange, aggregation):
        if self._halo_quant(plan, exchange, aggregation):
            return (1, 8)   # uint8 codes + f32 (scale, min) per row
        return (4, 0)

    def run(self, plan, feats, assignment, pg, exchange,
            aggregation="segment_sum"):
        g = dataclasses.replace(plan.graph, features=feats)
        return bsp.bsp_infer(
            list(plan.model.params), plan.model.kind, g, assignment,
            exchange=exchange, aggregation=aggregation,
            halo_quant=self._halo_quant(plan, exchange, aggregation), pg=pg)

    def run_many(self, plan, feats, assignment, pg, exchange,
                 aggregation="segment_sum"):
        """One shard_map launch for the whole micro-batch: the stacked
        [B, V, F] features become an [n, B, P, F] partition table and the
        per-layer halo collective ships every example's boundary rows in
        one all_gather (see ``bsp.bsp_apply_many``). Bit-identical to the
        serial per-request loop; singleton batches take the serial path.
        """
        stacked = _as_stack(feats)
        if stacked.shape[0] <= 1:
            return super().run_many(plan, stacked, assignment, pg,
                                    exchange, aggregation=aggregation)
        out = bsp.bsp_infer_many(
            list(plan.model.params), plan.model.kind, stacked, pg,
            exchange=exchange, aggregation=aggregation,
            halo_quant=self._halo_quant(plan, exchange, aggregation))
        return [np.asarray(o) for o in out]

    #: mesh numerics (per-shard layouts, halo accumulation order) differ
    #: from the single program's in the last float bits, so cached layers
    #: are tagged with a distinct family and never cross-merged.
    frontier_family = "mesh"

    def supports_frontier(self, plan, aggregation):
        return plan.model.kind in FRONTIER_KINDS

    def run_layers(self, plan, feats, assignment, pg, exchange,
                   aggregation="segment_sum"):
        feats = np.asarray(feats, np.float32)
        hq = self._halo_quant(plan, exchange, aggregation)
        if feats.ndim == 3:
            return bsp.bsp_infer_capture_many(
                list(plan.model.params), plan.model.kind, feats, pg,
                exchange=exchange, aggregation=aggregation, halo_quant=hq)
        g = dataclasses.replace(plan.graph, features=feats)
        return bsp.bsp_infer_capture(
            list(plan.model.params), plan.model.kind, g, assignment,
            exchange=exchange, aggregation=aggregation, halo_quant=hq,
            pg=pg)

    def run_frontier(self, plan, feats, assignment, pg, exchange,
                     aggregation, rows_per_layer, cached_layers):
        feats = np.asarray(feats, np.float32)
        hq = self._halo_quant(plan, exchange, aggregation)
        if feats.ndim == 3:
            merged = bsp.bsp_infer_frontier_many(
                list(plan.model.params), plan.model.kind, feats, pg,
                rows_per_layer, cached_layers, exchange=exchange,
                aggregation=aggregation, halo_quant=hq)
            return [e for e in merged[-1]], merged
        merged = bsp.bsp_infer_frontier(
            list(plan.model.params), plan.model.kind, feats, pg,
            rows_per_layer, cached_layers, exchange=exchange,
            aggregation=aggregation, halo_quant=hq)
        return merged[-1], merged

    def supports_stale_halo(self, plan, aggregation):
        return True

    def run_stale(self, plan, feats, assignment, pg, halo_tables,
                  aggregation="segment_sum"):
        """Replay recorded halo tables through the "stale" shard_map
        program (no per-layer collective; see ``bsp.bsp_infer_stale``)."""
        return bsp.bsp_infer_stale(
            list(plan.model.params), plan.model.kind,
            np.asarray(feats, np.float32), pg, halo_tables,
            aggregation=aggregation)

    def run_stale_many(self, plan, feats, assignment, pg, halo_tables,
                       aggregation="segment_sum"):
        stacked = _as_stack(feats)
        out = bsp.bsp_infer_stale_many(
            list(plan.model.params), plan.model.kind, stacked, pg,
            halo_tables, aggregation=aggregation)
        return [np.asarray(o) for o in out]


EXECUTORS.register("sim", _SingleProgram("sim", "multi"))
EXECUTORS.register("single", _SingleProgram("single", "single"))
EXECUTORS.register("mesh-bsp", _MeshBsp("mesh-bsp", "multi"))
EXECUTORS.register("cloud", _SingleProgram("cloud", "cloud"))
