"""Public serving API: ``Engine(model, cluster).compile(graph).session()``.

Exports resolve lazily (PEP 562): core modules register their components
into ``repro.api.registry`` at import time, and a lazy ``__init__`` keeps
that registration free of circular imports (core -> api.registry is a leaf
edge; api.engine -> core happens only on first attribute access).
"""
from repro.api.registry import (ALL_REGISTRIES, COMPRESSORS, EXCHANGES,
                                EXECUTORS, PARTITIONERS, PLACEMENTS,
                                Registry, UnknownComponentError)

_LAZY = {
    "Engine": "repro.api.engine",
    "Plan": "repro.api.plan",
    "EngineConfig": "repro.api.plan",
    "ModelSpec": "repro.api.plan",
    "as_model": "repro.api.plan",
    "Session": "repro.api.session",
    "QueryResult": "repro.api.session",
    "ExecutorBackend": "repro.api.executors",
    "Server": "repro.api.server",
    "Request": "repro.api.server",
    "Response": "repro.api.server",
    "UpdateResponse": "repro.api.server",
    "GraphDelta": "repro.api.updates",
    "UpdateRequest": "repro.api.updates",
    "UpdateReport": "repro.api.updates",
    "Fleet": "repro.api.fleet",
    "FleetServer": "repro.api.fleet",
    "Router": "repro.api.fleet",
    "Site": "repro.api.fleet",
    "Fault": "repro.api.faults",
    "FaultSchedule": "repro.api.faults",
    "FaultInjector": "repro.api.faults",
    "FailoverAudit": "repro.api.faults",
    "SLOPolicy": "repro.api.slo",
    "DegradationLevel": "repro.api.slo",
    "AdaptiveBatchController": "repro.api.slo",
    "Rejection": "repro.api.slo",
    "faults": "repro.api.faults",   # submodule: resolves to the module
    "fleet": "repro.api.fleet",     # submodule: resolves to the module
    "traces": "repro.api.traces",   # submodule: resolves to the module
    "updates": "repro.api.updates",  # submodule: resolves to the module
    "slo": "repro.api.slo",          # submodule: resolves to the module
}

__all__ = sorted(["Registry", "UnknownComponentError", "ALL_REGISTRIES",
                  "PARTITIONERS", "PLACEMENTS", "COMPRESSORS", "EXCHANGES",
                  "EXECUTORS", *_LAZY])


def __getattr__(name):
    if name in _LAZY:
        import importlib
        module = importlib.import_module(_LAZY[name])
        if _LAZY[name].rsplit(".", 1)[-1] == name:
            return module   # submodule entry (e.g. traces)
        return getattr(module, name)
    raise AttributeError(f"module 'repro.api' has no attribute {name!r}")


def __dir__():
    return __all__
