"""Deterministic chaos injection for node-level fault tolerance.

A :class:`FaultSchedule` is a time-sorted list of typed :class:`Fault`
events replayed against the ``Server``'s *simulated* clock — the same
clock that prices batches and updates — so every chaos run is exactly
reproducible: same schedule + same trace = same responses, bit for bit.

Fault kinds and the recovery tier that handles each:

  ``halo_loss``   transient loss of ``losses`` consecutive halo-exchange
                  rounds. Tier 1: retry with exponential backoff, priced
                  by ``simulation.simulate_retry`` through the exchange's
                  retry knobs (``ExchangeSpec.recovery_cost``) and
                  reported as ``breakdown["recovery"]``. When the retry
                  budget/timeout is exhausted, tier 2 rides through on
                  the stale halo store (``staleness_bound``); with no
                  stale capacity either, tier 3 fails the node over.
  ``straggler``   the node runs ``slowdown`` x slower for ``duration``
                  seconds (modeled as extra ``background_load``, so the
                  analytic clock prices it through the node's effective
                  capability). Numerics are unaffected.
  ``crash``       tier 3: the node's shards are re-placed onto the
                  survivors (``Engine.fail_nodes`` — PR 4's
                  ``repair_assignment`` machinery) and the session
                  rebases onto the degraded-capacity failover plan.
                  In-flight requests are served on the new plan — zero
                  drops by construction, mirroring the fleet invariant.
  ``recover``     the node rejoins: a crashed node's cluster is restored
                  (recompiling if the graph moved while degraded), a
                  straggler's extra load is lifted.

The :class:`FaultInjector` is the tiny runtime cursor the ``Server``
advances batch by batch; :class:`FailoverAudit` packages a failover for
the ``analysis`` fault-check family.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

#: legal Fault.kind values.
KINDS = ("crash", "recover", "halo_loss", "straggler")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One typed chaos event on the simulated clock.

    ``node`` names the target fog node (``SimNode.name``, e.g.
    ``"fog1(B)"``); required for every kind except ``halo_loss``, where
    None models an unattributed transient loss (tier 1/2 only — there
    is nothing to fail over). ``duration``/``slowdown`` apply to
    stragglers, ``losses`` to halo losses.
    """
    time: float
    kind: str
    node: Optional[str] = None
    duration: float = 0.0
    slowdown: float = 1.0
    losses: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"available: {', '.join(KINDS)}")
        if self.time < 0:
            raise ValueError(f"fault time must be >= 0, got {self.time}")
        if self.kind in ("crash", "recover", "straggler") and not self.node:
            raise ValueError(f"{self.kind!r} fault needs a node name")
        if self.kind == "straggler":
            if self.slowdown < 1.0:
                raise ValueError(f"straggler slowdown must be >= 1, "
                                 f"got {self.slowdown}")
            if self.duration <= 0:
                raise ValueError(f"straggler duration must be > 0, "
                                 f"got {self.duration}")
        if self.kind == "halo_loss" and self.losses < 1:
            raise ValueError(f"halo_loss losses must be >= 1, "
                             f"got {self.losses}")


class FaultSchedule:
    """An immutable, time-sorted sequence of :class:`Fault` events.

    Events at equal times keep their construction order (stable sort),
    so a schedule is a total order — the injector consumes it exactly
    once per run regardless of batch boundaries.
    """

    def __init__(self, faults: Sequence[Fault] = ()):
        for f in faults:
            if not isinstance(f, Fault):
                raise TypeError(f"FaultSchedule takes Fault events, got "
                                f"{type(f).__name__}")
        self.faults: Tuple[Fault, ...] = tuple(
            sorted(faults, key=lambda f: f.time))

    @classmethod
    def random(cls, nodes: Sequence[str], *, horizon: float,
               crash_rate: float = 0.0, loss_rate: float = 0.0,
               straggler_rate: float = 0.0, mean_outage: float = 1.0,
               mean_slowdown: float = 2.0, max_losses: int = 6,
               seed: int = 0) -> "FaultSchedule":
        """Seeded Poisson chaos over ``[0, horizon)``.

        Rates are events per simulated second. Each crash is paired with
        a ``recover`` ~``mean_outage`` later; crashes never take the last
        surviving node down (the generator tracks who is up). Same seed,
        nodes and rates -> the identical schedule, always.
        """
        if horizon <= 0:
            raise ValueError(f"horizon must be > 0, got {horizon}")
        nodes = list(nodes)
        if not nodes:
            raise ValueError("FaultSchedule.random needs node names")
        rng = np.random.default_rng(seed)
        events: List[Fault] = []
        for t in np.sort(rng.uniform(0, horizon,
                                     rng.poisson(loss_rate * horizon))):
            events.append(Fault(float(t), "halo_loss",
                                node=str(rng.choice(nodes)),
                                losses=int(rng.integers(1, max_losses + 1))))
        for t in np.sort(rng.uniform(0, horizon,
                                     rng.poisson(straggler_rate * horizon))):
            events.append(Fault(
                float(t), "straggler", node=str(rng.choice(nodes)),
                duration=float(rng.exponential(mean_outage) + 1e-3),
                slowdown=float(1.0 + rng.exponential(mean_slowdown - 1.0))))
        down_until: dict = {}
        for t in np.sort(rng.uniform(0, horizon,
                                     rng.poisson(crash_rate * horizon))):
            up = [n for n in nodes if down_until.get(n, -1.0) <= float(t)]
            if len(up) <= 1:
                continue   # never crash the last survivor
            victim = str(rng.choice(up))
            outage = float(rng.exponential(mean_outage) + 1e-3)
            events.append(Fault(float(t), "crash", node=victim))
            events.append(Fault(float(t) + outage, "recover", node=victim))
            down_until[victim] = float(t) + outage
        return cls(events)

    def window(self, t0: float, t1: float) -> Tuple[Fault, ...]:
        """Events with ``t0 <= time < t1``."""
        return tuple(f for f in self.faults if t0 <= f.time < t1)

    @property
    def node_names(self) -> Tuple[str, ...]:
        return tuple(sorted({f.node for f in self.faults
                             if f.node is not None}))

    def counts(self) -> dict:
        out = {k: 0 for k in KINDS}
        for f in self.faults:
            out[f.kind] += 1
        return out

    def __iter__(self) -> Iterator[Fault]:
        return iter(self.faults)

    def __len__(self) -> int:
        return len(self.faults)

    def __getitem__(self, i):
        return self.faults[i]

    def __repr__(self) -> str:
        c = self.counts()
        parts = ", ".join(f"{k}={v}" for k, v in c.items() if v)
        return f"FaultSchedule({len(self.faults)} events: {parts or 'none'})"


class FaultInjector:
    """Runtime cursor over one :class:`FaultSchedule`.

    The ``Server`` calls :meth:`due` with the simulated time of the next
    service instant; events fire exactly once, in schedule order. The
    injector holds no recovery state — that lives in the server, which
    owns the clock and the session.
    """

    def __init__(self, schedule: FaultSchedule):
        if not isinstance(schedule, FaultSchedule):
            schedule = FaultSchedule(schedule)
        self.schedule = schedule
        self._i = 0

    def due(self, t: float) -> List[Fault]:
        """Consume and return every unfired event with ``time <= t``."""
        out: List[Fault] = []
        while (self._i < len(self.schedule)
               and self.schedule[self._i].time <= t + 1e-12):
            out.append(self.schedule[self._i])
            self._i += 1
        return out

    def flush(self) -> List[Fault]:
        """Consume every remaining event (end-of-trace fire)."""
        out = list(self.schedule[self._i:])
        self._i = len(self.schedule)
        return out

    @property
    def remaining(self) -> int:
        return len(self.schedule) - self._i

    def __repr__(self) -> str:
        return (f"FaultInjector({self._i}/{len(self.schedule)} fired, "
                f"{self.schedule!r})")


@dataclasses.dataclass(frozen=True)
class FailoverAudit:
    """Input bundle for the ``analysis`` fault-check family.

    ``plan`` is the failover (or candidate) plan under audit;
    ``base_plan`` the pre-crash plan it was derived from and ``crashed``
    the evicted node names (both optional — coverage degrades to what
    can still be checked); ``server`` a fault-aware ``Server`` whose
    halo-store/session agreement is audited; ``schedule`` a
    :class:`FaultSchedule` for the retry-budget/well-formedness check.
    """
    plan: object
    base_plan: Optional[object] = None
    crashed: Tuple[str, ...] = ()
    server: Optional[object] = None
    schedule: Optional[FaultSchedule] = None
