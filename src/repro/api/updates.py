"""Typed graph updates for mutating IoT deployments.

Fograph's target workload is geo-distributed sensors whose graph is not
static: vertices and edges appear, disappear, and change features between
queries.  This module defines the *wire types* of the dynamic-graph
subsystem; the repair algorithms live in ``repro.core.incremental`` and the
entry points are ``Engine.apply_delta(plan, delta) -> Plan`` and
``Session.update(delta)``.

Id convention — every id in a :class:`GraphDelta` refers to the id space of
the graph the delta is applied *to* (the "old" graph):

  * surviving vertices keep their old ids ``0 .. V-1``;
  * the ``k`` new vertices are addressed as ``V .. V+k-1`` (so new edges may
    connect new vertices to old ones, or to each other);
  * after application, the mutated graph is compacted: survivors are
    renumbered in order, new vertices appended at the end.  The ``vmap``
    returned by ``incremental.mutate_graph`` translates old ids (including
    the ``V+i`` aliases of new vertices) to new ids, with ``-1`` for
    removed vertices.

Deltas applied in sequence (the ``Session``'s deferred-update buffer)
therefore each address the graph produced by the previous delta.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


def _as_ids(a, name: str) -> np.ndarray:
    out = np.asarray([] if a is None else a, dtype=np.int64).reshape(-1)
    return out


def _as_edges(a, name: str) -> np.ndarray:
    if a is None:
        return np.zeros((0, 2), np.int64)
    out = np.asarray(a, dtype=np.int64)
    if out.size == 0:
        return np.zeros((0, 2), np.int64)
    if out.ndim != 2 or out.shape[1] != 2:
        raise ValueError(f"{name} must be an [m, 2] array of (u, v) pairs, "
                         f"got shape {out.shape}")
    return out


@dataclasses.dataclass(frozen=True)
class GraphDelta:
    """One batch of graph mutations (see module docstring for id rules).

    Attributes:
      add_features: float[k, F] features of the ``k`` new vertices (their
        ids are ``V .. V+k-1``); ``None``/empty adds no vertices.
      remove_vertices: ids of vertices to drop (with all incident edges).
      add_edges / remove_edges: [m, 2] undirected (u, v) pairs — both
        directions are added/removed, mirroring ``graph.from_edge_list``.
        Adding an existing edge or removing a missing one is a no-op.
      feature_ids / feature_values: feature upserts — row ``i`` of
        ``feature_values`` replaces the features of vertex
        ``feature_ids[i]`` (new-vertex aliases ``V+i`` are legal targets).
      add_labels / add_positions: optional per-new-vertex labels/positions;
        when the graph carries labels/positions and these are omitted, new
        vertices get zeros.
    """
    add_features: Optional[np.ndarray] = None
    remove_vertices: Optional[np.ndarray] = None
    add_edges: Optional[np.ndarray] = None
    remove_edges: Optional[np.ndarray] = None
    feature_ids: Optional[np.ndarray] = None
    feature_values: Optional[np.ndarray] = None
    add_labels: Optional[np.ndarray] = None
    add_positions: Optional[np.ndarray] = None

    def __post_init__(self):
        set_ = object.__setattr__
        if self.add_features is not None:
            f = np.asarray(self.add_features, np.float32)
            if f.size and f.ndim != 2:
                raise ValueError("add_features must be a [k, F] array, got "
                                 f"shape {f.shape}")
            set_(self, "add_features", None if f.size == 0 else f)
        set_(self, "remove_vertices",
             np.unique(_as_ids(self.remove_vertices, "remove_vertices")))
        set_(self, "add_edges", _as_edges(self.add_edges, "add_edges"))
        set_(self, "remove_edges", _as_edges(self.remove_edges,
                                             "remove_edges"))
        set_(self, "feature_ids", _as_ids(self.feature_ids, "feature_ids"))
        k_upd = len(self.feature_ids)
        if self.feature_values is None:
            if k_upd:
                raise ValueError("feature_ids and feature_values must be "
                                 "given together")
        else:
            v = np.asarray(self.feature_values, np.float32)
            if v.ndim == 1 and k_upd == 1:
                v = v[None, :]
            if k_upd == 0 and v.size == 0:     # empty upsert set: a no-op
                set_(self, "feature_values", None)
            elif v.ndim != 2 or v.shape[0] != k_upd:
                raise ValueError(
                    f"feature_values must be a [{k_upd}, F] array (one row "
                    f"per feature_ids entry), got shape "
                    f"{np.shape(self.feature_values)}")
            else:
                set_(self, "feature_values", v)
        for name in ("add_labels", "add_positions"):
            a = getattr(self, name)
            if a is not None:
                a = np.asarray(a)
                if a.shape[0] != self.num_added_vertices:
                    raise ValueError(
                        f"{name} must have one row per added vertex "
                        f"({self.num_added_vertices}), got {a.shape[0]}")
                set_(self, name, a)

    # -- shape -----------------------------------------------------------

    @property
    def num_added_vertices(self) -> int:
        return 0 if self.add_features is None else int(
            self.add_features.shape[0])

    @property
    def num_removed_vertices(self) -> int:
        return int(len(self.remove_vertices))

    @property
    def is_empty(self) -> bool:
        return (self.num_added_vertices == 0
                and self.num_removed_vertices == 0
                and len(self.add_edges) == 0
                and len(self.remove_edges) == 0
                and len(self.feature_ids) == 0)

    @property
    def is_structural(self) -> bool:
        """True if the delta changes topology (not just feature values)."""
        return (self.num_added_vertices > 0
                or self.num_removed_vertices > 0
                or len(self.add_edges) > 0
                or len(self.remove_edges) > 0)

    def validate(self, num_vertices: int, feature_dim: int) -> None:
        """Check ids/shapes against the graph the delta applies to."""
        v, k = num_vertices, self.num_added_vertices
        if self.add_features is not None \
                and self.add_features.shape[1] != feature_dim:
            raise ValueError(
                f"add_features has {self.add_features.shape[1]} columns; the "
                f"graph's feature_dim is {feature_dim}")
        if len(self.remove_vertices):
            lo, hi = int(self.remove_vertices.min()), int(
                self.remove_vertices.max())
            if lo < 0 or hi >= v:
                raise ValueError(
                    f"remove_vertices ids must be existing vertices in "
                    f"[0, {v}), got range [{lo}, {hi}] — new vertices cannot "
                    f"be removed by the delta that adds them")
        for name, edges in (("add_edges", self.add_edges),
                            ("remove_edges", self.remove_edges)):
            if len(edges) == 0:
                continue
            hi = v + k if name == "add_edges" else v
            if int(edges.min()) < 0 or int(edges.max()) >= hi:
                raise ValueError(
                    f"{name} endpoints must lie in [0, {hi}) "
                    f"(|V|={v}, {k} added), got range "
                    f"[{int(edges.min())}, {int(edges.max())}]")
        if len(self.feature_ids):
            lo, hi = int(self.feature_ids.min()), int(self.feature_ids.max())
            if lo < 0 or hi >= v + k:
                raise ValueError(f"feature_ids must lie in [0, {v + k}), "
                                 f"got range [{lo}, {hi}]")
            if np.isin(self.feature_ids, self.remove_vertices).any():
                raise ValueError("feature_ids targets a vertex the same "
                                 "delta removes")
            if self.feature_values.shape[1] != feature_dim:
                raise ValueError(
                    f"feature_values has {self.feature_values.shape[1]} "
                    f"columns; the graph's feature_dim is {feature_dim}")

    def describe(self) -> dict:
        return {
            "added_vertices": self.num_added_vertices,
            "removed_vertices": self.num_removed_vertices,
            "added_edges": int(len(self.add_edges)),
            "removed_edges": int(len(self.remove_edges)),
            "feature_upserts": int(len(self.feature_ids)),
        }

    def __repr__(self) -> str:
        d = self.describe()
        body = ", ".join(f"{k}={v}" for k, v in d.items() if v)
        return f"GraphDelta({body or 'empty'})"


@dataclasses.dataclass(frozen=True)
class UpdateRequest:
    """One graph update in an arrival stream (the Server's control plane).

    Mirrors ``server.Request``: ``arrival_time`` is on the simulated clock
    (None = ready at admission); ids are assigned at ``submit`` from the
    same counter as query requests, so a mixed trace has one id space.
    ``deadline`` is a latency budget in simulated seconds from arrival and
    ``priority`` a class rank (higher = more important) — both read by the
    SLO control plane (``repro.api.slo``), which prices the delta's repair
    time on the serving clock and may reject an update whose repair cannot
    finish inside its deadline. Updates are never degraded (a partial
    repair has no meaning) and never reordered across queries.
    """
    delta: GraphDelta
    arrival_time: Optional[float] = None
    deadline: Optional[float] = None
    priority: int = 0
    request_id: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class UpdateReport:
    """What one ``apply_delta`` (or one deferred flush) actually did.

    ``mode``:
      "noop"         every delta was empty — the plan is unchanged.
      "features"     feature-only deltas: partition layout and block shards
                     reused verbatim, only the feature table refreshed.
      "incremental"  localized repair + dirty-shard rebuild.
      "recompile"    repair quality tripped a threshold (see ``reason``) —
                     the full Engine.compile pipeline ran instead.
    """
    mode: str
    num_deltas: int
    added_vertices: int
    removed_vertices: int
    added_edges: int
    removed_edges: int
    feature_upserts: int
    dirty_local: Tuple[int, ...] = ()
    dirty_halo: Tuple[int, ...] = ()
    num_partitions: int = 0
    imbalance_before: float = 0.0
    imbalance: float = 0.0
    cut_fraction_before: float = 0.0
    cut_fraction_after: float = 0.0
    reason: str = ""

    @property
    def shards_rebuilt(self) -> int:
        return len(set(self.dirty_local) | set(self.dirty_halo))
