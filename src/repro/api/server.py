"""Request-level serving front-end: ``Server`` / ``Request`` / ``Response``.

``Session.query`` is a strictly blocking, one-query-at-a-time call; the
paper's headline throughput numbers come from serving *streams* of
queries with feature collection pipelined against execution (§III-D).
This module adds the arrival-driven layer on top of the Session's
separately callable stages:

  * ``Request``   — one inference query: features (None = the graph's
    stored features), a simulated-clock arrival time (None = closed loop:
    the request is generated the moment the server can admit it, like the
    old serial ``Session.stream``), per-request knobs (executor backend
    override), and the SLO annotations ``deadline`` (latency budget in
    simulated seconds from arrival) and ``priority`` (class rank, higher
    = more important).
  * ``Response``  — extends ``QueryResult`` with queueing, batching and
    pipeline-overlap timings (``queue_delay``, ``batch_size``,
    ``collect_time`` / ``execute_time`` stage splits, ``overlap_saved``)
    plus the control-plane outcome (``deadline_met``, ``degradation``).
  * ``Server``    — admission queue + micro-batcher + two-stage pipeline.
    Compatible consecutive requests (same executor backend) coalesce into
    one micro-batch: one batched feature collect (priced by
    ``simulation.simulate(..., batch_size=B)``: coalesced long-tail, one
    packing overhead, one K*delta sync round) and one executor run over
    the batch. Batch k+1's collection overlaps batch k's execution
    (``simulation.pipeline_schedule``), so the steady-state period is
    max(collect, execute) instead of their sum.

With ``slo=`` (an :class:`repro.api.slo.SLOPolicy`) the Server grows the
SLO control plane: pending queries are served highest-priority-first
(never reordered across a graph update), each micro-batch's finish time
is estimated on the simulated clock before serving, over-budget batches
walk the degradation ladder (segment_sum / uniform8 / fewer layers —
served by cached degraded Sessions over ``plan.with_overrides``, so
degraded responses stay bit-identical to directly-configured sessions),
hopeless requests are rejected as :class:`repro.api.slo.Rejection`
entries, and graph updates are priced by ``simulation.simulate_update``
instead of being free control-plane work. ``adaptive_batch=`` replaces
the fixed ``max_batch`` with a closed-loop
:class:`repro.api.slo.AdaptiveBatchController` pick per drain.

Numerics are exact: each request's embeddings are computed by the same
compressor round-trip + executor numerics as ``Session.query``, so batched
responses are bit-identical to serial ones. Since the batch-axis executor
work (PR 5) this holds *with* genuinely batched execution: the micro-batch
is stacked into one [B, V, F] array and every backend's ``run_many``
serves it in a single traced call — one fused Pallas dispatch on the
kernel path, one vmapped program otherwise — instead of a per-request
Python loop (tested in ``tests/test_server.py`` and
``tests/test_batched_exec.py``).

    server = plan.server(max_batch=8)
    for r in server.replay(traces.poisson(64, rate=4.0)):
        print(r.request_id, r.queue_delay, r.latency)
    print(server.summarize(responses))
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.api.registry import EXECUTORS
from repro.api.session import QueryResult, Session
from repro.api.slo import (AdaptiveBatchController, Rejection, SLOPolicy,
                           default_ladder, load_bench_curve)
from repro.api.updates import GraphDelta, UpdateReport, UpdateRequest
from repro.core import simulation


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request for the serving front-end.

    ``features`` of None re-serves the graph's stored features.
    ``arrival_time`` is on the simulated clock (seconds); None means
    closed-loop — the request becomes ready the moment the server can
    admit it. ``executor`` optionally overrides the session's backend for
    this request only (requests only batch with same-backend neighbours).
    ``deadline`` is a latency budget in simulated seconds from arrival
    (None = best-effort) and ``priority`` a class rank (higher = more
    important) — both are inert without the Server's SLO control plane,
    except that a deadline always closes an open micro-batch early enough
    to remain meetable (see ``Server.max_wait``).

    ``origin`` is the request's geo coordinates ``(lat, lon)`` — read by
    the fleet router (``repro.api.fleet``) to pick the nearest fog site;
    inert on a single-cluster ``Server``.
    """
    features: Optional[np.ndarray] = None
    arrival_time: Optional[float] = None
    executor: Optional[str] = None
    deadline: Optional[float] = None
    priority: int = 0
    request_id: Optional[int] = None
    origin: Optional[Tuple[float, float]] = None


@dataclasses.dataclass(frozen=True)
class Response(QueryResult):
    """A ``QueryResult`` plus queueing / batching / pipeline timings.

    ``latency`` is end-to-end on the simulated clock: arrival ->
    execution finished (so it includes ``queue_delay``). Invariants
    (tested): ``queue_delay >= 0`` and
    ``latency >= max(collect_time, execute_time)``.

    Control-plane outcome: ``deadline_met`` is None for best-effort
    requests, else whether ``latency <= deadline``; ``degradation`` is
    the ladder rung this request was served at (0 = native knobs).

    Fleet outcome (``repro.api.fleet``; inert on a single-cluster
    server): ``site`` names the fog site (or "cloud") that served the
    request, ``route`` how it got there ("local" = nearest site,
    "spilled" = load spillover to another site, "failed_over" = rerouted
    off a down/saturated tier, "recovered" = pulled back to its revived
    home site), ``routing_delay`` the cross-site
    forwarding time included in ``latency``. ``staleness`` is how many
    serves old the halo features this response read were (0 = fresh
    synchronous exchange; > 0 only under ``exchange="halo_async"`` with
    a positive ``staleness_bound``).

    Fault outcome (``repro.api.faults``; inert without an injector):
    ``retries`` counts tier-1 halo-exchange retry attempts charged to
    this response (``breakdown["recovery"]`` carries their backoff
    seconds), ``recovered`` names the strongest recovery tier that fired
    while this batch was forming (None / "retry" / "stale" / "failover"
    / "restored"), and ``capacity`` is "degraded" when the serving plan
    is a post-crash failover plan (``provenance="failover"``) — the
    explicit degradation tag the chaos property test keys on.
    """
    request_id: int = 0
    arrival_time: float = 0.0
    queue_delay: float = 0.0
    service_start: float = 0.0
    finish_time: float = 0.0
    batch_size: int = 1
    batch_index: int = 0
    collect_time: float = 0.0
    execute_time: float = 0.0
    overlap_saved: float = 0.0
    priority: int = 0
    deadline: Optional[float] = None
    deadline_met: Optional[bool] = None
    degradation: int = 0
    staleness: int = 0
    site: Optional[str] = None
    route: str = "local"
    routing_delay: float = 0.0
    retries: int = 0
    recovered: Optional[str] = None
    capacity: str = "full"


@dataclasses.dataclass(frozen=True)
class UpdateResponse:
    """Acknowledgement of one ``UpdateRequest`` in a mixed stream.

    ``applied`` is False when the session's "deferred" policy buffered the
    delta (it is coalesced into one repair at the end of the drain; the
    merged report lands on ``Server.last_update_report``).  Without the
    SLO control plane, updates are free control-plane work on the
    simulated serving clock (``service_time`` = ``finish_time`` = 0);
    with it, ``service_time`` is the repair price
    (``simulation.simulate_update``) and ``finish_time`` when the
    pipeline's execution stage is free again.
    """
    request_id: int
    arrival_time: float
    applied: bool
    pending: int = 0
    report: Optional[UpdateReport] = None
    service_time: float = 0.0
    finish_time: float = 0.0
    deadline: Optional[float] = None
    priority: int = 0


class Server:
    """Micro-batching, pipelining request server over one ``Session``.

    Args:
      session: the ``Session`` whose collect/execute/account stages serve
        every request (or a ``Plan``, from which a fresh session is made).
      max_batch: micro-batch size cap (1 disables coalescing).
      max_wait: how long (simulated seconds) an open batch waits for more
        compatible arrivals beyond its first request before launching.
        An open batch also closes as soon as waiting longer would blow
        its oldest member's deadline.
      pipelined: overlap batch k+1's collection with batch k's execution
        (§III-D). False reproduces the strictly serial loop — the
        ``Session.stream`` baseline.
      slo: an :class:`repro.api.slo.SLOPolicy` (or True for the default
        policy) activating the control plane: priority-first service,
        deadline admission with the degradation ladder, rejections, and
        priced graph updates. None (default) is the PR 2 admit-all
        server, byte-for-byte.
      adaptive_batch: an :class:`repro.api.slo.AdaptiveBatchController`
        (or True for one seeded from ``BENCH_serving.json``) that picks
        the micro-batch size per drain from the measured batched-latency
        curve; ``max_batch`` stays the hard cap.
      faults: a :class:`repro.api.faults.FaultSchedule` (or
        ``FaultInjector``) of chaos events replayed against this
        server's simulated clock — node crashes walk the three recovery
        tiers (retry/backoff -> stale ride-through -> shard failover);
        see ``repro.api.faults``. None (default) adds zero overhead:
        the fault path is never consulted.

    The server runs on a simulated clock: collection and execution free
    times persist across ``submit``/``drain`` calls, so one server can
    replay an arrival trace incrementally.
    """

    def __init__(self, session: Union[Session, "object"], *,
                 max_batch: int = 8, max_wait: float = 0.0,
                 pipelined: bool = True,
                 slo: Union[None, bool, SLOPolicy] = None,
                 adaptive_batch: Union[None, bool,
                                       AdaptiveBatchController] = None,
                 faults: Union[None, "FaultSchedule",
                               "FaultInjector"] = None):
        if not isinstance(session, Session):   # accept a Plan for brevity
            session = session.session()
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.session = session
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.pipelined = bool(pipelined)
        if slo is True:
            slo = SLOPolicy()
        if slo is not None and not isinstance(slo, SLOPolicy):
            raise TypeError(f"slo must be an SLOPolicy (or True/None), got "
                            f"{type(slo).__name__}")
        self.slo = slo
        self.ladder = () if slo is None else (
            slo.ladder if slo.ladder is not None else default_ladder(session))
        if adaptive_batch is True:
            adaptive_batch = AdaptiveBatchController(
                max_batch=self.max_batch, seed_curve=load_bench_curve())
        self.batch_controller: Optional[AdaptiveBatchController] = (
            adaptive_batch or None)
        self._pending: List[Union[Request, UpdateRequest]] = []
        self._next_id = 0
        #: UpdateReport of the most recent applied (or flushed) update.
        self.last_update_report: Optional[UpdateReport] = None
        # (collect_free, execute_free, prev_execute_start) resource state
        # for simulation.pipeline_schedule, threaded batch-by-batch so the
        # overlap model lives in one place and the simulated clock
        # persists across drain() calls.
        self._pipe_state = (0.0, 0.0, 0.0)
        self.num_batches = 0
        # Degraded-session cache, one per ladder rung, keyed on the base
        # plan's identity so graph updates rebuild them lazily.
        self._degraded: Dict[int, Tuple[object, Session]] = {}
        # Per-drain cache of Session.account results, keyed
        # (executor key, batch size, ladder rung): admission estimates and
        # the serving accounting share one pricing call.
        self._svc_cache: Dict[Tuple[str, int, int],
                              simulation.ServingResult] = {}
        # -- node-level fault tolerance (repro.api.faults) ------------------
        self.injector = None
        if faults is not None:
            from repro.api.faults import FaultInjector, FaultSchedule
            if isinstance(faults, FaultInjector):
                self.injector = faults
            elif isinstance(faults, FaultSchedule):
                self.injector = FaultInjector(faults)
            else:
                self.injector = FaultInjector(FaultSchedule(faults))
            known = {n.name for n in session.plan.cluster.nodes}
            bad = set(self.injector.schedule.node_names) - known
            if bad:
                raise ValueError(
                    f"fault schedule targets unknown nodes "
                    f"{sorted(bad)}; cluster has: {', '.join(sorted(known))}")
        #: most recent full-cluster plan — the restore target when every
        #: crashed node has recovered (re-tracked on graph updates).
        self._full_plan = session.plan
        #: names of currently crashed (failed-over) nodes.
        self._crashed: set = set()
        # live stragglers: node name -> (extra background load, expiry t).
        self._slow: Dict[str, Tuple[float, float]] = {}
        # recovery charge pending for the next served batch:
        # (tier tag, priced seconds, retry attempts).
        self._recovery: Optional[Tuple[str, float, int]] = None
        #: requests that were in flight across a failover and were served
        #: on the degraded plan instead of being dropped.
        self.replayed = 0

    # -- admission ----------------------------------------------------------

    def submit(self, request: Union[Request, UpdateRequest, "GraphDelta",
                                    np.ndarray, None] = None, *,
               arrival_time: Optional[float] = None,
               executor: Optional[str] = None,
               deadline: Optional[float] = None,
               priority: int = 0
               ) -> Union[Request, UpdateRequest]:
        """Admit one request (a ``Request``, a feature array, or None) or
        one graph update (an ``UpdateRequest`` or a bare ``GraphDelta``).
        Updates share the query id space and are served in arrival order;
        whether they apply immediately or buffer is the session's
        ``updates`` policy."""
        if isinstance(request, GraphDelta):
            request = UpdateRequest(delta=request, arrival_time=arrival_time,
                                    deadline=deadline, priority=priority)
        if isinstance(request, UpdateRequest):
            if not isinstance(request.delta, GraphDelta):
                raise TypeError("UpdateRequest.delta must be a GraphDelta, "
                                f"got {type(request.delta).__name__}")
        else:
            if not isinstance(request, Request):
                request = Request(features=request,
                                  arrival_time=arrival_time,
                                  executor=executor, deadline=deadline,
                                  priority=priority)
            if isinstance(request.executor, str):
                EXECUTORS.resolve(request.executor)   # reject bad keys early
        if request.request_id is None:
            request = dataclasses.replace(request, request_id=self._next_id)
        self._next_id = max(self._next_id, request.request_id) + 1
        self._pending.append(request)
        return request

    def _exec_key(self, req: Request) -> str:
        key = req.executor
        if key is None:
            key = self.session._executor_key
        if not isinstance(key, str):
            key = getattr(key, "name", key)
        return EXECUTORS.canonical(key)

    def _deadline_of(self, req: Union[Request, UpdateRequest]
                     ) -> Optional[float]:
        """The request's effective latency budget under the policy."""
        if req.deadline is not None:
            return float(req.deadline)
        if self.slo is None:
            return None
        if isinstance(req, UpdateRequest):
            return self.slo.update_deadline
        return self.slo.default_deadline

    # -- serving ------------------------------------------------------------

    def drain(self) -> List[Union[Response, UpdateResponse, Rejection]]:
        """Serve every pending request; responses in service order.

        Updates interleave with query batches at their arrival position:
        an update always closes the open micro-batch (FIFO), then either
        applies immediately ("sync" session policy — later queries see the
        mutated graph) or buffers ("deferred" — later queries in this
        drain read the stale graph, and the whole buffer coalesces into
        one repair when the drain finishes).

        With the control plane active, queries are served
        highest-priority-first *between* updates (reordering across an
        update would change which graph version a query sees), each batch
        passes deadline admission (degrade / reject), and the output may
        contain :class:`~repro.api.slo.Rejection` entries in place of
        responses.

        On a mid-drain failure, unserved requests are requeued and the
        exception is re-raised with the responses already produced (served
        queries and applied-update acks, whose side effects persist)
        attached as ``exc.partial_responses``, so mixed streams stay
        recoverable.
        """
        reqs = self._pending
        self._pending = []
        self._svc_cache.clear()   # graph/load/placement may have moved
        # Stable order by arrival. A closed-loop request (arrival_time
        # None) is ready the moment it is admitted, i.e. no earlier than
        # anything submitted before it: it inherits the latest arrival
        # seen so far (0.0 when nothing timed precedes it), so untimed
        # submissions — in particular graph updates — keep their FIFO
        # position instead of sorting to the front of timed traffic.
        eff = []
        latest = 0.0
        for r in reqs:   # submission order
            if r.arrival_time is None:
                eff.append(latest)
            else:
                latest = max(latest, r.arrival_time)
                eff.append(r.arrival_time)
        order = sorted(range(len(reqs)), key=lambda i: eff[i])
        out: List[Union[Response, UpdateResponse, Rejection]] = []
        i = 0
        try:
            while i < len(order):
                if self.slo is not None:
                    order[i:] = self._reorder_ready(reqs, order[i:], eff)
                if self.injector is not None:
                    # Chaos events up to the next service instant fire
                    # before the batch forms: a crash here fails the node
                    # over and the remaining requests (still queued =
                    # in flight) are served on the degraded plan instead
                    # of being dropped.
                    self._advance_faults(
                        max(self._collect_floor(), eff[order[i]]),
                        in_flight=len(order) - i)
                req = reqs[order[i]]
                if isinstance(req, UpdateRequest):
                    # Consume the update *before* applying it: if the
                    # delta is rejected (bad ids for the current graph),
                    # the requeue handler below must not put it back at
                    # the head of the queue, or every later drain would
                    # re-trip on it and starve the requests behind it.
                    i += 1
                    out.append(self._handle_update(req))
                    continue
                batch, arrs = self._form_batch(reqs, order, i)
                if self.slo is None:
                    out.extend(self._serve_batch(
                        [reqs[k] for k in batch], max(arrs)))
                else:
                    survivors, s_arrs, level, rejections = self._admit(
                        [reqs[k] for k in batch], arrs)
                    out.extend(rejections)
                    if survivors:
                        out.extend(self._serve_batch(
                            survivors, max(s_arrs), level=level))
                i += len(batch)   # only after serving: a failed batch requeues
            if self.session.pending_updates:   # deferred: one coalesced repair
                self.last_update_report = self.session.flush_updates()
                self._note_plan()
        except BaseException as exc:
            # Don't lose work on a mid-drain failure (bad executor key,
            # wrong feature shape, rejected delta, ...): requeue
            # everything unserved, and hand the caller what was already
            # produced — applied updates mutated the session for good.
            self._pending = [reqs[k] for k in order[i:]] + self._pending
            exc.partial_responses = out
            raise
        return out

    def _reorder_ready(self, reqs: Sequence, rest: List[int],
                       eff: Sequence[float]) -> List[int]:
        """Clock-aware priority pick: move the highest class to the head.

        Only requests that have *arrived* by the next service instant
        compete — a future high-priority arrival never preempts work
        that is queued now (that would starve low classes even at
        sustainable load). Updates are a barrier in both directions:
        the ready set stops at the next update in arrival order, and an
        update at the head is served before any later query regardless
        of priority (reordering across it would change which graph
        version a query sees). Not-yet-arrived requests keep arrival
        order.
        """
        rest = sorted(rest, key=lambda k: (eff[k], k))
        if isinstance(reqs[rest[0]], UpdateRequest):
            return rest
        t = max(self._collect_floor(), eff[rest[0]])
        ready: List[int] = []
        for k in rest:
            if isinstance(reqs[k], UpdateRequest) or eff[k] > t + 1e-12:
                break
            ready.append(k)
        ready.sort(key=lambda k: (-reqs[k].priority, eff[k], k))
        return ready + rest[len(ready):]

    def _handle_update(self, req: UpdateRequest
                       ) -> Union[UpdateResponse, Rejection]:
        arrival = (self._collect_floor() if req.arrival_time is None
                   else req.arrival_time)
        if self.slo is None:
            # Legacy behavior: updates are free control-plane work.
            report = self.session.update(req.delta)
            if report is not None:
                self.last_update_report = report
            self._svc_cache.clear()   # pricing may have moved with the graph
            self._note_plan()
            return UpdateResponse(request_id=req.request_id,
                                  arrival_time=arrival,
                                  applied=report is not None,
                                  pending=self.session.pending_updates,
                                  report=report)
        # Update-aware admission: the repair occupies the execution stage
        # (the superstep must quiesce while the layout mutates), priced on
        # the same simulated clock as query batches.
        t_u = simulation.simulate_update(self.session.plan.cluster,
                                         req.delta)
        sched = simulation.pipeline_schedule(
            [(arrival, 0.0, t_u)], pipelined=self.pipelined,
            start=self._pipe_state)[-1]
        deadline = self._deadline_of(req)
        if (deadline is not None and self.slo.reject_hopeless
                and sched.execute_end > arrival + deadline + 1e-12):
            return Rejection(request_id=req.request_id, arrival_time=arrival,
                             priority=req.priority, deadline=deadline,
                             estimated_latency=sched.execute_end - arrival,
                             kind="update")
        report = self.session.update(req.delta)
        if report is not None:
            self.last_update_report = report
        self._pipe_state = simulation.schedule_state(sched)
        self._svc_cache.clear()   # pricing may have moved with the graph
        self._note_plan()
        return UpdateResponse(request_id=req.request_id,
                              arrival_time=arrival,
                              applied=report is not None,
                              pending=self.session.pending_updates,
                              report=report, service_time=t_u,
                              finish_time=sched.execute_end,
                              deadline=deadline, priority=req.priority)

    # -- fault tolerance (repro.api.faults) ---------------------------------

    def _advance_faults(self, t: float, in_flight: int = 0) -> None:
        """Replay every scheduled fault due by simulated time ``t``.

        Straggler expiries are undone first (their end time may precede
        the next injected event), then each due event walks the recovery
        machinery: stragglers mutate the live node's ``background_load``
        (pricing only — numerics are load-independent), halo losses walk
        the retry -> stale -> failover tier ladder, crashes fail the node
        over immediately, and recovers restore the full-cluster plan.
        """
        for name, (extra, end) in list(self._slow.items()):
            if end <= t + 1e-12:
                self._set_load(name, -extra)
                del self._slow[name]
        for f in self.injector.due(t):
            if f.kind == "straggler":
                if f.node in self._crashed:
                    continue   # a crashed node cannot also be slow
                old = self._slow.pop(f.node, None)
                if old is not None:
                    self._set_load(f.node, -old[0])
                extra = f.slowdown - 1.0
                if self._set_load(f.node, extra):
                    self._slow[f.node] = (extra, f.time + f.duration)
            elif f.kind == "halo_loss":
                self._handle_halo_loss(f, t, in_flight)
            elif f.kind == "crash":
                if f.node not in self._crashed:
                    self._crash(f.node, t, in_flight)
            elif f.kind == "recover":
                self._recover(f.node, t)

    def _handle_halo_loss(self, f, t: float, in_flight: int) -> None:
        """Walk the three recovery tiers for a lost halo exchange:
        (1) retry with exponential backoff within the exchange's timeout,
        (2) ride through on recorded stale halo tables (halo_async within
        ``staleness_bound``), (3) declare the peer dead and fail its
        shard over. The priced recovery seconds charge the next batch."""
        sess = self.session
        if getattr(sess._executor, "pipeline", "") != "multi":
            return   # no cross-fog exchange round to lose
        rec_s, attempts, ok = sess._exchange.recovery_cost(
            f.losses, sess.plan.cluster.sync_cost)
        if ok:
            self._add_recovery("retry", rec_s, attempts)
            return
        if sess.can_serve_stale():
            self._add_recovery("stale", rec_s, attempts)
            return
        names = {n.name for n in sess.plan.cluster.nodes}
        self._add_recovery("retry", rec_s, attempts)
        if f.node is not None and f.node in names and len(names) > 1:
            self._crash(f.node, t, in_flight)

    def _crash(self, name: str, t: float, in_flight: int) -> None:
        """Fail node ``name``'s shard over onto the surviving cluster.

        The session rebases onto ``Engine.fail_nodes`` output (identical
        to a fresh compile on the survivors), the priced failover time —
        re-uploading the evicted shard's rows over the LAN plus the
        rebuild flops on the degraded capacity — occupies the execution
        stage on the simulated clock, and the ``in_flight`` requests
        still queued are replayed on the new plan (zero drops). Crashing
        the last surviving node is ignored: there is nowhere to move the
        shard, so serving rides on (a real deployment would page here).
        """
        sess = self.session
        nodes = sess.plan.cluster.nodes
        names = [n.name for n in nodes]
        if name not in names or len(names) <= 1:
            return
        old = self._slow.pop(name, None)
        if old is not None:
            self._set_load(name, -old[0])
        j = names.index(name)
        moved = int((np.asarray(sess.state.placement.assignment) == j).sum())
        sess.failover([name])
        self._crashed.add(name)
        t_f = simulation.simulate_failover(
            sess.plan.cluster, moved, sess.plan.graph.feature_dim)
        self._occupy(t, t_f)
        self.replayed += in_flight
        self._add_recovery("failover", t_f, 0)

    def _recover(self, name: str, t: float) -> None:
        """Bring node ``name`` back: rebase onto the full-cluster restore
        target (recompiled first if graph updates landed while degraded),
        still minus any *other* nodes that remain crashed. Priced like a
        failover over the vertices that move back."""
        old = self._slow.pop(name, None)
        if old is not None:
            self._set_load(name, -old[0])
        if name not in self._crashed:
            return
        self._crashed.discard(name)
        sess = self.session
        g = sess.plan.graph
        full = self._full_plan
        same = g is full.graph
        if not same:
            from repro.gnn import ops
            same = (ops.graph_fingerprint(g) == ops.graph_fingerprint(
                full.graph) and np.array_equal(g.features,
                                               full.graph.features))
        if not same:
            # Graph updates landed while degraded: the restore target is
            # a fresh full-cluster compile of the *current* graph.
            from repro.api.engine import Engine
            full = Engine.from_plan(full)._recompile(g)
            self._full_plan = full
        if self._crashed:
            from repro.api.engine import Engine
            plan2 = Engine.from_plan(full).fail_nodes(
                full, sorted(self._crashed))
        else:
            plan2 = full
        # Vertices whose owning *node* changes move back over the wire.
        old_names = np.array([f.name for f in sess.plan.fogs])
        new_names = np.array([f.name for f in plan2.fogs])
        moved = int((old_names[np.asarray(sess.state.placement.assignment)]
                     != new_names[np.asarray(plan2.placement.assignment)]
                     ).sum())
        sess.rebind(plan2)
        t_r = simulation.simulate_failover(
            plan2.cluster, moved, plan2.graph.feature_dim)
        self._occupy(t, t_r)
        self._add_recovery("restored", t_r, 0)

    _TIER_RANK = {"retry": 0, "stale": 1, "restored": 2, "failover": 3}

    def _add_recovery(self, tag: str, seconds: float, retries: int) -> None:
        """Charge ``seconds`` of recovery work to the next served batch,
        merging with any charge already pending (strongest tag wins)."""
        if self._recovery is None:
            self._recovery = (tag, float(seconds), int(retries))
            return
        t0, s0, n0 = self._recovery
        rank = self._TIER_RANK
        self._recovery = (tag if rank.get(tag, 0) >= rank.get(t0, 0) else t0,
                          s0 + float(seconds), n0 + int(retries))

    def _occupy(self, t: float, seconds: float) -> None:
        """Occupy the execution stage with ``seconds`` of recovery work
        starting no earlier than ``t`` (same clock as update repairs)."""
        sched = simulation.pipeline_schedule(
            [(t, 0.0, seconds)], pipelined=self.pipelined,
            start=self._pipe_state)[-1]
        self._pipe_state = simulation.schedule_state(sched)
        self._svc_cache.clear()
        self._degraded.clear()
        self._rebuild_ladder()

    def _set_load(self, name: str, delta: float) -> bool:
        """Adjust a live node's background load by ``delta`` (straggler
        pricing); no-op (False) when the node is not in the current
        cluster — e.g. it crashed while slow."""
        for node in self.session.plan.cluster.nodes:
            if node.name == name:
                node.background_load = max(0.0,
                                           node.background_load + delta)
                self._svc_cache.clear()
                return True
        return False

    def _rebuild_ladder(self) -> None:
        """Re-derive the degradation ladder after a plan swap (a failover
        plan gets the single survivor-degraded rung; restore brings the
        full ladder back). Explicit ``SLOPolicy.ladder`` lists stick."""
        if self.slo is not None and self.slo.ladder is None:
            self.ladder = default_ladder(self.session)

    def _note_plan(self) -> None:
        """Re-track the full-cluster restore target after a graph update
        (only while no node is crashed: a degraded plan must never
        become the restore target)."""
        if not self._crashed:
            self._full_plan = self.session.plan

    def serve(self, requests: Iterable[Request]) -> List[Response]:
        """Submit then drain a whole arrival trace."""
        for r in requests:
            self.submit(r)
        return self.drain()

    def replay(self, queries: Union[int, Iterable], *,
               executor: Optional[str] = None) -> List[Response]:
        """Replay a query stream: an int (closed-loop re-serves of the
        stored features), an iterable of feature arrays (None entries use
        stored features), or an iterable of ``Request`` objects (e.g. from
        ``repro.api.traces``). ``executor`` overrides the backend for
        every request that does not carry its own override.
        """
        if isinstance(queries, int):
            queries = (None for _ in range(queries))
        for q in queries:
            if isinstance(q, Request):
                if executor is not None and q.executor is None:
                    q = dataclasses.replace(q, executor=executor)
                self.submit(q)
            elif isinstance(q, (UpdateRequest, GraphDelta)):
                self.submit(q)
            else:
                self.submit(q, executor=executor)
        return self.drain()

    # -- control plane ------------------------------------------------------

    def _session_for(self, level: int) -> Session:
        """The session serving ladder rung ``level`` (0 = the base
        session); degraded sessions are cached per rung and rebuilt when
        a graph update rebases the base session onto a new plan."""
        if level == 0:
            return self.session
        base_plan = self.session.plan
        cached = self._degraded.get(level)
        if cached is not None and cached[0] is base_plan:
            return cached[1]
        rung = self.ladder[level - 1]
        sess = Session(
            base_plan, executor=self.session._executor_key,
            aggregation=(self.session._aggregation
                         if rung.aggregation is None else rung.aggregation),
            compressor=rung.compressor, num_layers=rung.num_layers,
            accuracy_fn=self.session.accuracy_fn)
        self._degraded[level] = (base_plan, sess)
        return sess

    def _account_for(self, key: str, batch_size: int, level: int,
                     staleness: int = 0) -> simulation.ServingResult:
        # Admission estimates price conservatively at staleness=0 (the
        # fresh synchronous exchange); only the serving path passes the
        # batch's actual staleness, which drops the K*delta sync term.
        ck = (key, batch_size, level, bool(staleness))
        res = self._svc_cache.get(ck)
        if res is None:
            res = self._session_for(level).account(key,
                                                   batch_size=batch_size,
                                                   staleness=staleness)
            self._svc_cache[ck] = res
        return res

    def _estimated_finish(self, key: str, batch_size: int, level: int,
                          ready: float) -> float:
        """Dry-run the batch through the pipeline from the current clock
        state: the admission controller's finish-time estimate."""
        res = self._account_for(key, batch_size, level)
        c_t = float(res.collect.max())
        e_t = res.total_latency - c_t
        sched = simulation.pipeline_schedule(
            [(ready, c_t, e_t)], pipelined=self.pipelined,
            start=self._pipe_state)[-1]
        return sched.execute_end

    def _admit(self, members: List[Request], arrs: List[float]
               ) -> Tuple[List[Request], List[float], int, List[Rejection]]:
        """Deadline admission for one formed batch: pick the lowest ladder
        rung meeting every member's deadline, else reject the hopeless
        members (shrinking the batch and retrying — a smaller batch is
        cheaper, so rejection can rescue the rest)."""
        policy = self.slo
        key = self._exec_key(members[0])
        max_level = len(self.ladder) if policy.degrade else 0
        cur, cur_arrs = list(members), list(arrs)
        rejections: List[Rejection] = []
        while cur:
            ready = max(cur_arrs)
            deadlines = [self._deadline_of(r) for r in cur]
            for level in range(max_level + 1):
                finish = self._estimated_finish(key, len(cur), level, ready)
                if all(d is None or finish <= a + d + 1e-12
                       for a, d in zip(cur_arrs, deadlines)):
                    return cur, cur_arrs, level, rejections
            finish = self._estimated_finish(key, len(cur), max_level, ready)
            hopeless = [j for j, (a, d) in enumerate(zip(cur_arrs, deadlines))
                        if d is not None and finish > a + d + 1e-12]
            if not policy.reject_hopeless or not hopeless:
                # Serve late at the last rung; deadline_met records it.
                return cur, cur_arrs, max_level, rejections
            for j in hopeless:
                r = cur[j]
                rejections.append(Rejection(
                    request_id=r.request_id, arrival_time=cur_arrs[j],
                    priority=r.priority, deadline=deadlines[j],
                    estimated_latency=finish - cur_arrs[j]))
            keep = [j for j in range(len(cur)) if j not in set(hopeless)]
            cur = [cur[j] for j in keep]
            cur_arrs = [cur_arrs[j] for j in keep]
        return cur, cur_arrs, 0, rejections

    # -- internals ----------------------------------------------------------

    def _collect_floor(self) -> float:
        """Earliest simulated time the next collection can start."""
        collect_free, execute_free, _ = self._pipe_state
        if self.pipelined:
            return collect_free
        return max(collect_free, execute_free)

    def _form_batch(self, reqs: Sequence[Request], order: Sequence[int],
                    start: int) -> Tuple[List[int], List[float]]:
        """Coalesce compatible consecutive requests into one micro-batch.

        Returns the member indices (into ``reqs``) and their effective
        arrival times. The batch closes at ``open_t + max_wait`` — or
        earlier, as soon as waiting for the next arrival would leave the
        oldest member's deadline unmeetable at the estimated service
        time; the adaptive batch controller (when installed) caps the
        size below ``max_batch`` from the measured latency curve.
        """
        floor = self._collect_floor()
        first = reqs[order[start]]
        key = self._exec_key(first)
        first_arr = floor if first.arrival_time is None else first.arrival_time
        open_t = max(first_arr, floor)
        cap = self.max_batch
        if self.batch_controller is not None:
            backlog = 0
            for j in range(start, len(order)):
                if isinstance(reqs[order[j]], UpdateRequest):
                    break   # an update closes the batch anyway
                backlog += 1
            dl = self._deadline_of(first)
            slack = (None if dl is None
                     else max(first_arr + dl - open_t, 0.0))
            cap = max(1, min(cap,
                             self.batch_controller.pick(backlog,
                                                        slack=slack)))
        close_t = open_t + self.max_wait
        batch = [order[start]]
        arrs = [first_arr]
        # Earliest member finish-by time: waiting past
        # (min_deadline_t - service estimate) would make that member's
        # deadline unmeetable no matter what the admission stage does.
        dl = self._deadline_of(first)
        min_dl_t = math.inf if dl is None else first_arr + dl
        for j in range(start + 1, len(order)):
            if len(batch) >= cap:
                break
            r = reqs[order[j]]
            if isinstance(r, UpdateRequest):
                break   # FIFO: a graph update closes the batch
            arr = open_t if r.arrival_time is None else r.arrival_time
            limit = close_t
            if min_dl_t < math.inf:
                svc_now = self._account_for(key, len(batch), 0).total_latency
                if open_t + svc_now <= min_dl_t + 1e-12:
                    # The oldest member is still meetable: only grow the
                    # batch while that stays true. (When it is already
                    # doomed, shrinking the batch saves nothing and slows
                    # everyone else — fall back to the max_wait close.)
                    svc_next = self._account_for(key, len(batch) + 1,
                                                 0).total_latency
                    limit = min(limit, min_dl_t - svc_next)
            if arr > limit or self._exec_key(r) != key:
                break   # FIFO: an incompatible/late request closes the batch
            batch.append(order[j])
            arrs.append(arr)
            dl = self._deadline_of(r)
            if dl is not None:
                min_dl_t = min(min_dl_t, arr + dl)
        return batch, arrs

    def _serve_batch(self, batch: List[Request], ready: float, *,
                     level: int = 0) -> List[Response]:
        sess = self._session_for(level)
        b = len(batch)
        key = self._exec_key(batch[0])
        backend = sess.resolve_executor(batch[0].executor)
        # Numerics first: per-request compressor round-trip, then ONE
        # stacked [B, V, F] array handed to the session's batched execute
        # (bit-identical to serial Session.query — asserted in
        # tests/test_server.py and tests/test_batched_exec.py). Routing
        # through the session lets a cache-enabled session serve the
        # whole micro-batch with one stacked dirty-frontier pass, and
        # resolves this batch's staleness under the stale-tolerant halo
        # policy — which the accounting below depends on (a stale serve
        # skips the K*delta sync round and ships zero exchange bytes).
        collected = np.stack([np.asarray(sess.collect(r.features),
                                         np.float32) for r in batch])
        embs = sess.execute_many(collected, executor=backend)
        staleness = int(getattr(sess, "last_staleness", 0))
        xbytes = sess.exchange_bytes(backend)
        # Accounting: one batched collect + one batched executor run.
        res = self._account_for(key, b, level, staleness=staleness)
        c_t = float(res.collect.max())
        e_t = res.total_latency - c_t
        # Any pending recovery charge (halo retries, failover repair)
        # rides on this batch's execution stage and is consumed here.
        rec_tag, rec_s, rec_n = (self._recovery if self._recovery is not None
                                 else (None, 0.0, 0))
        self._recovery = None
        e_t += rec_s
        sched = simulation.pipeline_schedule(
            [(ready, c_t, e_t)], pipelined=self.pipelined,
            start=self._pipe_state)[-1]
        self._pipe_state = simulation.schedule_state(sched)
        if self.batch_controller is not None:
            self.batch_controller.observe(b, c_t + e_t)
        batch_index = self.num_batches
        self.num_batches += 1
        out = []
        for k, (req, emb) in enumerate(zip(batch, embs)):
            # Closed-loop requests are generated at admission: no queueing.
            arrival = (sched.collect_start if req.arrival_time is None
                       else req.arrival_time)
            queue_delay = sched.collect_start - arrival
            latency = sched.execute_end - arrival
            acc = None if sess.accuracy_fn is None else float(
                sess.accuracy_fn(emb))
            deadline = self._deadline_of(req)
            breakdown: Dict[str, float] = {
                "queue": queue_delay, "collect": c_t, "execute": e_t,
                "unpack": float(res.unpack.max()), "total": latency}
            if self.injector is not None:
                breakdown["recovery"] = rec_s
            out.append(Response(
                embeddings=emb, latency=latency, throughput=res.throughput,
                breakdown=breakdown, wire_bytes=res.wire_bytes / b,
                exchange_bytes=xbytes, backend=backend.name, accuracy=acc,
                request_id=req.request_id, arrival_time=arrival,
                queue_delay=queue_delay, service_start=sched.collect_start,
                finish_time=sched.execute_end, batch_size=b,
                batch_index=batch_index, collect_time=c_t, execute_time=e_t,
                overlap_saved=sched.overlap_saved, priority=req.priority,
                deadline=deadline,
                deadline_met=(None if deadline is None
                              else bool(latency <= deadline + 1e-9)),
                degradation=level, staleness=staleness,
                retries=rec_n, recovered=rec_tag,
                capacity=("degraded"
                          if sess.plan.provenance == "failover" else "full")))
            sess.tick()   # per-request adapt_every accounting (step 5)
        if sess.adapt_every:
            self._svc_cache.clear()   # adaptation may have moved placement
        return out

    # -- reporting ----------------------------------------------------------

    @staticmethod
    def summarize(responses: Sequence[Response],
                  sites: Optional[Sequence[str]] = None
                  ) -> Dict[str, object]:
        """Trace-level metrics for a batch of responses.

        Mixed traces are fine: ``UpdateResponse`` entries are counted as
        ``updates``, control-plane ``Rejection`` entries as ``rejected``,
        and both are excluded from the latency/throughput statistics.
        ``goodput_rps`` counts only in-deadline responses (best-effort
        responses count as met); ``deadline_miss_rate`` is misses plus
        rejections over deadline-carrying requests plus rejections; and
        ``priority_classes`` breaks requests / rejections / p95 / miss
        rate out per priority class. ``retried`` / ``recovered`` count
        fault-tolerance outcomes (requests whose batch paid a halo retry
        / requests served through any recovery tier) and
        ``availability`` is the answered fraction of admitted requests.

        When any response carries a fleet ``site`` (or ``sites`` lists
        names to always report, so a down site with zero served requests
        still appears), the summary grows a per-site breakdown —
        served/spilled/failed-over counts, per-site p95 (None for an
        empty site) and a staleness histogram — plus a fleet-wide
        ``staleness_histogram``.
        """
        rejected = [r for r in responses if isinstance(r, Rejection)]
        updates = [r for r in responses if isinstance(r, UpdateResponse)]
        responses = [r for r in responses if isinstance(r, Response)]
        if not responses:
            out = {"requests": 0, "updates": len(updates),
                   "rejected": len(rejected), "retried": 0, "recovered": 0,
                   "availability": 1.0 if not rejected else 0.0}
            if sites:
                out["sites"] = {s: {"served": 0, "spilled": 0,
                                    "failed_over": 0, "recovered": 0,
                                    "latency_p95_s": None,
                                    "staleness_histogram": {}}
                                for s in sites}
            return out
        lat = np.array([r.latency for r in responses])
        fin = max(r.finish_time for r in responses)
        t0 = min(r.arrival_time for r in responses)
        makespan = fin - t0
        with_dl = [r for r in responses if r.deadline is not None]
        missed = sum(1 for r in with_dl if not r.deadline_met)
        in_deadline = len(responses) - missed
        denom = len(with_dl) + len(rejected)

        def _class_stats(prio: int) -> Dict[str, object]:
            rs = [r for r in responses if r.priority == prio]
            rj = [r for r in rejected if r.priority == prio]
            wd = [r for r in rs if r.deadline is not None]
            miss = sum(1 for r in wd if not r.deadline_met)
            den = len(wd) + len(rj)
            return {
                "requests": len(rs),
                "rejected": len(rj),
                "degraded": sum(1 for r in rs if r.degradation > 0),
                "latency_p95_s": (float(np.percentile(
                    [r.latency for r in rs], 95)) if rs else None),
                "deadline_miss_rate": (miss + len(rj)) / den if den else 0.0,
                "goodput_rps": (len(rs) - miss) / max(makespan, 1e-12),
            }

        def _hist(rs: Sequence[Response]) -> Dict[str, int]:
            h: Dict[int, int] = {}
            for r in rs:
                h[r.staleness] = h.get(r.staleness, 0) + 1
            return {str(k): h[k] for k in sorted(h)}

        def _site_stats(name: str) -> Dict[str, object]:
            rs = [r for r in responses if r.site == name]
            return {
                "served": len(rs),
                "spilled": sum(1 for r in rs if r.route == "spilled"),
                "failed_over": sum(1 for r in rs
                                   if r.route == "failed_over"),
                "recovered": sum(1 for r in rs if r.route == "recovered"),
                # Guard: a site that served nothing (down the whole
                # trace) has no percentile to report.
                "latency_p95_s": (float(np.percentile(
                    [r.latency for r in rs], 95)) if rs else None),
                "staleness_histogram": _hist(rs),
            }

        site_names = sorted({r.site for r in responses
                             if r.site is not None}
                            | set(sites or ()))
        prios = sorted({r.priority for r in responses}
                       | {r.priority for r in rejected})
        fleet_extra: Dict[str, object] = {}
        if site_names:
            fleet_extra = {
                "sites": {s: _site_stats(s) for s in site_names},
                "staleness_histogram": _hist(responses),
                "routing_delay_mean_s": float(np.mean(
                    [r.routing_delay for r in responses])),
            }
        return {
            **fleet_extra,
            "requests": len(responses),
            "updates": len(updates),
            "rejected": len(rejected),
            "batches": len({r.batch_index for r in responses}),
            "mean_batch": len(responses)
            / len({r.batch_index for r in responses}),
            "makespan_s": makespan,
            "throughput_rps": len(responses) / max(makespan, 1e-12),
            "goodput_rps": in_deadline / max(makespan, 1e-12),
            "latency_mean_s": float(lat.mean()),
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "queue_delay_mean_s": float(np.mean(
                [r.queue_delay for r in responses])),
            "overlap_saved_s": float(sum(
                {r.batch_index: r.overlap_saved
                 for r in responses}.values())),
            "degraded": sum(1 for r in responses if r.degradation > 0),
            # Fault-tolerance outcomes: requests whose batch paid a halo
            # retry, requests served through any recovery tier, and the
            # answered fraction (admitted and answered / admitted).
            "retried": sum(1 for r in responses
                           if getattr(r, "retries", 0) > 0),
            "recovered": sum(1 for r in responses
                             if getattr(r, "recovered", None) is not None),
            "availability": len(responses) / (len(responses) + len(rejected)),
            "deadline_miss_rate": ((missed + len(rejected)) / denom
                                   if denom else 0.0),
            "priority_classes": {str(p): _class_stats(p) for p in prios},
        }

    def __repr__(self) -> str:
        return (f"Server(max_batch={self.max_batch}, "
                f"max_wait={self.max_wait}, pipelined={self.pipelined}, "
                f"slo={'on' if self.slo is not None else 'off'}, "
                f"adaptive_batch="
                f"{'on' if self.batch_controller is not None else 'off'}, "
                f"served_batches={self.num_batches})")
