"""Request-level serving front-end: ``Server`` / ``Request`` / ``Response``.

``Session.query`` is a strictly blocking, one-query-at-a-time call; the
paper's headline throughput numbers come from serving *streams* of
queries with feature collection pipelined against execution (§III-D).
This module adds the arrival-driven layer on top of the Session's
separately callable stages:

  * ``Request``   — one inference query: features (None = the graph's
    stored features), a simulated-clock arrival time (None = closed loop:
    the request is generated the moment the server can admit it, like the
    old serial ``Session.stream``), and per-request knobs (executor
    backend override).
  * ``Response``  — extends ``QueryResult`` with queueing, batching and
    pipeline-overlap timings (``queue_delay``, ``batch_size``,
    ``collect_time`` / ``execute_time`` stage splits, ``overlap_saved``).
  * ``Server``    — admission queue + micro-batcher + two-stage pipeline.
    Compatible consecutive requests (same executor backend) coalesce into
    one micro-batch: one batched feature collect (priced by
    ``simulation.simulate(..., batch_size=B)``: coalesced long-tail, one
    packing overhead, one K*delta sync round) and one executor run over
    the batch. Batch k+1's collection overlaps batch k's execution
    (``simulation.pipeline_schedule``), so the steady-state period is
    max(collect, execute) instead of their sum.

Numerics are exact: each request's embeddings are computed by the same
compressor round-trip + executor run as ``Session.query``, so batched
responses are bit-identical to serial ones — only the latency accounting
knows about batching (tested in ``tests/test_server.py``).

    server = plan.server(max_batch=8)
    for r in server.replay(traces.poisson(64, rate=4.0)):
        print(r.request_id, r.queue_delay, r.latency)
    print(server.summarize(responses))
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.api.registry import EXECUTORS
from repro.api.session import QueryResult, Session
from repro.core import simulation


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request for the serving front-end.

    ``features`` of None re-serves the graph's stored features.
    ``arrival_time`` is on the simulated clock (seconds); None means
    closed-loop — the request becomes ready the moment the server can
    admit it. ``executor`` optionally overrides the session's backend for
    this request only (requests only batch with same-backend neighbours).
    """
    features: Optional[np.ndarray] = None
    arrival_time: Optional[float] = None
    executor: Optional[str] = None
    request_id: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class Response(QueryResult):
    """A ``QueryResult`` plus queueing / batching / pipeline timings.

    ``latency`` is end-to-end on the simulated clock: arrival ->
    execution finished (so it includes ``queue_delay``). Invariants
    (tested): ``queue_delay >= 0`` and
    ``latency >= max(collect_time, execute_time)``.
    """
    request_id: int = 0
    arrival_time: float = 0.0
    queue_delay: float = 0.0
    service_start: float = 0.0
    finish_time: float = 0.0
    batch_size: int = 1
    batch_index: int = 0
    collect_time: float = 0.0
    execute_time: float = 0.0
    overlap_saved: float = 0.0


class Server:
    """Micro-batching, pipelining request server over one ``Session``.

    Args:
      session: the ``Session`` whose collect/execute/account stages serve
        every request (or a ``Plan``, from which a fresh session is made).
      max_batch: micro-batch size cap (1 disables coalescing).
      max_wait: how long (simulated seconds) an open batch waits for more
        compatible arrivals beyond its first request before launching.
      pipelined: overlap batch k+1's collection with batch k's execution
        (§III-D). False reproduces the strictly serial loop — the
        ``Session.stream`` baseline.

    The server runs on a simulated clock: collection and execution free
    times persist across ``submit``/``drain`` calls, so one server can
    replay an arrival trace incrementally.
    """

    def __init__(self, session: Union[Session, "object"], *,
                 max_batch: int = 8, max_wait: float = 0.0,
                 pipelined: bool = True):
        if not isinstance(session, Session):   # accept a Plan for brevity
            session = session.session()
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.session = session
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.pipelined = bool(pipelined)
        self._pending: List[Request] = []
        self._next_id = 0
        # (collect_free, execute_free, prev_execute_start) resource state
        # for simulation.pipeline_schedule, threaded batch-by-batch so the
        # overlap model lives in one place and the simulated clock
        # persists across drain() calls.
        self._pipe_state = (0.0, 0.0, 0.0)
        self.num_batches = 0

    # -- admission ----------------------------------------------------------

    def submit(self, request: Union[Request, np.ndarray, None] = None, *,
               arrival_time: Optional[float] = None,
               executor: Optional[str] = None) -> Request:
        """Admit one request (a ``Request``, a feature array, or None)."""
        if not isinstance(request, Request):
            request = Request(features=request, arrival_time=arrival_time,
                              executor=executor)
        if isinstance(request.executor, str):
            EXECUTORS.resolve(request.executor)   # reject bad keys at admission
        if request.request_id is None:
            request = dataclasses.replace(request, request_id=self._next_id)
        self._next_id = max(self._next_id, request.request_id) + 1
        self._pending.append(request)
        return request

    def _exec_key(self, req: Request) -> str:
        key = req.executor
        if key is None:
            key = self.session._executor_key
        if not isinstance(key, str):
            key = getattr(key, "name", key)
        return EXECUTORS.canonical(key)

    # -- serving ------------------------------------------------------------

    def drain(self) -> List[Response]:
        """Serve every pending request; responses in service order."""
        reqs = self._pending
        self._pending = []
        # Stable order by arrival (closed-loop requests keep submission
        # order: they are ready whenever the server is).
        order = sorted(range(len(reqs)),
                       key=lambda i: (reqs[i].arrival_time
                                      if reqs[i].arrival_time is not None
                                      else 0.0))
        out: List[Response] = []
        i = 0
        try:
            while i < len(order):
                batch, ready = self._form_batch(reqs, order, i)
                out.extend(self._serve_batch([reqs[k] for k in batch],
                                             ready))
                i += len(batch)
        except BaseException:
            # Don't lose work on a mid-drain failure (bad executor key,
            # wrong feature shape, ...): requeue everything unserved.
            self._pending = [reqs[k] for k in order[i:]] + self._pending
            raise
        return out

    def serve(self, requests: Iterable[Request]) -> List[Response]:
        """Submit then drain a whole arrival trace."""
        for r in requests:
            self.submit(r)
        return self.drain()

    def replay(self, queries: Union[int, Iterable], *,
               executor: Optional[str] = None) -> List[Response]:
        """Replay a query stream: an int (closed-loop re-serves of the
        stored features), an iterable of feature arrays (None entries use
        stored features), or an iterable of ``Request`` objects (e.g. from
        ``repro.api.traces``). ``executor`` overrides the backend for
        every request that does not carry its own override.
        """
        if isinstance(queries, int):
            queries = (None for _ in range(queries))
        for q in queries:
            if isinstance(q, Request):
                if executor is not None and q.executor is None:
                    q = dataclasses.replace(q, executor=executor)
                self.submit(q)
            else:
                self.submit(q, executor=executor)
        return self.drain()

    # -- internals ----------------------------------------------------------

    def _collect_floor(self) -> float:
        """Earliest simulated time the next collection can start."""
        collect_free, execute_free, _ = self._pipe_state
        if self.pipelined:
            return collect_free
        return max(collect_free, execute_free)

    def _form_batch(self, reqs: Sequence[Request], order: Sequence[int],
                    start: int):
        """Coalesce compatible consecutive requests into one micro-batch."""
        floor = self._collect_floor()
        first = reqs[order[start]]
        key = self._exec_key(first)
        first_arr = floor if first.arrival_time is None else first.arrival_time
        open_t = max(first_arr, floor)
        close_t = open_t + self.max_wait
        batch = [order[start]]
        ready = first_arr
        for j in range(start + 1, len(order)):
            if len(batch) >= self.max_batch:
                break
            r = reqs[order[j]]
            arr = open_t if r.arrival_time is None else r.arrival_time
            if arr > close_t or self._exec_key(r) != key:
                break   # FIFO: an incompatible/late request closes the batch
            batch.append(order[j])
            ready = max(ready, arr)
        return batch, ready

    def _serve_batch(self, batch: List[Request],
                     ready: float) -> List[Response]:
        sess = self.session
        b = len(batch)
        backend = sess.resolve_executor(batch[0].executor)
        # Accounting: one batched collect + one batched executor run.
        res = sess.account(backend, batch_size=b)
        c_t = float(res.collect.max())
        e_t = res.total_latency - c_t
        sched = simulation.pipeline_schedule(
            [(ready, c_t, e_t)], pipelined=self.pipelined,
            start=self._pipe_state)[-1]
        self._pipe_state = simulation.schedule_state(sched)
        # Numerics: per-request compressor round-trip, one run over the
        # batch (bit-identical to serial Session.query by construction).
        collected = [sess.collect(r.features) for r in batch]
        embs = backend.run_many(sess.plan, collected,
                                sess.state.placement.assignment,
                                sess.partitioned(backend),
                                sess._exchange.name,
                                aggregation=sess._aggregation)
        xbytes = sess.exchange_bytes(backend)
        batch_index = self.num_batches
        self.num_batches += 1
        out = []
        for k, (req, emb) in enumerate(zip(batch, embs)):
            # Closed-loop requests are generated at admission: no queueing.
            arrival = (sched.collect_start if req.arrival_time is None
                       else req.arrival_time)
            queue_delay = sched.collect_start - arrival
            latency = sched.execute_end - arrival
            acc = None if sess.accuracy_fn is None else float(
                sess.accuracy_fn(emb))
            breakdown: Dict[str, float] = {
                "queue": queue_delay, "collect": c_t, "execute": e_t,
                "unpack": float(res.unpack.max()), "total": latency}
            out.append(Response(
                embeddings=emb, latency=latency, throughput=res.throughput,
                breakdown=breakdown, wire_bytes=res.wire_bytes / b,
                exchange_bytes=xbytes, backend=backend.name, accuracy=acc,
                request_id=req.request_id, arrival_time=arrival,
                queue_delay=queue_delay, service_start=sched.collect_start,
                finish_time=sched.execute_end, batch_size=b,
                batch_index=batch_index, collect_time=c_t, execute_time=e_t,
                overlap_saved=sched.overlap_saved))
            sess.tick()   # per-request adapt_every accounting (step 5)
        return out

    # -- reporting ----------------------------------------------------------

    @staticmethod
    def summarize(responses: Sequence[Response]) -> Dict[str, float]:
        """Trace-level metrics for a batch of responses."""
        if not responses:
            return {"requests": 0}
        lat = np.array([r.latency for r in responses])
        fin = max(r.finish_time for r in responses)
        t0 = min(r.arrival_time for r in responses)
        makespan = fin - t0
        return {
            "requests": len(responses),
            "batches": len({r.batch_index for r in responses}),
            "mean_batch": len(responses)
            / len({r.batch_index for r in responses}),
            "makespan_s": makespan,
            "throughput_rps": len(responses) / max(makespan, 1e-12),
            "latency_mean_s": float(lat.mean()),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "queue_delay_mean_s": float(np.mean(
                [r.queue_delay for r in responses])),
            "overlap_saved_s": float(sum(
                {r.batch_index: r.overlap_saved
                 for r in responses}.values())),
        }

    def __repr__(self) -> str:
        return (f"Server(max_batch={self.max_batch}, "
                f"max_wait={self.max_wait}, pipelined={self.pipelined}, "
                f"served_batches={self.num_batches})")
