"""Request-level serving front-end: ``Server`` / ``Request`` / ``Response``.

``Session.query`` is a strictly blocking, one-query-at-a-time call; the
paper's headline throughput numbers come from serving *streams* of
queries with feature collection pipelined against execution (§III-D).
This module adds the arrival-driven layer on top of the Session's
separately callable stages:

  * ``Request``   — one inference query: features (None = the graph's
    stored features), a simulated-clock arrival time (None = closed loop:
    the request is generated the moment the server can admit it, like the
    old serial ``Session.stream``), and per-request knobs (executor
    backend override).
  * ``Response``  — extends ``QueryResult`` with queueing, batching and
    pipeline-overlap timings (``queue_delay``, ``batch_size``,
    ``collect_time`` / ``execute_time`` stage splits, ``overlap_saved``).
  * ``Server``    — admission queue + micro-batcher + two-stage pipeline.
    Compatible consecutive requests (same executor backend) coalesce into
    one micro-batch: one batched feature collect (priced by
    ``simulation.simulate(..., batch_size=B)``: coalesced long-tail, one
    packing overhead, one K*delta sync round) and one executor run over
    the batch. Batch k+1's collection overlaps batch k's execution
    (``simulation.pipeline_schedule``), so the steady-state period is
    max(collect, execute) instead of their sum.

Numerics are exact: each request's embeddings are computed by the same
compressor round-trip + executor numerics as ``Session.query``, so batched
responses are bit-identical to serial ones. Since the batch-axis executor
work (PR 5) this holds *with* genuinely batched execution: the micro-batch
is stacked into one [B, V, F] array and every backend's ``run_many``
serves it in a single traced call — one fused Pallas dispatch on the
kernel path, one vmapped program otherwise — instead of a per-request
Python loop (tested in ``tests/test_server.py`` and
``tests/test_batched_exec.py``).

    server = plan.server(max_batch=8)
    for r in server.replay(traces.poisson(64, rate=4.0)):
        print(r.request_id, r.queue_delay, r.latency)
    print(server.summarize(responses))
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.api.registry import EXECUTORS
from repro.api.session import QueryResult, Session
from repro.api.updates import GraphDelta, UpdateReport, UpdateRequest
from repro.core import simulation


@dataclasses.dataclass(frozen=True)
class Request:
    """One inference request for the serving front-end.

    ``features`` of None re-serves the graph's stored features.
    ``arrival_time`` is on the simulated clock (seconds); None means
    closed-loop — the request becomes ready the moment the server can
    admit it. ``executor`` optionally overrides the session's backend for
    this request only (requests only batch with same-backend neighbours).
    """
    features: Optional[np.ndarray] = None
    arrival_time: Optional[float] = None
    executor: Optional[str] = None
    request_id: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class Response(QueryResult):
    """A ``QueryResult`` plus queueing / batching / pipeline timings.

    ``latency`` is end-to-end on the simulated clock: arrival ->
    execution finished (so it includes ``queue_delay``). Invariants
    (tested): ``queue_delay >= 0`` and
    ``latency >= max(collect_time, execute_time)``.
    """
    request_id: int = 0
    arrival_time: float = 0.0
    queue_delay: float = 0.0
    service_start: float = 0.0
    finish_time: float = 0.0
    batch_size: int = 1
    batch_index: int = 0
    collect_time: float = 0.0
    execute_time: float = 0.0
    overlap_saved: float = 0.0


@dataclasses.dataclass(frozen=True)
class UpdateResponse:
    """Acknowledgement of one ``UpdateRequest`` in a mixed stream.

    ``applied`` is False when the session's "deferred" policy buffered the
    delta (it is coalesced into one repair at the end of the drain; the
    merged report lands on ``Server.last_update_report``).  Updates are
    control-plane: they take no time on the simulated serving clock.
    """
    request_id: int
    arrival_time: float
    applied: bool
    pending: int = 0
    report: Optional[UpdateReport] = None


class Server:
    """Micro-batching, pipelining request server over one ``Session``.

    Args:
      session: the ``Session`` whose collect/execute/account stages serve
        every request (or a ``Plan``, from which a fresh session is made).
      max_batch: micro-batch size cap (1 disables coalescing).
      max_wait: how long (simulated seconds) an open batch waits for more
        compatible arrivals beyond its first request before launching.
      pipelined: overlap batch k+1's collection with batch k's execution
        (§III-D). False reproduces the strictly serial loop — the
        ``Session.stream`` baseline.

    The server runs on a simulated clock: collection and execution free
    times persist across ``submit``/``drain`` calls, so one server can
    replay an arrival trace incrementally.
    """

    def __init__(self, session: Union[Session, "object"], *,
                 max_batch: int = 8, max_wait: float = 0.0,
                 pipelined: bool = True):
        if not isinstance(session, Session):   # accept a Plan for brevity
            session = session.session()
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait < 0:
            raise ValueError(f"max_wait must be >= 0, got {max_wait}")
        self.session = session
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.pipelined = bool(pipelined)
        self._pending: List[Union[Request, UpdateRequest]] = []
        self._next_id = 0
        #: UpdateReport of the most recent applied (or flushed) update.
        self.last_update_report: Optional[UpdateReport] = None
        # (collect_free, execute_free, prev_execute_start) resource state
        # for simulation.pipeline_schedule, threaded batch-by-batch so the
        # overlap model lives in one place and the simulated clock
        # persists across drain() calls.
        self._pipe_state = (0.0, 0.0, 0.0)
        self.num_batches = 0

    # -- admission ----------------------------------------------------------

    def submit(self, request: Union[Request, UpdateRequest, "GraphDelta",
                                    np.ndarray, None] = None, *,
               arrival_time: Optional[float] = None,
               executor: Optional[str] = None
               ) -> Union[Request, UpdateRequest]:
        """Admit one request (a ``Request``, a feature array, or None) or
        one graph update (an ``UpdateRequest`` or a bare ``GraphDelta``).
        Updates share the query id space and are served in arrival order;
        whether they apply immediately or buffer is the session's
        ``updates`` policy."""
        if isinstance(request, GraphDelta):
            request = UpdateRequest(delta=request, arrival_time=arrival_time)
        if isinstance(request, UpdateRequest):
            if not isinstance(request.delta, GraphDelta):
                raise TypeError("UpdateRequest.delta must be a GraphDelta, "
                                f"got {type(request.delta).__name__}")
        else:
            if not isinstance(request, Request):
                request = Request(features=request,
                                  arrival_time=arrival_time,
                                  executor=executor)
            if isinstance(request.executor, str):
                EXECUTORS.resolve(request.executor)   # reject bad keys early
        if request.request_id is None:
            request = dataclasses.replace(request, request_id=self._next_id)
        self._next_id = max(self._next_id, request.request_id) + 1
        self._pending.append(request)
        return request

    def _exec_key(self, req: Request) -> str:
        key = req.executor
        if key is None:
            key = self.session._executor_key
        if not isinstance(key, str):
            key = getattr(key, "name", key)
        return EXECUTORS.canonical(key)

    # -- serving ------------------------------------------------------------

    def drain(self) -> List[Union[Response, UpdateResponse]]:
        """Serve every pending request; responses in service order.

        Updates interleave with query batches at their arrival position:
        an update always closes the open micro-batch (FIFO), then either
        applies immediately ("sync" session policy — later queries see the
        mutated graph) or buffers ("deferred" — later queries in this
        drain read the stale graph, and the whole buffer coalesces into
        one repair when the drain finishes).

        On a mid-drain failure, unserved requests are requeued and the
        exception is re-raised with the responses already produced (served
        queries and applied-update acks, whose side effects persist)
        attached as ``exc.partial_responses``, so mixed streams stay
        recoverable.
        """
        reqs = self._pending
        self._pending = []
        # Stable order by arrival. A closed-loop request (arrival_time
        # None) is ready the moment it is admitted, i.e. no earlier than
        # anything submitted before it: it inherits the latest arrival
        # seen so far (0.0 when nothing timed precedes it), so untimed
        # submissions — in particular graph updates — keep their FIFO
        # position instead of sorting to the front of timed traffic.
        eff = []
        latest = 0.0
        for r in reqs:   # submission order
            if r.arrival_time is None:
                eff.append(latest)
            else:
                latest = max(latest, r.arrival_time)
                eff.append(r.arrival_time)
        order = sorted(range(len(reqs)), key=lambda i: eff[i])
        out: List[Union[Response, UpdateResponse]] = []
        i = 0
        try:
            while i < len(order):
                req = reqs[order[i]]
                if isinstance(req, UpdateRequest):
                    # Consume the update *before* applying it: if the
                    # delta is rejected (bad ids for the current graph),
                    # the requeue handler below must not put it back at
                    # the head of the queue, or every later drain would
                    # re-trip on it and starve the requests behind it.
                    i += 1
                    out.append(self._handle_update(req))
                    continue
                batch, ready = self._form_batch(reqs, order, i)
                out.extend(self._serve_batch([reqs[k] for k in batch],
                                             ready))
                i += len(batch)
            if self.session.pending_updates:   # deferred: one coalesced repair
                self.last_update_report = self.session.flush_updates()
        except BaseException as exc:
            # Don't lose work on a mid-drain failure (bad executor key,
            # wrong feature shape, rejected delta, ...): requeue
            # everything unserved, and hand the caller what was already
            # produced — applied updates mutated the session for good.
            self._pending = [reqs[k] for k in order[i:]] + self._pending
            exc.partial_responses = out
            raise
        return out

    def _handle_update(self, req: UpdateRequest) -> UpdateResponse:
        report = self.session.update(req.delta)
        if report is not None:
            self.last_update_report = report
        arrival = (self._collect_floor() if req.arrival_time is None
                   else req.arrival_time)
        return UpdateResponse(request_id=req.request_id,
                              arrival_time=arrival,
                              applied=report is not None,
                              pending=self.session.pending_updates,
                              report=report)

    def serve(self, requests: Iterable[Request]) -> List[Response]:
        """Submit then drain a whole arrival trace."""
        for r in requests:
            self.submit(r)
        return self.drain()

    def replay(self, queries: Union[int, Iterable], *,
               executor: Optional[str] = None) -> List[Response]:
        """Replay a query stream: an int (closed-loop re-serves of the
        stored features), an iterable of feature arrays (None entries use
        stored features), or an iterable of ``Request`` objects (e.g. from
        ``repro.api.traces``). ``executor`` overrides the backend for
        every request that does not carry its own override.
        """
        if isinstance(queries, int):
            queries = (None for _ in range(queries))
        for q in queries:
            if isinstance(q, Request):
                if executor is not None and q.executor is None:
                    q = dataclasses.replace(q, executor=executor)
                self.submit(q)
            elif isinstance(q, (UpdateRequest, GraphDelta)):
                self.submit(q)
            else:
                self.submit(q, executor=executor)
        return self.drain()

    # -- internals ----------------------------------------------------------

    def _collect_floor(self) -> float:
        """Earliest simulated time the next collection can start."""
        collect_free, execute_free, _ = self._pipe_state
        if self.pipelined:
            return collect_free
        return max(collect_free, execute_free)

    def _form_batch(self, reqs: Sequence[Request], order: Sequence[int],
                    start: int):
        """Coalesce compatible consecutive requests into one micro-batch."""
        floor = self._collect_floor()
        first = reqs[order[start]]
        key = self._exec_key(first)
        first_arr = floor if first.arrival_time is None else first.arrival_time
        open_t = max(first_arr, floor)
        close_t = open_t + self.max_wait
        batch = [order[start]]
        ready = first_arr
        for j in range(start + 1, len(order)):
            if len(batch) >= self.max_batch:
                break
            r = reqs[order[j]]
            if isinstance(r, UpdateRequest):
                break   # FIFO: a graph update closes the batch
            arr = open_t if r.arrival_time is None else r.arrival_time
            if arr > close_t or self._exec_key(r) != key:
                break   # FIFO: an incompatible/late request closes the batch
            batch.append(order[j])
            ready = max(ready, arr)
        return batch, ready

    def _serve_batch(self, batch: List[Request],
                     ready: float) -> List[Response]:
        sess = self.session
        b = len(batch)
        backend = sess.resolve_executor(batch[0].executor)
        # Accounting: one batched collect + one batched executor run.
        res = sess.account(backend, batch_size=b)
        c_t = float(res.collect.max())
        e_t = res.total_latency - c_t
        sched = simulation.pipeline_schedule(
            [(ready, c_t, e_t)], pipelined=self.pipelined,
            start=self._pipe_state)[-1]
        self._pipe_state = simulation.schedule_state(sched)
        # Numerics: per-request compressor round-trip, then ONE stacked
        # [B, V, F] array handed to the executor's natively batched
        # run_many (bit-identical to serial Session.query — asserted in
        # tests/test_server.py and tests/test_batched_exec.py).
        collected = np.stack([np.asarray(sess.collect(r.features),
                                         np.float32) for r in batch])
        embs = backend.run_many(sess.plan, collected,
                                sess.state.placement.assignment,
                                sess.partitioned(backend),
                                sess._exchange.name,
                                aggregation=sess._aggregation)
        xbytes = sess.exchange_bytes(backend)
        batch_index = self.num_batches
        self.num_batches += 1
        out = []
        for k, (req, emb) in enumerate(zip(batch, embs)):
            # Closed-loop requests are generated at admission: no queueing.
            arrival = (sched.collect_start if req.arrival_time is None
                       else req.arrival_time)
            queue_delay = sched.collect_start - arrival
            latency = sched.execute_end - arrival
            acc = None if sess.accuracy_fn is None else float(
                sess.accuracy_fn(emb))
            breakdown: Dict[str, float] = {
                "queue": queue_delay, "collect": c_t, "execute": e_t,
                "unpack": float(res.unpack.max()), "total": latency}
            out.append(Response(
                embeddings=emb, latency=latency, throughput=res.throughput,
                breakdown=breakdown, wire_bytes=res.wire_bytes / b,
                exchange_bytes=xbytes, backend=backend.name, accuracy=acc,
                request_id=req.request_id, arrival_time=arrival,
                queue_delay=queue_delay, service_start=sched.collect_start,
                finish_time=sched.execute_end, batch_size=b,
                batch_index=batch_index, collect_time=c_t, execute_time=e_t,
                overlap_saved=sched.overlap_saved))
            sess.tick()   # per-request adapt_every accounting (step 5)
        return out

    # -- reporting ----------------------------------------------------------

    @staticmethod
    def summarize(responses: Sequence[Response]) -> Dict[str, float]:
        """Trace-level metrics for a batch of responses.

        Mixed traces are fine: ``UpdateResponse`` entries are counted as
        ``updates`` and excluded from the latency/throughput statistics.
        """
        updates = [r for r in responses if isinstance(r, UpdateResponse)]
        responses = [r for r in responses if isinstance(r, Response)]
        if not responses:
            return {"requests": 0, "updates": len(updates)}
        lat = np.array([r.latency for r in responses])
        fin = max(r.finish_time for r in responses)
        t0 = min(r.arrival_time for r in responses)
        makespan = fin - t0
        return {
            "requests": len(responses),
            "updates": len(updates),
            "batches": len({r.batch_index for r in responses}),
            "mean_batch": len(responses)
            / len({r.batch_index for r in responses}),
            "makespan_s": makespan,
            "throughput_rps": len(responses) / max(makespan, 1e-12),
            "latency_mean_s": float(lat.mean()),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "queue_delay_mean_s": float(np.mean(
                [r.queue_delay for r in responses])),
            "overlap_saved_s": float(sum(
                {r.batch_index: r.overlap_saved
                 for r in responses}.values())),
        }

    def __repr__(self) -> str:
        return (f"Server(max_batch={self.max_batch}, "
                f"max_wait={self.max_wait}, pipelined={self.pipelined}, "
                f"served_batches={self.num_batches})")
