"""Geo-distributed fleet serving: ``Fleet`` / ``Router`` / ``FleetServer``.

Everything below PR 8 serves ONE fog cluster. The paper's
millions-of-users story is many geo-distributed fog *sites* plus a cloud
tier, with each request handled by the site nearest to it — the
multi-edge-server deployment shape. This module is that layer:

  * ``Site``        — one named fog site: a geo centroid plus the
    :class:`~repro.api.plan.Plan` compiled for its cluster (every site
    serves the same profiled fog model; ``Engine.compile_fleet`` builds
    them with per-site profiling seeds).
  * ``Fleet``       — N sites + the cloud tier's plan (the existing
    ``"cloud"`` executor as last-resort).
  * ``Router``      — assigns each request to its nearest site from the
    per-request geo ``origin`` (nearest-broker discovery), with
    load-aware spillover to the next-nearest site when the admission
    queue exceeds the ``capacity`` knob, and failover to the cloud tier
    when every site is down or saturated. ``set_down`` is the
    fault-injection hook.
  * ``FleetServer`` — one facade over per-site ``Server`` instances
    (each with its OWN pipeline clock, so sites serve in parallel on the
    simulated timeline) plus a cloud ``Server``. Cross-site clock
    accounting: a routed request arrives at its serving site
    ``routing_delay`` (distance-proportional forwarding) after its true
    arrival, and its ``Response.latency`` is end-to-end from the true
    arrival. Graph updates fan out to every site session and the cloud,
    so all tiers stay on one graph revision.

The WAN speed lever is the stale-tolerant ``exchange="halo_async"``
registry entry (``runtime.bsp``): a site whose shards are WAN-separated
may serve up to ``staleness_bound`` consecutive requests from recorded
halo tables instead of stalling every superstep on the exchange, with
the served staleness recorded on each ``Response``. ``staleness_bound=0``
is bit-identical to the synchronous ``halo`` exchange (the fresh path IS
the cached halo program — see ``bsp._wire_exchange``).

    fleet = Engine(model, "1A+3B", exchange="halo_async",
                   staleness_bound=2).compile_fleet(
        graph, {"north": (59.3, 18.1), "south": (48.2, 16.4)})
    fs = fleet.server(capacity=16)
    out = fs.replay(traces.poisson(
        256, rate=8.0,
        origin_fn=traces.geo_origins(fleet.centroids())))
    print(fs.summarize(out)["sites"])
"""
from __future__ import annotations

import dataclasses
import math
from typing import (Callable, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Tuple, Union)

from repro.api.server import Request, Response, Server
from repro.api.slo import SLOPolicy
from repro.api.updates import GraphDelta, UpdateReport, UpdateRequest

EARTH_RADIUS_KM = 6371.0
#: name of the last-resort tier (reserved; not a legal site name).
CLOUD = "cloud"
#: cross-site forwarding cost model: per-hop handoff overhead plus a
#: distance term at roughly fiber light-speed with routing detours.
ROUTING_BASE_S = 0.002
ROUTING_PER_KM_S = 1.5e-5
#: forwarding handoff into the cloud tier (the WAN feature upload itself
#: is priced by ``simulation.simulate_cloud``; this is just the redirect).
CLOUD_ROUTING_S = 0.004


def haversine_km(a: Tuple[float, float], b: Tuple[float, float]) -> float:
    """Great-circle distance in km between two (lat, lon) pairs (degrees)."""
    lat1, lon1 = math.radians(a[0]), math.radians(a[1])
    lat2, lon2 = math.radians(b[0]), math.radians(b[1])
    h = (math.sin((lat2 - lat1) / 2.0) ** 2
         + math.cos(lat1) * math.cos(lat2)
         * math.sin((lon2 - lon1) / 2.0) ** 2)
    return 2.0 * EARTH_RADIUS_KM * math.asin(min(1.0, math.sqrt(h)))


@dataclasses.dataclass(frozen=True)
class Site:
    """One named fog site: geo centroid + the Plan serving it."""
    name: str
    location: Tuple[float, float]
    plan: object

    def __post_init__(self):
        if not self.name or self.name == CLOUD:
            raise ValueError(f"illegal site name {self.name!r} "
                             f"({CLOUD!r} is the reserved last-resort tier)")
        loc = tuple(float(x) for x in self.location)
        if len(loc) != 2:
            raise ValueError(f"site {self.name!r} location must be "
                             f"(lat, lon), got {self.location!r}")
        object.__setattr__(self, "location", loc)


@dataclasses.dataclass(frozen=True)
class Fleet:
    """N geo-distributed fog sites plus the cloud tier, one shared model.

    Built by ``Engine.compile_fleet``; each site's ``Plan`` came from the
    same engine configuration (one profiled fog model) with a per-site
    profiling seed, and ``cloud_plan`` is the same model compiled for the
    ``"cloud"`` executor.
    """
    sites: Tuple[Site, ...]
    cloud_plan: object

    def __post_init__(self):
        if not self.sites:
            raise ValueError("a Fleet needs at least one site")
        names = [s.name for s in self.sites]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate site names: {names}")

    @property
    def site_names(self) -> Tuple[str, ...]:
        return tuple(s.name for s in self.sites)

    def site(self, name: str) -> Site:
        for s in self.sites:
            if s.name == name:
                return s
        raise KeyError(f"unknown site {name!r}; "
                       f"available: {', '.join(self.site_names)}")

    def centroids(self) -> List[Tuple[float, float]]:
        """Site centroids in listed order (feed ``traces.geo_origins``)."""
        return [s.location for s in self.sites]

    def server(self, **kw) -> "FleetServer":
        """Open the fleet-wide serving facade (see :class:`FleetServer`)."""
        return FleetServer(self, **kw)

    def describe(self) -> dict:
        return {
            "sites": {s.name: {"location": s.location,
                               "fogs": [f.name for f in s.plan.fogs]}
                      for s in self.sites},
            "cloud": {"executor": self.cloud_plan.config.executor},
            "model": {"kind": self.cloud_plan.model.kind,
                      "layers": self.cloud_plan.model.num_layers},
        }


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Where one request goes and why.

    ``route`` ∈ {"local", "spilled", "failed_over", "recovered"}:
    nearest site / load spillover to another site / rerouted off a down
    tier (or to the cloud because everything is down or saturated) /
    pulled back to its revived home site by ``set_down(name, False)``.
    """
    site: str
    route: str
    distance_km: float

    @property
    def routing_delay(self) -> float:
        if self.site == CLOUD:
            return CLOUD_ROUTING_S
        return ROUTING_BASE_S + self.distance_km * ROUTING_PER_KM_S


class Router:
    """Nearest-site router with load spillover and cloud failover.

    The routing table maps every site name to its centroid — the
    ``analysis.fleet_checks`` coverage check asserts it covers the whole
    fleet. ``set_down`` marks a site unroutable (fault injection);
    ``route`` never returns a down site, spilling first to the
    next-nearest site with admission-queue room and last to the cloud.
    """

    def __init__(self, fleet: Fleet, *, capacity: int = 16):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.fleet = fleet
        self.capacity = int(capacity)
        #: site name -> (lat, lon); must cover every fleet site.
        self.table: Dict[str, Tuple[float, float]] = {
            s.name: s.location for s in fleet.sites}
        self._down: set = set()

    def set_down(self, name: str, down: bool = True) -> None:
        self.fleet.site(name)   # reject unknown names
        if down:
            self._down.add(name)
        else:
            self._down.discard(name)

    def is_down(self, name: str) -> bool:
        return name in self._down

    @property
    def down_sites(self) -> Tuple[str, ...]:
        return tuple(sorted(self._down))

    def rank(self, origin: Optional[Tuple[float, float]]
             ) -> List[Tuple[str, float]]:
        """Every site (down ones included) by distance from ``origin``;
        an origin-less request keeps the fleet's listed site order at
        distance 0 (the first site is its de-facto home)."""
        if origin is None:
            return [(s.name, 0.0) for s in self.fleet.sites]
        o = (float(origin[0]), float(origin[1]))
        return sorted(
            ((name, haversine_km(o, loc)) for name, loc in
             self.table.items()),
            key=lambda nd: (nd[1], nd[0]))

    def route(self, origin: Optional[Tuple[float, float]],
              queue_depth: Callable[[str], int]) -> RouteDecision:
        """Pick the serving tier for one request.

        ``queue_depth(name)`` is the site's current admission-queue
        length; a site at or above ``capacity`` is skipped (spillover).
        """
        ranked = self.rank(origin)
        nearest = ranked[0][0]
        for name, dist in ranked:
            if name in self._down:
                continue
            if queue_depth(name) >= self.capacity:
                continue
            if name == nearest:
                route = "local"
            elif nearest in self._down:
                route = "failed_over"
            else:
                route = "spilled"
            return RouteDecision(name, route, dist)
        return RouteDecision(CLOUD, "failed_over", ranked[0][1])


@dataclasses.dataclass
class _RouteMeta:
    """Per-request routing bookkeeping (keyed by global request id)."""
    site: str
    route: str
    routing_delay: float
    arrival_time: Optional[float]   # TRUE arrival (pre-forwarding)
    origin: Optional[Tuple[float, float]]


class FleetServer:
    """One serving facade over per-site Servers plus the cloud tier.

    Args:
      fleet: the compiled :class:`Fleet`.
      capacity: per-site admission-queue depth; a submit that would push
        a site's pending queue past it spills to the next-nearest site
        (and ultimately to the cloud). This is the Router's load knob.
      staleness_bound: overrides every site plan's
        ``config.staleness_bound`` (the cloud tier always serves fresh —
        it holds the whole graph, there is no exchange to skip).
      slo: ``None`` / ``True`` / one :class:`~repro.api.slo.SLOPolicy`
        for every tier, or a per-site table from
        :func:`repro.api.slo.per_site` (``"default"`` covers unnamed
        sites, ``"cloud"`` the last-resort tier).
      faults: optional per-site chaos table ``{site_name:
        FaultSchedule}`` (``repro.api.faults``) — each named site's
        Server replays its schedule on its own clock (node crashes fail
        shards over *within* the site; whole-site outages are
        ``set_down``). The cloud tier never takes node faults.
      max_batch / max_wait / pipelined / adaptive_batch / session kwargs:
        forwarded to each per-site ``Server``/``Session``.

    Every site Server keeps its own pipeline clock: two sites serve
    concurrently on the simulated timeline, and only requests routed to
    the same site queue behind each other. Responses are post-adjusted so
    ``latency`` runs from the TRUE arrival (forwarding delay included,
    ``deadline_met`` re-evaluated) and carry ``site`` / ``route`` /
    ``routing_delay``.
    """

    def __init__(self, fleet: Fleet, *, capacity: int = 16,
                 max_batch: int = 8, max_wait: float = 0.0,
                 pipelined: bool = True,
                 slo: Union[None, bool, SLOPolicy, Mapping[str, object]]
                 = None,
                 adaptive_batch=None,
                 staleness_bound: Optional[int] = None,
                 faults: Optional[Mapping[str, object]] = None,
                 **session_kw):
        self.fleet = fleet
        self.router = Router(fleet, capacity=capacity)
        if faults is not None:
            unknown = set(faults) - set(fleet.site_names)
            if unknown:
                raise ValueError(
                    f"fault schedules for unknown sites {sorted(unknown)}; "
                    f"available: {', '.join(fleet.site_names)}")
        if isinstance(slo, Mapping):
            unknown = (set(slo) - set(fleet.site_names)
                       - {CLOUD, "default"})
            if unknown:
                raise ValueError(
                    f"per-site slo names {sorted(unknown)} are not fleet "
                    f"sites; available: {', '.join(fleet.site_names)} "
                    f"(+ 'cloud', 'default')")
        self._slo_table = slo
        self.staleness_bound = (
            max(s.plan.config.staleness_bound for s in fleet.sites)
            if staleness_bound is None else int(staleness_bound))
        srv_kw = dict(max_batch=max_batch, max_wait=max_wait,
                      pipelined=pipelined, adaptive_batch=adaptive_batch)
        self.servers: Dict[str, Server] = {}
        for site in fleet.sites:
            kw = dict(session_kw)
            if staleness_bound is not None:
                kw["staleness_bound"] = int(staleness_bound)
            self.servers[site.name] = site.plan.server(
                slo=self._slo_for(site.name),
                faults=None if faults is None else faults.get(site.name),
                **srv_kw, **kw)
        # The cloud tier serves fresh: single-program numerics, no
        # cross-fog exchange, nothing to replay.
        self.servers[CLOUD] = fleet.cloud_plan.server(
            slo=self._slo_for(CLOUD), **srv_kw, **session_kw)
        self._next_id = 0
        self._routes: Dict[int, _RouteMeta] = {}
        #: per-fleet drop counter — stays 0 by construction (set_down
        #: reroutes pending work; the counter exists so benchmarks can
        #: assert it).
        self.dropped = 0

    def _slo_for(self, name: str):
        slo = self._slo_table
        if isinstance(slo, Mapping):
            return slo.get(name, slo.get("default"))
        return slo

    # -- routing ------------------------------------------------------------

    def queue_depth(self, name: str) -> int:
        return len(self.servers[name]._pending)

    @property
    def tier_names(self) -> Tuple[str, ...]:
        return self.fleet.site_names + (CLOUD,)

    def submit(self, request: Union[Request, "object", None] = None, *,
               arrival_time: Optional[float] = None,
               origin: Optional[Tuple[float, float]] = None,
               **kw) -> Request:
        """Route one request to a tier and enqueue it there.

        Accepts a ``Request``, a feature array, or None (re-serve stored
        features); ``origin`` overrides the request's coordinates. Graph
        updates don't route — they fan out to every tier; use
        :meth:`update` (a ``GraphDelta``/``UpdateRequest`` here raises).
        """
        if isinstance(request, (GraphDelta, UpdateRequest)):
            raise TypeError(
                "graph updates are not routable requests — they must "
                "reach every tier; use FleetServer.update(delta)")
        if not isinstance(request, Request):
            request = Request(features=request, arrival_time=arrival_time,
                              origin=origin, **kw)
        elif origin is not None:
            request = dataclasses.replace(request, origin=origin)
        if request.request_id is None:
            request = dataclasses.replace(request,
                                          request_id=self._next_id)
        self._next_id = max(self._next_id, request.request_id) + 1
        decision = self.router.route(request.origin, self.queue_depth)
        self._enqueue(request, decision, request.arrival_time,
                      decision.routing_delay)
        return request

    def _enqueue(self, request: Request, decision: RouteDecision,
                 true_arrival: Optional[float], delay: float) -> None:
        """Hand a routed request to its tier's Server: it arrives there
        ``delay`` after its true arrival (cross-site clock accounting);
        closed-loop requests (true arrival None) keep their closed-loop
        semantics and the delay is added to reported latency instead."""
        shifted = (None if true_arrival is None
                   else float(true_arrival) + delay)
        self.servers[decision.site].submit(
            dataclasses.replace(request, arrival_time=shifted))
        self._routes[request.request_id] = _RouteMeta(
            site=decision.site, route=decision.route, routing_delay=delay,
            arrival_time=true_arrival, origin=request.origin)

    # -- fault injection -----------------------------------------------------

    def set_down(self, name: str, down: bool = True) -> int:
        """Mark a site down (or back up). Going down reroutes the site's
        whole pending queue through the router — queued work is forwarded
        (one extra site-to-site hop on its routing delay), never dropped.
        Coming back up pulls still-pending requests that failed over off
        this site back to it (route ``"recovered"``, one return hop);
        fresh submits to the revived site simply route ``"local"``
        again. Returns how many pending requests were moved either way.
        """
        self.router.set_down(name, down)
        if not down:
            dst_loc = self.fleet.site(name).location
            moved = 0
            for other in self.tier_names:
                if other == name:
                    continue
                srv = self.servers[other]
                keep = []
                for req in srv._pending:
                    meta = (self._routes.get(req.request_id)
                            if isinstance(req, Request) else None)
                    if (meta is None or meta.route != "failed_over"
                            or self.router.rank(meta.origin)[0][0] != name):
                        keep.append(req)
                        continue
                    # Pull the refugee home: it pays one return hop from
                    # wherever it was parked back to its revived site.
                    hop = (CLOUD_ROUTING_S if other == CLOUD
                           else ROUTING_BASE_S
                           + ROUTING_PER_KM_S * haversine_km(
                               self.fleet.site(other).location, dst_loc))
                    home_dist = self.router.rank(meta.origin)[0][1]
                    self._enqueue(
                        dataclasses.replace(req,
                                            arrival_time=meta.arrival_time),
                        RouteDecision(name, "recovered", home_dist),
                        meta.arrival_time, meta.routing_delay + hop)
                    moved += 1
                srv._pending = keep
            return moved
        srv = self.servers[name]
        pending, srv._pending = srv._pending, []
        src_loc = self.fleet.site(name).location
        for req in pending:
            meta = self._routes[req.request_id]
            decision = self.router.route(meta.origin, self.queue_depth)
            hop = (CLOUD_ROUTING_S if decision.site == CLOUD
                   else ROUTING_BASE_S + ROUTING_PER_KM_S * haversine_km(
                       src_loc, self.fleet.site(decision.site).location))
            # The request already traveled to the down site; it pays one
            # more forwarding hop to wherever it lands now.
            self._enqueue(
                dataclasses.replace(req, arrival_time=meta.arrival_time),
                dataclasses.replace(decision, route="failed_over"),
                meta.arrival_time, meta.routing_delay + hop)
        return len(pending)

    # -- updates -------------------------------------------------------------

    def update(self, delta: GraphDelta) -> Dict[str, UpdateReport]:
        """Fan one graph mutation out to EVERY tier (sites + cloud), so
        all plans stay on one graph revision (asserted by
        ``analysis.fleet_checks``). Returns per-tier update reports."""
        out: Dict[str, UpdateReport] = {}
        for name in self.tier_names:
            srv = self.servers[name]
            out[name] = srv.session.update(delta)
            srv.last_update_report = out[name]
            srv._svc_cache.clear()
            srv._note_plan()   # re-track the fault-recovery restore target
        return out

    # -- serving -------------------------------------------------------------

    def drain(self) -> List[object]:
        """Drain every tier and merge the responses onto the fleet
        timeline (ordered by finish time). Each site drains on its own
        pipeline clock — the parallelism of geo-distributed serving.
        Responses are rewritten to fleet view: ``site``/``route``/
        ``routing_delay`` set, ``latency`` end-to-end from the TRUE
        arrival, ``deadline_met`` re-evaluated against it.
        """
        out: List[object] = []
        for name in self.tier_names:
            for r in self.servers[name].drain():
                meta = self._routes.pop(getattr(r, "request_id", -1), None)
                if meta is None or not isinstance(r, Response):
                    out.append(r)
                    continue
                latency = r.latency + meta.routing_delay
                true_arrival = (meta.arrival_time
                                if meta.arrival_time is not None
                                else r.arrival_time - meta.routing_delay)
                breakdown = dict(r.breakdown)
                breakdown["routing"] = meta.routing_delay
                breakdown["total"] = latency
                out.append(dataclasses.replace(
                    r, site=name, route=meta.route,
                    routing_delay=meta.routing_delay,
                    arrival_time=true_arrival, latency=latency,
                    breakdown=breakdown,
                    deadline_met=(None if r.deadline is None
                                  else bool(latency <= r.deadline + 1e-9))))
        out.sort(key=lambda r: (getattr(r, "finish_time", None)
                                or r.arrival_time))
        return out

    def serve(self, requests: Iterable[Request]) -> List[object]:
        """Submit then drain a whole arrival trace.

        Graph updates in the trace fan out fleet-wide at submission time
        (a consistency barrier: every tier moves to the new revision
        before any query in this call is served); their per-tier reports
        land via :meth:`update`, not in the returned list.
        """
        for r in requests:
            if isinstance(r, (GraphDelta, UpdateRequest)):
                self.update(r.delta if isinstance(r, UpdateRequest) else r)
            else:
                self.submit(r)
        return self.drain()

    replay = serve

    # -- reporting -----------------------------------------------------------

    def summarize(self, responses: Sequence[object]) -> Dict[str, object]:
        """Fleet-level metrics: the per-site breakdown of
        ``Server.summarize`` over ALL tiers (a down site with zero served
        requests still appears, its percentile None), plus routing
        counters and the zero-drop assertion input."""
        summary = Server.summarize(responses, sites=self.tier_names)
        resp = [r for r in responses if isinstance(r, Response)]
        summary["routes"] = {
            kind: sum(1 for r in resp if r.route == kind)
            for kind in ("local", "spilled", "failed_over", "recovered")}
        summary["down_sites"] = list(self.router.down_sites)
        summary["capacity"] = self.router.capacity
        summary["staleness_bound"] = self.staleness_bound
        dropped = self.dropped + len(self._routes)
        summary["dropped"] = dropped
        # Fleet view of availability: dropped requests (0 by
        # construction) count against the answered fraction too.
        rej = summary.get("rejected", 0)
        den = len(resp) + rej + dropped
        summary["availability"] = len(resp) / den if den else 1.0
        return summary

    def __repr__(self) -> str:
        return (f"FleetServer(sites={list(self.fleet.site_names)}, "
                f"capacity={self.router.capacity}, "
                f"staleness_bound={self.staleness_bound}, "
                f"down={list(self.router.down_sites)})")
